//! `cargo bench --bench bench_wds` — regenerates paper experiment(s) f22.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("f22", scale)?;
    Ok(())
}
