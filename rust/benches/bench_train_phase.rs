//! `cargo bench --bench bench_train_phase` — regenerates paper experiment(s) f20.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("f20", scale)?;
    Ok(())
}
