//! `cargo bench --bench bench_prefetch` — regenerates the prefetch-engine
//! experiment: readahead-depth sweep over the s3/ceph_os/gluster_fs
//! profiles plus the LRU-vs-2Q hot-tier comparison.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("prefetch", scale)?;
    Ok(())
}
