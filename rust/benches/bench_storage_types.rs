//! `cargo bench --bench bench_storage_types` — regenerates paper experiment(s) f16.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("f16", scale)?;
    Ok(())
}
