//! `cargo bench --bench bench_motivational` — regenerates paper experiment(s) t3,f2.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("t3", scale)?;
    cdl::bench::run_experiment("f2", scale)?;
    Ok(())
}
