//! `cargo bench --bench bench_lightning_lanes` — regenerates paper experiment(s) f17.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("f17", scale)?;
    Ok(())
}
