//! `cargo bench --bench bench_endtoend` — regenerates paper experiment(s) f13,f14.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("f13", scale)?;
    cdl::bench::run_experiment("f14", scale)?;
    Ok(())
}
