//! `cargo bench --bench bench_cache` — regenerates paper experiment(s) f9.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("f9", scale)?;
    Ok(())
}
