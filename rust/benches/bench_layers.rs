//! `cargo bench --bench bench_layers` — regenerates paper experiment(s) f15.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("f15", scale)?;
    Ok(())
}
