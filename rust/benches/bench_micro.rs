//! Micro-benchmarks of the L3 hot paths (the §Perf targets): SIMG
//! decode, augmentation crop, collate, span recording, RNG, LRU cache
//! hit path, tar streaming. In-tree harness (criterion is not in the
//! offline vendor set): warmup + N timed iterations, median & mean.

use std::sync::Arc;
use std::time::Instant;

use cdl::data::augment::{Augment, AugmentConfig};
use cdl::data::simg::{SimgImage, SimgRef};
use cdl::data::synth::{generate_image, CorpusSpec};
use cdl::dataloader::collate::collate;
use cdl::dataloader::BatchArena;
use cdl::dataset::{ItemMeta, Sample};
use cdl::storage::{MemStore, ObjectStore, VarnishCache};
use cdl::telemetry::Recorder;
use cdl::util::rng::Rng;

fn bench(name: &str, iters: usize, mut f: impl FnMut()) {
    // warmup
    for _ in 0..iters.div_ceil(10) {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let med = cdl::util::stats::median(&times);
    let mean = cdl::util::stats::mean(&times);
    println!(
        "{name:<42} median {:>10}  mean {:>10}  ({iters} iters)",
        cdl::util::fmt_duration(med),
        cdl::util::fmt_duration(mean)
    );
}

fn main() {
    println!("## micro-benchmarks (L3 hot paths)");
    let spec = CorpusSpec { mean_bytes: 115 * 1024, ..Default::default() };
    let img = generate_image(&spec, 3);
    let encoded = img.encode();
    println!(
        "reference image: {}x{} ({} encoded)",
        img.height,
        img.width,
        cdl::util::fmt_bytes(encoded.len() as u64)
    );

    bench("simg_decode (crc + copy)", 300, || {
        std::hint::black_box(SimgImage::decode(&encoded).unwrap());
    });

    let aug = Augment::new(AugmentConfig { crop: 64, ..Default::default() });
    let mut epoch = 0;
    bench("random_resized_crop 64x64 (bilinear)", 300, || {
        epoch += 1;
        std::hint::black_box(aug.apply_u8(&img, epoch, 0));
    });

    let aug224 = Augment::new(AugmentConfig { crop: 224, ..Default::default() });
    bench("random_resized_crop 224x224 (paper size)", 100, || {
        epoch += 1;
        std::hint::black_box(aug224.apply_u8(&img, epoch, 0));
    });

    let crop = aug.apply_u8(&img, 0, 0);
    bench("to_f32_normalized 64x64 (CPU ref path)", 300, || {
        std::hint::black_box(aug.to_f32_normalized(&crop));
    });

    let samples: Vec<Sample> = (0..64)
        .map(|i| Sample {
            index: i,
            label: 0,
            crop: crop.clone(),
            raw_bytes: encoded.len(),
            fetch_time: 0.0,
            decode_time: 0.0,
        })
        .collect();
    bench("collate batch=64 of 64x64 crops", 200, || {
        std::hint::black_box(collate(0, samples.clone()).unwrap());
    });

    // the fused arena path those copies disappear into: parse the raw
    // object, augment straight into a recycled slab slot
    let arena = BatchArena::new(64, 64, 2);
    let view = SimgRef::parse(&encoded).unwrap();
    let mut id = 0usize;
    bench("arena batch=64 fused fill (zero-alloc)", 200, || {
        let builder = arena.clone().checkout(id, 64);
        id += 1;
        for pos in 0..64 {
            builder
                .fill(pos, pos, |out| {
                    aug.apply_u8_into(&view, 0, pos, out);
                    Ok(ItemMeta { label: view.label, raw_bytes: encoded.len() })
                })
                .unwrap();
        }
        builder.finish().unwrap().recycle();
    });

    let rec = Recorder::new();
    bench("span record x1000", 200, || {
        for i in 0..1000 {
            rec.record("bench", 0, i, 0.0, 1.0);
        }
        rec.clear();
    });

    let mut rng = Rng::new(1);
    bench("rng permutation n=15000 (epoch plan)", 200, || {
        std::hint::black_box(rng.permutation(15000));
    });

    let mem = Arc::new(MemStore::new("m"));
    for i in 0..64 {
        mem.put(&format!("k{i}"), vec![0u8; 64 * 1024]).unwrap();
    }
    let cache = VarnishCache::new(mem, 64 * 64 * 1024);
    for i in 0..64 {
        cache.get(&format!("k{i}")).unwrap();
    }
    let mut i = 0;
    bench("varnish cache hit", 500, || {
        i = (i + 1) % 64;
        std::hint::black_box(cache.get(&format!("k{i}")).unwrap());
    });

    let entries: Vec<cdl::shards::TarEntry> = (0..32)
        .map(|i| cdl::shards::TarEntry {
            name: format!("e{i}"),
            data: vec![0u8; 32 * 1024],
        })
        .collect();
    let tar = cdl::shards::write_tar(&entries).unwrap();
    bench("tar stream 32x32KiB entries", 200, || {
        let n = cdl::shards::TarStream::new(&tar).count();
        assert_eq!(n, 32);
    });
}
