//! `cargo bench --bench bench_init` — regenerates paper experiment(s) f8.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("f8", scale)?;
    Ok(())
}
