//! `cargo bench --bench bench_dataset_pool` — regenerates paper experiment(s) f12.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("f12", scale)?;
    Ok(())
}
