//! `cargo bench --bench bench_fetchers` — regenerates paper experiment(s) f5,f6.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("f5", scale)?;
    cdl::bench::run_experiment("f6", scale)?;
    Ok(())
}
