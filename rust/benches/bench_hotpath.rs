//! `cargo bench --bench bench_hotpath` — regenerates the hot-path
//! experiment: fused arena assembly vs the legacy copy path (batches/s,
//! p50/p99 batch latency, allocs/batch via the counting allocator) plus
//! work stealing vs static assignment on the high-latency profiles.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("hotpath", scale)?;
    Ok(())
}
