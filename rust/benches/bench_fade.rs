//! `cargo bench --bench bench_fade` — regenerates paper experiment(s) f23.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("f23", scale)?;
    Ok(())
}
