//! `cargo bench --bench bench_colab` — regenerates paper experiment(s) t10.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("t10", scale)?;
    Ok(())
}
