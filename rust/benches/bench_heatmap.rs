//! `cargo bench --bench bench_heatmap` — regenerates paper experiment(s) f10,f11.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("f10", scale)?;
    cdl::bench::run_experiment("f11", scale)?;
    Ok(())
}
