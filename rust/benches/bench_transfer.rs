//! `cargo bench --bench bench_transfer` — regenerates paper experiment(s) f7.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("f7", scale)?;
    Ok(())
}
