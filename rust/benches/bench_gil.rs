//! `cargo bench --bench bench_gil` — regenerates paper experiment(s) f21.
//! Scale via CDL_SCALE=quick|paper|<items multiplier> (default quick).

fn main() -> anyhow::Result<()> {
    let scale = cdl::bench::Scale::from_env();
    cdl::bench::run_experiment("f21", scale)?;
    Ok(())
}
