//! CPython GIL simulation.
//!
//! The paper's §A.4 ("The dreaded GIL") shows that Python's global
//! interpreter lock is the final ceiling on loader throughput: a Java
//! client reaches ~700 Mbit/s from S3 where Python's
//! threading+multiprocessing mix peaks at ~250 Mbit/s.
//!
//! We model the interpreter faithfully at the granularity that matters:
//!
//! * each simulated worker *process* owns one [`Gil`];
//! * CPU-bound sections (image decode, augmentation) run while holding
//!   the lock — threads within one process serialize exactly like
//!   CPython bytecode;
//! * I/O sections (socket reads, disk reads, simulated latency sleeps)
//!   run with the lock released, exactly like CPython's blocking I/O;
//! * a configurable `python_tax` multiplies CPU section duration to
//!   account for interpreter overhead vs native code (§A.4's Java gap);
//! * [`Runtime::Native`] is the no-GIL comparator (rust/Java semantics).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Which concurrency semantics a simulated component runs under.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Runtime {
    /// CPython: CPU sections hold the owning process's GIL and pay
    /// `python_tax`.
    Python,
    /// Native (rust/Java/C++): free threading, no tax.
    Native,
}

impl Runtime {
    pub fn label(&self) -> &'static str {
        match self {
            Runtime::Python => "python",
            Runtime::Native => "native",
        }
    }
}

#[derive(Default)]
struct GilStats {
    wait_ns: AtomicU64,
    hold_ns: AtomicU64,
    acquisitions: AtomicU64,
}

/// One interpreter lock (one per simulated worker process).
pub struct Gil {
    runtime: Runtime,
    lock: Mutex<()>,
    /// CPU-section duration multiplier under Python semantics.
    python_tax: f64,
    stats: GilStats,
}

impl Gil {
    pub fn new(runtime: Runtime, python_tax: f64) -> Arc<Gil> {
        Arc::new(Gil {
            runtime,
            lock: Mutex::new(()),
            python_tax: python_tax.max(1.0),
            stats: GilStats::default(),
        })
    }

    /// Native GIL-less runtime (rust semantics).
    pub fn native() -> Arc<Gil> {
        Gil::new(Runtime::Native, 1.0)
    }

    /// Default CPython model (tax from DESIGN.md §4).
    pub fn python() -> Arc<Gil> {
        Gil::new(Runtime::Python, 4.0)
    }

    pub fn runtime(&self) -> Runtime {
        self.runtime
    }

    /// Run a CPU-bound section. Under [`Runtime::Python`] this holds the
    /// GIL for the (taxed) duration of `f`; under native it just runs.
    pub fn cpu<T>(&self, f: impl FnOnce() -> T) -> T {
        match self.runtime {
            Runtime::Native => f(),
            Runtime::Python => {
                let wait_start = Instant::now();
                let guard = self.lock.lock().unwrap();
                let waited = wait_start.elapsed();
                let hold_start = Instant::now();
                let out = f();
                let work = hold_start.elapsed();
                // interpreter overhead: stretch the section to tax × work
                let extra = work.mul_f64(self.python_tax - 1.0);
                spin_for(extra);
                drop(guard);
                self.stats
                    .wait_ns
                    .fetch_add(waited.as_nanos() as u64, Ordering::Relaxed);
                self.stats.hold_ns.fetch_add(
                    (work + extra).as_nanos() as u64,
                    Ordering::Relaxed,
                );
                self.stats.acquisitions.fetch_add(1, Ordering::Relaxed);
                out
            }
        }
    }

    /// Run an I/O-bound (lock-released) section — CPython releases the
    /// GIL around blocking syscalls.
    pub fn io<T>(&self, f: impl FnOnce() -> T) -> T {
        f()
    }

    /// (total wait, total hold, acquisitions) since creation.
    pub fn stats(&self) -> (Duration, Duration, u64) {
        (
            Duration::from_nanos(self.stats.wait_ns.load(Ordering::Relaxed)),
            Duration::from_nanos(self.stats.hold_ns.load(Ordering::Relaxed)),
            self.stats.acquisitions.load(Ordering::Relaxed),
        )
    }
}

/// Busy-wait (the GIL holder burns the core, it does not sleep).
fn spin_for(d: Duration) {
    if d.is_zero() {
        return;
    }
    let end = Instant::now() + d;
    while Instant::now() < end {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy(ms: u64) {
        spin_for(Duration::from_millis(ms));
    }

    #[test]
    fn native_runs_in_parallel() {
        let gil = Gil::native();
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let g = gil.clone();
                s.spawn(move || g.cpu(|| busy(30)));
            }
        });
        // 4×30 ms of CPU work across ≥2 cores must beat full serialization
        assert!(t0.elapsed() < Duration::from_millis(110), "{:?}", t0.elapsed());
    }

    #[test]
    fn python_serializes_cpu_sections() {
        let gil = Gil::new(Runtime::Python, 1.0);
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let g = gil.clone();
                s.spawn(move || g.cpu(|| busy(20)));
            }
        });
        // 4×20 ms serialized ⇒ ≥ ~80 ms
        assert!(t0.elapsed() >= Duration::from_millis(75), "{:?}", t0.elapsed());
        let (_, hold, acq) = gil.stats();
        assert_eq!(acq, 4);
        assert!(hold >= Duration::from_millis(75));
    }

    #[test]
    fn python_tax_stretches_sections() {
        let gil = Gil::new(Runtime::Python, 3.0);
        let t0 = Instant::now();
        gil.cpu(|| busy(10));
        assert!(t0.elapsed() >= Duration::from_millis(28), "{:?}", t0.elapsed());
    }

    #[test]
    fn io_sections_do_not_serialize() {
        let gil = Gil::new(Runtime::Python, 1.0);
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let g = gil.clone();
                s.spawn(move || {
                    g.io(|| std::thread::sleep(Duration::from_millis(40)))
                });
            }
        });
        assert!(t0.elapsed() < Duration::from_millis(120), "{:?}", t0.elapsed());
    }

    #[test]
    fn cpu_returns_value() {
        assert_eq!(Gil::python().cpu(|| 5), 5);
        assert_eq!(Gil::native().cpu(|| 5), 5);
    }
}
