//! `cdl` — ConcurrentDataloader: a Rust + JAX + Pallas reproduction of
//! *"Profiling and Improving the PyTorch Dataloader for high-latency
//! Storage: A Technical Report"* (Svogor et al., IARAI 2022).
//!
//! The crate re-implements the paper's full data-loading stack as a
//! production Rust library (Layer 3), drives an AOT-compiled JAX/Pallas
//! model through PJRT (Layers 2/1), and ships the complete benchmark
//! harness that regenerates every table and figure of the paper's
//! evaluation on simulated storage substrates.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`util`] — RNG, stats, JSON, tables, CLI, property-test harness.
//! * [`asyncrt`] — in-tree mini async runtime (the "asyncio" analogue).
//! * [`simnet`] — latency models, bandwidth token buckets, conn pools.
//! * [`gil`] — CPython GIL simulation (per-worker-process lock).
//! * [`storage`] — object stores: mem/dir/simulated-remote/Varnish
//!   cache, plus the unified O(1) eviction core (`storage::evict`)
//!   behind every byte-capped cache.
//! * [`prefetch`] — sampler-ahead prefetch engine with tiered caching
//!   (hot in-memory tier + pluggable LRU / 2Q-ghost / S3-FIFO policies)
//!   composable over any store.
//! * [`data`] — SIMG codec, synthetic ImageNet generator, pixel ops.
//! * [`dataset`] — map-style `Dataset`, transforms, pool experiment.
//! * [`dataloader`] — the paper's contribution: vanilla / threaded /
//!   asyncio fetchers, lazy init, batch disassembly, backpressure.
//! * [`device`] — simulated training device (XLA-backed or cost model).
//! * [`runtime`] — PJRT artifact loading and execution.
//! * [`trainer`] — Torch-like and Lightning-like training harnesses.
//! * [`shards`] — tar shards: WebDataset / FastAI analogues.
//! * [`telemetry`] — span recorder, GPU-util aggregation, exports.
//! * [`bench`] — experiment harness for every paper table/figure.

pub mod asyncrt;
pub mod bench;
pub mod config;
pub mod data;
pub mod dataloader;
pub mod dataset;
pub mod device;
pub mod gil;
pub mod governor;
pub mod prefetch;
pub mod runtime;
pub mod shards;
pub mod simnet;
pub mod storage;
pub mod telemetry;
pub mod trainer;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Counting allocator (default `count-alloc` feature): every binary
/// linking `cdl` gets process-wide and per-thread allocation counters
/// ([`util::alloc`]) — the `hotpath` experiment's allocs/batch column
/// and the arena zero-alloc regression test read them. Overhead per
/// malloc/free is two relaxed atomic adds and two thread-local bumps;
/// build with `--no-default-features` for allocator-untouched timing
/// runs (the counters then read zero).
#[cfg(feature = "count-alloc")]
#[global_allocator]
static GLOBAL_ALLOC: util::alloc::CountingAlloc = util::alloc::CountingAlloc;
