//! Human-readable formatting of bytes, rates and durations for reports.

/// "12.3 KiB", "4.6 MiB", ...
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Throughput in Mbit/s from bytes and seconds, using the paper's
/// convention (`size/1024^2*8`).
pub fn mbit_s(bytes: u64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return f64::NAN;
    }
    bytes as f64 / (1024.0 * 1024.0) * 8.0 / secs
}

pub fn fmt_mbit_s(bytes: u64, secs: f64) -> String {
    format!("{:.2} Mbit/s", mbit_s(bytes, secs))
}

/// "1.23 s", "45.6 ms", "789 µs"
pub fn fmt_duration(secs: f64) -> String {
    if secs.is_nan() {
        "n/a".to_string()
    } else if secs >= 1.0 {
        format!("{secs:.2} s")
    } else if secs >= 1e-3 {
        format!("{:.1} ms", secs * 1e3)
    } else {
        format!("{:.0} µs", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.0 MiB");
    }

    #[test]
    fn mbit_convention_matches_paper() {
        // 1 MiB in 1 s = 8 Mbit/s under the paper's 1024^2 convention
        assert!((mbit_s(1024 * 1024, 1.0) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn durations() {
        assert_eq!(fmt_duration(2.5), "2.50 s");
        assert_eq!(fmt_duration(0.0456), "45.6 ms");
        assert_eq!(fmt_duration(500e-6), "500 µs");
    }
}
