//! Counting allocator — the measurement substrate for the zero-alloc
//! hot path (dataloader arena, PR 3).
//!
//! [`CountingAlloc`] wraps the system allocator and keeps two sets of
//! counters:
//!
//! * **process-wide** atomics — what the `hotpath` experiment reads to
//!   report allocs/batch across the whole worker pipeline;
//! * **per-thread** cells — what the steady-state regression test reads,
//!   so concurrent activity on other threads (the libtest harness, a
//!   sampler sidecar) cannot pollute a single-threaded measurement.
//!
//! The crate installs it as the `#[global_allocator]` (see `lib.rs`), so
//! every binary linking `cdl` pays two relaxed atomic adds and two
//! thread-local bumps per malloc/free — noise next to the allocation
//! itself.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

static G_ALLOCS: AtomicU64 = AtomicU64::new(0);
static G_FREES: AtomicU64 = AtomicU64::new(0);
static G_BYTES: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static T_ALLOCS: Cell<u64> = const { Cell::new(0) };
    static T_FREES: Cell<u64> = const { Cell::new(0) };
    static T_BYTES: Cell<u64> = const { Cell::new(0) };
}

/// Allocation counters at one instant (or a delta between two instants).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct AllocCounters {
    /// calls into `alloc`/`alloc_zeroed`/`realloc`
    pub allocs: u64,
    /// calls into `dealloc`
    pub frees: u64,
    /// bytes requested by the counted alloc calls
    pub bytes: u64,
}

impl AllocCounters {
    /// Counter movement since `earlier` (saturating, so a stale snapshot
    /// never underflows).
    pub fn since(&self, earlier: AllocCounters) -> AllocCounters {
        AllocCounters {
            allocs: self.allocs.saturating_sub(earlier.allocs),
            frees: self.frees.saturating_sub(earlier.frees),
            bytes: self.bytes.saturating_sub(earlier.bytes),
        }
    }
}

/// Process-wide counters (all threads).
pub fn counters() -> AllocCounters {
    AllocCounters {
        allocs: G_ALLOCS.load(Ordering::Relaxed),
        frees: G_FREES.load(Ordering::Relaxed),
        bytes: G_BYTES.load(Ordering::Relaxed),
    }
}

/// Counters for the calling thread only.
pub fn thread_counters() -> AllocCounters {
    AllocCounters {
        allocs: T_ALLOCS.with(|c| c.get()),
        frees: T_FREES.with(|c| c.get()),
        bytes: T_BYTES.with(|c| c.get()),
    }
}

#[inline]
fn count(size: usize) {
    G_ALLOCS.fetch_add(1, Ordering::Relaxed);
    G_BYTES.fetch_add(size as u64, Ordering::Relaxed);
    // try_with: never panic inside the allocator, even during thread
    // teardown
    let _ = T_ALLOCS.try_with(|c| c.set(c.get() + 1));
    let _ = T_BYTES.try_with(|c| c.set(c.get() + size as u64));
}

#[inline]
fn count_free() {
    G_FREES.fetch_add(1, Ordering::Relaxed);
    let _ = T_FREES.try_with(|c| c.set(c.get() + 1));
}

/// The counting `GlobalAlloc` wrapper over [`System`].
pub struct CountingAlloc;

// SAFETY: pure pass-through to `System` plus relaxed counter updates;
// no allocation happens inside the hooks themselves (thread-locals are
// const-initialized `Cell`s).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        count(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        count(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        count_free();
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // a realloc is one alloc event (the regression test treats any
        // growth in the hot loop as a failure) plus the implicit free of
        // the old block, keeping allocs/frees symmetric
        count(new_size);
        count_free();
        System.realloc(ptr, layout, new_size)
    }
}

// Counter behavior is only observable when the crate's global
// allocator is installed (the default `count-alloc` feature).
#[cfg(all(test, feature = "count-alloc"))]
mod tests {
    use super::*;

    #[test]
    fn vec_growth_is_counted() {
        let before = thread_counters();
        let v: Vec<u64> = Vec::with_capacity(1024);
        let d = thread_counters().since(before);
        assert!(d.allocs >= 1, "{d:?}");
        assert!(d.bytes >= 8 * 1024, "{d:?}");
        drop(v);
        let d = thread_counters().since(before);
        assert!(d.frees >= 1, "{d:?}");
    }

    #[test]
    fn no_alloc_loop_counts_zero() {
        let mut buf = vec![0u8; 4096];
        let before = thread_counters();
        for i in 0..1000usize {
            buf[i % 4096] = (i % 251) as u8;
        }
        let d = thread_counters().since(before);
        assert_eq!(d.allocs, 0, "{d:?}");
        assert_eq!(std::hint::black_box(&buf).len(), 4096);
    }

    #[test]
    fn global_counters_monotonic() {
        let a = counters();
        let v = vec![1u8; 64];
        let b = counters();
        assert!(b.allocs >= a.allocs + 1);
        drop(v);
        let c = counters();
        assert!(c.frees >= b.frees + 1);
    }
}
