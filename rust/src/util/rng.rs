//! Deterministic pseudo-random numbers (SplitMix64 seeding +
//! xoshiro256** core) plus the distributions the simulation needs:
//! uniform, normal (Box-Muller), lognormal, and shuffling.
//!
//! Every stochastic component of the reproduction (latency draws, image
//! sizes, samplers, synthetic pixels) takes an explicit seed so whole
//! experiments replay bit-for-bit.

/// xoshiro256** PRNG. Small, fast, good statistical quality; seeded via
/// SplitMix64 exactly as recommended by the xoshiro authors.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal sample from Box-Muller
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-worker / per-item RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Rejection-free (modulo bias is < 2^-32
    /// for the ranges used here, acceptable for simulation work).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi].
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid ln(0).
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Lognormal with the given *median* (= e^mu) and shape sigma.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        (median.ln() + sigma * self.normal()).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n (the sampler's epoch order).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// `true` with probability p.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(13);
        let mut xs: Vec<f64> = (0..50_001).map(|_| r.lognormal(0.120, 0.6)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[xs.len() / 2];
        assert!((med - 0.120).abs() < 0.01, "median {med}");
        assert!(xs.iter().all(|x| *x > 0.0));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(5);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
