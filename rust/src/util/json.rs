//! Minimal JSON: a value model, a writer, and a recursive-descent parser.
//!
//! Used for `artifacts/manifest.json` (read) and benchmark result export
//! (write). Not a general-purpose library — no unicode escapes beyond
//! \uXXXX pass-through, no streaming — but fully round-trips everything
//! this repo produces.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys are sorted (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `json.at(&["model", "num_params"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let c = self.peek().ok_or_else(|| anyhow!("unexpected EOF"))?;
        self.i += 1;
        Ok(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        let got = self.bump()?;
        if got != c {
            bail!("expected '{}' got '{}' at {}", c as char, got as char, self.i);
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected EOF"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                c => bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(v)),
                c => bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                },
                c if c < 0x80 => s.push(c as char),
                c => {
                    // UTF-8 continuation: collect the full sequence.
                    let start = self.i - 1;
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    self.i = start + len;
                    let chunk = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                    s.push_str(std::str::from_utf8(chunk)?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("{e}: {text}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": 2.5}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn path_access() {
        let v = parse(r#"{"model": {"num_params": 546816}}"#).unwrap();
        assert_eq!(v.at(&["model", "num_params"]).unwrap().as_usize(), Some(546816));
        assert!(v.at(&["model", "missing"]).is_none());
    }

    #[test]
    fn builder_and_pretty() {
        let mut j = Json::obj();
        j.set("name", "bench").set("runs", 3usize).set("ok", true);
        let s = j.pretty();
        assert!(s.contains("\"name\": \"bench\""));
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(parse("42").unwrap().as_usize(), Some(42));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}x").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""µs · naïve""#).unwrap();
        assert_eq!(v.as_str(), Some("µs · naïve"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::obj());
    }
}
