//! ASCII table rendering for benchmark reports (the harness prints the
//! same rows the paper's tables/figures report).

/// A simple column-aligned table with a title and optional footnote.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub note: Option<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            note: None,
        }
    }

    /// Fully-dynamic constructor (computed headers, e.g. sweep columns).
    pub fn new_dyn(title: impl Into<String>, header: Vec<String>) -> Self {
        Table { title: title.into(), header, rows: Vec::new(), note: None }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn note(&mut self, note: &str) -> &mut Self {
        self.note = Some(note.to_string());
        self
    }

    /// Render with unicode box-drawing, columns auto-sized.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep = |l: char, m: char, r: char| {
            let mut s = String::new();
            s.push(l);
            for (i, w) in widths.iter().enumerate() {
                s.push_str(&"─".repeat(w + 2));
                s.push(if i + 1 == ncols { r } else { m });
            }
            s.push('\n');
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("│");
            for (i, c) in cells.iter().enumerate() {
                let pad = widths[i] - c.chars().count();
                // numbers right-aligned, text left-aligned
                if c.parse::<f64>().is_ok() {
                    s.push_str(&format!(" {}{} │", " ".repeat(pad), c));
                } else {
                    s.push_str(&format!(" {}{} │", c, " ".repeat(pad)));
                }
            }
            s.push('\n');
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&sep('┌', '┬', '┐'));
        out.push_str(&fmt_row(&self.header));
        out.push_str(&sep('├', '┼', '┤'));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out.push_str(&sep('└', '┴', '┘'));
        if let Some(n) = &self.note {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    /// CSV export (results/ directory).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }
}

/// Format an f64 with `digits` decimals, "n/a" for NaN.
pub fn num(x: f64, digits: usize) -> String {
    if x.is_nan() {
        "n/a".to_string()
    } else {
        format!("{x:.digits$}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["alpha".into(), "1.50".into()]);
        t.row(&["b".into(), "222.00".into()]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("alpha"));
        // all lines between borders have equal display width
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('│')).collect();
        let w = lines[0].chars().count();
        assert!(lines.iter().all(|l| l.chars().count() == w));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["has,comma".into()]);
        assert_eq!(t.to_csv(), "a\n\"has,comma\"\n");
    }

    #[test]
    fn num_formats() {
        assert_eq!(num(1.23456, 2), "1.23");
        assert_eq!(num(f64::NAN, 2), "n/a");
    }
}
