//! A small declarative CLI argument parser (clap is not available in the
//! offline vendor set). Supports `--flag`, `--key value`, `--key=value`,
//! positional arguments, defaults, and auto-generated help.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone)]
struct Opt {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument set for one (sub)command.
#[derive(Debug, Clone)]
pub struct Args {
    program: String,
    about: String,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Self {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            values: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a required `--name <value>`.
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.program, self.about);
        for o in &self.opts {
            let lhs = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let def = match &o.default {
                Some(d) if !o.is_flag => format!(" [default: {d}]"),
                _ => String::new(),
            };
            s.push_str(&format!("{lhs:<26} {}{def}\n", o.help));
        }
        s
    }

    /// Parse a token list (exclusive of the program/subcommand name).
    pub fn parse(mut self, tokens: &[String]) -> Result<Parsed> {
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if t == "--help" || t == "-h" {
                bail!("{}", self.help_text());
            }
            if let Some(stripped) = t.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .cloned();
                let Some(opt) = opt else {
                    bail!("unknown option --{key}\n\n{}", self.help_text());
                };
                let val = if opt.is_flag {
                    inline_val.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    if i >= tokens.len() {
                        bail!("--{key} expects a value");
                    }
                    tokens[i].clone()
                };
                self.values.insert(key, val);
            } else {
                self.positional.push(t.clone());
            }
            i += 1;
        }
        // defaults + required check
        for o in &self.opts {
            if !self.values.contains_key(&o.name) {
                if let Some(d) = &o.default {
                    self.values.insert(o.name.clone(), d.clone());
                } else if !o.is_flag {
                    bail!("missing required --{}\n\n{}", o.name, self.help_text());
                }
            }
        }
        Ok(Parsed { values: self.values, positional: self.positional })
    }
}

/// Parsed argument values with typed getters.
#[derive(Debug, Clone)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("undeclared option {name}"))
    }

    pub fn get_opt(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        Ok(self.get(name).parse()?)
    }

    pub fn u64(&self, name: &str) -> Result<u64> {
        Ok(self.get(name).parse()?)
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        Ok(self.get(name).parse()?)
    }

    pub fn flag(&self, name: &str) -> bool {
        matches!(self.values.get(name).map(|s| s.as_str()), Some("true"))
    }

    /// Comma-separated list of usize ("1,2,4,8").
    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| Ok(s.trim().parse()?))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = Args::new("t", "test")
            .opt("workers", "4", "n workers")
            .flag("verbose", "chatty")
            .parse(&toks(&["--workers", "8"]))
            .unwrap();
        assert_eq!(p.usize("workers").unwrap(), 8);
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let p = Args::new("t", "test")
            .opt("mode", "a", "")
            .flag("fast", "")
            .parse(&toks(&["--mode=b", "--fast", "pos1"]))
            .unwrap();
        assert_eq!(p.get("mode"), "b");
        assert!(p.flag("fast"));
        assert_eq!(p.positional, vec!["pos1"]);
    }

    #[test]
    fn required_missing_errors() {
        let r = Args::new("t", "test").req("out", "output").parse(&toks(&[]));
        assert!(r.is_err());
    }

    #[test]
    fn unknown_option_errors() {
        let r = Args::new("t", "test").parse(&toks(&["--nope"]));
        assert!(r.is_err());
    }

    #[test]
    fn lists() {
        let p = Args::new("t", "test")
            .opt("ws", "1,2,4", "")
            .parse(&toks(&[]))
            .unwrap();
        assert_eq!(p.usize_list("ws").unwrap(), vec![1, 2, 4]);
    }
}
