//! Foundation utilities: deterministic RNG, statistics, JSON, ascii
//! tables, a small CLI argument parser, a property-testing harness, and
//! byte/duration formatting.
//!
//! All of these exist in-tree because the reproduction builds fully
//! offline (no crates.io): `rng` replaces `rand`, `prop` replaces
//! `proptest`, `cli` replaces `clap`, `json` replaces `serde_json`,
//! and `alloc` provides the counting global allocator behind the
//! zero-alloc hot-path measurements.

pub mod alloc;
pub mod cli;
pub mod fmt;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use fmt::{fmt_bytes, fmt_duration, fmt_mbit_s};
pub use rng::Rng;
pub use stats::Summary;

use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Monotonic timestamp in seconds since an arbitrary process-local epoch.
#[derive(Clone, Copy)]
pub struct Clock {
    origin: Instant,
}

impl Default for Clock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock {
    pub fn new() -> Self {
        Self { origin: Instant::now() }
    }

    /// Seconds since this clock was created.
    pub fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }

    pub fn elapsed(&self) -> Duration {
        self.origin.elapsed()
    }
}

/// Wall-clock unix timestamp (the paper logs unix timestamps).
pub fn unix_now() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default()
        .as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic() {
        let c = Clock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn unix_now_is_post_2020() {
        assert!(unix_now() > 1_577_836_800.0);
    }
}
