//! Descriptive statistics used by every benchmark report: mean, stddev,
//! median, arbitrary percentiles, min/max, and fixed-bin histograms
//! (the paper reports medians, means, and 400-bin histograms in §A.6).

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                count: 0,
                mean: f64::NAN,
                std: f64::NAN,
                min: f64::NAN,
                max: f64::NAN,
                p50: f64::NAN,
                p90: f64::NAN,
                p99: f64::NAN,
            };
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = mean(xs);
        Summary {
            count: xs.len(),
            mean,
            std: stddev(xs, mean),
            min: sorted[0],
            max: sorted[sorted.len() - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64], mean: f64) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / (xs.len() - 1) as f64;
    var.sqrt()
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Fixed-bin histogram over [lo, hi]; out-of-range values clamp to the
/// first/last bin (the paper's Fig 7 "overflow bin").
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub overflow: u64,
    pub underflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Self { lo, hi, bins: vec![0; nbins], overflow: 0, underflow: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        if x >= self.hi {
            self.overflow += 1;
            return;
        }
        let n = self.bins.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
        self.bins[idx.min(n - 1)] += 1;
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.overflow + self.underflow
    }

    /// Render as a compact sparkline string (for terminal reports).
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0).max(1);
        self.bins
            .iter()
            .map(|&b| GLYPHS[(b * 7 / max) as usize])
            .collect()
    }
}

/// Online mean/max tracker (used by the 10 Hz GPU-util sampler).
#[derive(Debug, Clone, Default)]
pub struct Online {
    pub count: u64,
    pub sum: f64,
    pub max: f64,
}

impl Online {
    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_nan() {
        let s = Summary::of(&[]);
        assert_eq!(s.count, 0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn median_even_interpolates() {
        assert!((median(&[1.0, 2.0, 3.0, 4.0]) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_extremes() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn stddev_matches_hand_computation() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let m = mean(&xs);
        // sample stddev of the classic example = sqrt(32/7)
        assert!((stddev(&xs, m) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn histogram_binning_and_overflow() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(42.0);
        assert_eq!(h.bins, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
        assert_eq!(h.sparkline().chars().count(), 10);
    }

    #[test]
    fn online_tracker() {
        let mut o = Online::default();
        for x in [1.0, 2.0, 6.0] {
            o.add(x);
        }
        assert_eq!(o.count, 3);
        assert!((o.mean() - 3.0).abs() < 1e-12);
        assert_eq!(o.max, 6.0);
    }
}
