//! Samplers and batch samplers (torch `RandomSampler` /
//! `SequentialSampler` / `BatchSampler` semantics): produce the epoch's
//! batch index lists that get distributed over worker index queues —
//! either pre-split round-robin (torch), or through a shared
//! [`BatchInjector`] that idle workers steal from (`work_stealing`
//! knob), which kills the end-of-epoch straggler stall on
//! high-latency storage.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::util::rng::Rng;

/// Item-order sampler for one epoch.
#[derive(Debug, Clone)]
pub enum Sampler {
    Sequential,
    /// seeded random permutation; reseeded per epoch like
    /// `DistributedSampler.set_epoch`
    Random { seed: u64 },
}

impl Sampler {
    pub fn order(&self, len: usize, epoch: usize) -> Vec<usize> {
        match self {
            Sampler::Sequential => (0..len).collect(),
            Sampler::Random { seed } => {
                let mut rng = Rng::new(seed ^ ((epoch as u64) << 20).wrapping_add(epoch as u64));
                rng.permutation(len)
            }
        }
    }
}

/// Chunk an item order into batch index lists.
pub fn batches(order: &[usize], batch_size: usize, drop_last: bool) -> Vec<Vec<usize>> {
    assert!(batch_size > 0);
    let mut out: Vec<Vec<usize>> = order
        .chunks(batch_size)
        .map(|c| c.to_vec())
        .collect();
    if drop_last {
        if let Some(last) = out.last() {
            if last.len() < batch_size {
                out.pop();
            }
        }
    }
    out
}

/// Shared batch injector queue for work-stealing dispatch: every worker
/// pops the globally-next batch when it goes idle, so one slow batch
/// never pins the batches behind it to a busy worker (in-order delivery
/// is preserved by the consumer's reorder buffer, exactly as with
/// static assignment).
pub struct BatchInjector {
    queue: Mutex<VecDeque<(usize, Vec<usize>)>>,
}

impl BatchInjector {
    /// Build from an epoch's batch plan; batch ids are assigned in plan
    /// order (the same ids static assignment would use).
    pub fn new(batches: Vec<Vec<usize>>) -> BatchInjector {
        BatchInjector {
            queue: Mutex::new(batches.into_iter().enumerate().collect()),
        }
    }

    /// Steal the next batch; `None` once the epoch is drained.
    pub fn steal(&self) -> Option<(usize, Vec<usize>)> {
        self.queue.lock().unwrap().pop_front()
    }

    /// Steal up to `k` consecutive batches in one grab (batch
    /// disassembly pulls a whole wave at once).
    pub fn steal_group(&self, k: usize) -> Vec<(usize, Vec<usize>)> {
        let mut q = self.queue.lock().unwrap();
        let take = k.max(1).min(q.len());
        q.drain(..take).collect()
    }

    /// Batches not yet claimed.
    pub fn remaining(&self) -> usize {
        self.queue.lock().unwrap().len()
    }
}

/// Round-robin assignment of (batch_id, indices) to workers — torch
/// hands batch k to worker `k % num_workers`.
pub fn assign_round_robin(
    batches: Vec<Vec<usize>>,
    num_workers: usize,
) -> Vec<Vec<(usize, Vec<usize>)>> {
    let w = num_workers.max(1);
    let mut per_worker: Vec<Vec<(usize, Vec<usize>)>> = vec![Vec::new(); w];
    for (id, idxs) in batches.into_iter().enumerate() {
        per_worker[id % w].push((id, idxs));
    }
    per_worker
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_order() {
        assert_eq!(Sampler::Sequential.order(5, 0), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn random_is_permutation_and_epoch_dependent() {
        let s = Sampler::Random { seed: 1 };
        let a = s.order(100, 0);
        let b = s.order(100, 0);
        let c = s.order(100, 1);
        assert_eq!(a, b);
        assert_ne!(a, c);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn batching_with_remainder() {
        let order: Vec<usize> = (0..10).collect();
        let b = batches(&order, 4, false);
        assert_eq!(b.len(), 3);
        assert_eq!(b[2], vec![8, 9]);
        let b = batches(&order, 4, true);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn exact_multiple_keeps_all() {
        let order: Vec<usize> = (0..8).collect();
        assert_eq!(batches(&order, 4, true).len(), 2);
    }

    #[test]
    fn injector_steals_in_plan_order_exactly_once() {
        let inj = BatchInjector::new(batches(&(0..20).collect::<Vec<_>>(), 4, false));
        assert_eq!(inj.remaining(), 5);
        let first = inj.steal().unwrap();
        assert_eq!(first.0, 0);
        assert_eq!(first.1, vec![0, 1, 2, 3]);
        let group = inj.steal_group(3);
        assert_eq!(
            group.iter().map(|(id, _)| *id).collect::<Vec<_>>(),
            vec![1, 2, 3]
        );
        let tail = inj.steal_group(10); // clamped to what's left
        assert_eq!(tail.len(), 1);
        assert_eq!(tail[0].0, 4);
        assert!(inj.steal().is_none());
        assert_eq!(inj.remaining(), 0);
    }

    #[test]
    fn injector_concurrent_steals_partition_the_epoch() {
        use std::sync::Arc;
        let inj = Arc::new(BatchInjector::new(batches(
            &(0..64).collect::<Vec<_>>(),
            2,
            false,
        )));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let inj = inj.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some((id, _)) = inj.steal() {
                    got.push(id);
                }
                got
            }));
        }
        let mut all: Vec<usize> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn round_robin_covers_all_batches() {
        let b = batches(&(0..20).collect::<Vec<_>>(), 4, false);
        let assigned = assign_round_robin(b, 3);
        assert_eq!(assigned.len(), 3);
        let mut ids: Vec<usize> = assigned
            .iter()
            .flat_map(|v| v.iter().map(|(id, _)| *id))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        // worker 0 gets 0, 3; worker 1 gets 1, 4; worker 2 gets 2
        assert_eq!(assigned[0].iter().map(|(i, _)| *i).collect::<Vec<_>>(), vec![0, 3]);
    }
}
