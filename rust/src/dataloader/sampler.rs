//! Samplers and batch samplers (torch `RandomSampler` /
//! `SequentialSampler` / `BatchSampler` semantics): produce the epoch's
//! batch index lists that get distributed over worker index queues —
//! either pre-split round-robin (torch), or through a shared
//! [`BatchInjector`] that idle workers steal from (`work_stealing`
//! knob), which kills the end-of-epoch straggler stall on
//! high-latency storage.
//!
//! Two tail-taming mechanisms ride on the same types (PR 4):
//!
//! * **Item-level stealing** ([`ItemTask`], `steal_items` knob): a
//!   worker processing a batch registers it with the injector; an idle
//!   sibling claims *unclaimed tail items* and decodes them straight
//!   into the batch's arena slab (the slab's per-slot claim bits make
//!   the concurrent in-place fill safe). The batch completes when every
//!   claimed slot is filled; the original owner publishes it.
//! * **Consumer credit** ([`CreditGate`], `consumer_credit` knob):
//!   workers may only *start* a batch while its id is within `credit`
//!   of the consumer's in-order delivery cursor, bounding the reorder
//!   buffer at O(credit) instead of O(epoch) behind a straggler.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::dataloader::arena::BatchBuilder;
use crate::util::rng::Rng;

/// Item-order sampler for one epoch.
#[derive(Debug, Clone)]
pub enum Sampler {
    Sequential,
    /// seeded random permutation; reseeded per epoch like
    /// `DistributedSampler.set_epoch`
    Random { seed: u64 },
}

impl Sampler {
    pub fn order(&self, len: usize, epoch: usize) -> Vec<usize> {
        match self {
            Sampler::Sequential => (0..len).collect(),
            Sampler::Random { seed } => {
                let mut rng = Rng::new(seed ^ ((epoch as u64) << 20).wrapping_add(epoch as u64));
                rng.permutation(len)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Generation-tagged batch tickets
// ---------------------------------------------------------------------------

/// One batch of the dispatch stream, generation-tagged with its epoch.
///
/// Since PR 5 the dispatch layer runs a **continuous stream across
/// epochs**: `seq` is the global dispatch sequence number (epoch N+1's
/// first batch follows epoch N's last), which is what the
/// [`CreditGate`], the consumer's reorder buffer, and the arena
/// checkout key on. `epoch` is the sampler epoch (the augmentation seed
/// travels with the item loads), and `id` is the batch's position
/// *within* its epoch — the consumer-visible `Batch::id`.
#[derive(Debug, Clone)]
pub struct BatchTicket {
    /// global dispatch sequence, continuous across epochs
    pub seq: usize,
    /// sampler epoch this batch belongs to
    pub epoch: usize,
    /// batch id within the epoch (consumer-visible)
    pub id: usize,
    /// dataset indices of the batch's items, in request order
    pub indices: Vec<usize>,
}

impl BatchTicket {
    /// A single-epoch ticket whose `seq` equals its `id` (tests and the
    /// inline loader, where no cross-epoch stream exists).
    pub fn solo(id: usize, indices: Vec<usize>) -> BatchTicket {
        BatchTicket { seq: id, epoch: 0, id, indices }
    }

    /// Tag one epoch's batch plan onto the continuous stream starting
    /// at `base_seq`.
    pub fn plan(
        epoch: usize,
        base_seq: usize,
        batches: Vec<Vec<usize>>,
    ) -> Vec<BatchTicket> {
        batches
            .into_iter()
            .enumerate()
            .map(|(id, indices)| BatchTicket {
                seq: base_seq + id,
                epoch,
                id,
                indices,
            })
            .collect()
    }
}

/// Chunk an item order into batch index lists.
pub fn batches(order: &[usize], batch_size: usize, drop_last: bool) -> Vec<Vec<usize>> {
    assert!(batch_size > 0);
    let mut out: Vec<Vec<usize>> = order
        .chunks(batch_size)
        .map(|c| c.to_vec())
        .collect();
    if drop_last {
        if let Some(last) = out.last() {
            if last.len() < batch_size {
                out.pop();
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Consumer-credit gate
// ---------------------------------------------------------------------------

struct GateState {
    /// the consumer's in-order delivery cursor (next expected batch id)
    cursor: usize,
    /// epoch torn down (consumer dropped): admit everything so workers
    /// drain their sources and exit on the dead channel
    closed: bool,
}

/// Bounds how far ahead of in-order delivery the workers may run: batch
/// `id` may only be *started* while `id < cursor + credit`. Since the
/// reorder buffer can only hold finished batches with ids in
/// `[cursor, cursor + credit)`, its size is bounded by `credit` instead
/// of O(epoch) behind one straggling batch. `credit = 0` disables the
/// gate (legacy unbounded behavior).
pub struct CreditGate {
    /// live credit (0 = unbounded); resizable at epoch seams via
    /// [`set_credit`](CreditGate::set_credit) — workers re-read it on
    /// every admission check, so a seam-time store is all it takes
    credit: AtomicUsize,
    state: Mutex<GateState>,
    cv: Condvar,
    /// total time workers spent blocked on the credit window (the
    /// "credit-blocked" stall lane of the telemetry plane)
    blocked_ns: AtomicU64,
    /// extra wake hook fired on every cursor advance/close — lets
    /// item-stealing workers park on the injector's condvar and still
    /// wake the instant the credit window moves
    waker: Mutex<Option<Arc<dyn Fn() + Send + Sync>>>,
}

impl CreditGate {
    pub fn new(credit: usize) -> Arc<CreditGate> {
        Arc::new(CreditGate {
            credit: AtomicUsize::new(credit),
            state: Mutex::new(GateState { cursor: 0, closed: false }),
            cv: Condvar::new(),
            blocked_ns: AtomicU64::new(0),
            waker: Mutex::new(None),
        })
    }

    /// The live credit (0 = unbounded).
    pub fn credit(&self) -> usize {
        self.credit.load(Ordering::Relaxed)
    }

    /// Resize the credit window (Governor seam application). Widening —
    /// or opening the gate entirely (`0`) — admits batches that were
    /// blocked a moment ago, so parked workers are woken.
    pub fn set_credit(&self, credit: usize) {
        let old = self.credit.swap(credit, Ordering::Relaxed);
        if credit == 0 || (old != 0 && credit > old) {
            self.wake();
        }
    }

    /// Install the extra wake hook (setup-time only).
    pub fn set_waker(&self, waker: Arc<dyn Fn() + Send + Sync>) {
        *self.waker.lock().unwrap() = Some(waker);
    }

    fn wake(&self) {
        self.cv.notify_all();
        let waker = self.waker.lock().unwrap().clone();
        if let Some(w) = waker {
            w();
        }
    }

    /// Cumulative time workers spent parked on (or around) the credit
    /// window.
    pub fn blocked(&self) -> Duration {
        Duration::from_nanos(self.blocked_ns.load(Ordering::Relaxed))
    }

    /// Attribute externally measured park time to the credit-blocked
    /// lane (item-stealing workers park on the injector condvar, not the
    /// gate's own).
    pub fn note_blocked(&self, d: Duration) {
        self.blocked_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    fn admits_locked(&self, st: &GateState, id: usize) -> bool {
        let credit = self.credit.load(Ordering::Relaxed);
        credit == 0 || st.closed || id < st.cursor + credit
    }

    /// May batch `id` be started right now?
    pub fn admits(&self, id: usize) -> bool {
        self.admits_locked(&self.state.lock().unwrap(), id)
    }

    /// Consumer side: publish the new in-order cursor (monotonic), waking
    /// every worker parked on the gate.
    pub fn advance(&self, cursor: usize) {
        let mut st = self.state.lock().unwrap();
        if cursor > st.cursor {
            st.cursor = cursor;
            drop(st);
            self.wake();
        }
    }

    /// Consumer gone / epoch torn down: open the gate permanently.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.wake();
    }

    /// Block until batch `id` is admitted.
    pub fn wait_admit(&self, id: usize) {
        let t0 = std::time::Instant::now();
        let mut st = self.state.lock().unwrap();
        while !self.admits_locked(&st, id) {
            st = self.cv.wait(st).unwrap();
        }
        drop(st);
        self.note_blocked(t0.elapsed());
    }

    /// Block until batch `id` is admitted or `timeout` elapses; returns
    /// whether it is now admitted. Workers that can do useful side work
    /// while parked (item stealing) use this instead of [`wait_admit`].
    ///
    /// [`wait_admit`]: CreditGate::wait_admit
    pub fn wait_admit_timeout(&self, id: usize, timeout: Duration) -> bool {
        let t0 = std::time::Instant::now();
        let mut st = self.state.lock().unwrap();
        let deadline = t0 + timeout;
        while !self.admits_locked(&st, id) {
            let now = std::time::Instant::now();
            if now >= deadline {
                drop(st);
                self.note_blocked(t0.elapsed());
                return false;
            }
            let (guard, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        drop(st);
        self.note_blocked(t0.elapsed());
        true
    }
}

// ---------------------------------------------------------------------------
// Item-level work stealing
// ---------------------------------------------------------------------------

struct TaskState {
    /// slots handed out so far (positions `0..claimed` are claimed)
    claimed: usize,
    /// claimed slots whose fill has completed (success or error)
    done: usize,
    /// first fill error; once set, no further claims are handed out
    error: Option<anyhow::Error>,
}

/// One in-progress batch whose unclaimed tail items may be filled by
/// any worker. Created by the owning worker around an arena
/// [`BatchBuilder`]; fillers claim `(slot, dataset index)` pairs through
/// [`ItemTask::claim`] and report completion, and the owner blocks in
/// [`ItemTask::wait_settled`] until every claimed slot has been filled
/// (the mutex/condvar pair is the happens-before edge that makes the
/// subsequent `finish()` sound — same role the channel/join played for
/// the in-worker fetchers).
pub struct ItemTask {
    /// global dispatch sequence (unique across epochs — the registry
    /// identity; two epochs' in-progress batches coexist under
    /// pipelining, so the per-epoch id cannot be the key)
    seq: usize,
    /// sampler epoch — fillers pass it to the epoch-tagged dataset loads
    epoch: usize,
    /// batch id within the epoch (telemetry / `Batch::id`)
    batch_id: usize,
    owner: u32,
    /// passive handle on the batch's slab (the owner keeps the primary)
    builder: BatchBuilder,
    indices: Vec<usize>,
    state: Mutex<TaskState>,
    cv: Condvar,
}

impl ItemTask {
    pub fn new(ticket: &BatchTicket, owner: u32, builder: BatchBuilder) -> Arc<ItemTask> {
        Arc::new(ItemTask {
            seq: ticket.seq,
            epoch: ticket.epoch,
            batch_id: ticket.id,
            owner,
            builder,
            indices: ticket.indices.clone(),
            state: Mutex::new(TaskState { claimed: 0, done: 0, error: None }),
            cv: Condvar::new(),
        })
    }

    pub fn batch_id(&self) -> usize {
        self.batch_id
    }

    /// Global dispatch sequence of this batch.
    pub fn seq(&self) -> usize {
        self.seq
    }

    /// Sampler epoch of this batch (fillers decode with this tag).
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Worker id of the batch's owner (the publisher).
    pub fn owner(&self) -> u32 {
        self.owner
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// The slab handle fillers decode into.
    pub fn builder(&self) -> &BatchBuilder {
        &self.builder
    }

    /// Slots not yet handed out (0 once fully claimed or failed).
    pub fn unclaimed(&self) -> usize {
        let st = self.state.lock().unwrap();
        if st.error.is_some() {
            0
        } else {
            self.indices.len() - st.claimed
        }
    }

    /// Hand out the next unfilled slot: `(slot position, dataset
    /// index)`. `None` once every slot is claimed or the batch has
    /// failed. Prefer [`ItemTask::claim`], which wraps the result in
    /// the RAII [`ItemClaim`] guard.
    fn take_slot(&self) -> Option<(usize, usize)> {
        let mut st = self.state.lock().unwrap();
        if st.error.is_some() || st.claimed >= self.indices.len() {
            return None;
        }
        let pos = st.claimed;
        st.claimed += 1;
        Some((pos, self.indices[pos]))
    }

    /// Claim the next unfilled slot of `task` as an RAII [`ItemClaim`].
    /// (An associated fn because the guard needs its own `Arc` handle —
    /// `&Arc<Self>` receivers aren't a stable self type.)
    pub fn claim(task: &Arc<ItemTask>) -> Option<ItemClaim> {
        let (pos, index) = task.take_slot()?;
        Some(ItemClaim {
            task: task.clone(),
            pos,
            index,
            completed: false,
        })
    }

    fn complete(&self, res: anyhow::Result<()>) {
        let mut st = self.state.lock().unwrap();
        st.done += 1;
        if let Err(e) = res {
            if st.error.is_none() {
                st.error = Some(e);
            }
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Owner side: block until no fill is outstanding (every claimed
    /// slot completed, and either all slots were claimed or the batch
    /// failed). Returns the first fill error, if any. After this
    /// returns `None` the owner may `finish()` the primary builder.
    pub fn wait_settled(&self) -> Option<anyhow::Error> {
        let mut st = self.state.lock().unwrap();
        loop {
            let settled = st.done == st.claimed
                && (st.error.is_some() || st.claimed == self.indices.len());
            if settled {
                // exhaust the cursor for good: taking the error must not
                // let a late thief resurrect claims on a batch the owner
                // is about to fail/finish (its fill would still bail on
                // the recovered slab, but don't even hand out the slot)
                st.claimed = self.indices.len();
                return st.error.take();
            }
            st = self.cv.wait(st).unwrap();
        }
    }
}

/// RAII claim on one slot of an [`ItemTask`]. Call [`ItemClaim::finish`]
/// with the fill result; dropping an unfinished claim (a panicking fill)
/// reports it as an error so the owner's [`ItemTask::wait_settled`]
/// never hangs on a slot that will never complete.
pub struct ItemClaim {
    task: Arc<ItemTask>,
    pos: usize,
    index: usize,
    completed: bool,
}

impl ItemClaim {
    /// Slot position inside the batch.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Dataset index to load into the slot.
    pub fn index(&self) -> usize {
        self.index
    }

    pub fn task(&self) -> &Arc<ItemTask> {
        &self.task
    }

    /// Report the fill outcome for this slot.
    pub fn finish(mut self, res: anyhow::Result<()>) {
        self.completed = true;
        self.task.complete(res);
    }
}

impl Drop for ItemClaim {
    fn drop(&mut self) {
        if !self.completed {
            self.task.complete(Err(anyhow::anyhow!(
                "slot {} abandoned mid-fill (filler panicked or was dropped)",
                self.pos
            )));
        }
    }
}

// ---------------------------------------------------------------------------
// Batch injector
// ---------------------------------------------------------------------------

/// Result of a credit-gated grab from the injector.
pub enum Claimed {
    /// Admitted batches to work on (≥ 1).
    Work(Vec<BatchTicket>),
    /// The queue head (this seq) exists but is outside the credit
    /// window — park on the gate or steal items meanwhile.
    Blocked(usize),
    /// The published batch stream is drained (the next epoch's plan, if
    /// any, has not been published yet).
    Drained,
}

/// Shared batch injector queue for work-stealing dispatch: every worker
/// pops the globally-next batch when it goes idle, so one slow batch
/// never pins the batches behind it to a busy worker (in-order delivery
/// is preserved by the consumer's reorder buffer, exactly as with
/// static assignment). The queue is a **continuous stream**: the
/// epoch-pipelined planner publishes each epoch's tickets onto it, so
/// epoch N+1's head follows epoch N's tail with no drain barrier. With
/// `steal_items` it also tracks the in-progress batches whose unclaimed
/// tail items idle workers may fill in place.
pub struct BatchInjector {
    queue: Mutex<VecDeque<BatchTicket>>,
    /// in-progress item tasks, registered in pop order (≈ seq order, so
    /// thieves help the batch the consumer wants soonest)
    active: Mutex<Vec<Arc<ItemTask>>>,
    /// items filled by a worker other than the batch's owner
    item_steals: AtomicU64,
    /// bumped whenever new work may have appeared (ticket publication,
    /// task registration, or an external wake such as a credit advance);
    /// idle workers park on the paired condvar instead of polling
    work_seq: Mutex<u64>,
    work_cv: Condvar,
}

impl Default for BatchInjector {
    fn default() -> Self {
        BatchInjector::new()
    }
}

impl BatchInjector {
    /// An empty injector; epoch plans arrive through
    /// [`BatchInjector::publish`].
    pub fn new() -> BatchInjector {
        BatchInjector {
            queue: Mutex::new(VecDeque::new()),
            active: Mutex::new(Vec::new()),
            item_steals: AtomicU64::new(0),
            work_seq: Mutex::new(0),
            work_cv: Condvar::new(),
        }
    }

    /// Append one epoch's tickets to the stream (publication order is
    /// seq order — the planner publishes epochs in sequence).
    pub fn publish(&self, tickets: Vec<BatchTicket>) {
        self.queue.lock().unwrap().extend(tickets);
        self.bump();
    }

    /// Signal parked workers that the work horizon may have moved.
    /// Fired by [`publish`]/[`register`] and wired as the
    /// [`CreditGate`]'s extra waker so a credit advance also lands here.
    ///
    /// [`publish`]: BatchInjector::publish
    /// [`register`]: BatchInjector::register
    pub fn bump(&self) {
        *self.work_seq.lock().unwrap() += 1;
        self.work_cv.notify_all();
    }

    /// Current work-signal version; grab it *before* probing for work,
    /// then hand it to [`BatchInjector::wait_version`] — any signal in
    /// between returns immediately (no lost wakeups).
    pub fn work_version(&self) -> u64 {
        *self.work_seq.lock().unwrap()
    }

    /// Park until the work signal moves past `seen` or `timeout`
    /// elapses; returns whether it moved. Replaces the old 1 kHz
    /// `STEAL_PARK` polling — the timeout is only a crash-safety
    /// fallback, not the wake path.
    pub fn wait_version(&self, seen: u64, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        let mut seq = self.work_seq.lock().unwrap();
        while *seq == seen {
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.work_cv.wait_timeout(seq, deadline - now).unwrap();
            seq = guard;
        }
        true
    }

    /// Steal the next batch; `None` once the published stream is
    /// drained.
    pub fn steal(&self) -> Option<BatchTicket> {
        self.queue.lock().unwrap().pop_front()
    }

    /// Steal up to `k` consecutive batches in one grab (batch
    /// disassembly pulls a whole wave at once).
    pub fn steal_group(&self, k: usize) -> Vec<BatchTicket> {
        let mut q = self.queue.lock().unwrap();
        let take = k.max(1).min(q.len());
        q.drain(..take).collect()
    }

    /// Credit-gated wave grab: pop up to `k` batches whose ids the gate
    /// admits right now.
    pub fn steal_group_admitted(&self, k: usize, gate: &CreditGate) -> Claimed {
        take_admitted(&mut self.queue.lock().unwrap(), k, gate)
    }

    /// Batches not yet claimed.
    pub fn remaining(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Plan revocation: drop every unclaimed ticket with `seq >=
    /// min_seq` (a mispredicted speculative epoch being unpublished).
    /// Tickets a worker already claimed cannot be recalled — they run
    /// to completion and the consumer discards their stale seqs, which
    /// is still far cheaper than a full pipeline teardown. Returns how
    /// many tickets were withdrawn.
    pub fn revoke(&self, min_seq: usize) -> usize {
        let mut q = self.queue.lock().unwrap();
        let before = q.len();
        q.retain(|t| t.seq < min_seq);
        let dropped = before - q.len();
        drop(q);
        if dropped > 0 {
            self.bump();
        }
        dropped
    }

    /// Publish an in-progress batch for item-level stealing.
    pub fn register(&self, task: Arc<ItemTask>) {
        self.active.lock().unwrap().push(task);
        self.bump();
    }

    /// Withdraw a finished/failed batch from the steal registry, by its
    /// global seq (unique across epochs; the per-epoch id is not).
    pub fn unregister(&self, seq: usize) {
        self.active.lock().unwrap().retain(|t| t.seq() != seq);
    }

    /// Steal one unclaimed item from the oldest in-progress batch that
    /// has any. `thief` is the calling worker's id — a claim on a batch
    /// it does not own counts toward [`BatchInjector::item_steal_count`].
    pub fn steal_item(&self, thief: u32) -> Option<ItemClaim> {
        let active = self.active.lock().unwrap();
        for task in active.iter() {
            if let Some(claim) = ItemTask::claim(task) {
                if task.owner() != thief {
                    self.item_steals.fetch_add(1, Ordering::Relaxed);
                }
                return Some(claim);
            }
        }
        None
    }

    /// In-progress batches currently registered.
    pub fn active_tasks(&self) -> usize {
        self.active.lock().unwrap().len()
    }

    /// Items filled by non-owner workers so far this epoch.
    pub fn item_steal_count(&self) -> u64 {
        self.item_steals.load(Ordering::Relaxed)
    }
}

/// Pop the admitted prefix (up to `k` batches) off a ticket queue —
/// the one credit-window grab shared by the injector and the static
/// per-worker deques, so the two dispatch modes cannot diverge. The
/// gate admits by global seq, so the window rolls straight across an
/// epoch seam when the next epoch's tickets are already published.
pub fn take_admitted(
    q: &mut VecDeque<BatchTicket>,
    k: usize,
    gate: &CreditGate,
) -> Claimed {
    let Some(head) = q.front().map(|t| t.seq) else {
        return Claimed::Drained;
    };
    if !gate.admits(head) {
        return Claimed::Blocked(head);
    }
    let mut take = 1;
    let max = k.max(1).min(q.len());
    while take < max && gate.admits(q[take].seq) {
        take += 1;
    }
    Claimed::Work(q.drain(..take).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_order() {
        assert_eq!(Sampler::Sequential.order(5, 0), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn random_is_permutation_and_epoch_dependent() {
        let s = Sampler::Random { seed: 1 };
        let a = s.order(100, 0);
        let b = s.order(100, 0);
        let c = s.order(100, 1);
        assert_eq!(a, b);
        assert_ne!(a, c);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn batching_with_remainder() {
        let order: Vec<usize> = (0..10).collect();
        let b = batches(&order, 4, false);
        assert_eq!(b.len(), 3);
        assert_eq!(b[2], vec![8, 9]);
        let b = batches(&order, 4, true);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn exact_multiple_keeps_all() {
        let order: Vec<usize> = (0..8).collect();
        assert_eq!(batches(&order, 4, true).len(), 2);
    }

    fn published(epoch: usize, base: usize, items: usize, bs: usize) -> BatchInjector {
        let inj = BatchInjector::new();
        inj.publish(BatchTicket::plan(
            epoch,
            base,
            batches(&(0..items).collect::<Vec<_>>(), bs, false),
        ));
        inj
    }

    #[test]
    fn injector_steals_in_plan_order_exactly_once() {
        let inj = published(0, 0, 20, 4);
        assert_eq!(inj.remaining(), 5);
        let first = inj.steal().unwrap();
        assert_eq!((first.seq, first.id, first.epoch), (0, 0, 0));
        assert_eq!(first.indices, vec![0, 1, 2, 3]);
        let group = inj.steal_group(3);
        assert_eq!(group.iter().map(|t| t.seq).collect::<Vec<_>>(), vec![1, 2, 3]);
        let tail = inj.steal_group(10); // clamped to what's left
        assert_eq!(tail.len(), 1);
        assert_eq!(tail[0].seq, 4);
        assert!(inj.steal().is_none());
        assert_eq!(inj.remaining(), 0);
    }

    #[test]
    fn published_epochs_form_one_continuous_stream() {
        // epoch 1's tickets follow epoch 0's on the same queue: seqs are
        // continuous, per-epoch ids restart, epochs tag each ticket
        let inj = published(0, 0, 8, 4);
        inj.publish(BatchTicket::plan(
            1,
            2,
            batches(&(0..8).collect::<Vec<_>>(), 4, false),
        ));
        let all = inj.steal_group(10);
        assert_eq!(all.iter().map(|t| t.seq).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(all.iter().map(|t| t.id).collect::<Vec<_>>(), vec![0, 1, 0, 1]);
        assert_eq!(all.iter().map(|t| t.epoch).collect::<Vec<_>>(), vec![0, 0, 1, 1]);
    }

    #[test]
    fn injector_concurrent_steals_partition_the_epoch() {
        use std::sync::Arc;
        let inj = Arc::new(published(0, 0, 64, 2));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let inj = inj.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(t) = inj.steal() {
                    got.push(t.seq);
                }
                got
            }));
        }
        let mut all: Vec<usize> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn credit_gate_admits_within_window_only() {
        let gate = CreditGate::new(3);
        assert!(gate.admits(0));
        assert!(gate.admits(2));
        assert!(!gate.admits(3));
        gate.advance(2);
        assert!(gate.admits(4));
        assert!(!gate.admits(5));
        // cursor is monotonic
        gate.advance(1);
        assert!(gate.admits(4));
        assert!(!gate.admits(5));
        // close opens everything
        gate.close();
        assert!(gate.admits(1_000_000));
    }

    #[test]
    fn credit_gate_zero_is_unbounded() {
        let gate = CreditGate::new(0);
        assert!(gate.admits(usize::MAX - 1));
        assert!(gate.wait_admit_timeout(10_000, Duration::from_millis(1)));
    }

    #[test]
    fn credit_gate_resizes_live() {
        let gate = CreditGate::new(2);
        assert!(!gate.admits(2));
        gate.set_credit(4); // widen: admits more without a cursor move
        assert!(gate.admits(3));
        assert!(!gate.admits(4));
        gate.set_credit(1); // narrow: takes effect immediately
        assert!(!gate.admits(1));
        assert!(gate.admits(0));
        gate.set_credit(0); // open entirely
        assert!(gate.admits(usize::MAX - 1));
    }

    #[test]
    fn injector_revoke_drops_only_the_unclaimed_suffix() {
        let inj = published(0, 0, 8, 4); // seqs 0..2
        inj.publish(BatchTicket::plan(
            1,
            2,
            batches(&(0..8).collect::<Vec<_>>(), 4, false),
        )); // seqs 2..4
        let first = inj.steal().unwrap();
        assert_eq!(first.seq, 0);
        // unpublish the speculative epoch 1 (seqs >= 2)
        assert_eq!(inj.revoke(2), 2);
        let rest = inj.steal_group(10);
        assert_eq!(rest.iter().map(|t| t.seq).collect::<Vec<_>>(), vec![1]);
        // revoking an empty range is a no-op
        assert_eq!(inj.revoke(2), 0);
    }

    #[test]
    fn credit_gate_wait_wakes_on_advance() {
        let gate = CreditGate::new(1);
        assert!(!gate.wait_admit_timeout(3, Duration::from_millis(5)));
        let g2 = gate.clone();
        let h = std::thread::spawn(move || {
            g2.wait_admit(3); // needs cursor ≥ 3
        });
        std::thread::sleep(Duration::from_millis(10));
        gate.advance(3);
        h.join().unwrap();
    }

    #[test]
    fn credit_gated_grab_respects_window() {
        let inj = published(0, 0, 20, 4);
        let gate = CreditGate::new(2);
        // window [0, 2): only seqs 0 and 1 admitted
        match inj.steal_group_admitted(10, &gate) {
            Claimed::Work(w) => {
                assert_eq!(w.iter().map(|t| t.seq).collect::<Vec<_>>(), vec![0, 1]);
            }
            _ => panic!("expected work"),
        }
        match inj.steal_group_admitted(10, &gate) {
            Claimed::Blocked(seq) => assert_eq!(seq, 2),
            _ => panic!("expected blocked"),
        }
        gate.advance(3); // window [3, 5)
        match inj.steal_group_admitted(1, &gate) {
            Claimed::Work(w) => assert_eq!(w[0].seq, 2),
            _ => panic!("expected work"),
        }
        inj.steal_group(10);
        assert!(matches!(inj.steal_group_admitted(1, &gate), Claimed::Drained));
    }

    #[test]
    fn credit_window_rolls_across_the_epoch_seam() {
        // two published epochs, credit 3: the admitted prefix may span
        // the seam — epoch 0's tail and epoch 1's head in one grab
        let inj = published(0, 0, 8, 4); // seqs 0, 1
        inj.publish(BatchTicket::plan(
            1,
            2,
            batches(&(0..8).collect::<Vec<_>>(), 4, false),
        )); // seqs 2, 3
        let gate = CreditGate::new(3);
        gate.advance(1); // window [1, 4)
        inj.steal_group(1); // seq 0 taken elsewhere
        match inj.steal_group_admitted(10, &gate) {
            Claimed::Work(w) => {
                assert_eq!(w.iter().map(|t| t.seq).collect::<Vec<_>>(), vec![1, 2, 3]);
                assert_eq!(w.iter().map(|t| t.epoch).collect::<Vec<_>>(), vec![0, 1, 1]);
            }
            _ => panic!("expected a cross-seam grab"),
        }
    }

    #[test]
    fn gate_accumulates_blocked_time() {
        let gate = CreditGate::new(1);
        assert_eq!(gate.blocked(), Duration::ZERO);
        assert!(!gate.wait_admit_timeout(5, Duration::from_millis(8)));
        assert!(gate.blocked() >= Duration::from_millis(8));
        gate.note_blocked(Duration::from_millis(2));
        assert!(gate.blocked() >= Duration::from_millis(10));
    }

    #[test]
    fn injector_signal_wakes_parked_worker_without_polling() {
        let inj = Arc::new(BatchInjector::new());
        // publication before the version grab → no wait at all
        let seen = inj.work_version();
        inj.publish(BatchTicket::plan(0, 0, vec![vec![0, 1]]));
        assert!(inj.wait_version(seen, Duration::from_secs(5)));
        // nothing new → times out
        let seen = inj.work_version();
        assert!(!inj.wait_version(seen, Duration::from_millis(5)));
        // a bump from another thread wakes the parked waiter promptly
        let seen = inj.work_version();
        let inj2 = inj.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            inj2.bump();
        });
        let t0 = std::time::Instant::now();
        assert!(inj.wait_version(seen, Duration::from_secs(30)));
        assert!(t0.elapsed() < Duration::from_secs(5));
        h.join().unwrap();
    }

    #[test]
    fn gate_waker_routes_credit_advances_to_the_injector() {
        let inj = Arc::new(BatchInjector::new());
        let gate = CreditGate::new(2);
        let hook = inj.clone();
        gate.set_waker(Arc::new(move || hook.bump()));
        let seen = inj.work_version();
        gate.advance(3);
        assert!(inj.wait_version(seen, Duration::from_millis(1)));
        let seen = inj.work_version();
        gate.close();
        assert!(inj.wait_version(seen, Duration::from_millis(1)));
    }

    mod item_tasks {
        use super::*;
        use crate::dataloader::arena::{BatchArena, BatchBuilder};
        use crate::dataset::ItemMeta;

        fn task_of(n: usize, owner: u32) -> (BatchBuilder, Arc<ItemTask>) {
            // batch id = owner, so registry tests can tell tasks apart
            let id = owner as usize;
            let arena = BatchArena::new(2, n, 2);
            let b = arena.checkout(id, n);
            let ticket = BatchTicket::solo(id, (10..10 + n).collect());
            let t = ItemTask::new(&ticket, owner, b.clone());
            (b, t)
        }

        fn fill_claim(claim: ItemClaim) {
            let res = claim.task().builder().fill(claim.pos(), claim.index(), |out| {
                out.fill(claim.pos() as u8);
                Ok(ItemMeta { label: 0, raw_bytes: 1 })
            });
            claim.finish(res);
        }

        #[test]
        fn claims_hand_out_each_slot_once_and_settle() {
            let (b, t) = task_of(4, 0);
            let mut seen = Vec::new();
            while let Some(c) = ItemTask::claim(&t) {
                seen.push((c.pos(), c.index()));
                fill_claim(c);
            }
            assert_eq!(seen, vec![(0, 10), (1, 11), (2, 12), (3, 13)]);
            assert!(t.wait_settled().is_none());
            let batch = b.finish().unwrap();
            assert_eq!(batch.indices, vec![10, 11, 12, 13]);
        }

        #[test]
        fn error_stops_further_claims_and_surfaces_in_settle() {
            let (b, t) = task_of(4, 0);
            let c = ItemTask::claim(&t).unwrap();
            c.finish(Err(anyhow::anyhow!("boom")));
            assert!(ItemTask::claim(&t).is_none());
            assert_eq!(t.unclaimed(), 0);
            let err = t.wait_settled().unwrap();
            assert!(err.to_string().contains("boom"), "{err}");
            drop(b); // slab recovery is the owner's job
        }

        #[test]
        fn dropped_claim_reports_abandonment() {
            let (_b, t) = task_of(2, 0);
            let c = ItemTask::claim(&t).unwrap();
            drop(c); // simulated panic mid-fill
            let err = t.wait_settled().unwrap();
            assert!(err.to_string().contains("abandoned"), "{err}");
        }

        #[test]
        fn settle_waits_for_concurrent_fillers() {
            let (b, t) = task_of(8, 0);
            std::thread::scope(|s| {
                for _ in 0..3 {
                    let t = t.clone();
                    s.spawn(move || {
                        while let Some(c) = ItemTask::claim(&t) {
                            std::thread::sleep(Duration::from_millis(1));
                            fill_claim(c);
                        }
                    });
                }
                assert!(t.wait_settled().is_none());
            });
            assert_eq!(b.finish().unwrap().len(), 8);
        }

        #[test]
        fn injector_registry_steals_from_oldest_and_counts() {
            let inj = BatchInjector::new();
            let (_b0, t0) = task_of(2, 0);
            let (_b1, t1) = task_of(2, 1);
            inj.register(t0.clone());
            inj.register(t1.clone());
            assert_eq!(inj.active_tasks(), 2);
            // thief = worker 1: first two claims come from t0 (owner 0)
            let c = inj.steal_item(1).unwrap();
            assert_eq!(c.task().batch_id(), t0.batch_id());
            fill_claim(c);
            fill_claim(inj.steal_item(1).unwrap());
            assert_eq!(inj.item_steal_count(), 2);
            // next claims come from t1 — owner 1 stealing its own batch
            // does not count
            fill_claim(inj.steal_item(1).unwrap());
            assert_eq!(inj.item_steal_count(), 2);
            inj.unregister(t0.seq());
            assert_eq!(inj.active_tasks(), 1);
            fill_claim(inj.steal_item(0).unwrap());
            assert_eq!(inj.item_steal_count(), 3);
            assert!(inj.steal_item(0).is_none());
        }
    }
}
