//! The three fetcher strategies (§2.2 of the paper, Fig 4):
//!
//! * [`fetch_vanilla`] — `_MapDatasetFetcher`: items of a batch loaded
//!   **sequentially** (the bottleneck the paper identifies).
//! * [`fetch_threaded`] — `_ThreadedMapDatasetFetcher`: a per-worker
//!   thread pool fetches items of one batch (or, with *batch
//!   disassembly*, of several batches at once) in parallel. Threads
//!   share the worker's GIL for the CPU decode sections, exactly like
//!   CPython threads.
//! * [`fetch_async`] — `_AsyncMapDatasetFetcher`: a single-threaded
//!   asyncio-style event loop overlaps the I/O of all items; CPU decode
//!   serializes on the loop thread.
//!
//! All three return samples **in request order** (the paper sorts after
//! parallel arrival) and record one `get_item` span per item.

use std::sync::mpsc;
use std::sync::Arc;

use anyhow::Result;

use super::collate::restore_order;
use crate::asyncrt;
use crate::dataset::{Dataset, Sample};
use crate::gil::Gil;
use crate::telemetry::{names, Recorder};

/// Shared context for one worker's fetchers.
pub struct FetchCtx {
    pub worker_id: u32,
    pub dataset: Arc<dyn Dataset>,
    pub gil: Arc<Gil>,
    pub recorder: Arc<Recorder>,
}

impl FetchCtx {
    fn get_one(&self, batch_id: usize, index: usize) -> Result<Sample> {
        let t0 = self.recorder.now();
        let s = self.dataset.get_item(index, &self.gil);
        self.recorder.record(
            names::GET_ITEM,
            self.worker_id,
            batch_id as i64,
            t0,
            self.recorder.now(),
        );
        s
    }
}

/// Sequential in-batch fetch (vanilla torch).
pub fn fetch_vanilla(ctx: &FetchCtx, batch_id: usize, indices: &[usize]) -> Result<Vec<Sample>> {
    indices.iter().map(|&i| ctx.get_one(batch_id, i)).collect()
}

// ---------------------------------------------------------------------------
// Threaded fetcher
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Persistent in-worker thread pool (`ThreadPoolExecutor` analogue).
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    threads: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize, name: &str) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let threads = (0..size)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-fetch{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn fetch thread")
            })
            .collect();
        ThreadPool { tx: Some(tx), threads, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn submit(&self, job: Job) {
        self.tx.as_ref().expect("pool closed").send(job).expect("pool hung up");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Parallel fetch of one *or several* batches through the worker's
/// thread pool. `work` is a list of (batch_id, indices); with batch
/// disassembly the worker passes several batches, and all their items
/// are fetched in one wave (the paper's `batch_pool`). Returns each
/// batch's samples in request order.
pub fn fetch_threaded(
    ctx: &Arc<FetchCtx>,
    pool: &ThreadPool,
    work: &[(usize, Vec<usize>)],
) -> Result<Vec<(usize, Vec<Sample>)>> {
    // disassemble: flat list of (batch_pos, item_pos, dataset_index)
    let (otx, orx) = mpsc::channel::<(usize, usize, Result<Sample>)>();
    let mut total = 0usize;
    for (bpos, (batch_id, indices)) in work.iter().enumerate() {
        for (ipos, &index) in indices.iter().enumerate() {
            let ctx = ctx.clone();
            let otx = otx.clone();
            let batch_id = *batch_id;
            total += 1;
            pool.submit(Box::new(move || {
                let out = ctx.get_one(batch_id, index);
                let _ = otx.send((bpos, ipos, out));
            }));
        }
    }
    drop(otx);

    // reassemble
    let mut per_batch: Vec<Vec<(usize, Sample)>> =
        work.iter().map(|_| Vec::new()).collect();
    for _ in 0..total {
        let (bpos, ipos, res) = orx.recv().expect("fetch thread died");
        per_batch[bpos].push((ipos, res?));
    }
    let mut out = Vec::with_capacity(work.len());
    for (bpos, fetched) in per_batch.into_iter().enumerate() {
        let n = work[bpos].1.len();
        out.push((work[bpos].0, restore_order(n, fetched)));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Asyncio fetcher
// ---------------------------------------------------------------------------

/// Async in-batch fetch on the worker's single-threaded event loop,
/// bounded by `num_fetch_workers` concurrent tasks.
pub fn fetch_async(
    ctx: &Arc<FetchCtx>,
    rt: &Arc<asyncrt::Runtime>,
    sem: &Arc<asyncrt::Semaphore>,
    batch_id: usize,
    indices: &[usize],
) -> Result<Vec<Sample>> {
    let handles: Vec<_> = indices
        .iter()
        .enumerate()
        .map(|(pos, &index)| {
            let ctx = ctx.clone();
            let sem = sem.clone();
            rt.spawn(async move {
                let _permit = sem.acquire().await;
                let t0 = ctx.recorder.now();
                let s = ctx.dataset.get_item_async(index, &ctx.gil).await;
                ctx.recorder.record(
                    names::GET_ITEM,
                    ctx.worker_id,
                    batch_id as i64,
                    t0,
                    ctx.recorder.now(),
                );
                (pos, s)
            })
        })
        .collect();
    let fetched = asyncrt::block_on(asyncrt::join_all(handles));
    let mut ok = Vec::with_capacity(fetched.len());
    for (pos, res) in fetched {
        ok.push((pos, res?));
    }
    Ok(restore_order(indices.len(), ok))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_corpus, CorpusSpec};
    use crate::data::AugmentConfig;
    use crate::dataset::ImageFolderDataset;
    use crate::storage::{MemStore, ObjectStore, RemoteProfile, SimRemoteStore};
    use std::time::Instant;

    fn ctx_on(remote: bool, items: usize) -> Arc<FetchCtx> {
        let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        generate_corpus(&mem, &CorpusSpec::tiny(items)).unwrap();
        let store: Arc<dyn ObjectStore> = if remote {
            SimRemoteStore::new(mem, RemoteProfile::s3().scaled(0.25), 5)
        } else {
            mem
        };
        let ds = ImageFolderDataset::new(
            store,
            AugmentConfig { crop: 16, ..Default::default() },
        );
        Arc::new(FetchCtx {
            worker_id: 0,
            dataset: Arc::new(ds),
            gil: Gil::native(),
            recorder: Recorder::new(),
        })
    }

    fn indices(n: usize) -> Vec<usize> {
        (0..n).collect()
    }

    #[test]
    fn vanilla_order_and_spans() {
        let ctx = ctx_on(false, 6);
        let samples = fetch_vanilla(&ctx, 0, &indices(6)).unwrap();
        assert_eq!(samples.iter().map(|s| s.index).collect::<Vec<_>>(), indices(6));
        assert_eq!(ctx.recorder.durations(names::GET_ITEM).len(), 6);
    }

    #[test]
    fn threaded_restores_order() {
        let ctx = ctx_on(true, 8);
        let pool = ThreadPool::new(8, "t");
        let work = vec![(0usize, indices(8))];
        let out = fetch_threaded(&ctx, &pool, &work).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(
            out[0].1.iter().map(|s| s.index).collect::<Vec<_>>(),
            indices(8)
        );
    }

    #[test]
    fn threaded_beats_vanilla_on_latency() {
        let ctx = ctx_on(true, 8);
        let t0 = Instant::now();
        fetch_vanilla(&ctx, 0, &indices(8)).unwrap();
        let seq = t0.elapsed();

        let ctx2 = ctx_on(true, 8);
        let pool = ThreadPool::new(8, "t");
        let t0 = Instant::now();
        fetch_threaded(&ctx2, &pool, &[(0, indices(8))]).unwrap();
        let par = t0.elapsed();
        assert!(
            par < seq / 2,
            "threaded {par:?} not ≪ vanilla {seq:?}"
        );
    }

    #[test]
    fn threaded_multi_batch_disassembly() {
        let ctx = ctx_on(false, 12);
        let pool = ThreadPool::new(4, "t");
        let work = vec![(3usize, indices(6)), (4usize, (6..12).collect())];
        let out = fetch_threaded(&ctx, &pool, &work).unwrap();
        assert_eq!(out[0].0, 3);
        assert_eq!(out[1].0, 4);
        assert_eq!(out[1].1.iter().map(|s| s.index).collect::<Vec<_>>(), (6..12).collect::<Vec<_>>());
    }

    #[test]
    fn async_restores_order_and_overlaps() {
        let ctx = ctx_on(true, 8);
        let rt = asyncrt::Runtime::new(1);
        let sem = asyncrt::Semaphore::new(16);
        let t0 = Instant::now();
        let out = fetch_async(&ctx, &rt, &sem, 0, &indices(8)).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(out.iter().map(|s| s.index).collect::<Vec<_>>(), indices(8));
        // must be clearly faster than the 8-item sequential sum
        let sum: f64 = ctx.recorder.durations(names::GET_ITEM).iter().sum();
        assert!(wall < 0.7 * sum, "wall {wall} vs sum {sum}");
    }

    #[test]
    fn async_semaphore_bounds_concurrency() {
        let ctx = ctx_on(true, 6);
        let rt = asyncrt::Runtime::new(1);
        let sem = asyncrt::Semaphore::new(1); // degenerate: sequential
        let out = fetch_async(&ctx, &rt, &sem, 0, &indices(4)).unwrap();
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(3, "p");
        let (tx, rx) = mpsc::channel();
        for i in 0..20 {
            let tx = tx.clone();
            pool.submit(Box::new(move || {
                tx.send(i).unwrap();
            }));
        }
        drop(tx);
        let mut got: Vec<usize> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..20).collect::<Vec<_>>());
    }
}
