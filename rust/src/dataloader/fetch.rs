//! The three fetcher strategies (§2.2 of the paper, Fig 4):
//!
//! * [`fetch_vanilla`] — `_MapDatasetFetcher`: items of a batch loaded
//!   **sequentially** (the bottleneck the paper identifies).
//! * [`fetch_threaded`] — `_ThreadedMapDatasetFetcher`: a per-worker
//!   thread pool fetches items of one batch (or, with *batch
//!   disassembly*, of several batches at once) in parallel. Threads
//!   share the worker's GIL for the CPU decode sections, exactly like
//!   CPython threads.
//! * [`fetch_async`] — `_AsyncMapDatasetFetcher`: a single-threaded
//!   asyncio-style event loop overlaps the I/O of all items; CPU decode
//!   serializes on the loop thread.
//!
//! The legacy variants return samples **in request order** (the paper
//! sorts after parallel arrival) for the copying `collate`. Each has a
//! `*_fused` twin that decodes every item **directly into its slot of a
//! checked-out arena slab** ([`crate::dataloader::arena`]) — no
//! intermediate `Sample.crop`, no `restore_order` re-sort (slots are
//! positional), no collate copy. All variants record one `get_item`
//! span per item.
//!
//! The fused twins schedule at **item granularity** through
//! [`ItemTask`] claim cursors: the threaded/asyncio paths submit one
//! job/future per *executor slot* (a wave slice), each looping "claim
//! next unfilled slot → decode into it" until the wave is dry — not one
//! boxed job per item, and never an item parked behind a slow sibling.
//! Passing the worker's [`BatchInjector`] (`steal_items`) additionally
//! registers each in-progress batch so *other* workers' idle threads
//! can claim its tail items.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Result};

use super::arena::{BatchArena, BatchBuilder};
use super::collate::{restore_order, Batch};
use super::sampler::{BatchInjector, BatchTicket, ItemClaim, ItemTask};
use crate::asyncrt;
use crate::dataset::{copy_sample_into, Dataset, Sample};
use crate::gil::Gil;
use crate::storage::{IoRing, ReadOp};
use crate::telemetry::{names, Recorder};

/// Shared context for one worker's fetchers.
pub struct FetchCtx {
    pub worker_id: u32,
    pub dataset: Arc<dyn Dataset>,
    pub gil: Arc<Gil>,
    pub recorder: Arc<Recorder>,
}

impl FetchCtx {
    fn get_one(&self, batch_id: usize, epoch: usize, index: usize) -> Result<Sample> {
        let t0 = self.recorder.now();
        // the epoch travels with the call: under cross-epoch pipelining
        // items of two adjacent epochs are in flight at once, so the
        // dataset's global set_epoch state cannot disambiguate them
        let s = self.dataset.get_item_at(index, epoch, &self.gil);
        self.recorder.record_tagged(
            names::GET_ITEM,
            self.worker_id,
            batch_id as i64,
            epoch as i64,
            -1,
            t0,
            self.recorder.now(),
        );
        s
    }

    /// Fused counterpart of [`FetchCtx::get_one`]: load item `index`
    /// straight into slot `pos` of `builder`, recording the same
    /// `get_item` span.
    fn fill_one(
        &self,
        builder: &BatchBuilder,
        batch_id: usize,
        epoch: usize,
        pos: usize,
        index: usize,
    ) -> Result<()> {
        let t0 = self.recorder.now();
        let res = builder.fill(pos, index, |out| {
            self.dataset.get_item_into_at(index, epoch, &self.gil, out)
        });
        self.recorder.record_tagged(
            names::GET_ITEM,
            self.worker_id,
            batch_id as i64,
            epoch as i64,
            -1,
            t0,
            self.recorder.now(),
        );
        res
    }

    /// Execute one [`ItemClaim`]: decode the claimed item into its slot
    /// and report the outcome. This is the unit both wave-slice jobs and
    /// cross-worker item thieves run — the task carries its epoch, so a
    /// thief filling a next-epoch batch decodes with the right seed.
    pub fn run_claim(&self, claim: ItemClaim) {
        let task = claim.task();
        let (batch_id, epoch) = (task.batch_id(), task.epoch());
        let res = self.fill_one(
            claim.task().builder(),
            batch_id,
            epoch,
            claim.pos(),
            claim.index(),
        );
        claim.finish(res);
    }
}

/// Sequential in-batch fetch (vanilla torch).
pub fn fetch_vanilla(
    ctx: &FetchCtx,
    epoch: usize,
    batch_id: usize,
    indices: &[usize],
) -> Result<Vec<Sample>> {
    indices.iter().map(|&i| ctx.get_one(batch_id, epoch, i)).collect()
}

/// Sequential fused fetch: assemble the batch in its arena slab with no
/// intermediate sample allocations.
pub fn fetch_vanilla_fused(
    ctx: &FetchCtx,
    arena: &Arc<BatchArena>,
    ticket: &BatchTicket,
) -> Result<Batch> {
    let builder = arena
        .clone()
        .checkout_tagged(ticket.id, ticket.seq, ticket.epoch, ticket.indices.len());
    for (pos, &index) in ticket.indices.iter().enumerate() {
        // on error the builder drops here and the slab returns to the
        // pool (the worker surfaces the error per batch)
        ctx.fill_one(&builder, ticket.id, ticket.epoch, pos, index)?;
    }
    builder.finish()
}

// ---------------------------------------------------------------------------
// Item-task wave machinery (shared by the fused threaded/asyncio paths)
// ---------------------------------------------------------------------------

/// One checked-out batch of a fused wave: the primary builder (owns the
/// slab's fate) plus the claim cursor fillers pull from.
struct WaveEntry {
    builder: BatchBuilder,
    task: Arc<ItemTask>,
}

/// Check out a slab + item task per batch of the wave, registering each
/// task with the injector when item stealing is on.
fn wave_entries(
    ctx: &FetchCtx,
    arena: &Arc<BatchArena>,
    work: &[BatchTicket],
    registry: Option<&BatchInjector>,
) -> Vec<WaveEntry> {
    work.iter()
        .map(|ticket| {
            let builder = arena.clone().checkout_tagged(
                ticket.id,
                ticket.seq,
                ticket.epoch,
                ticket.indices.len(),
            );
            let task = ItemTask::new(ticket, ctx.worker_id, builder.clone());
            if let Some(inj) = registry {
                inj.register(task.clone());
            }
            WaveEntry { builder, task }
        })
        .collect()
}

/// Settle every batch of the wave in order: wait until no fill is
/// outstanding, withdraw it from the steal registry, then publish
/// (finish) or fail it. Results are keyed by global **seq** (the
/// reorder-buffer key — unique across epochs, unlike the batch id).
fn settle_wave(
    entries: Vec<WaveEntry>,
    registry: Option<&BatchInjector>,
) -> Vec<(usize, Result<Batch>)> {
    entries
        .into_iter()
        .map(|WaveEntry { builder, task }| {
            let err = task.wait_settled();
            if let Some(inj) = registry {
                inj.unregister(task.seq());
            }
            let seq = task.seq();
            match err {
                None => (seq, builder.finish()),
                Some(e) => {
                    drop(builder); // recover the slab
                    (seq, Err(e))
                }
            }
        })
        .collect()
}

/// Run a wave's fill phase with panic containment around the slab
/// lifecycle: if `fill` unwinds (e.g. the fetch pool lost its last
/// thread mid-submit), every still-unclaimed slot is claimed and
/// failed — so [`settle_wave`] cannot hang on slots no thread will
/// ever fill — and the wave *settles* (waiting out every in-flight
/// sibling/thief fill) before any builder drops. Only then is the
/// panic resumed. Without this, unwinding would drop the primary
/// builders and recycle slabs while concurrent fillers are still
/// writing into them — a silent cross-batch pixel race once the slab
/// is re-checked out.
fn fill_wave_contained<F: FnOnce()>(
    tasks: &[Arc<ItemTask>],
    entries: Vec<WaveEntry>,
    registry: Option<&BatchInjector>,
    fill: F,
) -> Vec<(usize, Result<Batch>)> {
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(fill));
    if outcome.is_err() {
        for task in tasks {
            while let Some(claim) = ItemTask::claim(task) {
                claim.finish(Err(anyhow::anyhow!(
                    "wave aborted: worker panicked mid-fill"
                )));
            }
        }
    }
    let results = settle_wave(entries, registry);
    match outcome {
        Ok(()) => results,
        // the caller's panic containment (run_worker) turns this into
        // per-batch tombstones; the settled results are dropped, which
        // is safe — their slabs are fully published or recovered
        Err(p) => std::panic::resume_unwind(p),
    }
}

/// Sequential fused wave over claim cursors — the vanilla engine's
/// item-steal path: the worker fills its registered batches in order
/// while siblings may concurrently take tail items off the same
/// cursors. Without a registry this is behaviorally identical to
/// looping [`fetch_vanilla_fused`].
pub fn fill_wave_sequential(
    ctx: &Arc<FetchCtx>,
    arena: &Arc<BatchArena>,
    work: &[BatchTicket],
    registry: Option<&BatchInjector>,
) -> Vec<(usize, Result<Batch>)> {
    let entries = wave_entries(ctx, arena, work, registry);
    let tasks: Vec<Arc<ItemTask>> = entries.iter().map(|e| e.task.clone()).collect();
    fill_wave_contained(&tasks, entries, registry, || {
        for task in &tasks {
            while let Some(claim) = ItemTask::claim(task) {
                ctx.run_claim(claim);
            }
        }
    })
}

// ---------------------------------------------------------------------------
// Batched-submission ring wave
// ---------------------------------------------------------------------------

/// Fused wave over the batched-submission ring: every item read of the
/// wave is described as a [`ReadOp`] and submitted as **one batch**, so
/// a single worker thread keeps up to `io_depth` reads in flight
/// instead of one per fetch thread. Completions are reaped out of
/// order and each is decoded straight into its slab slot as it lands;
/// `(key, buf)` pairs recycle through `scratch`, so the wave performs
/// no per-item allocation in steady state.
///
/// Returns `None` — before submitting anything — when the dataset
/// cannot describe one of the wave's items as a plain ranged read
/// ([`Dataset::raw_desc`]); the caller falls back to the per-item
/// engines. Ring waves do not register [`ItemTask`]s: the steal
/// cursors hand out slots in claim order, which an out-of-order reap
/// loop cannot honor, so ring batches simply are not steal donors.
pub fn fill_wave_ring(
    ctx: &Arc<FetchCtx>,
    ring: &Arc<IoRing>,
    arena: &Arc<BatchArena>,
    work: &[BatchTicket],
    scratch: &mut Vec<(String, Vec<u8>)>,
) -> Option<Vec<(usize, Result<Batch>)>> {
    // slot = starts[b] + pos: each batch owns a contiguous slot window,
    // so a completion finds its batch with one partition-point probe
    let mut starts = Vec::with_capacity(work.len());
    let mut total = 0usize;
    for t in work {
        starts.push(total);
        total += t.indices.len();
    }
    let mut ops: Vec<ReadOp> = Vec::with_capacity(total);
    for (b, t) in work.iter().enumerate() {
        for (pos, &index) in t.indices.iter().enumerate() {
            let (mut key, buf) = scratch.pop().unwrap_or_default();
            let Some((offset, len)) = ctx.dataset.raw_desc(index, &mut key) else {
                // undescribable item: hand every buffer back and let
                // the caller run the legacy engine instead
                scratch.push((key, buf));
                for op in ops {
                    scratch.push((op.key, op.buf));
                }
                return None;
            };
            ops.push(ReadOp::range(starts[b] + pos, key, offset, len, buf));
        }
    }
    let builders: Vec<BatchBuilder> = work
        .iter()
        .map(|t| {
            arena
                .clone()
                .checkout_tagged(t.id, t.seq, t.epoch, t.indices.len())
        })
        .collect();
    let mut errs: Vec<Option<anyhow::Error>> = work.iter().map(|_| None).collect();
    let mut sub = ring.submit(ops);
    while let Some(comp) = sub.next() {
        let slot = comp.slot;
        let b = starts.partition_point(|&s| s <= slot) - 1;
        let t = &work[b];
        let pos = slot - starts[b];
        let index = t.indices[pos];
        let key = comp.key;
        let buf = comp.buf;
        let t0 = ctx.recorder.now();
        let res = match comp.result {
            Ok(n) => builders[b].fill(pos, index, |out| {
                ctx.dataset
                    .process_raw_into_at(index, t.epoch, &buf[..n], &ctx.gil, out)
            }),
            // an isolated I/O failure tombstones this item, not the
            // wave: one blocking per-item attempt down the legacy path,
            // and only its failure marks the batch — sibling slots in
            // the wave still deliver
            Err(ring_err) => builders[b]
                .fill(pos, index, |out| {
                    ctx.dataset.get_item_into_at(index, t.epoch, &ctx.gil, out)
                })
                .map_err(|e| {
                    e.context(format!("after ring read failed: {ring_err:#}"))
                }),
        };
        ctx.recorder.record_tagged(
            names::GET_ITEM,
            ctx.worker_id,
            t.id as i64,
            t.epoch as i64,
            -1,
            t0,
            ctx.recorder.now(),
        );
        if let Err(e) = res {
            // first error wins; the batch fails as a unit below
            if errs[b].is_none() {
                errs[b] = Some(e);
            }
        }
        scratch.push((key, buf));
    }
    let results = builders
        .into_iter()
        .zip(work)
        .zip(errs)
        .map(|((builder, t), err)| match err {
            None => (t.seq, builder.finish()),
            Some(e) => {
                drop(builder); // recover the slab
                (t.seq, Err(e))
            }
        })
        .collect();
    Some(results)
}

// ---------------------------------------------------------------------------
// Threaded fetcher
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Sentinel depth marking a queue whose thread died (panicked job).
const DEAD: usize = usize::MAX;

/// Shared state behind one [`ThreadPool`].
struct PoolShared {
    /// per-thread job queues (affinity at submit time; any idle thread
    /// may *take over* another queue's jobs — see the worker loop)
    queues: Vec<Mutex<VecDeque<Job>>>,
    /// per-queue load: jobs queued or running; `DEAD` = thread gone
    depth: Vec<AtomicUsize>,
    /// parking lot for idle threads (also orders the submit-notify
    /// handshake: notify runs under this lock *after* the push, so an
    /// idle thread that saw empty queues cannot miss the wakeup)
    park: Mutex<bool>, // = shutdown flag
    cv: Condvar,
}

impl PoolShared {
    /// Pop the front of queue `i`.
    fn pop(&self, i: usize) -> Option<Job> {
        self.queues[i].lock().unwrap().pop_front()
    }

    /// Take over a queued job from the most-loaded *other* queue — a
    /// job parked behind a dead-slow (or dead) sibling gets drained by
    /// whoever is idle instead of waiting the straggler out. Returns the
    /// source queue index alongside the job for depth re-accounting.
    /// Allocation-free: this runs on every idle poll of the hot path.
    fn takeover(&self, me: usize) -> Option<(usize, Job)> {
        let n = self.queues.len();
        // most-loaded live sibling first
        let mut best: Option<(usize, usize)> = None;
        for i in (0..n).filter(|&i| i != me) {
            let d = self.depth[i].load(Ordering::Relaxed);
            if d == DEAD || d == 0 {
                continue;
            }
            if best.is_none_or(|(bd, _)| d > bd) {
                best = Some((d, i));
            }
        }
        if let Some((_, i)) = best {
            if let Some(job) = self.pop(i) {
                return Some((i, job));
            }
        }
        // fallback sweep: dead queues (their gauge is the sentinel, but
        // their leftovers still need draining) and load-gauge races
        for i in (0..n).filter(|&i| i != me) {
            if let Some(job) = self.pop(i) {
                return Some((i, job));
            }
        }
        None
    }
}

/// Depth bookkeeping for one running job; marks the queue `DEAD` if the
/// job panics (the thread unwinds and exits — submit skips the queue
/// from then on, and siblings take over whatever was left queued
/// behind the panic). If the *last* live thread dies, every queued job
/// is dropped so wave reassembly fails cleanly instead of hanging on
/// jobs no thread will ever run.
struct RunGuard<'a> {
    shared: &'a PoolShared,
    i: usize,
    done: bool,
}

impl Drop for RunGuard<'_> {
    fn drop(&mut self) {
        if self.done {
            self.shared.depth[self.i].fetch_sub(1, Ordering::Relaxed);
            return;
        }
        // panicking job: this thread is about to die
        self.shared.depth[self.i].store(DEAD, Ordering::Relaxed);
        let all_dead = self
            .shared
            .depth
            .iter()
            .all(|d| d.load(Ordering::Relaxed) == DEAD);
        if all_dead {
            for q in &self.shared.queues {
                q.lock().unwrap().clear(); // drop orphaned jobs
            }
        }
        self.shared.cv.notify_all(); // siblings: come take over my queue
    }
}

/// Persistent in-worker thread pool (`ThreadPoolExecutor` analogue).
///
/// Each thread owns its private job queue; `submit` places a job on the
/// **least-loaded live queue** (per-queue depth counters count queued +
/// running jobs), so no job is parked behind a p99-slow storage fetch
/// while sibling threads idle — the pool is work-conserving at submit
/// time. It is also work-conserving *after* submit: an idle thread
/// whose own queue is empty **takes over** queued jobs from its
/// most-loaded sibling, so a job that landed behind a fetch that turned
/// slow (or behind a panic-killed thread) still completes as soon as
/// any thread frees up. Ties rotate, a large `num_fetch_workers` never
/// serializes on one shared `Mutex<Receiver>` funnel (queues have
/// per-thread locks), and a queue whose thread died is skipped by
/// submit while its leftovers drain through takeover.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    next: AtomicUsize,
    threads: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize, name: &str) -> ThreadPool {
        let size = size.max(1);
        let shared = Arc::new(PoolShared {
            queues: (0..size).map(|_| Mutex::new(VecDeque::new())).collect(),
            depth: (0..size).map(|_| AtomicUsize::new(0)).collect(),
            park: Mutex::new(false),
            cv: Condvar::new(),
        });
        let mut threads = Vec::with_capacity(size);
        for i in 0..size {
            let shared = shared.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("{name}-fetch{i}"))
                    .spawn(move || pool_worker(&shared, i))
                    .expect("spawn fetch thread"),
            );
        }
        ThreadPool { shared, next: AtomicUsize::new(0), threads, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn submit(&self, job: Job) {
        let n = self.size;
        let rot = self.next.fetch_add(1, Ordering::Relaxed);
        let i = loop {
            // least-loaded live queue, rotating tie-break
            let mut best: Option<(usize, usize)> = None;
            for k in 0..n {
                let i = (rot + k) % n;
                let d = self.shared.depth[i].load(Ordering::Relaxed);
                if d == DEAD {
                    continue;
                }
                if best.is_none_or(|(bd, _)| d < bd) {
                    best = Some((d, i));
                }
            }
            let Some((_, i)) = best else {
                panic!("every fetch pool thread died");
            };
            // claim a load slot without ever incrementing the DEAD
            // sentinel — the thread may have died between the scan and
            // here, and a blind fetch_add would wrap the sentinel back
            // to a live-looking depth (resurrecting the queue past the
            // all-dead orphan sweep). On a lost race, re-scan.
            let claimed = self.shared.depth[i]
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                    if v == DEAD {
                        None
                    } else {
                        Some(v + 1)
                    }
                })
                .is_ok();
            if claimed {
                break i;
            }
        };
        self.shared.queues[i].lock().unwrap().push_back(job);
        // re-check after the push: if the last live thread died while we
        // were placing the job, nobody will ever run it — drop the
        // orphans and fail loudly (the queue-lock handoff makes the DEAD
        // marks visible here), exactly like the all-dead scan above
        if self
            .shared
            .depth
            .iter()
            .all(|d| d.load(Ordering::Relaxed) == DEAD)
        {
            for q in &self.shared.queues {
                q.lock().unwrap().clear();
            }
            panic!("every fetch pool thread died");
        }
        // notify under the park lock: pairs with the scan-then-wait in
        // pool_worker so the push above is never missed
        drop(self.shared.park.lock().unwrap());
        self.shared.cv.notify_all();
    }
}

fn pool_worker(shared: &PoolShared, i: usize) {
    loop {
        // own queue first (submit affinity), then take over the
        // most-loaded sibling's backlog
        let claimed = match shared.pop(i) {
            Some(job) => Some(job),
            None => shared.takeover(i).map(|(src, job)| {
                // the job now runs here: move its load accounting (a
                // dead source keeps its DEAD sentinel)
                let d = &shared.depth[src];
                let _ = d.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                    if v != DEAD && v > 0 {
                        Some(v - 1)
                    } else {
                        None
                    }
                });
                shared.depth[i].fetch_add(1, Ordering::Relaxed);
                job
            }),
        };
        match claimed {
            Some(job) => {
                let mut guard = RunGuard { shared, i, done: false };
                job(); // a panic here unwinds through RunGuard
                guard.done = true;
                drop(guard);
            }
            None => {
                let st = shared.park.lock().unwrap();
                // re-check under the park lock: a push that raced the
                // scan above is visible here, and a later one must take
                // this lock in `submit` before notifying — which blocks
                // until `wait` releases it, so the wakeup cannot be
                // missed
                let any = shared
                    .queues
                    .iter()
                    .any(|q| !q.lock().unwrap().is_empty());
                if any {
                    continue;
                }
                if *st {
                    return; // shutdown, queues drained
                }
                let _unused = shared.cv.wait(st).unwrap();
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.park.lock().unwrap() = true;
        self.shared.cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Parallel fetch of one *or several* batches through the worker's
/// thread pool. `work` is a list of tickets; with batch disassembly the
/// worker passes several batches, and all their items are fetched in
/// one wave (the paper's `batch_pool`). Returns each batch's samples in
/// request order, aligned with `work`.
pub fn fetch_threaded(
    ctx: &Arc<FetchCtx>,
    pool: &ThreadPool,
    work: &[BatchTicket],
) -> Result<Vec<Vec<Sample>>> {
    // disassemble: flat list of (batch_pos, item_pos, dataset_index)
    let (otx, orx) = mpsc::channel::<(usize, usize, Result<Sample>)>();
    let mut total = 0usize;
    for (bpos, ticket) in work.iter().enumerate() {
        for (ipos, &index) in ticket.indices.iter().enumerate() {
            let ctx = ctx.clone();
            let otx = otx.clone();
            let (batch_id, epoch) = (ticket.id, ticket.epoch);
            total += 1;
            pool.submit(Box::new(move || {
                let out = ctx.get_one(batch_id, epoch, index);
                let _ = otx.send((bpos, ipos, out));
            }));
        }
    }
    drop(otx);

    // reassemble
    let mut per_batch: Vec<Vec<(usize, Sample)>> =
        work.iter().map(|_| Vec::new()).collect();
    for _ in 0..total {
        // recv only disconnects once every job has run or been dropped
        // (a pool thread unwound) — fail the wave, don't kill the worker
        let Ok((bpos, ipos, res)) = orx.recv() else {
            bail!("fetch pool thread died mid-wave (a job panicked)");
        };
        per_batch[bpos].push((ipos, res?));
    }
    let mut out = Vec::with_capacity(work.len());
    for (bpos, fetched) in per_batch.into_iter().enumerate() {
        let n = work[bpos].indices.len();
        out.push(restore_order(n, fetched));
    }
    Ok(out)
}

/// Fused threaded fetch: the wave's items decode in parallel directly
/// into their slabs. Per-batch results — one failed item fails only its
/// own batch, the rest of the wave is delivered (and the failed batch's
/// slab returns to the pool).
pub fn fetch_threaded_fused(
    ctx: &Arc<FetchCtx>,
    pool: &ThreadPool,
    arena: &Arc<BatchArena>,
    work: &[BatchTicket],
) -> Vec<(usize, Result<Batch>)> {
    fetch_threaded_fused_tasks(ctx, pool, arena, work, None)
}

/// [`fetch_threaded_fused`] with an optional steal registry: one boxed
/// job per pool thread (a *wave slice*), each claiming slots off the
/// wave's [`ItemTask`] cursors until the wave is dry. The calling worker
/// participates too, so the wave completes even if every pool thread is
/// dead, and `wait_settled` can never hang on an unclaimed slot.
pub fn fetch_threaded_fused_tasks(
    ctx: &Arc<FetchCtx>,
    pool: &ThreadPool,
    arena: &Arc<BatchArena>,
    work: &[BatchTicket],
    registry: Option<&BatchInjector>,
) -> Vec<(usize, Result<Batch>)> {
    let entries = wave_entries(ctx, arena, work, registry);
    let tasks: Vec<Arc<ItemTask>> = entries.iter().map(|e| e.task.clone()).collect();
    let total: usize = tasks.iter().map(|t| t.len()).sum();
    // wave slices: one job per executor slot, not one per item. The
    // worker thread itself takes one slice, so only size-1 go to the
    // pool when the wave is small.
    let slices = pool.size().min(total).saturating_sub(1);
    fill_wave_contained(&tasks, entries, registry, || {
        for _ in 0..slices {
            let tasks = tasks.clone();
            let ctx = ctx.clone();
            pool.submit(Box::new(move || {
                for task in &tasks {
                    while let Some(claim) = ItemTask::claim(task) {
                        ctx.run_claim(claim);
                    }
                }
            }));
        }
        for task in &tasks {
            while let Some(claim) = ItemTask::claim(task) {
                ctx.run_claim(claim);
            }
        }
    })
}

// ---------------------------------------------------------------------------
// Asyncio fetcher
// ---------------------------------------------------------------------------

/// Async in-batch fetch on the worker's single-threaded event loop,
/// bounded by `num_fetch_workers` concurrent tasks.
pub fn fetch_async(
    ctx: &Arc<FetchCtx>,
    rt: &Arc<asyncrt::Runtime>,
    sem: &Arc<asyncrt::Semaphore>,
    epoch: usize,
    batch_id: usize,
    indices: &[usize],
) -> Result<Vec<Sample>> {
    let handles: Vec<_> = indices
        .iter()
        .enumerate()
        .map(|(pos, &index)| {
            let ctx = ctx.clone();
            let sem = sem.clone();
            rt.spawn(async move {
                let _permit = sem.acquire().await;
                let t0 = ctx.recorder.now();
                let s = ctx.dataset.get_item_async_at(index, epoch, &ctx.gil).await;
                ctx.recorder.record_tagged(
                    names::GET_ITEM,
                    ctx.worker_id,
                    batch_id as i64,
                    epoch as i64,
                    -1,
                    t0,
                    ctx.recorder.now(),
                );
                (pos, s)
            })
        })
        .collect();
    let fetched = asyncrt::block_on(asyncrt::join_all(handles));
    let mut ok = Vec::with_capacity(fetched.len());
    for (pos, res) in fetched {
        ok.push((pos, res?));
    }
    Ok(restore_order(indices.len(), ok))
}

/// One async claim execution: overlap the raw-byte wait on the event
/// loop, then decode straight into the slab slot (datasets with
/// [`Dataset::supports_raw`]; others fall back to `get_item_async` plus
/// one copy into the slot). The task's epoch tag rides into the decode.
async fn run_claim_async(ctx: &FetchCtx, claim: ItemClaim) {
    let task = claim.task().clone();
    let (pos, index) = (claim.pos(), claim.index());
    let (batch_id, epoch) = (task.batch_id(), task.epoch());
    let t0 = ctx.recorder.now();
    let res = if ctx.dataset.supports_raw() {
        match ctx.dataset.get_raw_async(index).await {
            Ok(raw) => task.builder().fill(pos, index, |out| {
                ctx.dataset.process_raw_into_at(index, epoch, &raw, &ctx.gil, out)
            }),
            Err(e) => Err(e),
        }
    } else {
        match ctx.dataset.get_item_async_at(index, epoch, &ctx.gil).await {
            Ok(s) => task.builder().fill(pos, index, |out| copy_sample_into(&s, out)),
            Err(e) => Err(e),
        }
    };
    ctx.recorder.record_tagged(
        names::GET_ITEM,
        ctx.worker_id,
        batch_id as i64,
        epoch as i64,
        -1,
        t0,
        ctx.recorder.now(),
    );
    claim.finish(res);
}

/// Fused asyncio fetch over one batch (see
/// [`fetch_async_fused_tasks`] for the wave/steal-aware variant).
pub fn fetch_async_fused(
    ctx: &Arc<FetchCtx>,
    rt: &Arc<asyncrt::Runtime>,
    sem: &Arc<asyncrt::Semaphore>,
    arena: &Arc<BatchArena>,
    ticket: BatchTicket,
) -> Result<Batch> {
    let work = [ticket];
    fetch_async_fused_tasks(ctx, rt, sem, arena, &work, None)
        .pop()
        .expect("one batch in, one result out")
        .1
}

/// Fused asyncio fetch of a wave: `min(num_fetch_workers, items)`
/// looping futures (not one per item) each claim the next unfilled
/// slot, await its raw bytes on the event loop, and decode into the
/// slab. With a registry, other workers may claim tail items of the
/// same batches concurrently.
pub fn fetch_async_fused_tasks(
    ctx: &Arc<FetchCtx>,
    rt: &Arc<asyncrt::Runtime>,
    sem: &Arc<asyncrt::Semaphore>,
    arena: &Arc<BatchArena>,
    work: &[BatchTicket],
    registry: Option<&BatchInjector>,
) -> Vec<(usize, Result<Batch>)> {
    let entries = wave_entries(ctx, arena, work, registry);
    let tasks: Vec<Arc<ItemTask>> = entries.iter().map(|e| e.task.clone()).collect();
    let total: usize = tasks.iter().map(|t| t.len()).sum();
    let loops = sem.available().max(1).min(total.max(1));
    fill_wave_contained(&tasks, entries, registry, || {
        let handles: Vec<_> = (0..loops)
            .map(|_| {
                let ctx = ctx.clone();
                let tasks = tasks.clone();
                rt.spawn(async move {
                    for task in &tasks {
                        while let Some(claim) = ItemTask::claim(task) {
                            run_claim_async(&ctx, claim).await;
                        }
                    }
                })
            })
            .collect();
        // join_all completes only after every loop future finished — all
        // *locally* claimed slots are filled; wait_settled in settle_wave
        // covers slots claimed by thieves on other workers
        asyncrt::block_on(asyncrt::join_all(handles));
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_corpus, CorpusSpec};
    use crate::data::AugmentConfig;
    use crate::dataset::ImageFolderDataset;
    use crate::storage::{MemStore, ObjectStore, RemoteProfile, SimRemoteStore};
    use std::time::Instant;

    fn ctx_on(remote: bool, items: usize) -> Arc<FetchCtx> {
        let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        generate_corpus(&mem, &CorpusSpec::tiny(items)).unwrap();
        let store: Arc<dyn ObjectStore> = if remote {
            SimRemoteStore::new(mem, RemoteProfile::s3().scaled(0.25), 5)
        } else {
            mem
        };
        let ds = ImageFolderDataset::new(
            store,
            AugmentConfig { crop: 16, ..Default::default() },
        );
        Arc::new(FetchCtx {
            worker_id: 0,
            dataset: Arc::new(ds),
            gil: Gil::native(),
            recorder: Recorder::new(),
        })
    }

    fn indices(n: usize) -> Vec<usize> {
        (0..n).collect()
    }

    fn ticket(id: usize, idxs: Vec<usize>) -> BatchTicket {
        BatchTicket::solo(id, idxs)
    }

    fn arena_for(ctx: &FetchCtx, batch: usize) -> Arc<BatchArena> {
        BatchArena::new(ctx.dataset.crop(), batch, 4)
    }

    #[test]
    fn vanilla_order_and_spans() {
        let ctx = ctx_on(false, 6);
        let samples = fetch_vanilla(&ctx, 0, 0, &indices(6)).unwrap();
        assert_eq!(samples.iter().map(|s| s.index).collect::<Vec<_>>(), indices(6));
        assert_eq!(ctx.recorder.durations(names::GET_ITEM).len(), 6);
    }

    #[test]
    fn vanilla_epoch_tag_steers_augmentation() {
        // the per-call epoch must override the dataset's global state
        let ctx = ctx_on(false, 4);
        let e0 = fetch_vanilla(&ctx, 0, 0, &[1]).unwrap();
        let e1 = fetch_vanilla(&ctx, 1, 0, &[1]).unwrap();
        let e0b = fetch_vanilla(&ctx, 0, 0, &[1]).unwrap();
        assert_ne!(e0[0].crop.data, e1[0].crop.data);
        assert_eq!(e0[0].crop.data, e0b[0].crop.data);
    }

    #[test]
    fn threaded_restores_order() {
        let ctx = ctx_on(true, 8);
        let pool = ThreadPool::new(8, "t");
        let work = vec![ticket(0, indices(8))];
        let out = fetch_threaded(&ctx, &pool, &work).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(
            out[0].iter().map(|s| s.index).collect::<Vec<_>>(),
            indices(8)
        );
    }

    #[test]
    fn threaded_beats_vanilla_on_latency() {
        let ctx = ctx_on(true, 8);
        let t0 = Instant::now();
        fetch_vanilla(&ctx, 0, 0, &indices(8)).unwrap();
        let seq = t0.elapsed();

        let ctx2 = ctx_on(true, 8);
        let pool = ThreadPool::new(8, "t");
        let t0 = Instant::now();
        fetch_threaded(&ctx2, &pool, &[ticket(0, indices(8))]).unwrap();
        let par = t0.elapsed();
        assert!(
            par < seq / 2,
            "threaded {par:?} not ≪ vanilla {seq:?}"
        );
    }

    #[test]
    fn threaded_multi_batch_disassembly() {
        let ctx = ctx_on(false, 12);
        let pool = ThreadPool::new(4, "t");
        let work = vec![ticket(3, indices(6)), ticket(4, (6..12).collect())];
        let out = fetch_threaded(&ctx, &pool, &work).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[1].iter().map(|s| s.index).collect::<Vec<_>>(), (6..12).collect::<Vec<_>>());
    }

    #[test]
    fn async_restores_order_and_overlaps() {
        let ctx = ctx_on(true, 8);
        let rt = asyncrt::Runtime::new(1);
        let sem = asyncrt::Semaphore::new(16);
        let t0 = Instant::now();
        let out = fetch_async(&ctx, &rt, &sem, 0, 0, &indices(8)).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(out.iter().map(|s| s.index).collect::<Vec<_>>(), indices(8));
        // must be clearly faster than the 8-item sequential sum
        let sum: f64 = ctx.recorder.durations(names::GET_ITEM).iter().sum();
        assert!(wall < 0.7 * sum, "wall {wall} vs sum {sum}");
    }

    #[test]
    fn async_semaphore_bounds_concurrency() {
        let ctx = ctx_on(true, 6);
        let rt = asyncrt::Runtime::new(1);
        let sem = asyncrt::Semaphore::new(1); // degenerate: sequential
        let out = fetch_async(&ctx, &rt, &sem, 0, 0, &indices(4)).unwrap();
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(3, "p");
        let (tx, rx) = mpsc::channel();
        for i in 0..20 {
            let tx = tx.clone();
            pool.submit(Box::new(move || {
                tx.send(i).unwrap();
            }));
        }
        drop(tx);
        let mut got: Vec<usize> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn pool_submit_fails_over_past_a_dead_thread() {
        let pool = ThreadPool::new(2, "dead");
        pool.submit(Box::new(|| panic!("deliberate: kill this pool thread")));
        // Don't race the unwind on a fixed sleep: keep submitting small
        // rounds until 8 jobs have actually run. Once the dead queue is
        // marked, submit places everything on the live thread — and any
        // job that landed on the dying queue first is *taken over* by
        // the survivor, so every round completes in full.
        let deadline = Instant::now() + std::time::Duration::from_secs(10);
        let mut ran = 0usize;
        while ran < 8 {
            assert!(
                Instant::now() < deadline,
                "pool failover never engaged ({ran}/8 jobs ran)"
            );
            let (tx, rx) = mpsc::channel();
            for _ in 0..2 {
                let tx = tx.clone();
                pool.submit(Box::new(move || {
                    let _ = tx.send(());
                }));
            }
            drop(tx);
            ran += rx.iter().count();
        }
    }

    #[test]
    fn pool_idle_thread_takes_over_a_stuck_siblings_queue() {
        // the ROADMAP queue-takeover item: a job already queued behind a
        // fetch that turned dead-slow must complete as soon as any other
        // thread frees up — not wait the straggler out.
        let pool = ThreadPool::new(2, "tko");
        // occupy both threads with blocking jobs we control
        let (stuck_tx, stuck_rx) = mpsc::channel::<()>();
        let (brief_tx, brief_rx) = mpsc::channel::<()>();
        pool.submit(Box::new(move || {
            let _ = stuck_rx.recv(); // the dead-slow fetch
        }));
        pool.submit(Box::new(move || {
            let _ = brief_rx.recv(); // a normal fetch, released below
        }));
        // both threads now run a blocker (depth 1 each), so these two
        // probes land one per queue — one of them is necessarily queued
        // behind the stuck fetch
        let (done_tx, done_rx) = mpsc::channel::<()>();
        for _ in 0..2 {
            let done_tx = done_tx.clone();
            pool.submit(Box::new(move || {
                let _ = done_tx.send(());
            }));
        }
        drop(done_tx);
        // release only the brief job: its thread goes idle and must
        // drain BOTH probes — its own queue's and, via takeover, the one
        // parked behind the stuck fetch
        brief_tx.send(()).unwrap();
        for _ in 0..2 {
            assert!(
                done_rx
                    .recv_timeout(std::time::Duration::from_secs(10))
                    .is_ok(),
                "a probe queued behind the stuck fetch never ran while a \
                 sibling thread sat idle"
            );
        }
        stuck_tx.send(()).unwrap(); // unstick for clean drop
    }

    #[test]
    fn pool_spreads_jobs_across_idle_threads() {
        // 4 back-to-back jobs on a 4-thread pool land on 4 distinct
        // threads: each submit sees the previous queues still loaded
        // (depth decrements only after the 20 ms hold) and picks an
        // empty one
        let pool = ThreadPool::new(4, "ll");
        let (tx, rx) = mpsc::channel();
        for _ in 0..4 {
            let tx = tx.clone();
            pool.submit(Box::new(move || {
                tx.send(std::thread::current().name().unwrap_or("?").to_string())
                    .unwrap();
                std::thread::sleep(std::time::Duration::from_millis(20));
            }));
        }
        drop(tx);
        let mut names: Vec<String> = rx.iter().collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 4, "{names:?}");
    }

    #[test]
    fn pool_submit_avoids_a_busy_queue() {
        // occupy one thread with a long job, then trickle quick jobs:
        // none may land behind the sleeper (the old round-robin parked
        // every other job there)
        let pool = ThreadPool::new(2, "busy");
        let (stx, srx) = mpsc::channel();
        pool.submit(Box::new(move || {
            stx.send(std::thread::current().name().unwrap_or("?").to_string())
                .unwrap();
            std::thread::sleep(std::time::Duration::from_millis(80));
        }));
        let sleeper = srx.recv().unwrap();
        let (tx, rx) = mpsc::channel();
        for _ in 0..4 {
            let tx = tx.clone();
            pool.submit(Box::new(move || {
                tx.send(std::thread::current().name().unwrap_or("?").to_string())
                    .unwrap();
            }));
            // let the quick job drain so its queue reads depth 0 again
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        drop(tx);
        for name in rx.iter() {
            assert_ne!(name, sleeper, "job parked behind the busy thread");
        }
    }

    #[test]
    fn fused_vanilla_matches_legacy_bytes() {
        let ctx = ctx_on(false, 8);
        let arena = arena_for(&ctx, 8);
        let samples = fetch_vanilla(&ctx, 0, 0, &indices(8)).unwrap();
        let legacy = crate::dataloader::collate::collate(0, samples).unwrap();
        let fused = fetch_vanilla_fused(&ctx, &arena, &ticket(0, indices(8))).unwrap();
        assert_eq!(legacy.images, fused.images);
        assert_eq!(legacy.labels, fused.labels);
        assert_eq!(legacy.indices, fused.indices);
        assert_eq!(legacy.raw_bytes, fused.raw_bytes);
    }

    #[test]
    fn fused_threaded_fills_slots_in_request_order() {
        let ctx = ctx_on(true, 12);
        let pool = ThreadPool::new(6, "tf");
        let arena = arena_for(&ctx, 6);
        let work = vec![ticket(0, indices(6)), ticket(1, (6..12).collect())];
        let out = fetch_threaded_fused(&ctx, &pool, &arena, &work);
        assert_eq!(out.len(), 2);
        let b0 = out[0].1.as_ref().unwrap();
        let b1 = out[1].1.as_ref().unwrap();
        assert_eq!(b0.indices, indices(6));
        assert_eq!(b1.indices, (6..12).collect::<Vec<_>>());
        // equivalence with the legacy copy path
        let legacy = {
            let samples = fetch_vanilla(&ctx, 0, 0, &indices(6)).unwrap();
            crate::dataloader::collate::collate(0, samples).unwrap()
        };
        assert_eq!(legacy.images, b0.images);
        assert_eq!(legacy.labels, b0.labels);
    }

    #[test]
    fn fused_async_matches_legacy_bytes() {
        let ctx = ctx_on(true, 8);
        let rt = asyncrt::Runtime::new(1);
        let sem = asyncrt::Semaphore::new(16);
        let arena = arena_for(&ctx, 8);
        let fused =
            fetch_async_fused(&ctx, &rt, &sem, &arena, ticket(0, indices(8))).unwrap();
        let samples = fetch_vanilla(&ctx, 0, 0, &indices(8)).unwrap();
        let legacy = crate::dataloader::collate::collate(0, samples).unwrap();
        assert_eq!(legacy.images, fused.images);
        assert_eq!(legacy.labels, fused.labels);
        assert_eq!(legacy.indices, fused.indices);
    }

    #[test]
    fn fused_failure_recovers_slab() {
        // a corrupt object fails its batch but must not leak the slab
        let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        let (keys, _) = generate_corpus(&mem, &CorpusSpec::tiny(4)).unwrap();
        mem.put(&keys[2], vec![0xDE, 0xAD]).unwrap(); // not a SIMG
        let ds = ImageFolderDataset::new(
            mem,
            AugmentConfig { crop: 16, ..Default::default() },
        );
        let ctx = Arc::new(FetchCtx {
            worker_id: 0,
            dataset: Arc::new(ds),
            gil: Gil::native(),
            recorder: Recorder::new(),
        });
        let arena = arena_for(&ctx, 4);
        assert!(fetch_vanilla_fused(&ctx, &arena, &ticket(0, indices(4))).is_err());
        let s = arena.stats();
        assert_eq!(s.recycled, 1, "{s:?}");
        // the recovered slab serves the next (healthy) batch
        let ok = fetch_vanilla_fused(&ctx, &arena, &ticket(1, vec![0, 1, 3])).unwrap();
        assert_eq!(ok.len(), 3);
        assert_eq!(arena.stats().reused, 1);
    }

    #[test]
    fn fused_threaded_failure_fails_only_its_batch() {
        let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        let (keys, _) = generate_corpus(&mem, &CorpusSpec::tiny(8)).unwrap();
        mem.put(&keys[1], vec![9, 9]).unwrap(); // corrupt batch 0's item
        let ds = ImageFolderDataset::new(
            mem,
            AugmentConfig { crop: 16, ..Default::default() },
        );
        let ctx = Arc::new(FetchCtx {
            worker_id: 0,
            dataset: Arc::new(ds),
            gil: Gil::native(),
            recorder: Recorder::new(),
        });
        let pool = ThreadPool::new(4, "pf");
        let arena = arena_for(&ctx, 4);
        let work = vec![ticket(0, indices(4)), ticket(1, (4..8).collect())];
        let out = fetch_threaded_fused(&ctx, &pool, &arena, &work);
        assert!(out[0].1.is_err());
        let b1 = out[1].1.as_ref().unwrap();
        assert_eq!(b1.indices, (4..8).collect::<Vec<_>>());
        // failed batch's slab recovered, healthy one published
        assert_eq!(arena.stats().checkouts, 2);
        assert_eq!(arena.stats().recycled, 1);
    }
}
