//! The ConcurrentDataloader — the paper's contribution as a production
//! Rust component.
//!
//! Drop-in semantics follow `torch.utils.data.DataLoader` plus the two
//! extensions of the paper (§2.2):
//!
//! * `fetch_impl` ∈ {Vanilla, Threaded, Asyncio} selects the in-batch
//!   fetch strategy (`num_fetch_workers` bounds in-batch parallelism);
//! * `batch_pool` enables *batch disassembly* (Threaded only): a worker
//!   pulls several batches, fetches all their items in one parallel
//!   wave, reassembles, and emits them in order.
//!
//! Also modeled from the paper:
//! * `num_workers` worker processes (threads with per-worker GILs),
//!   round-robin batch assignment, bounded data queue of
//!   `num_workers × prefetch_factor` (backpressure);
//! * `start_method` fork/spawn start-up cost, and **lazy initialization**
//!   (§2.4 / Fig 8): workers are yielded as they are created instead of
//!   a blocking creation loop;
//! * `pin_memory` staging (disabled under `fork`, as in torch);
//! * in-order batch delivery (out-of-order arrivals are buffered).
//!
//! Beyond the paper, two hot-path extensions (PR 3):
//! * `arena_slabs` attaches a recycled [`arena::BatchArena`]: fetchers
//!   decode straight into pooled batch slabs (no decode buffer, no crop
//!   tensor, no collate copy) and the trainer recycles each batch after
//!   `to_device`, making steady-state epochs allocation-free;
//! * `work_stealing` replaces the static round-robin batch assignment
//!   with a shared injector queue ([`sampler::BatchInjector`]) that idle
//!   workers steal from, killing the straggler stall on high-latency
//!   storage (in-order delivery still holds via the reorder buffer).

pub mod arena;
pub mod collate;
pub mod fetch;
pub mod sampler;
pub mod worker;

pub use arena::{ArenaStats, BatchArena};
pub use collate::Batch;
pub use sampler::Sampler;

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Arc;
use std::time::Duration;

use crate::dataset::Dataset;
use crate::gil;
use crate::prefetch::CachePolicy;
use crate::telemetry::{names, Recorder};

/// In-batch fetch strategy (§2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FetchImpl {
    Vanilla,
    Threaded,
    Asyncio,
}

impl FetchImpl {
    pub fn label(&self) -> &'static str {
        match self {
            FetchImpl::Vanilla => "vanilla",
            FetchImpl::Threaded => "threaded",
            FetchImpl::Asyncio => "asyncio",
        }
    }

    pub fn all() -> [FetchImpl; 3] {
        [FetchImpl::Vanilla, FetchImpl::Asyncio, FetchImpl::Threaded]
    }
}

/// Worker process start method (§2.4 "Process creation").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StartMethod {
    /// child inherits the parent — cheap, but GPU calls (pin_memory)
    /// cannot be mixed in
    Fork,
    /// fresh interpreter — expensive start-up
    Spawn,
}

impl StartMethod {
    /// Simulated per-process creation cost.
    pub fn cost(&self) -> Duration {
        match self {
            StartMethod::Fork => Duration::from_millis(4),
            StartMethod::Spawn => Duration::from_millis(120),
        }
    }
}

/// Full loader configuration (torch parameters + the paper's additions).
#[derive(Debug, Clone)]
pub struct DataloaderConfig {
    pub batch_size: usize,
    pub num_workers: usize,
    pub prefetch_factor: usize,
    pub fetch_impl: FetchImpl,
    /// max parallel in-batch fetch tasks (threads or async tasks)
    pub num_fetch_workers: usize,
    /// batch disassembly pool in *items*; 0 disables (§2.2, Fig 4 right)
    pub batch_pool: usize,
    pub pin_memory: bool,
    pub start_method: StartMethod,
    /// lazy, non-blocking worker creation (§2.4, Fig 8 right)
    pub lazy_init: bool,
    /// CPython vs native concurrency semantics for the workers
    pub runtime: gil::Runtime,
    pub python_tax: f64,
    pub shuffle: bool,
    pub seed: u64,
    pub drop_last: bool,
    /// override the start-method cost (tests / sweeps)
    pub spawn_cost_override: Option<Duration>,
    /// sampler-ahead readahead window in items for the storage prefetch
    /// engine (`crate::prefetch`); 0 disables the engine. NOTE: the
    /// loader itself only *publishes* the sampler order each epoch —
    /// the store wrapping happens in whatever assembles the stack
    /// (`bench::rig::build` wraps in a `PrefetchStore` when this is
    /// non-zero; direct library users wrap their store themselves, as
    /// `examples/prefetch_s3.rs` shows).
    pub prefetch_depth: usize,
    /// hot-tier admission/eviction policy for the prefetch cache
    /// (applied by the stack assembler, like `prefetch_depth`)
    pub prefetch_policy: CachePolicy,
    /// recycled batch-slab pool size (0 disables the arena): with an
    /// arena attached, fetchers assemble batches in place (zero-alloc
    /// hot path) and the trainer returns slabs after `to_device`. Size
    /// it ≥ the in-flight batch count — normally `queue_capacity() +
    /// num_workers`, but a straggling batch holding up in-order delivery
    /// widens the window (bounded by `consumer_credit` when set; under
    /// plain `work_stealing` the other workers keep racing ahead); an
    /// undersized pool stays correct, checkouts just fall back to fresh
    /// allocations. With `pin_memory` under `spawn`, slabs are handed
    /// out page-locked, so batches are born pinned and skip the staging
    /// copy.
    pub arena_slabs: usize,
    /// dispatch batches through a shared work-stealing injector instead
    /// of the static per-worker round-robin split
    pub work_stealing: bool,
    /// steal at *item* granularity: a worker that cannot start a new
    /// batch claims unclaimed tail items of siblings' in-progress
    /// batches and decodes them into the owners' arena slabs. Requires
    /// `work_stealing` and `arena_slabs > 0` (ignored otherwise).
    pub steal_items: bool,
    /// max batches any worker may run ahead of in-order delivery; bounds
    /// the consumer's reorder buffer at O(credit) instead of O(epoch)
    /// behind a straggler. 0 = unbounded (legacy).
    pub consumer_credit: usize,
}

impl Default for DataloaderConfig {
    fn default() -> Self {
        DataloaderConfig {
            batch_size: 64,
            num_workers: 4,
            prefetch_factor: 2,
            fetch_impl: FetchImpl::Vanilla,
            num_fetch_workers: 16,
            batch_pool: 0,
            pin_memory: false,
            start_method: StartMethod::Fork,
            lazy_init: true,
            runtime: gil::Runtime::Python,
            python_tax: 4.0,
            shuffle: true,
            seed: 1234,
            drop_last: false,
            spawn_cost_override: None,
            prefetch_depth: 0,
            prefetch_policy: CachePolicy::Lru,
            arena_slabs: 0,
            work_stealing: false,
            steal_items: false,
            consumer_credit: 0,
        }
    }
}

impl DataloaderConfig {
    pub fn spawn_cost(&self) -> Duration {
        self.spawn_cost_override.unwrap_or_else(|| self.start_method.cost())
    }

    /// torch rule: pin_memory needs CUDA init which `fork` forbids.
    pub fn effective_pin_memory(&self) -> bool {
        self.pin_memory && self.start_method == StartMethod::Spawn
    }

    /// Data-queue capacity (backpressure bound, Table 4 row 2).
    pub fn queue_capacity(&self) -> usize {
        (self.num_workers.max(1)) * self.prefetch_factor.max(1)
    }
}

/// The dataloader: construct once, iterate per epoch.
pub struct Dataloader {
    dataset: Arc<dyn Dataset>,
    cfg: Arc<DataloaderConfig>,
    recorder: Arc<Recorder>,
    /// batch-slab pool, shared by every epoch's workers (`arena_slabs`)
    arena: Option<Arc<BatchArena>>,
}

impl Dataloader {
    pub fn new(
        dataset: Arc<dyn Dataset>,
        cfg: DataloaderConfig,
        recorder: Arc<Recorder>,
    ) -> Dataloader {
        if cfg.pin_memory && cfg.start_method == StartMethod::Fork {
            eprintln!(
                "warning: pin_memory=true with start_method=fork: pinning \
                 disabled (CUDA init cannot follow fork)"
            );
        }
        if cfg.steal_items && (!cfg.work_stealing || cfg.arena_slabs == 0) {
            eprintln!(
                "warning: steal_items=true needs work_stealing=true and \
                 arena_slabs > 0 (item claims live in the slab's claim \
                 bits); falling back to batch-level dispatch"
            );
        }
        let arena = if cfg.arena_slabs > 0 {
            // under effective pin_memory the arena hands out page-locked
            // slabs: batches are born pinned, to_device takes the
            // pinned-bandwidth path, and the staging copy disappears
            Some(BatchArena::new_opts(
                dataset.crop(),
                cfg.batch_size,
                cfg.arena_slabs,
                cfg.effective_pin_memory(),
            ))
        } else {
            None
        };
        Dataloader { dataset, cfg: Arc::new(cfg), recorder, arena }
    }

    pub fn config(&self) -> &DataloaderConfig {
        &self.cfg
    }

    pub fn recorder(&self) -> &Arc<Recorder> {
        &self.recorder
    }

    pub fn dataset(&self) -> &Arc<dyn Dataset> {
        &self.dataset
    }

    /// The batch arena, when `arena_slabs > 0` (pool stats live here).
    pub fn arena(&self) -> Option<&Arc<BatchArena>> {
        self.arena.as_ref()
    }

    /// Number of batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        let n = self.dataset.len();
        let b = self.cfg.batch_size;
        if self.cfg.drop_last {
            n / b
        } else {
            n.div_ceil(b)
        }
    }

    /// Begin an epoch: builds the batch plan, (lazily or eagerly) starts
    /// workers, and returns the batch iterator.
    pub fn epoch(&self, epoch: usize) -> EpochIter {
        self.dataset.set_epoch(epoch);
        let sampler = if self.cfg.shuffle {
            Sampler::Random { seed: self.cfg.seed }
        } else {
            Sampler::Sequential
        };
        let order = sampler.order(self.dataset.len(), epoch);
        // publish the epoch's access order so a prefetching store can
        // fetch ahead of demand (no-op for plain stores)
        self.dataset.hint_epoch_order(epoch, &order);
        let plan = sampler::batches(&order, self.cfg.batch_size, self.cfg.drop_last);
        let n_batches = plan.len();

        let (tx, rx) =
            std::sync::mpsc::sync_channel::<worker::WorkerMsg>(self.cfg.queue_capacity());

        // dispatch mode: shared injector (work stealing) or the torch
        // static round-robin split
        let (static_plan, injector) = if self.cfg.work_stealing && self.cfg.num_workers > 0
        {
            (None, Some(Arc::new(sampler::BatchInjector::new(plan))))
        } else {
            (Some(sampler::assign_round_robin(plan, self.cfg.num_workers)), None)
        };

        let mut iter = EpochIter {
            dataset: self.dataset.clone(),
            cfg: self.cfg.clone(),
            recorder: self.recorder.clone(),
            arena: self.arena.clone(),
            rx: Some(rx),
            tx: Some(tx),
            pending: HashMap::new(),
            next_id: 0,
            n_batches,
            plan: static_plan,
            injector_stats: injector.clone(),
            injector,
            gate: sampler::CreditGate::new(self.cfg.consumer_credit),
            reorder_hwm: 0,
            inline_plan: None,
            workers: Vec::new(),
            spawner: None,
            started: false,
        };

        if self.cfg.num_workers == 0 {
            // torch num_workers=0: load inline in the consumer
            let flat: Vec<(usize, Vec<usize>)> =
                iter.plan.take().unwrap().into_iter().flatten().collect();
            let mut flat = flat;
            flat.sort_by_key(|(id, _)| *id);
            iter.inline_plan = Some(flat.into_iter().collect());
            iter.started = true;
        } else if !self.cfg.lazy_init {
            // blocking creation loop (vanilla torch, Fig 8 left): pay all
            // start-up costs before the constructor returns
            iter.start_workers_blocking();
        }
        iter
    }
}

/// Iterator over one epoch's batches (in order).
pub struct EpochIter {
    dataset: Arc<dyn Dataset>,
    cfg: Arc<DataloaderConfig>,
    recorder: Arc<Recorder>,
    arena: Option<Arc<BatchArena>>,
    rx: Option<Receiver<worker::WorkerMsg>>,
    tx: Option<SyncSender<worker::WorkerMsg>>,
    /// reorder buffer: out-of-order arrivals, `None` = failure tombstone
    pending: HashMap<usize, Option<Batch>>,
    next_id: usize,
    n_batches: usize,
    plan: Option<Vec<Vec<(usize, Vec<usize>)>>>,
    injector: Option<Arc<sampler::BatchInjector>>,
    /// second handle on the injector, kept across `take_sources` so
    /// steal counters survive for reporting
    injector_stats: Option<Arc<sampler::BatchInjector>>,
    /// consumer-credit gate shared with the workers (`consumer_credit`)
    gate: Arc<sampler::CreditGate>,
    /// max reorder-buffer occupancy seen this epoch
    reorder_hwm: usize,
    inline_plan: Option<std::collections::VecDeque<(usize, Vec<usize>)>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    spawner: Option<std::thread::JoinHandle<Vec<std::thread::JoinHandle<()>>>>,
    started: bool,
}

impl EpochIter {
    pub fn n_batches(&self) -> usize {
        self.n_batches
    }

    /// Highest reorder-buffer occupancy observed so far this epoch.
    /// With `consumer_credit = K > 0` this never exceeds K (the workers
    /// cannot start batch `cursor + K` before the cursor advances).
    pub fn reorder_high_water(&self) -> usize {
        self.reorder_hwm
    }

    /// Items filled by non-owner workers so far this epoch (0 without
    /// `steal_items`/work-stealing dispatch).
    pub fn item_steals(&self) -> u64 {
        self.injector_stats
            .as_ref()
            .map_or(0, |inj| inj.item_steal_count())
    }

    /// One work source per worker: clones of the shared injector, or the
    /// pre-split static assignments.
    fn take_sources(&mut self) -> Vec<worker::WorkSource> {
        if let Some(inj) = self.injector.take() {
            (0..self.cfg.num_workers)
                .map(|_| worker::WorkSource::Stealing(inj.clone()))
                .collect()
        } else {
            self.plan
                .take()
                .expect("already started")
                .into_iter()
                .map(|assignments| worker::WorkSource::Static(assignments.into()))
                .collect()
        }
    }

    fn start_workers_blocking(&mut self) {
        let sources = self.take_sources();
        let tx = self.tx.take().expect("tx taken");
        let cost = self.cfg.spawn_cost();
        for (w, source) in sources.into_iter().enumerate() {
            // the creation loop itself blocks per process (Fig 8 left)
            std::thread::sleep(cost);
            self.workers.push(worker::spawn_worker(
                w as u32,
                self.dataset.clone(),
                self.recorder.clone(),
                self.cfg.clone(),
                source,
                self.arena.clone(),
                self.gate.clone(),
                tx.clone(),
                Duration::ZERO, // cost already paid in the loop
            ));
        }
        self.started = true;
    }

    fn start_workers_lazy(&mut self) {
        let sources = self.take_sources();
        let tx = self.tx.take().expect("tx taken");
        let cost = self.cfg.spawn_cost();
        let dataset = self.dataset.clone();
        let recorder = self.recorder.clone();
        let cfg = self.cfg.clone();
        let arena = self.arena.clone();
        let gate = self.gate.clone();
        // start_download(): yield each worker as it is created (Fig 8
        // right) — creation runs off the consumer's critical path
        self.spawner = Some(
            std::thread::Builder::new()
                .name("dl-spawner".into())
                .spawn(move || {
                    let mut handles = Vec::new();
                    for (w, source) in sources.into_iter().enumerate() {
                        std::thread::sleep(cost);
                        handles.push(worker::spawn_worker(
                            w as u32,
                            dataset.clone(),
                            recorder.clone(),
                            cfg.clone(),
                            source,
                            arena.clone(),
                            gate.clone(),
                            tx.clone(),
                            Duration::ZERO,
                        ));
                    }
                    handles
                })
                .expect("spawn dl-spawner"),
        );
        self.started = true;
    }

    fn next_inline(&mut self) -> Option<Batch> {
        let gil = gil::Gil::new(self.cfg.runtime, self.cfg.python_tax);
        let ctx = fetch::FetchCtx {
            worker_id: 0,
            dataset: self.dataset.clone(),
            gil: gil.clone(),
            recorder: self.recorder.clone(),
        };
        loop {
            let (batch_id, indices) = self.inline_plan.as_mut()?.pop_front()?;
            let t0 = self.recorder.now();
            let res = if let Some(arena) = &self.arena {
                // fused: assemble in the recycled slab, no copies
                fetch::fetch_vanilla_fused(&ctx, arena, batch_id, &indices)
            } else {
                fetch::fetch_vanilla(&ctx, batch_id, &indices)
                    .and_then(|samples| gil.cpu(|| collate::collate(batch_id, samples)))
            };
            match res {
                Ok(batch) => {
                    self.recorder.record(
                        names::BATCH_INFLIGHT,
                        0,
                        batch_id as i64,
                        t0,
                        self.recorder.now(),
                    );
                    return Some(batch);
                }
                Err(e) => {
                    // same per-batch error semantics as the worker path
                    eprintln!("inline loader batch {batch_id}: {e:#}");
                }
            }
        }
    }

    /// Apply the pin-memory staging cost and flag. Batches born in a
    /// pinned arena slab skip the staging copy entirely — they are
    /// already page-locked at the source.
    fn pin(&self, mut batch: Batch) -> Batch {
        if self.cfg.effective_pin_memory() {
            if batch.pinned {
                return batch;
            }
            let t0 = self.recorder.now();
            // page-locked copy at ~12 GB/s
            let secs = batch.tensor_bytes() as f64 / 12.0e9 + 50e-6;
            std::thread::sleep(Duration::from_secs_f64(secs));
            batch.pinned = true;
            self.recorder.record(
                names::PIN_MEMORY,
                0,
                batch.id as i64,
                t0,
                self.recorder.now(),
            );
        }
        batch
    }
}

impl Iterator for EpochIter {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.next_id >= self.n_batches {
            return None;
        }
        let t0 = self.recorder.now();

        if self.inline_plan.is_some() {
            let b = self.next_inline()?;
            self.recorder.record(names::GET_BATCH, 0, b.id as i64, t0, self.recorder.now());
            self.next_id += 1;
            return Some(self.pin(b));
        }

        if !self.started {
            // lazy init: first __next__ triggers start_download()
            self.start_workers_lazy();
        }
        // in-order delivery: drain until the expected id arrives
        loop {
            match self.pending.remove(&self.next_id) {
                Some(Some(b)) => {
                    self.next_id += 1;
                    // publish the new cursor: credit-blocked workers may
                    // now start the next batch of the window
                    self.gate.advance(self.next_id);
                    self.recorder.record(
                        names::GET_BATCH,
                        0,
                        b.id as i64,
                        t0,
                        self.recorder.now(),
                    );
                    return Some(self.pin(b));
                }
                Some(None) => {
                    // failure tombstone: the worker already logged it —
                    // advance past the gap and keep delivering
                    self.next_id += 1;
                    self.gate.advance(self.next_id);
                    continue;
                }
                None => {}
            }
            match self.rx.as_ref().expect("rx gone").recv() {
                Ok(worker::WorkerMsg::Batch(b)) => {
                    self.pending.insert(b.id, Some(b));
                    self.reorder_hwm = self.reorder_hwm.max(self.pending.len());
                }
                Ok(worker::WorkerMsg::Failed(id)) => {
                    self.pending.insert(id, None);
                    self.reorder_hwm = self.reorder_hwm.max(self.pending.len());
                }
                Err(_) => {
                    // all workers done & channel drained. Backstop for a
                    // gap with no tombstone (e.g. a worker died): skip
                    // to the next buffered id instead of silently
                    // truncating the epoch.
                    let Some(&next) = self.pending.keys().min() else {
                        return None;
                    };
                    self.next_id = next;
                    self.gate.advance(self.next_id);
                }
            }
        }
    }
}

impl Drop for EpochIter {
    fn drop(&mut self) {
        // open the credit gate first (workers parked on it must wake to
        // notice the dead channel), then drop our receiver
        self.gate.close();
        self.pending.clear();
        drop(self.rx.take());
        drop(self.tx.take());
        if let Some(sp) = self.spawner.take() {
            if let Ok(handles) = sp.join() {
                for h in handles {
                    let _ = h.join();
                }
            }
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_corpus, CorpusSpec};
    use crate::data::AugmentConfig;
    use crate::dataset::ImageFolderDataset;
    use crate::storage::{MemStore, ObjectStore, RemoteProfile, SimRemoteStore};
    use std::time::Instant;

    fn dataset(items: usize, remote: bool) -> Arc<dyn Dataset> {
        let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        generate_corpus(&mem, &CorpusSpec::tiny(items)).unwrap();
        let store: Arc<dyn ObjectStore> = if remote {
            SimRemoteStore::new(mem, RemoteProfile::s3().scaled(0.15), 5)
        } else {
            mem
        };
        Arc::new(ImageFolderDataset::new(
            store,
            AugmentConfig { crop: 16, ..Default::default() },
        ))
    }

    fn collect_epoch(dl: &Dataloader, epoch: usize) -> Vec<Batch> {
        dl.epoch(epoch).collect()
    }

    fn check_full_coverage(batches: &[Batch], n_items: usize) {
        let mut seen: Vec<usize> =
            batches.iter().flat_map(|b| b.indices.iter().copied()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..n_items).collect::<Vec<_>>());
    }

    #[test]
    fn epoch_covers_dataset_exactly_once_all_impls() {
        for impl_ in FetchImpl::all() {
            let dl = Dataloader::new(
                dataset(22, false),
                DataloaderConfig {
                    batch_size: 5,
                    num_workers: 3,
                    fetch_impl: impl_,
                    num_fetch_workers: 4,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            );
            let batches = collect_epoch(&dl, 0);
            assert_eq!(batches.len(), 5, "{impl_:?}");
            check_full_coverage(&batches, 22);
            // in-order ids
            let ids: Vec<usize> = batches.iter().map(|b| b.id).collect();
            assert_eq!(ids, vec![0, 1, 2, 3, 4], "{impl_:?}");
        }
    }

    #[test]
    fn work_stealing_epoch_covers_dataset_in_order_all_impls() {
        for impl_ in FetchImpl::all() {
            let dl = Dataloader::new(
                dataset(22, false),
                DataloaderConfig {
                    batch_size: 5,
                    num_workers: 3,
                    fetch_impl: impl_,
                    num_fetch_workers: 4,
                    work_stealing: true,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            );
            let batches = collect_epoch(&dl, 0);
            assert_eq!(batches.len(), 5, "{impl_:?}");
            check_full_coverage(&batches, 22);
            let ids: Vec<usize> = batches.iter().map(|b| b.id).collect();
            assert_eq!(ids, vec![0, 1, 2, 3, 4], "{impl_:?}");
        }
    }

    #[test]
    fn item_steal_epoch_covers_dataset_in_order_all_impls() {
        for impl_ in FetchImpl::all() {
            let dl = Dataloader::new(
                dataset(22, false),
                DataloaderConfig {
                    batch_size: 5,
                    num_workers: 3,
                    fetch_impl: impl_,
                    num_fetch_workers: 4,
                    work_stealing: true,
                    steal_items: true,
                    arena_slabs: 12,
                    consumer_credit: 3,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            );
            let mut it = dl.epoch(0);
            let mut batches = Vec::new();
            for b in it.by_ref() {
                batches.push(b);
            }
            let hwm = it.reorder_high_water();
            drop(it);
            assert_eq!(batches.len(), 5, "{impl_:?}");
            check_full_coverage(&batches, 22);
            let ids: Vec<usize> = batches.iter().map(|b| b.id).collect();
            assert_eq!(ids, vec![0, 1, 2, 3, 4], "{impl_:?}");
            assert!(batches.iter().all(|b| b.is_pooled()), "{impl_:?}");
            assert!(hwm <= 3, "{impl_:?}: reorder hwm {hwm} > credit 3");
        }
    }

    #[test]
    fn consumer_credit_bounds_reorder_buffer_in_every_dispatch_mode() {
        for (stealing, items) in [(false, false), (true, false), (true, true)] {
            let dl = Dataloader::new(
                dataset(30, true), // remote latency: real reordering
                DataloaderConfig {
                    batch_size: 3,
                    num_workers: 4,
                    fetch_impl: FetchImpl::Threaded,
                    num_fetch_workers: 4,
                    work_stealing: stealing,
                    steal_items: items,
                    arena_slabs: 10,
                    consumer_credit: 2,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            );
            let mut it = dl.epoch(0);
            let mut n = 0;
            for b in it.by_ref() {
                n += 1;
                b.recycle();
            }
            let hwm = it.reorder_high_water();
            assert_eq!(n, 10, "stealing={stealing} items={items}");
            assert!(
                hwm <= 2,
                "stealing={stealing} items={items}: hwm {hwm} > credit 2"
            );
        }
    }

    #[test]
    fn pinned_arena_batches_are_born_pinned() {
        let mk = |arena_slabs| {
            Dataloader::new(
                dataset(8, false),
                DataloaderConfig {
                    batch_size: 4,
                    num_workers: 2,
                    pin_memory: true,
                    start_method: StartMethod::Spawn,
                    arena_slabs,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            )
        };
        // arena path: slabs are page-locked, no staging copy recorded
        let dl = mk(6);
        assert!(dl.arena().unwrap().pinned());
        let batches = collect_epoch(&dl, 0);
        assert!(batches.iter().all(|b| b.pinned && b.is_pooled()));
        assert_eq!(dl.recorder().durations(names::PIN_MEMORY).len(), 0);
        // legacy path: heap batches still pay the staging copy
        let dl = mk(0);
        let batches = collect_epoch(&dl, 0);
        assert!(batches.iter().all(|b| b.pinned && !b.is_pooled()));
        assert_eq!(dl.recorder().durations(names::PIN_MEMORY).len(), 2);
    }

    #[test]
    fn arena_epochs_reuse_slabs_across_epochs() {
        let dl = Dataloader::new(
            dataset(24, false),
            DataloaderConfig {
                batch_size: 4,
                num_workers: 2,
                arena_slabs: 16,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            Recorder::new(),
        );
        for epoch in 0..3 {
            let batches = collect_epoch(&dl, epoch);
            assert_eq!(batches.len(), 6);
            check_full_coverage(&batches, 24);
            assert!(batches.iter().all(|b| b.is_pooled()));
            // consumer side of the lifecycle: recycle after use
            for b in batches {
                b.recycle();
            }
        }
        let s = dl.arena().unwrap().stats();
        assert_eq!(s.checkouts, 18, "{s:?}");
        assert_eq!(s.recycled, 18, "{s:?}");
        // steady state: only the first epoch's in-flight window ever
        // allocated fresh slabs
        assert!(s.fresh <= 8, "{s:?}");
        assert!(s.reused >= 10, "{s:?}");
    }

    #[test]
    fn arena_with_work_stealing_and_shuffle_is_equivalent_to_legacy() {
        let mk = |arena: usize, stealing: bool| {
            Dataloader::new(
                dataset(19, false),
                DataloaderConfig {
                    batch_size: 4,
                    num_workers: 3,
                    fetch_impl: FetchImpl::Threaded,
                    num_fetch_workers: 4,
                    arena_slabs: arena,
                    work_stealing: stealing,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            )
        };
        let legacy: Vec<Batch> = collect_epoch(&mk(0, false), 1);
        let fused: Vec<Batch> = collect_epoch(&mk(12, true), 1);
        assert_eq!(legacy.len(), fused.len());
        for (a, b) in legacy.iter().zip(fused.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.images, b.images);
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.indices, b.indices);
            assert_eq!(a.raw_bytes, b.raw_bytes);
        }
    }

    #[test]
    fn failed_batch_skips_not_truncates_the_epoch() {
        use crate::data::synth::generate_corpus as gen;
        // corrupt one object: its batch fails in the worker, every other
        // batch must still be delivered, in order
        let mem: Arc<dyn crate::storage::ObjectStore> = Arc::new(MemStore::new("m"));
        let (keys, _) = gen(&mem, &CorpusSpec::tiny(12)).unwrap();
        mem.put(&keys[2], vec![7, 7, 7]).unwrap(); // not a SIMG
        let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
            mem,
            AugmentConfig { crop: 16, ..Default::default() },
        ));
        for (workers, stealing) in [(2usize, false), (3, true), (0, false)] {
            let dl = Dataloader::new(
                ds.clone(),
                DataloaderConfig {
                    batch_size: 4,
                    num_workers: workers,
                    shuffle: false, // item 2 lands in batch 0
                    work_stealing: stealing,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            );
            let batches = collect_epoch(&dl, 0);
            let ids: Vec<usize> = batches.iter().map(|b| b.id).collect();
            assert_eq!(ids, vec![1, 2], "workers={workers} stealing={stealing}");
        }
    }

    #[test]
    fn num_workers_zero_inline() {
        let dl = Dataloader::new(
            dataset(10, false),
            DataloaderConfig {
                batch_size: 4,
                num_workers: 0,
                ..Default::default()
            },
            Recorder::new(),
        );
        let batches = collect_epoch(&dl, 0);
        assert_eq!(batches.len(), 3);
        check_full_coverage(&batches, 10);
    }

    #[test]
    fn drop_last_drops_partial() {
        let dl = Dataloader::new(
            dataset(10, false),
            DataloaderConfig {
                batch_size: 4,
                drop_last: true,
                num_workers: 2,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            Recorder::new(),
        );
        assert_eq!(dl.batches_per_epoch(), 2);
        let batches = collect_epoch(&dl, 0);
        assert_eq!(batches.len(), 2);
        assert!(batches.iter().all(|b| b.len() == 4));
    }

    #[test]
    fn shuffle_changes_across_epochs_deterministically() {
        let dl = Dataloader::new(
            dataset(16, false),
            DataloaderConfig {
                batch_size: 4,
                num_workers: 2,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            Recorder::new(),
        );
        let e0: Vec<usize> = collect_epoch(&dl, 0).iter().flat_map(|b| b.indices.clone()).collect();
        let e0b: Vec<usize> = collect_epoch(&dl, 0).iter().flat_map(|b| b.indices.clone()).collect();
        let e1: Vec<usize> = collect_epoch(&dl, 1).iter().flat_map(|b| b.indices.clone()).collect();
        assert_eq!(e0, e0b);
        assert_ne!(e0, e1);
    }

    #[test]
    fn lazy_init_returns_first_batch_sooner() {
        let slow_spawn = Duration::from_millis(60);
        let mk = |lazy| {
            Dataloader::new(
                dataset(8, false),
                DataloaderConfig {
                    batch_size: 2,
                    num_workers: 4,
                    lazy_init: lazy,
                    spawn_cost_override: Some(slow_spawn),
                    ..Default::default()
                },
                Recorder::new(),
            )
        };
        let dl = mk(false);
        let t0 = Instant::now();
        let mut it = dl.epoch(0);
        let _b = it.next().unwrap();
        let blocking_first = t0.elapsed();
        drop(it);

        let dl = mk(true);
        let t0 = Instant::now();
        let mut it = dl.epoch(0);
        let _b = it.next().unwrap();
        let lazy_first = t0.elapsed();
        drop(it);

        // blocking pays 4×60ms before the first fetch; lazy pays ~1×60ms
        assert!(
            lazy_first < blocking_first,
            "lazy {lazy_first:?} !< blocking {blocking_first:?}"
        );
    }

    #[test]
    fn pin_memory_requires_spawn() {
        let cfg = DataloaderConfig {
            pin_memory: true,
            start_method: StartMethod::Fork,
            ..Default::default()
        };
        assert!(!cfg.effective_pin_memory());
        let cfg = DataloaderConfig {
            pin_memory: true,
            start_method: StartMethod::Spawn,
            ..Default::default()
        };
        assert!(cfg.effective_pin_memory());
    }

    #[test]
    fn early_drop_does_not_hang() {
        let dl = Dataloader::new(
            dataset(32, false),
            DataloaderConfig {
                batch_size: 2,
                num_workers: 4,
                prefetch_factor: 1,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            Recorder::new(),
        );
        let mut it = dl.epoch(0);
        let _ = it.next().unwrap();
        drop(it); // workers blocked on a full queue must unblock and exit
    }

    #[test]
    fn threaded_epoch_faster_than_vanilla_on_remote() {
        let mk = |impl_| {
            Dataloader::new(
                dataset(24, true),
                DataloaderConfig {
                    batch_size: 8,
                    num_workers: 2,
                    fetch_impl: impl_,
                    num_fetch_workers: 8,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            )
        };
        let t0 = Instant::now();
        let v = collect_epoch(&mk(FetchImpl::Vanilla), 0);
        let vanilla = t0.elapsed();
        let t0 = Instant::now();
        let t = collect_epoch(&mk(FetchImpl::Threaded), 0);
        let threaded = t0.elapsed();
        assert_eq!(v.len(), t.len());
        assert!(
            threaded.as_secs_f64() < 0.55 * vanilla.as_secs_f64(),
            "threaded {threaded:?} not ≪ vanilla {vanilla:?}"
        );
    }

    #[test]
    fn spans_recorded() {
        let rec = Recorder::new();
        let dl = Dataloader::new(
            dataset(8, false),
            DataloaderConfig {
                batch_size: 4,
                num_workers: 1,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            rec.clone(),
        );
        let _ = collect_epoch(&dl, 0);
        assert_eq!(rec.durations(names::GET_ITEM).len(), 8);
        assert_eq!(rec.durations(names::GET_BATCH).len(), 2);
        assert_eq!(rec.durations(names::BATCH_INFLIGHT).len(), 2);
    }
}
