//! The ConcurrentDataloader — the paper's contribution as a production
//! Rust component.
//!
//! Drop-in semantics follow `torch.utils.data.DataLoader` plus the two
//! extensions of the paper (§2.2):
//!
//! * `fetch_impl` ∈ {Vanilla, Threaded, Asyncio} selects the in-batch
//!   fetch strategy (`num_fetch_workers` bounds in-batch parallelism);
//! * `batch_pool` enables *batch disassembly* (Threaded only): a worker
//!   pulls several batches, fetches all their items in one parallel
//!   wave, reassembles, and emits them in order.
//!
//! Also modeled from the paper:
//! * `num_workers` worker processes (threads with per-worker GILs),
//!   round-robin batch assignment, bounded data queue of
//!   `num_workers × prefetch_factor` (backpressure);
//! * `start_method` fork/spawn start-up cost, and **lazy initialization**
//!   (§2.4 / Fig 8): workers are yielded as they are created instead of
//!   a blocking creation loop;
//! * `pin_memory` staging (disabled under `fork`, as in torch);
//! * in-order batch delivery (out-of-order arrivals are buffered).
//!
//! Beyond the paper, the hot-path extensions:
//! * `arena_slabs` (PR 3) attaches a recycled [`arena::BatchArena`]:
//!   fetchers decode straight into pooled batch slabs and the trainer
//!   recycles each batch after `to_device` (zero-alloc steady state);
//! * `work_stealing` / `steal_items` / `consumer_credit` (PR 4) tame
//!   the dispatch tail: shared injector, item-granular stealing inside
//!   straggling batches, and a credit-bounded reorder buffer.
//!
//! ## Cross-epoch pipelining (PR 5)
//!
//! Workers are **persistent**: spawned once per `Dataloader` (on the
//! first epoch), they serve every subsequent epoch without re-paying
//! the start-method cost or re-building channels. Dispatch runs on a
//! continuous, generation-tagged stream of
//! [`sampler::BatchTicket`]s — `(seq, epoch, id)` — so the
//! [`sampler::CreditGate`], the consumer's reorder buffer, and the
//! arena checkout all roll straight across epoch seams. With
//! `epoch_pipeline = k > 0`, a worker that runs out of epoch N's
//! batches asks the [`Planner`] for more, which publishes epoch N+1's
//! plan on the spot (up to k epochs ahead of the consumer) and fires
//! `hint_epoch_order_next` so the prefetch engine's readahead horizon
//! is primed before the boundary; `epoch_pipeline = 0` keeps the legacy
//! drain (the next plan is only published when the consumer asks for
//! the next epoch). Pipelined and drained runs are byte-identical: the
//! augmentation epoch travels with every item load
//! (`Dataset::get_item_into_at` and friends), not through global
//! `set_epoch` state.

pub mod arena;
pub mod collate;
pub mod fetch;
pub mod sampler;
pub mod worker;

pub use arena::{ArenaStats, BatchArena};
pub use collate::Batch;
pub use sampler::{BatchTicket, Sampler};

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::dataset::Dataset;
use crate::gil;
use crate::governor::TunedKnobs;
use crate::prefetch::CachePolicy;
use crate::telemetry::{names, Recorder};

use self::sampler::{BatchInjector, CreditGate};
use self::worker::{StaticQueue, WorkSource, WorkerMsg};

/// In-batch fetch strategy (§2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FetchImpl {
    Vanilla,
    Threaded,
    Asyncio,
}

impl FetchImpl {
    pub fn label(&self) -> &'static str {
        match self {
            FetchImpl::Vanilla => "vanilla",
            FetchImpl::Threaded => "threaded",
            FetchImpl::Asyncio => "asyncio",
        }
    }

    pub fn all() -> [FetchImpl; 3] {
        [FetchImpl::Vanilla, FetchImpl::Asyncio, FetchImpl::Threaded]
    }
}

/// Worker process start method (§2.4 "Process creation").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StartMethod {
    /// child inherits the parent — cheap, but GPU calls (pin_memory)
    /// cannot be mixed in
    Fork,
    /// fresh interpreter — expensive start-up
    Spawn,
}

impl StartMethod {
    /// Simulated per-process creation cost.
    pub fn cost(&self) -> Duration {
        match self {
            StartMethod::Fork => Duration::from_millis(4),
            StartMethod::Spawn => Duration::from_millis(120),
        }
    }
}

/// Full loader configuration (torch parameters + the paper's additions).
#[derive(Debug, Clone)]
pub struct DataloaderConfig {
    pub batch_size: usize,
    pub num_workers: usize,
    pub prefetch_factor: usize,
    pub fetch_impl: FetchImpl,
    /// max parallel in-batch fetch tasks (threads or async tasks)
    pub num_fetch_workers: usize,
    /// batch disassembly pool in *items*; 0 disables (§2.2, Fig 4 right)
    pub batch_pool: usize,
    pub pin_memory: bool,
    pub start_method: StartMethod,
    /// lazy, non-blocking worker creation (§2.4, Fig 8 right)
    pub lazy_init: bool,
    /// CPython vs native concurrency semantics for the workers
    pub runtime: gil::Runtime,
    pub python_tax: f64,
    pub shuffle: bool,
    pub seed: u64,
    pub drop_last: bool,
    /// override the start-method cost (tests / sweeps)
    pub spawn_cost_override: Option<Duration>,
    /// sampler-ahead readahead window in items for the storage prefetch
    /// engine (`crate::prefetch`); 0 disables the engine. NOTE: the
    /// loader itself only *publishes* the sampler order each epoch —
    /// the store wrapping happens in whatever assembles the stack
    /// (`bench::rig::build` wraps in a `PrefetchStore` when this is
    /// non-zero; direct library users wrap their store themselves, as
    /// `examples/prefetch_s3.rs` shows).
    pub prefetch_depth: usize,
    /// hot-tier admission/eviction policy for the prefetch cache
    /// (applied by the stack assembler, like `prefetch_depth`)
    pub prefetch_policy: CachePolicy,
    /// recycled batch-slab pool size (0 disables the arena): with an
    /// arena attached, fetchers assemble batches in place (zero-alloc
    /// hot path) and the trainer returns slabs after `to_device`. Size
    /// it ≥ the in-flight batch count — normally `queue_capacity() +
    /// num_workers`, but a straggling batch holding up in-order delivery
    /// widens the window (bounded by `consumer_credit` when set; under
    /// plain `work_stealing` the other workers keep racing ahead); an
    /// undersized pool stays correct, checkouts just fall back to fresh
    /// allocations. With `pin_memory` under `spawn`, slabs are handed
    /// out page-locked, so batches are born pinned and skip the staging
    /// copy.
    pub arena_slabs: usize,
    /// dispatch batches through a shared work-stealing injector instead
    /// of the static per-worker round-robin split
    pub work_stealing: bool,
    /// steal at *item* granularity: a worker that cannot start a new
    /// batch claims unclaimed tail items of siblings' in-progress
    /// batches and decodes them into the owners' arena slabs. Requires
    /// `work_stealing` and `arena_slabs > 0` (ignored otherwise).
    pub steal_items: bool,
    /// max batches any worker may run ahead of in-order delivery; bounds
    /// the consumer's reorder buffer at O(credit) instead of O(epoch)
    /// behind a straggler. 0 = unbounded (legacy). The window is in
    /// global seqs, so with `epoch_pipeline` it rolls across seams.
    pub consumer_credit: usize,
    /// cross-epoch pipelining depth: how many epochs' plans may be
    /// published ahead of the one the consumer is on. With k > 0, a
    /// worker that drains epoch N's tickets publishes epoch N+1's plan
    /// (sampler order + prefetch hint + tickets) and starts its batches
    /// — subject to `consumer_credit` — while N's tail is still
    /// delivering, so the fetch pipeline never goes cold at the
    /// boundary. 0 = legacy drain (the next plan is published only when
    /// `epoch()` is called). Pipelining predicts sequential epoch
    /// numbers; requesting a different epoch tears the pre-published
    /// plan down and rebuilds (correct, just not pipelined).
    pub epoch_pipeline: usize,
    /// in-flight read budget of the batched-submission I/O ring. With
    /// k > 0 (and a dataset whose items are plain ranged reads —
    /// [`Dataset::raw_desc`]), the threaded/asyncio fused fetchers
    /// submit a whole wave's item reads as **one batch** to a shared
    /// [`crate::storage::IoRing`] and reap completions out of order, so
    /// a single worker thread keeps up to k reads in flight instead of
    /// one per fetch thread. 0 = legacy per-item fetch paths.
    pub io_depth: usize,
}

impl Default for DataloaderConfig {
    fn default() -> Self {
        DataloaderConfig {
            batch_size: 64,
            num_workers: 4,
            prefetch_factor: 2,
            fetch_impl: FetchImpl::Vanilla,
            num_fetch_workers: 16,
            batch_pool: 0,
            pin_memory: false,
            start_method: StartMethod::Fork,
            lazy_init: true,
            runtime: gil::Runtime::Python,
            python_tax: 4.0,
            shuffle: true,
            seed: 1234,
            drop_last: false,
            spawn_cost_override: None,
            prefetch_depth: 0,
            prefetch_policy: CachePolicy::Lru,
            arena_slabs: 0,
            work_stealing: false,
            steal_items: false,
            consumer_credit: 0,
            epoch_pipeline: 0,
            io_depth: 0,
        }
    }
}

impl DataloaderConfig {
    pub fn spawn_cost(&self) -> Duration {
        self.spawn_cost_override.unwrap_or_else(|| self.start_method.cost())
    }

    /// torch rule: pin_memory needs CUDA init which `fork` forbids.
    pub fn effective_pin_memory(&self) -> bool {
        self.pin_memory && self.start_method == StartMethod::Spawn
    }

    /// Data-queue capacity (backpressure bound, Table 4 row 2).
    pub fn queue_capacity(&self) -> usize {
        (self.num_workers.max(1)) * self.prefetch_factor.max(1)
    }
}

// ---------------------------------------------------------------------------
// Epoch-plan publication (the planner)
// ---------------------------------------------------------------------------

/// Sampler selection + order + batch chunking for one epoch — the one
/// place the shuffle/seed/drop_last policy lives, shared by the
/// planner (worker mode) and the inline `num_workers = 0` loader.
///
/// A dataset that needs a storage-aware visit order (the shard dataset's
/// two-level shuffle, which keeps samples of one shard window together)
/// supplies it through [`Dataset::epoch_order`]; otherwise the generic
/// sampler decides.
fn epoch_plan(
    cfg: &DataloaderConfig,
    dataset: &Arc<dyn Dataset>,
    epoch: usize,
) -> (Vec<usize>, Vec<Vec<usize>>) {
    let order = dataset.epoch_order(epoch).unwrap_or_else(|| {
        let sampler = if cfg.shuffle {
            Sampler::Random { seed: cfg.seed }
        } else {
            Sampler::Sequential
        };
        sampler.order(dataset.len(), epoch)
    });
    let plan = sampler::batches(&order, cfg.batch_size, cfg.drop_last);
    (order, plan)
}

/// Where published tickets land: the shared work-stealing injector, or
/// the per-worker static deques (torch round-robin *within* each epoch:
/// batch `id` goes to worker `id % w`).
pub(crate) enum PlanSink {
    Injector(Arc<BatchInjector>),
    Static(Vec<StaticQueue>),
}

impl PlanSink {
    fn publish(&self, tickets: Vec<BatchTicket>) {
        match self {
            PlanSink::Injector(inj) => inj.publish(tickets),
            PlanSink::Static(queues) => {
                let w = queues.len().max(1);
                for t in tickets {
                    queues[t.id % w].lock().unwrap().push_back(t);
                }
            }
        }
    }

    /// Withdraw every unclaimed ticket with `seq >= min_seq` (plan
    /// revocation); returns how many came back.
    fn revoke(&self, min_seq: usize) -> usize {
        match self {
            PlanSink::Injector(inj) => inj.revoke(min_seq),
            PlanSink::Static(queues) => {
                let mut dropped = 0;
                for q in queues {
                    let mut q = q.lock().unwrap();
                    let before = q.len();
                    q.retain(|t| t.seq < min_seq);
                    dropped += before - q.len();
                }
                dropped
            }
        }
    }
}

/// One published epoch plan: its epoch number and seq range.
#[derive(Debug, Clone, Copy)]
pub(crate) struct PlanMeta {
    pub epoch: usize,
    /// first seq of the plan (batches span `base .. base + n`)
    pub base: usize,
    pub n: usize,
}

struct PlanState {
    /// published plans, in publication (= seq) order
    plans: Vec<PlanMeta>,
    /// plans the consumer has attached an [`EpochIter`] to
    attached: usize,
    /// next global seq to assign — monotonic for the generation's
    /// lifetime, never rolled back by a revocation (revoked seq ranges
    /// stay burned; the consumer fast-forwards over the gap)
    next_seq: usize,
    /// the epoch the consumer is waiting on after a revocation, so
    /// pipelining workers publish *it* next instead of re-predicting
    /// the sequence that was just revoked
    pending_request: Option<usize>,
    /// a revocation invalidated the prefetch engine's readahead
    /// horizon: the next publication re-seeds it with a fresh
    /// `hint_epoch_order` instead of extending the stale one
    fresh_hint: bool,
    shutdown: bool,
}

/// Publishes epoch plans onto the continuous ticket stream — shared by
/// the consumer (`Dataloader::epoch` attaches through it) and the
/// persistent workers (a worker that drains the stream publishes the
/// next epoch itself when `epoch_pipeline` allows, instead of idling
/// at the seam).
pub(crate) struct Planner {
    dataset: Arc<dyn Dataset>,
    cfg: Arc<DataloaderConfig>,
    sink: PlanSink,
    /// whether pipelining is allowed at all: gated off for datasets
    /// that do not honor epoch-tagged loads (pipelining two epochs'
    /// items through global `set_epoch` state would mis-seed the
    /// pipelined head's augmentation). The *depth* itself is read live
    /// from the tuned knobs on every decision, so the Governor can
    /// raise/lower it at epoch seams.
    pipeline_ok: bool,
    /// live tunable knob values (epoch-seam committed)
    knobs: Arc<TunedKnobs>,
    /// mispredicted speculative plans unpublished instead of torn down
    plans_revoked: AtomicU64,
    state: Mutex<PlanState>,
    cv: Condvar,
    /// cumulative time workers spent parked waiting for a plan (ns) —
    /// the "idle at the seam" gauge the epoch-boundary table reports
    seam_idle_ns: AtomicU64,
    /// the same idle, attributed per worker id (who pays the seam?)
    seam_idle_by_worker: Vec<AtomicU64>,
    /// plan computation/publication shows up as `plan_publish` spans on
    /// the planner track of the Chrome trace
    recorder: Arc<Recorder>,
}

impl Planner {
    fn new(
        dataset: Arc<dyn Dataset>,
        cfg: Arc<DataloaderConfig>,
        sink: PlanSink,
        knobs: Arc<TunedKnobs>,
        recorder: Arc<Recorder>,
    ) -> Planner {
        let pipeline_ok = dataset.supports_epoch_tagged();
        let workers = cfg.num_workers.max(1);
        Planner {
            dataset,
            cfg,
            sink,
            pipeline_ok,
            knobs,
            plans_revoked: AtomicU64::new(0),
            state: Mutex::new(PlanState {
                plans: Vec::new(),
                attached: 0,
                next_seq: 0,
                pending_request: None,
                fresh_hint: false,
                shutdown: false,
            }),
            cv: Condvar::new(),
            seam_idle_ns: AtomicU64::new(0),
            seam_idle_by_worker: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            recorder,
        }
    }

    /// Compute, hint, and publish one epoch's plan. The caller hands
    /// its held state guard in; the epoch permutation — the O(dataset)
    /// shuffle + ticket chunking — is built with the lock **released**,
    /// then swapped in under a re-taken lock, so workers checking for
    /// tickets and consumers attaching never stall behind the shuffle.
    /// Publication is revalidated against the state observed at entry:
    /// if another thread published (or the pipeline shut down) while
    /// the lock was free, the computed plan is discarded and `None`
    /// comes back — the caller re-reads the returned guard and decides
    /// again. The prefetch hint fires at publication, which under
    /// pipelining is before the previous epoch finished, so the
    /// engine's horizon is primed before the boundary.
    fn publish_swap<'a>(
        &'a self,
        st: std::sync::MutexGuard<'a, PlanState>,
        epoch: usize,
    ) -> (std::sync::MutexGuard<'a, PlanState>, Option<PlanMeta>) {
        let expect_len = st.plans.len();
        drop(st);
        let t0 = self.recorder.now();
        let (order, plan) = epoch_plan(&self.cfg, &self.dataset, epoch);
        let mut st = self.state.lock().unwrap();
        if st.shutdown || st.plans.len() != expect_len {
            // lost the publication race: the stream moved while the
            // permutation was being built
            return (st, None);
        }
        if st.plans.is_empty() || st.fresh_hint {
            // first plan of this pipeline generation — or the first
            // after a revocation polluted the horizon: fresh start
            self.dataset.hint_epoch_order(epoch, &order);
            st.fresh_hint = false;
        } else {
            // extend the horizon — the engine keeps finishing the
            // current epoch's readahead and rolls into this one
            self.dataset.hint_epoch_order_next(epoch, &order);
        }
        if st.pending_request == Some(epoch) {
            st.pending_request = None;
        }
        let meta = PlanMeta { epoch, base: st.next_seq, n: plan.len() };
        st.next_seq += plan.len();
        st.plans.push(meta);
        self.sink.publish(BatchTicket::plan(epoch, meta.base, plan));
        self.cv.notify_all();
        self.recorder.record_tagged(
            names::PLAN_PUBLISH,
            crate::telemetry::PLANNER_WORKER,
            -1,
            epoch as i64,
            meta.base as i64,
            t0,
            self.recorder.now(),
        );
        (st, Some(meta))
    }

    /// Consumer side: attach an [`EpochIter`] for `epoch`. Returns the
    /// plan to consume, or `None` only when the pipeline is shut down —
    /// a pre-published plan that predicted a *different* epoch is
    /// revoked in place (its unclaimed tickets withdrawn, its seq range
    /// burned) and the requested epoch published instead, so a
    /// non-sequential `epoch()` request no longer costs a full worker
    /// teardown + respawn.
    fn attach(&self, epoch: usize) -> Option<PlanMeta> {
        let mut st = self.state.lock().unwrap();
        let meta = loop {
            if st.shutdown {
                return None;
            }
            if st.attached < st.plans.len() {
                // a worker pre-published this plan while the previous
                // epoch drained; usually it predicted right
                let meta = st.plans[st.attached];
                if meta.epoch != epoch {
                    // misprediction: unpublish every unattached plan and
                    // ask the pipelining workers for `epoch` instead
                    st.pending_request = Some(epoch);
                    self.revoke_unattached(&mut st);
                    continue;
                }
                break meta;
            }
            let (guard, published) = self.publish_swap(st, epoch);
            st = guard;
            if let Some(meta) = published {
                break meta;
            }
            // lost the race to a pipelining worker: re-read and retry
        };
        st.attached += 1;
        drop(st);
        // wake drained workers: the publication budget moved
        self.cv.notify_all();
        Some(meta)
    }

    /// Unpublish every plan the consumer has not attached: withdraw
    /// their unclaimed tickets from the sink and forget their metas.
    /// Tickets a worker already claimed run to completion and are
    /// discarded by the consumer as stale seqs (the revoked seq range
    /// is never reassigned). Called with the state lock held.
    fn revoke_unattached(&self, st: &mut PlanState) {
        let keep = st.attached;
        if st.plans.len() <= keep {
            return;
        }
        let revoke_base = st.plans[keep].base;
        let t0 = self.recorder.now();
        let dropped = self.sink.revoke(revoke_base);
        let revoked = st.plans.len() - keep;
        st.plans.truncate(keep);
        st.fresh_hint = true;
        self.plans_revoked.fetch_add(revoked as u64, Ordering::Relaxed);
        self.recorder.record_tagged(
            names::PLAN_REVOKE,
            crate::telemetry::PLANNER_WORKER,
            dropped as i64,
            -1,
            revoke_base as i64,
            t0,
            self.recorder.now(),
        );
    }

    /// Live cross-epoch pipelining depth (0 when the dataset cannot
    /// pipeline, else the seam-committed knob value).
    fn pipeline_depth(&self) -> usize {
        if self.pipeline_ok {
            self.knobs.epoch_pipeline()
        } else {
            0
        }
    }

    /// The loader's live tunable knobs (workers read per-acquisition
    /// toggles — steal/parallelism — through this).
    pub(crate) fn knobs(&self) -> &Arc<TunedKnobs> {
        &self.knobs
    }

    /// Worker side: called when the published stream ran dry. Publishes
    /// the predicted next epoch when `epoch_pipeline` allows, else
    /// parks. Returns false on shutdown (the worker exits); with a
    /// `park` timeout it returns true on expiry too, so item-stealing
    /// workers can re-poll their registries. `seen` tracks how many
    /// publications this worker has observed, so it parks instead of
    /// spinning on a stream it already drained. `worker` attributes any
    /// park time to that worker's seam-idle lane.
    pub(crate) fn wait_for_work(
        &self,
        worker: u32,
        seen: &mut usize,
        park: Option<Duration>,
    ) -> bool {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.shutdown {
                return false;
            }
            let depth = self.pipeline_depth();
            if (depth > 0 || st.pending_request.is_some())
                && !st.plans.is_empty()
                && st.plans.len() < st.attached + depth.max(1)
            {
                // publish ahead: the consumer's explicit post-revocation
                // request if one is pending, else the predicted next
                // sequential epoch — this worker (and its siblings) can
                // start on it immediately, subject to the credit gate
                let next = st
                    .pending_request
                    .unwrap_or_else(|| st.plans.last().unwrap().epoch + 1);
                let (guard, _) = self.publish_swap(st, next);
                st = guard;
                // won or lost the race, the stream advanced (or shut
                // down) while the lock was free: re-read from the top
                continue;
            }
            if st.plans.len() > *seen {
                *seen = st.plans.len();
                return true;
            }
            if park == Some(Duration::ZERO) {
                // non-blocking probe (item-stealing workers park on the
                // injector condvar instead)
                return true;
            }
            let t0 = Instant::now();
            let timed_out = match park {
                Some(d) => {
                    let (guard, res) = self.cv.wait_timeout(st, d).unwrap();
                    st = guard;
                    res.timed_out()
                }
                None => {
                    st = self.cv.wait(st).unwrap();
                    false
                }
            };
            self.add_seam_idle(worker, t0.elapsed());
            if timed_out {
                return true;
            }
        }
    }

    /// Attribute idle time at the seam to `worker` (also counted in the
    /// aggregate gauge). Called by `wait_for_work` and by item-stealing
    /// workers that park on the injector condvar instead.
    pub(crate) fn add_seam_idle(&self, worker: u32, d: Duration) {
        let ns = d.as_nanos() as u64;
        self.seam_idle_ns.fetch_add(ns, Ordering::Relaxed);
        if let Some(cell) = self.seam_idle_by_worker.get(worker as usize) {
            cell.fetch_add(ns, Ordering::Relaxed);
        }
    }

    pub(crate) fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }

    pub(crate) fn is_shutdown(&self) -> bool {
        self.state.lock().unwrap().shutdown
    }

    /// Total epoch plans published by this pipeline generation.
    fn plans_published(&self) -> usize {
        self.state.lock().unwrap().plans.len()
    }

    /// Mispredicted speculative plans revoked (instead of torn down).
    fn plans_revoked_count(&self) -> u64 {
        self.plans_revoked.load(Ordering::Relaxed)
    }

    fn seam_idle(&self) -> Duration {
        Duration::from_nanos(self.seam_idle_ns.load(Ordering::Relaxed))
    }

    fn seam_idle_per_worker(&self) -> Vec<Duration> {
        self.seam_idle_by_worker
            .iter()
            .map(|ns| Duration::from_nanos(ns.load(Ordering::Relaxed)))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// The persistent worker pipeline
// ---------------------------------------------------------------------------

/// The consumer's end of the pipeline: the receiver plus the reorder
/// buffer and in-order cursor, both in global seqs so they persist
/// across epochs (a pipelined run buffers epoch N+1 arrivals while N's
/// tail delivers).
struct ConsumerState {
    rx: Receiver<WorkerMsg>,
    /// reorder buffer: out-of-order arrivals by seq with their arrival
    /// time on the recorder clock (feeds the reorder-hold stall lane),
    /// `None` = failure tombstone
    pending: HashMap<usize, (f64, Option<Batch>)>,
    /// next seq to deliver in order
    next_seq: usize,
}

/// Deferred worker start-up (lazy init): everything the first
/// `next()` needs to spawn the fleet.
struct SpawnArgs {
    sources: Vec<WorkSource>,
    tx: SyncSender<WorkerMsg>,
    cost: Duration,
}

struct PipeCtl {
    /// home slot for the consumer state between epochs; taken by the
    /// active [`EpochIter`], returned when its epoch completes
    consumer: Option<ConsumerState>,
    /// present until the workers are started (first epoch)
    pending_spawn: Option<SpawnArgs>,
    spawner: Option<std::thread::JoinHandle<Vec<std::thread::JoinHandle<()>>>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// One generation of the persistent pipeline: planner + gate + worker
/// fleet. Lives from the first `epoch()` call until teardown (drop,
/// a poisoned early-terminated epoch, or an epoch-sequence mismatch).
pub(crate) struct PipeCore {
    planner: Arc<Planner>,
    gate: Arc<CreditGate>,
    injector: Option<Arc<BatchInjector>>,
    ctl: Mutex<PipeCtl>,
    /// cumulative time finished batches sat in the reorder buffer
    /// waiting for an earlier seq (the reorder-hold stall lane)
    reorder_hold_ns: AtomicU64,
}

/// Join every thread of the pipeline. Callers must have dropped the
/// consumer's receiver (or know the stream is drained) first, or
/// workers blocked on a full data queue would never exit.
fn reap(core: &PipeCore) {
    let spawner = core.ctl.lock().unwrap().spawner.take();
    if let Some(sp) = spawner {
        if let Ok(handles) = sp.join() {
            core.ctl.lock().unwrap().workers.extend(handles);
        }
    }
    let workers: Vec<_> = {
        let mut ctl = core.ctl.lock().unwrap();
        ctl.workers.drain(..).collect()
    };
    for h in workers {
        let _ = h.join();
    }
}

/// Shut a pipeline generation down. If an [`EpochIter`] is still out
/// there holding the consumer state, joining is deferred to its drop
/// (it owns the receiver whose drop unblocks the workers).
fn teardown(core: &PipeCore) {
    core.planner.shutdown();
    core.gate.close();
    let (consumer, spawn) = {
        let mut ctl = core.ctl.lock().unwrap();
        (ctl.consumer.take(), ctl.pending_spawn.take())
    };
    let had_consumer = consumer.is_some();
    let never_started = spawn.is_some();
    drop(spawn); // drops the tx of a never-started fleet
    drop(consumer); // drops rx: workers blocked on send fail out
    // joining is only safe once the receiver is gone; when an EpochIter
    // still holds it (early teardown under an active epoch), its drop
    // performs the reap instead
    if had_consumer || never_started {
        reap(core);
    }
}

/// The dataloader: construct once, iterate per epoch. Workers persist
/// across epochs (see the module docs).
pub struct Dataloader {
    dataset: Arc<dyn Dataset>,
    cfg: Arc<DataloaderConfig>,
    recorder: Arc<Recorder>,
    /// batch-slab pool, shared by every epoch's workers (`arena_slabs`)
    arena: Option<Arc<BatchArena>>,
    /// batched-submission I/O ring shared by every worker (`io_depth`);
    /// None when disabled or the dataset has no ring store
    ring: Option<Arc<crate::storage::IoRing>>,
    /// live tunable knob values, seeded from `cfg`. The Governor (or
    /// any caller) *stages* new values at will; they go live only when
    /// `epoch()` commits them at the seam, so mid-epoch behavior —
    /// byte identity, zero-alloc steady state — is never disturbed.
    knobs: Arc<TunedKnobs>,
    /// the current pipeline generation (None until the first epoch)
    pipeline: Mutex<Option<Arc<PipeCore>>>,
}

impl Dataloader {
    pub fn new(
        dataset: Arc<dyn Dataset>,
        cfg: DataloaderConfig,
        recorder: Arc<Recorder>,
    ) -> Dataloader {
        if cfg.pin_memory && cfg.start_method == StartMethod::Fork {
            eprintln!(
                "warning: pin_memory=true with start_method=fork: pinning \
                 disabled (CUDA init cannot follow fork)"
            );
        }
        if cfg.steal_items && (!cfg.work_stealing || cfg.arena_slabs == 0) {
            eprintln!(
                "warning: steal_items=true needs work_stealing=true and \
                 arena_slabs > 0 (item claims live in the slab's claim \
                 bits); falling back to batch-level dispatch"
            );
        }
        if cfg.epoch_pipeline > 0 && !dataset.supports_epoch_tagged() {
            eprintln!(
                "warning: epoch_pipeline={} but the dataset does not honor \
                 epoch-tagged loads (Dataset::supports_epoch_tagged): \
                 pipelining two epochs through global set_epoch state would \
                 mis-seed augmentation, falling back to drained boundaries",
                cfg.epoch_pipeline
            );
        }
        let arena = if cfg.arena_slabs > 0 {
            // under effective pin_memory the arena hands out page-locked
            // slabs: batches are born pinned, to_device takes the
            // pinned-bandwidth path, and the staging copy disappears
            Some(BatchArena::new_opts(
                dataset.crop(),
                cfg.batch_size,
                cfg.arena_slabs,
                cfg.effective_pin_memory(),
            ))
        } else {
            None
        };
        let ring = if cfg.io_depth > 0 {
            match dataset.ring_store() {
                Some(store) => {
                    let ring = crate::storage::IoRing::new(store, cfg.io_depth);
                    ring.set_recorder(recorder.clone());
                    Some(ring)
                }
                None => {
                    eprintln!(
                        "warning: io_depth={} but the dataset exposes no ring \
                         store (Dataset::ring_store): falling back to the \
                         per-item fetch paths",
                        cfg.io_depth
                    );
                    None
                }
            }
        } else {
            None
        };
        let knobs = TunedKnobs::from_config(&cfg);
        if let Some(ring) = &ring {
            // seam-committed io_depth lands in the ring's semaphore
            let ring = ring.clone();
            knobs.register_applier(Box::new(move |k| ring.set_depth(k.io_depth())));
        }
        Dataloader {
            dataset,
            cfg: Arc::new(cfg),
            recorder,
            arena,
            ring,
            knobs,
            pipeline: Mutex::new(None),
        }
    }

    pub fn config(&self) -> &DataloaderConfig {
        &self.cfg
    }

    pub fn recorder(&self) -> &Arc<Recorder> {
        &self.recorder
    }

    pub fn dataset(&self) -> &Arc<dyn Dataset> {
        &self.dataset
    }

    /// The batch arena, when `arena_slabs > 0` (pool stats live here).
    pub fn arena(&self) -> Option<&Arc<BatchArena>> {
        self.arena.as_ref()
    }

    /// The batched-submission I/O ring, when `io_depth > 0` and the
    /// dataset exposes a ring store (queue-depth gauges live here).
    pub fn ring(&self) -> Option<&Arc<crate::storage::IoRing>> {
        self.ring.as_ref()
    }

    /// The loader's live tunable knobs. Stage values anytime (the
    /// Governor does); they commit — and propagate to the credit gate,
    /// I/O ring, and workers — at the next `epoch()` seam.
    pub fn knobs(&self) -> &Arc<TunedKnobs> {
        &self.knobs
    }

    /// Mispredicted speculative epoch plans revoked in place (instead
    /// of a full pipeline teardown) by the current generation.
    pub fn plans_revoked(&self) -> u64 {
        self.pipeline
            .lock()
            .unwrap()
            .as_ref()
            .map_or(0, |core| core.planner.plans_revoked_count())
    }

    /// Number of batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        let n = self.dataset.len();
        let b = self.cfg.batch_size;
        if self.cfg.drop_last {
            n / b
        } else {
            n.div_ceil(b)
        }
    }

    /// Cumulative time the persistent workers have spent parked at
    /// epoch seams waiting for the next plan (drained mode pays the
    /// full boundary here; pipelined mode ~none).
    pub fn seam_idle(&self) -> Duration {
        self.pipeline
            .lock()
            .unwrap()
            .as_ref()
            .map_or(Duration::ZERO, |core| core.planner.seam_idle())
    }

    /// Epoch plans published by the current pipeline generation.
    pub fn plans_published(&self) -> usize {
        self.pipeline
            .lock()
            .unwrap()
            .as_ref()
            .map_or(0, |core| core.planner.plans_published())
    }

    /// [`Dataloader::seam_idle`], attributed per worker id.
    pub fn seam_idle_per_worker(&self) -> Vec<Duration> {
        self.pipeline
            .lock()
            .unwrap()
            .as_ref()
            .map_or_else(Vec::new, |core| core.planner.seam_idle_per_worker())
    }

    /// Cumulative time workers spent blocked on (or parked around) the
    /// consumer-credit window — the credit-blocked stall lane.
    pub fn credit_blocked(&self) -> Duration {
        self.pipeline
            .lock()
            .unwrap()
            .as_ref()
            .map_or(Duration::ZERO, |core| core.gate.blocked())
    }

    /// Cumulative time finished batches waited in the reorder buffer
    /// for an earlier seq — the reorder-hold stall lane.
    pub fn reorder_hold(&self) -> Duration {
        self.pipeline.lock().unwrap().as_ref().map_or(Duration::ZERO, |core| {
            Duration::from_nanos(core.reorder_hold_ns.load(Ordering::Relaxed))
        })
    }

    /// Items filled by non-owner workers across the current pipeline
    /// generation (see [`EpochIter::item_steals`] for the per-epoch
    /// delta).
    pub fn item_steals(&self) -> u64 {
        self.pipeline
            .lock()
            .unwrap()
            .as_ref()
            .and_then(|core| core.injector.as_ref().map(|inj| inj.item_steal_count()))
            .unwrap_or(0)
    }

    fn build_pipeline(&self) -> Arc<PipeCore> {
        let w = self.cfg.num_workers;
        let (tx, rx) =
            std::sync::mpsc::sync_channel::<WorkerMsg>(self.cfg.queue_capacity());
        let gate = CreditGate::new(self.cfg.consumer_credit);
        let (sink, injector, sources): (PlanSink, _, Vec<WorkSource>) =
            if self.cfg.work_stealing {
                let inj = Arc::new(BatchInjector::new());
                (
                    PlanSink::Injector(inj.clone()),
                    Some(inj.clone()),
                    (0..w).map(|_| WorkSource::Stealing(inj.clone())).collect(),
                )
            } else {
                let queues: Vec<StaticQueue> = (0..w)
                    .map(|_| Arc::new(Mutex::new(VecDeque::new())))
                    .collect();
                (
                    PlanSink::Static(queues.clone()),
                    None,
                    queues.into_iter().map(WorkSource::Static).collect(),
                )
            };
        if let Some(inj) = &injector {
            // wake item-stealing workers parked on the injector condvar
            // whenever the credit window moves (or the gate closes)
            let hook = inj.clone();
            gate.set_waker(Arc::new(move || hook.bump()));
        }
        // seam-committed consumer_credit lands in this generation's
        // gate (rebuilds are rare — a superseded gate costs one stale
        // applier entry, and resizing a closed gate is harmless)
        {
            let gate = gate.clone();
            self.knobs
                .register_applier(Box::new(move |k| gate.set_credit(k.credit())));
        }
        let planner = Arc::new(Planner::new(
            self.dataset.clone(),
            self.cfg.clone(),
            sink,
            self.knobs.clone(),
            self.recorder.clone(),
        ));
        Arc::new(PipeCore {
            planner,
            gate,
            injector,
            reorder_hold_ns: AtomicU64::new(0),
            ctl: Mutex::new(PipeCtl {
                consumer: Some(ConsumerState {
                    rx,
                    pending: HashMap::new(),
                    next_seq: 0,
                }),
                pending_spawn: Some(SpawnArgs {
                    sources,
                    tx,
                    cost: self.cfg.spawn_cost(),
                }),
                spawner: None,
                workers: Vec::new(),
            }),
        })
    }

    /// Blocking creation loop (vanilla torch, Fig 8 left): pay every
    /// start-up cost on the caller before the epoch constructor returns.
    /// Persistent workers make this a first-epoch-only cost.
    fn start_workers_blocking(&self, core: &Arc<PipeCore>) {
        let Some(args) = core.ctl.lock().unwrap().pending_spawn.take() else {
            return; // already started (earlier epoch)
        };
        let mut handles = Vec::new();
        for (wid, source) in args.sources.into_iter().enumerate() {
            std::thread::sleep(args.cost);
            handles.push(worker::spawn_worker(
                wid as u32,
                self.dataset.clone(),
                self.recorder.clone(),
                self.cfg.clone(),
                source,
                self.arena.clone(),
                core.gate.clone(),
                Some(core.planner.clone()),
                args.tx.clone(),
                Duration::ZERO, // cost already paid in the loop
                self.ring.clone(),
            ));
        }
        core.ctl.lock().unwrap().workers.extend(handles);
    }

    /// Attach an [`EpochIter`] to the current pipeline, or report that
    /// it must be rebuilt (poisoned, mid-epoch consumer still out, or
    /// an epoch-sequence mismatch with a pre-published plan).
    fn try_attach(&self, core: &Arc<PipeCore>, epoch: usize) -> Option<EpochIter> {
        let mut consumer = core.ctl.lock().unwrap().consumer.take()?;
        let Some(meta) = core.planner.attach(epoch) else {
            core.ctl.lock().unwrap().consumer = Some(consumer);
            return None;
        };
        if meta.base > consumer.next_seq {
            // a revocation burned the seqs in between: fast-forward the
            // in-order cursor over the gap. Buffered stragglers from
            // the revoked range are recycled here; still-in-flight ones
            // are discarded on arrival (EpochIter::next).
            let stale: Vec<usize> = consumer
                .pending
                .keys()
                .copied()
                .filter(|&s| s < meta.base)
                .collect();
            for s in stale {
                if let Some((_, Some(b))) = consumer.pending.remove(&s) {
                    b.recycle();
                }
            }
            consumer.next_seq = meta.base;
            core.gate.advance(meta.base);
        }
        if !self.cfg.lazy_init {
            self.start_workers_blocking(core);
        }
        let steals_base = core
            .injector
            .as_ref()
            .map_or(0, |inj| inj.item_steal_count());
        let reorder_hwm = consumer.pending.len();
        Some(EpochIter {
            dataset: self.dataset.clone(),
            cfg: self.cfg.clone(),
            recorder: self.recorder.clone(),
            arena: self.arena.clone(),
            ring: self.ring.clone(),
            epoch,
            core: Some(core.clone()),
            consumer: Some(consumer),
            base: meta.base,
            n_batches: meta.n,
            reorder_hwm,
            steals_base,
            complete: false,
            spawn_checked: false,
            inline_plan: None,
        })
    }

    /// Begin an epoch: attaches to the persistent pipeline (building it
    /// on the first call), publishes the epoch's plan if a worker has
    /// not already pre-published it, and returns the batch iterator.
    pub fn epoch(&self, epoch: usize) -> EpochIter {
        // legacy global-epoch state for datasets without epoch-tagged
        // loads; the built-in dataset ignores it on the hot path
        self.dataset.set_epoch(epoch);

        // mark the seam on the consumer track: a zero-width instant the
        // Chrome-trace exporter renders as a global marker
        let seam = self.recorder.now();
        self.recorder
            .record_tagged(names::EPOCH_SEAM, 0, -1, epoch as i64, -1, seam, seam);

        // the one place staged knob values go live: anything the
        // Governor staged since the last seam commits here, before the
        // epoch's plan publishes — never mid-epoch
        self.knobs.commit();

        if self.cfg.num_workers == 0 {
            // torch num_workers=0: load inline in the consumer
            let (order, plan) = epoch_plan(&self.cfg, &self.dataset, epoch);
            self.dataset.hint_epoch_order(epoch, &order);
            let tickets: VecDeque<BatchTicket> =
                BatchTicket::plan(epoch, 0, plan).into();
            let n_batches = tickets.len();
            return EpochIter {
                dataset: self.dataset.clone(),
                cfg: self.cfg.clone(),
                recorder: self.recorder.clone(),
                arena: self.arena.clone(),
                ring: None, // inline loads stay on the direct item path
                epoch,
                core: None,
                consumer: None,
                base: 0,
                n_batches,
                reorder_hwm: 0,
                steals_base: 0,
                complete: false,
                spawn_checked: true,
                inline_plan: Some(tickets),
            };
        }

        let mut slot = self.pipeline.lock().unwrap();
        loop {
            if slot.is_none() {
                *slot = Some(self.build_pipeline());
            }
            let core = slot.as_ref().unwrap().clone();
            match self.try_attach(&core, epoch) {
                Some(iter) => return iter,
                None => {
                    // poisoned pipeline or epoch-sequence mismatch:
                    // tear down this generation and rebuild fresh
                    let old = slot.take().unwrap();
                    teardown(&old);
                }
            }
        }
    }
}

impl Drop for Dataloader {
    fn drop(&mut self) {
        if let Some(core) = self.pipeline.lock().unwrap().take() {
            teardown(&core);
        }
    }
}

/// Iterator over one epoch's batches (in order). Borrows the loader's
/// persistent pipeline for the duration of the epoch; dropping it
/// mid-epoch poisons the pipeline (the next `epoch()` rebuilds it).
pub struct EpochIter {
    dataset: Arc<dyn Dataset>,
    cfg: Arc<DataloaderConfig>,
    recorder: Arc<Recorder>,
    arena: Option<Arc<BatchArena>>,
    ring: Option<Arc<crate::storage::IoRing>>,
    epoch: usize,
    core: Option<Arc<PipeCore>>,
    consumer: Option<ConsumerState>,
    /// first seq of this epoch's plan
    base: usize,
    n_batches: usize,
    /// max reorder-buffer occupancy seen while this epoch consumed —
    /// includes early next-epoch arrivals under pipelining, so this is
    /// the *through-the-seam* high-water mark
    reorder_hwm: usize,
    steals_base: u64,
    complete: bool,
    spawn_checked: bool,
    inline_plan: Option<VecDeque<BatchTicket>>,
}

impl EpochIter {
    pub fn n_batches(&self) -> usize {
        self.n_batches
    }

    /// The sampler epoch this iterator serves.
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Highest reorder-buffer occupancy observed so far this epoch.
    /// With `consumer_credit = K > 0` this never exceeds K — including
    /// across the epoch seam under `epoch_pipeline` (the credit window
    /// is in global seqs).
    pub fn reorder_high_water(&self) -> usize {
        self.reorder_hwm
    }

    /// Items filled by non-owner workers during this epoch (0 without
    /// `steal_items`/work-stealing dispatch).
    pub fn item_steals(&self) -> u64 {
        let now = self
            .core
            .as_ref()
            .and_then(|c| c.injector.as_ref())
            .map_or(0, |inj| inj.item_steal_count());
        now.saturating_sub(self.steals_base)
    }

    fn start_workers_lazy(&mut self) {
        self.spawn_checked = true;
        let Some(core) = &self.core else { return };
        let Some(args) = core.ctl.lock().unwrap().pending_spawn.take() else {
            return; // already started (earlier epoch)
        };
        let dataset = self.dataset.clone();
        let recorder = self.recorder.clone();
        let cfg = self.cfg.clone();
        let arena = self.arena.clone();
        let ring = self.ring.clone();
        let gate = core.gate.clone();
        let planner = core.planner.clone();
        // start_download(): yield each worker as it is created (Fig 8
        // right) — creation runs off the consumer's critical path
        let spawner = std::thread::Builder::new()
            .name("dl-spawner".into())
            .spawn(move || {
                let mut handles = Vec::new();
                for (wid, source) in args.sources.into_iter().enumerate() {
                    std::thread::sleep(args.cost);
                    handles.push(worker::spawn_worker(
                        wid as u32,
                        dataset.clone(),
                        recorder.clone(),
                        cfg.clone(),
                        source,
                        arena.clone(),
                        gate.clone(),
                        Some(planner.clone()),
                        args.tx.clone(),
                        Duration::ZERO,
                        ring.clone(),
                    ));
                }
                handles
            })
            .expect("spawn dl-spawner");
        core.ctl.lock().unwrap().spawner = Some(spawner);
    }

    /// Epoch exhausted: hand the consumer state back to the pipeline so
    /// the next `epoch()` call continues the stream.
    fn finish_epoch(&mut self) {
        if self.complete {
            return;
        }
        self.complete = true;
        let Some(core) = &self.core else { return };
        if core.planner.is_shutdown() {
            return; // drop() handles cleanup for a dead pipeline
        }
        if let Some(consumer) = self.consumer.take() {
            core.ctl.lock().unwrap().consumer = Some(consumer);
        }
    }

    fn next_inline(&mut self) -> Option<Batch> {
        let gil = gil::Gil::new(self.cfg.runtime, self.cfg.python_tax);
        let ctx = fetch::FetchCtx {
            worker_id: 0,
            dataset: self.dataset.clone(),
            gil: gil.clone(),
            recorder: self.recorder.clone(),
        };
        loop {
            let ticket = self.inline_plan.as_mut()?.pop_front()?;
            let t0 = self.recorder.now();
            let res = if let Some(arena) = &self.arena {
                // fused: assemble in the recycled slab, no copies
                fetch::fetch_vanilla_fused(&ctx, arena, &ticket)
            } else {
                fetch::fetch_vanilla(&ctx, ticket.epoch, ticket.id, &ticket.indices)
                    .and_then(|samples| gil.cpu(|| collate::collate(ticket.id, samples)))
            };
            match res {
                Ok(batch) => {
                    self.recorder.record_tagged(
                        names::BATCH_INFLIGHT,
                        0,
                        batch.id as i64,
                        ticket.epoch as i64,
                        ticket.seq as i64,
                        t0,
                        self.recorder.now(),
                    );
                    return Some(batch);
                }
                Err(e) => {
                    // same per-batch error semantics as the worker path
                    eprintln!("inline loader batch {}: {e:#}", ticket.id);
                }
            }
        }
    }

    /// Apply the pin-memory staging cost and flag. Batches born in a
    /// pinned arena slab skip the staging copy entirely — they are
    /// already page-locked at the source.
    fn pin(&self, mut batch: Batch) -> Batch {
        if self.cfg.effective_pin_memory() {
            if batch.pinned {
                return batch;
            }
            let t0 = self.recorder.now();
            // page-locked copy at ~12 GB/s
            let secs = batch.tensor_bytes() as f64 / 12.0e9 + 50e-6;
            std::thread::sleep(Duration::from_secs_f64(secs));
            batch.pinned = true;
            self.recorder.record_tagged(
                names::PIN_MEMORY,
                0,
                batch.id as i64,
                self.epoch as i64,
                -1,
                t0,
                self.recorder.now(),
            );
        }
        batch
    }
}

impl Iterator for EpochIter {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        let t0 = self.recorder.now();

        if self.inline_plan.is_some() {
            let b = self.next_inline()?;
            self.recorder.record_tagged(
                names::GET_BATCH,
                0,
                b.id as i64,
                self.epoch as i64,
                -1,
                t0,
                self.recorder.now(),
            );
            return Some(self.pin(b));
        }

        let end = self.base + self.n_batches;
        if !self.spawn_checked {
            // lazy init: first __next__ triggers start_download()
            self.start_workers_lazy();
        }
        let gate = self
            .core
            .as_ref()
            .expect("worker-mode iter has a core")
            .gate
            .clone();
        // in-order delivery by global seq: drain until the expected seq
        // arrives (early arrivals — including next-epoch ones under
        // pipelining — buffer in `pending`)
        loop {
            let Some(consumer) = self.consumer.as_mut() else {
                return None;
            };
            if consumer.next_seq >= end {
                self.finish_epoch();
                return None;
            }
            match consumer.pending.remove(&consumer.next_seq) {
                Some((arrived, Some(b))) => {
                    let seq = consumer.next_seq;
                    consumer.next_seq += 1;
                    // publish the new cursor: credit-blocked workers may
                    // now start the next batch of the window
                    gate.advance(consumer.next_seq);
                    let now = self.recorder.now();
                    // reorder-hold stall lane: how long this batch sat
                    // buffered waiting for an earlier seq to deliver
                    let hold = now - arrived;
                    if hold > 0.0 {
                        if let Some(core) = &self.core {
                            core.reorder_hold_ns
                                .fetch_add((hold * 1e9) as u64, Ordering::Relaxed);
                        }
                    }
                    self.recorder.record_tagged(
                        names::GET_BATCH,
                        0,
                        b.id as i64,
                        self.epoch as i64,
                        seq as i64,
                        t0,
                        now,
                    );
                    return Some(self.pin(b));
                }
                Some((_, None)) => {
                    // failure tombstone: the worker already logged it —
                    // advance past the gap and keep delivering
                    consumer.next_seq += 1;
                    gate.advance(consumer.next_seq);
                    continue;
                }
                None => {}
            }
            match consumer.rx.recv() {
                Ok(WorkerMsg::Batch { seq, batch }) => {
                    if seq < consumer.next_seq {
                        // straggler from a revoked plan (the cursor
                        // fast-forwarded over its burned seq range):
                        // return the slab and move on
                        batch.recycle();
                        continue;
                    }
                    consumer.pending.insert(seq, (self.recorder.now(), Some(batch)));
                    self.reorder_hwm = self.reorder_hwm.max(consumer.pending.len());
                }
                Ok(WorkerMsg::Failed { seq }) => {
                    if seq < consumer.next_seq {
                        continue; // revoked-plan straggler tombstone
                    }
                    consumer.pending.insert(seq, (self.recorder.now(), None));
                    self.reorder_hwm = self.reorder_hwm.max(consumer.pending.len());
                }
                Err(_) => {
                    // every worker exited and the channel drained — the
                    // pipeline died. Poison this generation so the next
                    // `epoch()` rebuilds instead of attaching to a fleet
                    // that no longer exists (finish_epoch sees the
                    // shutdown and leaves cleanup to drop()); then
                    // backstop a gap with no tombstone by skipping to
                    // the next buffered seq of this epoch instead of
                    // silently truncating it.
                    if let Some(core) = &self.core {
                        core.planner.shutdown();
                    }
                    let next = consumer
                        .pending
                        .keys()
                        .copied()
                        .filter(|&s| s >= consumer.next_seq && s < end)
                        .min();
                    match next {
                        Some(s) => {
                            consumer.next_seq = s;
                            gate.advance(s);
                        }
                        None => {
                            consumer.next_seq = end;
                            self.finish_epoch();
                            return None;
                        }
                    }
                }
            }
        }
    }
}

impl Drop for EpochIter {
    fn drop(&mut self) {
        let Some(core) = self.core.take() else {
            return; // inline mode: nothing to clean up
        };
        if self.complete && !core.planner.is_shutdown() {
            // normal epoch end: consumer state already back home, the
            // pipeline keeps serving the next epoch
            return;
        }
        // early termination (or a pipeline torn down under us): poison
        // and reap. Open the credit gate first (workers parked on it
        // must wake to notice the dead channel), then drop the receiver
        // so workers blocked on a full queue fail out of their send.
        core.planner.shutdown();
        core.gate.close();
        drop(self.consumer.take());
        {
            let mut ctl = core.ctl.lock().unwrap();
            drop(ctl.consumer.take());
            drop(ctl.pending_spawn.take());
        }
        reap(&core);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_corpus, CorpusSpec};
    use crate::data::AugmentConfig;
    use crate::dataset::ImageFolderDataset;
    use crate::storage::{MemStore, ObjectStore, RemoteProfile, SimRemoteStore};
    use std::time::Instant;

    fn dataset(items: usize, remote: bool) -> Arc<dyn Dataset> {
        let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        generate_corpus(&mem, &CorpusSpec::tiny(items)).unwrap();
        let store: Arc<dyn ObjectStore> = if remote {
            SimRemoteStore::new(mem, RemoteProfile::s3().scaled(0.15), 5)
        } else {
            mem
        };
        Arc::new(ImageFolderDataset::new(
            store,
            AugmentConfig { crop: 16, ..Default::default() },
        ))
    }

    fn collect_epoch(dl: &Dataloader, epoch: usize) -> Vec<Batch> {
        dl.epoch(epoch).collect()
    }

    fn check_full_coverage(batches: &[Batch], n_items: usize) {
        let mut seen: Vec<usize> =
            batches.iter().flat_map(|b| b.indices.iter().copied()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..n_items).collect::<Vec<_>>());
    }

    #[test]
    fn epoch_covers_dataset_exactly_once_all_impls() {
        for impl_ in FetchImpl::all() {
            let dl = Dataloader::new(
                dataset(22, false),
                DataloaderConfig {
                    batch_size: 5,
                    num_workers: 3,
                    fetch_impl: impl_,
                    num_fetch_workers: 4,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            );
            let batches = collect_epoch(&dl, 0);
            assert_eq!(batches.len(), 5, "{impl_:?}");
            check_full_coverage(&batches, 22);
            // in-order ids
            let ids: Vec<usize> = batches.iter().map(|b| b.id).collect();
            assert_eq!(ids, vec![0, 1, 2, 3, 4], "{impl_:?}");
        }
    }

    #[test]
    fn work_stealing_epoch_covers_dataset_in_order_all_impls() {
        for impl_ in FetchImpl::all() {
            let dl = Dataloader::new(
                dataset(22, false),
                DataloaderConfig {
                    batch_size: 5,
                    num_workers: 3,
                    fetch_impl: impl_,
                    num_fetch_workers: 4,
                    work_stealing: true,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            );
            let batches = collect_epoch(&dl, 0);
            assert_eq!(batches.len(), 5, "{impl_:?}");
            check_full_coverage(&batches, 22);
            let ids: Vec<usize> = batches.iter().map(|b| b.id).collect();
            assert_eq!(ids, vec![0, 1, 2, 3, 4], "{impl_:?}");
        }
    }

    #[test]
    fn item_steal_epoch_covers_dataset_in_order_all_impls() {
        for impl_ in FetchImpl::all() {
            let dl = Dataloader::new(
                dataset(22, false),
                DataloaderConfig {
                    batch_size: 5,
                    num_workers: 3,
                    fetch_impl: impl_,
                    num_fetch_workers: 4,
                    work_stealing: true,
                    steal_items: true,
                    arena_slabs: 12,
                    consumer_credit: 3,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            );
            let mut it = dl.epoch(0);
            let mut batches = Vec::new();
            for b in it.by_ref() {
                batches.push(b);
            }
            let hwm = it.reorder_high_water();
            drop(it);
            assert_eq!(batches.len(), 5, "{impl_:?}");
            check_full_coverage(&batches, 22);
            let ids: Vec<usize> = batches.iter().map(|b| b.id).collect();
            assert_eq!(ids, vec![0, 1, 2, 3, 4], "{impl_:?}");
            assert!(batches.iter().all(|b| b.is_pooled()), "{impl_:?}");
            assert!(hwm <= 3, "{impl_:?}: reorder hwm {hwm} > credit 3");
        }
    }

    #[test]
    fn consumer_credit_bounds_reorder_buffer_in_every_dispatch_mode() {
        for (stealing, items) in [(false, false), (true, false), (true, true)] {
            let dl = Dataloader::new(
                dataset(30, true), // remote latency: real reordering
                DataloaderConfig {
                    batch_size: 3,
                    num_workers: 4,
                    fetch_impl: FetchImpl::Threaded,
                    num_fetch_workers: 4,
                    work_stealing: stealing,
                    steal_items: items,
                    arena_slabs: 10,
                    consumer_credit: 2,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            );
            let mut it = dl.epoch(0);
            let mut n = 0;
            for b in it.by_ref() {
                n += 1;
                b.recycle();
            }
            let hwm = it.reorder_high_water();
            assert_eq!(n, 10, "stealing={stealing} items={items}");
            assert!(
                hwm <= 2,
                "stealing={stealing} items={items}: hwm {hwm} > credit 2"
            );
        }
    }

    #[test]
    fn pinned_arena_batches_are_born_pinned() {
        let mk = |arena_slabs| {
            Dataloader::new(
                dataset(8, false),
                DataloaderConfig {
                    batch_size: 4,
                    num_workers: 2,
                    pin_memory: true,
                    start_method: StartMethod::Spawn,
                    arena_slabs,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            )
        };
        // arena path: slabs are page-locked, no staging copy recorded
        let dl = mk(6);
        assert!(dl.arena().unwrap().pinned());
        let batches = collect_epoch(&dl, 0);
        assert!(batches.iter().all(|b| b.pinned && b.is_pooled()));
        assert_eq!(dl.recorder().durations(names::PIN_MEMORY).len(), 0);
        // legacy path: heap batches still pay the staging copy
        let dl = mk(0);
        let batches = collect_epoch(&dl, 0);
        assert!(batches.iter().all(|b| b.pinned && !b.is_pooled()));
        assert_eq!(dl.recorder().durations(names::PIN_MEMORY).len(), 2);
    }

    #[test]
    fn arena_epochs_reuse_slabs_across_epochs() {
        let dl = Dataloader::new(
            dataset(24, false),
            DataloaderConfig {
                batch_size: 4,
                num_workers: 2,
                arena_slabs: 16,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            Recorder::new(),
        );
        for epoch in 0..3 {
            let batches = collect_epoch(&dl, epoch);
            assert_eq!(batches.len(), 6);
            check_full_coverage(&batches, 24);
            assert!(batches.iter().all(|b| b.is_pooled()));
            // consumer side of the lifecycle: recycle after use
            for b in batches {
                b.recycle();
            }
        }
        let s = dl.arena().unwrap().stats();
        assert_eq!(s.checkouts, 18, "{s:?}");
        assert_eq!(s.recycled, 18, "{s:?}");
        // steady state: only the first epoch's in-flight window ever
        // allocated fresh slabs
        assert!(s.fresh <= 8, "{s:?}");
        assert!(s.reused >= 10, "{s:?}");
    }

    #[test]
    fn persistent_workers_spawn_once_across_epochs() {
        // the PR 5 tentpole: workers are per-Dataloader, not per-epoch —
        // three epochs, exactly num_workers spawn spans
        let rec = Recorder::new();
        let dl = Dataloader::new(
            dataset(12, false),
            DataloaderConfig {
                batch_size: 4,
                num_workers: 3,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            rec.clone(),
        );
        for epoch in 0..3 {
            let batches = collect_epoch(&dl, epoch);
            assert_eq!(batches.len(), 3);
        }
        assert_eq!(
            rec.durations(names::WORKER_SPAWN).len(),
            3,
            "workers must be spawned once per Dataloader, not per epoch"
        );
    }

    #[test]
    fn pipelined_epochs_match_drained_epochs() {
        // epoch_pipeline=1: same loader config, same per-epoch batches
        let mk = |pipeline: usize| {
            Dataloader::new(
                dataset(22, false),
                DataloaderConfig {
                    batch_size: 5,
                    num_workers: 3,
                    fetch_impl: FetchImpl::Threaded,
                    num_fetch_workers: 4,
                    work_stealing: true,
                    arena_slabs: 12,
                    consumer_credit: 3,
                    epoch_pipeline: pipeline,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            )
        };
        let drained = mk(0);
        let pipelined = mk(1);
        for epoch in 0..3 {
            let a = collect_epoch(&drained, epoch);
            let b = collect_epoch(&pipelined, epoch);
            assert_eq!(a.len(), b.len(), "epoch {epoch}");
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.id, y.id, "epoch {epoch}");
                assert_eq!(x.images, y.images, "epoch {epoch} batch {}", x.id);
                assert_eq!(x.labels, y.labels, "epoch {epoch} batch {}", x.id);
                assert_eq!(x.indices, y.indices, "epoch {epoch} batch {}", x.id);
            }
            for b in a.into_iter().chain(b) {
                b.recycle();
            }
        }
        // the pipelined loader pre-published ahead of the consumer
        assert!(pipelined.plans_published() >= 3);
    }

    #[test]
    fn pipelined_epoch_mismatch_rebuilds_correctly() {
        // pipelining predicts epoch+1; asking for something else must
        // still produce correct (deterministic) output via rebuild
        let dl = Dataloader::new(
            dataset(16, false),
            DataloaderConfig {
                batch_size: 4,
                num_workers: 2,
                epoch_pipeline: 1,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            Recorder::new(),
        );
        let e0: Vec<usize> =
            collect_epoch(&dl, 0).iter().flat_map(|b| b.indices.clone()).collect();
        // the pipeline has pre-published epoch 1; ask for 0 again
        let e0b: Vec<usize> =
            collect_epoch(&dl, 0).iter().flat_map(|b| b.indices.clone()).collect();
        assert_eq!(e0, e0b);
        let e5: Vec<usize> =
            collect_epoch(&dl, 5).iter().flat_map(|b| b.indices.clone()).collect();
        assert_ne!(e0, e5);
    }

    #[test]
    fn arena_with_work_stealing_and_shuffle_is_equivalent_to_legacy() {
        let mk = |arena: usize, stealing: bool| {
            Dataloader::new(
                dataset(19, false),
                DataloaderConfig {
                    batch_size: 4,
                    num_workers: 3,
                    fetch_impl: FetchImpl::Threaded,
                    num_fetch_workers: 4,
                    arena_slabs: arena,
                    work_stealing: stealing,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            )
        };
        let legacy: Vec<Batch> = collect_epoch(&mk(0, false), 1);
        let fused: Vec<Batch> = collect_epoch(&mk(12, true), 1);
        assert_eq!(legacy.len(), fused.len());
        for (a, b) in legacy.iter().zip(fused.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.images, b.images);
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.indices, b.indices);
            assert_eq!(a.raw_bytes, b.raw_bytes);
        }
    }

    #[test]
    fn failed_batch_skips_not_truncates_the_epoch() {
        use crate::data::synth::generate_corpus as gen;
        // corrupt one object: its batch fails in the worker, every other
        // batch must still be delivered, in order
        let mem: Arc<dyn crate::storage::ObjectStore> = Arc::new(MemStore::new("m"));
        let (keys, _) = gen(&mem, &CorpusSpec::tiny(12)).unwrap();
        mem.put(&keys[2], vec![7, 7, 7]).unwrap(); // not a SIMG
        let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
            mem,
            AugmentConfig { crop: 16, ..Default::default() },
        ));
        for (workers, stealing) in [(2usize, false), (3, true), (0, false)] {
            let dl = Dataloader::new(
                ds.clone(),
                DataloaderConfig {
                    batch_size: 4,
                    num_workers: workers,
                    shuffle: false, // item 2 lands in batch 0
                    work_stealing: stealing,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            );
            let batches = collect_epoch(&dl, 0);
            let ids: Vec<usize> = batches.iter().map(|b| b.id).collect();
            assert_eq!(ids, vec![1, 2], "workers={workers} stealing={stealing}");
        }
    }

    #[test]
    fn num_workers_zero_inline() {
        let dl = Dataloader::new(
            dataset(10, false),
            DataloaderConfig {
                batch_size: 4,
                num_workers: 0,
                ..Default::default()
            },
            Recorder::new(),
        );
        let batches = collect_epoch(&dl, 0);
        assert_eq!(batches.len(), 3);
        check_full_coverage(&batches, 10);
    }

    #[test]
    fn drop_last_drops_partial() {
        let dl = Dataloader::new(
            dataset(10, false),
            DataloaderConfig {
                batch_size: 4,
                drop_last: true,
                num_workers: 2,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            Recorder::new(),
        );
        assert_eq!(dl.batches_per_epoch(), 2);
        let batches = collect_epoch(&dl, 0);
        assert_eq!(batches.len(), 2);
        assert!(batches.iter().all(|b| b.len() == 4));
    }

    #[test]
    fn shuffle_changes_across_epochs_deterministically() {
        let dl = Dataloader::new(
            dataset(16, false),
            DataloaderConfig {
                batch_size: 4,
                num_workers: 2,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            Recorder::new(),
        );
        let e0: Vec<usize> = collect_epoch(&dl, 0).iter().flat_map(|b| b.indices.clone()).collect();
        let e0b: Vec<usize> = collect_epoch(&dl, 0).iter().flat_map(|b| b.indices.clone()).collect();
        let e1: Vec<usize> = collect_epoch(&dl, 1).iter().flat_map(|b| b.indices.clone()).collect();
        assert_eq!(e0, e0b);
        assert_ne!(e0, e1);
    }

    #[test]
    fn lazy_init_returns_first_batch_sooner() {
        let slow_spawn = Duration::from_millis(60);
        let mk = |lazy| {
            Dataloader::new(
                dataset(8, false),
                DataloaderConfig {
                    batch_size: 2,
                    num_workers: 4,
                    lazy_init: lazy,
                    spawn_cost_override: Some(slow_spawn),
                    ..Default::default()
                },
                Recorder::new(),
            )
        };
        let dl = mk(false);
        let t0 = Instant::now();
        let mut it = dl.epoch(0);
        let _b = it.next().unwrap();
        let blocking_first = t0.elapsed();
        drop(it);

        let dl = mk(true);
        let t0 = Instant::now();
        let mut it = dl.epoch(0);
        let _b = it.next().unwrap();
        let lazy_first = t0.elapsed();
        drop(it);

        // blocking pays 4×60ms before the first fetch; lazy pays ~1×60ms
        assert!(
            lazy_first < blocking_first,
            "lazy {lazy_first:?} !< blocking {blocking_first:?}"
        );
    }

    #[test]
    fn persistent_workers_skip_spawn_cost_after_first_epoch() {
        // the boundary win in its simplest form: epoch 2's first batch
        // arrives without re-paying 4×60ms of start-up
        let dl = Dataloader::new(
            dataset(8, false),
            DataloaderConfig {
                batch_size: 2,
                num_workers: 4,
                lazy_init: false, // spawn cost paid up front, once
                spawn_cost_override: Some(Duration::from_millis(60)),
                ..Default::default()
            },
            Recorder::new(),
        );
        let _ = collect_epoch(&dl, 0); // pays 4×60ms here
        let t0 = Instant::now();
        let _ = collect_epoch(&dl, 1);
        let second = t0.elapsed();
        assert!(
            second < Duration::from_millis(120),
            "second epoch re-paid worker start-up: {second:?}"
        );
    }

    #[test]
    fn pin_memory_requires_spawn() {
        let cfg = DataloaderConfig {
            pin_memory: true,
            start_method: StartMethod::Fork,
            ..Default::default()
        };
        assert!(!cfg.effective_pin_memory());
        let cfg = DataloaderConfig {
            pin_memory: true,
            start_method: StartMethod::Spawn,
            ..Default::default()
        };
        assert!(cfg.effective_pin_memory());
    }

    #[test]
    fn early_drop_does_not_hang() {
        let dl = Dataloader::new(
            dataset(32, false),
            DataloaderConfig {
                batch_size: 2,
                num_workers: 4,
                prefetch_factor: 1,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            Recorder::new(),
        );
        let mut it = dl.epoch(0);
        let _ = it.next().unwrap();
        drop(it); // workers blocked on a full queue must unblock and exit
        // the loader stays usable: the next epoch rebuilds the pipeline
        let batches = collect_epoch(&dl, 1);
        assert_eq!(batches.len(), 16);
    }

    #[test]
    fn threaded_epoch_faster_than_vanilla_on_remote() {
        let mk = |impl_| {
            Dataloader::new(
                dataset(24, true),
                DataloaderConfig {
                    batch_size: 8,
                    num_workers: 2,
                    fetch_impl: impl_,
                    num_fetch_workers: 8,
                    spawn_cost_override: Some(Duration::ZERO),
                    ..Default::default()
                },
                Recorder::new(),
            )
        };
        let t0 = Instant::now();
        let v = collect_epoch(&mk(FetchImpl::Vanilla), 0);
        let vanilla = t0.elapsed();
        let t0 = Instant::now();
        let t = collect_epoch(&mk(FetchImpl::Threaded), 0);
        let threaded = t0.elapsed();
        assert_eq!(v.len(), t.len());
        assert!(
            threaded.as_secs_f64() < 0.55 * vanilla.as_secs_f64(),
            "threaded {threaded:?} not ≪ vanilla {vanilla:?}"
        );
    }

    #[test]
    fn spans_recorded() {
        let rec = Recorder::new();
        let dl = Dataloader::new(
            dataset(8, false),
            DataloaderConfig {
                batch_size: 4,
                num_workers: 1,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            rec.clone(),
        );
        let _ = collect_epoch(&dl, 0);
        assert_eq!(rec.durations(names::GET_ITEM).len(), 8);
        assert_eq!(rec.durations(names::GET_BATCH).len(), 2);
        assert_eq!(rec.durations(names::BATCH_INFLIGHT).len(), 2);
    }
}
