//! Recycled batch arenas — the zero-alloc hot path (PR 3 tentpole).
//!
//! The legacy assembly path allocates three times per item and twice per
//! batch: a decode buffer in `SimgImage::decode`, a crop tensor in
//! `Augment::apply_u8`, and a *zeroed* batch tensor plus a copy loop in
//! `collate`. Once storage latency is hidden (prefetch engine, PR 1),
//! that memory traffic is what the workers burn CPU on.
//!
//! A [`BatchArena`] removes all of it. It pools `[B, crop, crop, 3]` u8
//! slabs (plus their label/index/shape side-arrays); a worker checks a
//! slab out as a [`BatchBuilder`], every fetch task decodes + augments
//! its item **directly into its pre-assigned slot**, and `finish()`
//! converts the filled slab into a [`Batch`] with no copy. After
//! `to_device` the trainer calls [`Batch::recycle`], returning the
//! buffers to the pool, so steady-state epochs run with **zero per-batch
//! heap allocation** (asserted by `tests/test_alloc.rs` with the
//! counting allocator).
//!
//! Lifecycle: `checkout → fill×n → finish → to_device → recycle`.
//!
//! ## Concurrency protocol
//!
//! A slab is filled by many threads at once (the threaded and asyncio
//! fetchers). Slot windows are disjoint; exclusivity per slot is
//! enforced by an atomic claim bit, and the consumer (`finish`) only
//! runs after the worker has observed completion of every fill through
//! a channel/join, which provides the happens-before edge for the raw
//! slot writes. Builder clones held by fetch tasks are passive handles:
//! only the primary builder (the one `checkout` returned) recovers the
//! slab on drop.

use std::fmt;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use super::collate::Batch;
use crate::data::U8Tensor;
use crate::dataset::ItemMeta;

/// The reusable buffer set behind one batch: pixel slab + side arrays.
struct SlabBuf {
    pixels: Vec<u8>,
    shape: Vec<usize>,
    labels: Vec<i32>,
    indices: Vec<usize>,
}

impl SlabBuf {
    fn with_capacity(slots: usize, per: usize) -> SlabBuf {
        SlabBuf {
            pixels: Vec::with_capacity(slots * per),
            shape: Vec::with_capacity(4),
            labels: Vec::with_capacity(slots),
            indices: Vec::with_capacity(slots),
        }
    }
}

/// Shared fill-state of one checked-out slab. The raw pointers are
/// write windows into the owned buffers in `owned`; they are published
/// at checkout and nulled at finish/recover.
struct SlabState {
    /// per-slot claim words, generation-tagged: a slot checked out for
    /// generation `g` holds `2g` while unclaimed and `2g + 1` once
    /// claimed. Claiming is a single compare-exchange on `2g`, so a
    /// stale builder clone (older generation) can *never* claim a slot
    /// of a later checkout — no check-then-act window.
    claimed: Box<[AtomicU64]>,
    filled: AtomicUsize,
    raw_bytes: AtomicU64,
    /// checkout generation: bumped on every install, snapshotted by the
    /// builder, fused into the claim words above
    generation: AtomicU64,
    /// sampler epoch of the current checkout (diagnostics: a stale fill
    /// across an epoch seam names both sides)
    epoch: AtomicUsize,
    /// slot count of the current checkout (0 = not checked out)
    n: AtomicUsize,
    /// bytes per slot of the current checkout
    per: AtomicUsize,
    pixels: AtomicPtr<u8>,
    labels: AtomicPtr<i32>,
    indices: AtomicPtr<usize>,
    /// the owning buffers; present from checkout until finish/recover
    owned: Mutex<Option<SlabBuf>>,
}

impl SlabState {
    fn new(slots: usize) -> SlabState {
        SlabState {
            claimed: (0..slots).map(|_| AtomicU64::new(0)).collect(),
            filled: AtomicUsize::new(0),
            raw_bytes: AtomicU64::new(0),
            generation: AtomicU64::new(0),
            epoch: AtomicUsize::new(0),
            n: AtomicUsize::new(0),
            per: AtomicUsize::new(0),
            pixels: AtomicPtr::new(std::ptr::null_mut()),
            labels: AtomicPtr::new(std::ptr::null_mut()),
            indices: AtomicPtr::new(std::ptr::null_mut()),
            owned: Mutex::new(None),
        }
    }

    /// Publish write windows into `buf` for an `n`-item batch. Runs with
    /// exclusive access (checkout path, before any filler exists).
    fn install(&self, buf: &mut SlabBuf, n: usize, per: usize, epoch: usize) {
        self.epoch.store(epoch, Ordering::Relaxed);
        let gen = self.generation.fetch_add(1, Ordering::Relaxed) + 1;
        let unclaimed = gen.wrapping_mul(2);
        for c in self.claimed.iter() {
            c.store(unclaimed, Ordering::Relaxed);
        }
        self.filled.store(0, Ordering::Relaxed);
        self.raw_bytes.store(0, Ordering::Relaxed);
        self.per.store(per, Ordering::Relaxed);
        self.pixels.store(buf.pixels.as_mut_ptr(), Ordering::Relaxed);
        self.labels.store(buf.labels.as_mut_ptr(), Ordering::Relaxed);
        self.indices.store(buf.indices.as_mut_ptr(), Ordering::Relaxed);
        // the Release on `n` publishes everything above to fillers that
        // Acquire-load it
        self.n.store(n, Ordering::Release);
    }

    /// Retract the write windows (after finish/recover): any stray fill
    /// now fails cleanly instead of scribbling on recycled memory.
    fn clear_windows(&self) {
        self.n.store(0, Ordering::Relaxed);
        self.pixels.store(std::ptr::null_mut(), Ordering::Relaxed);
        self.labels.store(std::ptr::null_mut(), Ordering::Relaxed);
        self.indices.store(std::ptr::null_mut(), Ordering::Release);
    }
}

#[derive(Debug, Default)]
struct Counters {
    checkouts: AtomicU64,
    reused: AtomicU64,
    fresh: AtomicU64,
    recycled: AtomicU64,
    discarded: AtomicU64,
}

/// Arena counters (cumulative since creation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ArenaStats {
    /// slabs checked out
    pub checkouts: u64,
    /// checkouts served from the pool (zero-alloc path)
    pub reused: u64,
    /// checkouts that had to allocate a fresh slab
    pub fresh: u64,
    /// slabs returned to the pool
    pub recycled: u64,
    /// returns dropped because the pool was full / the buffer undersized
    pub discarded: u64,
    /// slabs currently resting in the pool
    pub pooled: u64,
}

struct Pool {
    states: Vec<Arc<SlabState>>,
    bufs: Vec<SlabBuf>,
}

/// Pool of reference-counted, recycled batch slabs.
pub struct BatchArena {
    crop: usize,
    /// bytes per item slot (crop × crop × 3)
    per: usize,
    /// slots per slab (the loader's batch_size)
    max_batch: usize,
    /// max slabs retained in the pool (`arena_slabs` knob)
    capacity: usize,
    /// hand out page-locked (simulated-pinned) slabs: batches are born
    /// pinned (`Batch.pinned`), `to_device` takes the pinned-bandwidth
    /// path, and the loader skips the staging copy. Fresh allocations
    /// pay a one-time registration cost; recycling amortizes it away.
    pinned: bool,
    pool: Mutex<Pool>,
    stats: Counters,
}

impl fmt::Debug for BatchArena {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "BatchArena(crop={}, slots={}, capacity={}, pinned={})",
            self.crop, self.max_batch, self.capacity, self.pinned
        )
    }
}

impl BatchArena {
    /// An arena for `[batch_size, crop, crop, 3]` slabs retaining up to
    /// `capacity` recycled slabs.
    pub fn new(crop: usize, batch_size: usize, capacity: usize) -> Arc<BatchArena> {
        BatchArena::new_opts(crop, batch_size, capacity, false)
    }

    /// [`BatchArena::new`] with pinning control (`pin_memory` knob).
    pub fn new_opts(
        crop: usize,
        batch_size: usize,
        capacity: usize,
        pinned: bool,
    ) -> Arc<BatchArena> {
        let capacity = capacity.max(1);
        Arc::new(BatchArena {
            crop,
            per: crop * crop * 3,
            max_batch: batch_size.max(1),
            capacity,
            pinned,
            pool: Mutex::new(Pool {
                states: Vec::with_capacity(capacity),
                bufs: Vec::with_capacity(capacity),
            }),
            stats: Counters::default(),
        })
    }

    pub fn crop(&self) -> usize {
        self.crop
    }

    /// Whether slabs are handed out page-locked.
    pub fn pinned(&self) -> bool {
        self.pinned
    }

    /// Bytes per item slot.
    pub fn item_bytes(&self) -> usize {
        self.per
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn stats(&self) -> ArenaStats {
        let pooled = self.pool.lock().unwrap().bufs.len() as u64;
        ArenaStats {
            checkouts: self.stats.checkouts.load(Ordering::Relaxed),
            reused: self.stats.reused.load(Ordering::Relaxed),
            fresh: self.stats.fresh.load(Ordering::Relaxed),
            recycled: self.stats.recycled.load(Ordering::Relaxed),
            discarded: self.stats.discarded.load(Ordering::Relaxed),
            pooled,
        }
    }

    /// Check a slab out for batch `id` with `n` items. Never blocks: if
    /// the pool is empty a fresh slab is allocated (counted in
    /// `stats().fresh` — nonzero in steady state means `arena_slabs` is
    /// too small for the in-flight batch count).
    ///
    /// Takes the `Arc` handle by value (clone it — a refcount bump, no
    /// allocation): the builder and the batch it produces both keep a
    /// handle for the recycle leg.
    pub fn checkout(self: Arc<Self>, id: usize, n: usize) -> BatchBuilder {
        self.checkout_tagged(id, id, 0, n)
    }

    /// [`BatchArena::checkout`] for the generation-tagged batch stream
    /// (cross-epoch pipelined loader): `id` is the consumer-visible
    /// per-epoch batch id, `seq` the continuous global dispatch
    /// sequence, and `epoch` the sampler epoch — the slab's claim-word
    /// generation plus the recorded epoch make an epoch-N straggler's
    /// stale fill into an epoch-N+1 re-checkout a clean per-batch error
    /// that names both sides of the seam.
    pub fn checkout_tagged(
        self: Arc<Self>,
        id: usize,
        seq: usize,
        epoch: usize,
        n: usize,
    ) -> BatchBuilder {
        self.stats.checkouts.fetch_add(1, Ordering::Relaxed);
        let (state, buf) = {
            let mut pool = self.pool.lock().unwrap();
            (pool.states.pop(), pool.bufs.pop())
        };
        let state = match state {
            Some(s) if s.claimed.len() >= n => s,
            _ => Arc::new(SlabState::new(n.max(self.max_batch))),
        };
        let mut buf = match buf {
            Some(b) => {
                self.stats.reused.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                self.stats.fresh.fetch_add(1, Ordering::Relaxed);
                if self.pinned {
                    // one-time page-lock registration (cudaHostRegister
                    // analogue): setup plus per-byte pinning cost — paid
                    // only on fresh slabs, so a warm pool never pays it
                    let bytes = n.max(self.max_batch) * self.per;
                    std::thread::sleep(
                        std::time::Duration::from_micros(60)
                            + std::time::Duration::from_secs_f64(
                                bytes as f64 / 1.5e9,
                            ),
                    );
                }
                SlabBuf::with_capacity(n.max(self.max_batch), self.per)
            }
        };
        // size for this batch: within the retained capacity these are
        // len adjustments only (a grow memsets just the regrown tail
        // after a partial batch)
        buf.pixels.resize(n * self.per, 0);
        buf.labels.resize(n, 0);
        buf.indices.resize(n, 0);
        state.install(&mut buf, n, self.per, epoch);
        *state.owned.lock().unwrap() = Some(buf);
        let generation = state.generation.load(Ordering::Relaxed);
        BatchBuilder {
            arena: self,
            state,
            generation,
            id,
            seq,
            epoch,
            n,
            primary: true,
        }
    }

    /// Return a finished batch's buffers to the pool (called by
    /// [`Batch::recycle`] — trainer/device side, after `to_device`).
    pub(crate) fn recycle_batch(&self, b: &mut Batch) {
        let buf = SlabBuf {
            shape: std::mem::take(&mut b.images.shape),
            pixels: std::mem::take(&mut b.images.data),
            labels: std::mem::take(&mut b.labels),
            indices: std::mem::take(&mut b.indices),
        };
        self.recycle_parts(buf);
    }

    fn recycle_parts(&self, buf: SlabBuf) {
        // undersized buffers (e.g. from a recycled clone of a partial
        // batch) would churn with reallocs — drop them instead
        if buf.pixels.capacity() < self.max_batch * self.per {
            self.stats.discarded.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let mut pool = self.pool.lock().unwrap();
        if pool.bufs.len() < self.capacity {
            pool.bufs.push(buf);
            self.stats.recycled.fetch_add(1, Ordering::Relaxed);
        } else {
            self.stats.discarded.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn return_state(&self, state: Arc<SlabState>) {
        let mut pool = self.pool.lock().unwrap();
        if pool.states.len() < self.capacity {
            pool.states.push(state);
        }
    }
}

/// Handle on one checked-out slab. Cloned into each parallel fetch task;
/// the clone that `checkout` returned (the *primary*) owns the slab's
/// fate: `finish()` turns it into a [`Batch`], dropping it recovers the
/// slab into the pool (the per-batch error path).
pub struct BatchBuilder {
    arena: Arc<BatchArena>,
    state: Arc<SlabState>,
    /// checkout generation this builder belongs to (see SlabState)
    generation: u64,
    id: usize,
    /// global dispatch sequence of this checkout (== `id` for untagged
    /// checkouts)
    seq: usize,
    /// sampler epoch of this checkout
    epoch: usize,
    n: usize,
    primary: bool,
}

impl Clone for BatchBuilder {
    fn clone(&self) -> BatchBuilder {
        BatchBuilder {
            arena: self.arena.clone(),
            state: self.state.clone(),
            generation: self.generation,
            id: self.id,
            seq: self.seq,
            epoch: self.epoch,
            n: self.n,
            primary: false,
        }
    }
}

impl BatchBuilder {
    pub fn id(&self) -> usize {
        self.id
    }

    /// Global dispatch sequence number of this checkout.
    pub fn seq(&self) -> usize {
        self.seq
    }

    /// Sampler epoch this checkout belongs to.
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Item count of this batch.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Fill slot `pos` with item `index`: claims the slot, hands its
    /// pixel window to `f` (which decodes + augments into it and returns
    /// the item metadata), then records label/index/raw_bytes. Errors on
    /// an out-of-range or doubly-filled slot and propagates `f`'s error
    /// (the slot stays claimed; the batch then fails in `finish`).
    pub fn fill<F>(&self, pos: usize, index: usize, f: F) -> Result<()>
    where
        F: FnOnce(&mut [u8]) -> Result<ItemMeta>,
    {
        let st = &*self.state;
        let n = st.n.load(Ordering::Acquire);
        if pos >= n {
            bail!("slot {pos} out of range (batch of {n})");
        }
        // claim atomically *for this builder's generation*: one CAS both
        // takes the slot and proves the slab wasn't re-checked out
        let unclaimed = self.generation.wrapping_mul(2);
        match st.claimed[pos].compare_exchange(
            unclaimed,
            unclaimed + 1,
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => {}
            Err(cur) if cur == unclaimed + 1 => bail!("slot {pos} filled twice"),
            Err(_) => {
                bail!(
                    "stale builder (batch {}, epoch {}): slab was re-checked \
                     out for another batch (now epoch {})",
                    self.id,
                    self.epoch,
                    st.epoch.load(Ordering::Relaxed)
                )
            }
        }
        let per = st.per.load(Ordering::Relaxed);
        let px = st.pixels.load(Ordering::Relaxed);
        let lb = st.labels.load(Ordering::Relaxed);
        let ix = st.indices.load(Ordering::Relaxed);
        if px.is_null() || lb.is_null() || ix.is_null() {
            bail!("slab no longer checked out");
        }
        // SAFETY: the claim bit above grants this call exclusive access
        // to slot `pos`; slot windows are disjoint by construction, and
        // the owning SlabBuf stays resident in `st.owned` until
        // finish()/recover, which the worker only runs after observing
        // completion of every fill (channel/join happens-before).
        let out = unsafe { std::slice::from_raw_parts_mut(px.add(pos * per), per) };
        let meta = f(out)?;
        // SAFETY: same exclusivity argument, one element at `pos`.
        unsafe {
            *lb.add(pos) = meta.label as i32;
            *ix.add(pos) = index;
        }
        st.raw_bytes.fetch_add(meta.raw_bytes as u64, Ordering::Relaxed);
        st.filled.fetch_add(1, Ordering::Release);
        Ok(())
    }

    /// Slots successfully filled so far.
    pub fn filled(&self) -> usize {
        self.state.filled.load(Ordering::Acquire)
    }

    /// Convert the fully-filled slab into a [`Batch`] (no copy). Errors
    /// — returning the slab to the pool — if any slot is unfilled. Must
    /// only be called after every fill completed (see module docs).
    pub fn finish(mut self) -> Result<Batch> {
        // disarm the Drop recovery — this call consumes the slab itself
        self.primary = false;
        let arena = self.arena.clone();
        let state = self.state.clone();
        let (id, n) = (self.id, self.n);
        drop(self);
        let filled = state.filled.load(Ordering::Acquire);
        let Some(mut buf) = state.owned.lock().unwrap().take() else {
            bail!("batch {id}: slab already finished or recovered");
        };
        state.clear_windows();
        if n == 0 || filled != n {
            arena.recycle_parts(buf);
            arena.return_state(state);
            bail!("batch {id}: {filled}/{n} slots filled");
        }
        let per = arena.per;
        buf.pixels.truncate(n * per);
        buf.labels.truncate(n);
        buf.indices.truncate(n);
        let mut shape = std::mem::take(&mut buf.shape);
        shape.clear();
        shape.extend_from_slice(&[n, arena.crop, arena.crop, 3]);
        let images = U8Tensor {
            shape,
            data: std::mem::take(&mut buf.pixels),
        };
        let labels = std::mem::take(&mut buf.labels);
        let indices = std::mem::take(&mut buf.indices);
        let raw_bytes = state.raw_bytes.load(Ordering::Relaxed);
        arena.return_state(state);
        let pinned = arena.pinned;
        Ok(Batch {
            id,
            images,
            labels,
            indices,
            raw_bytes,
            pinned,
            arena: Some(arena),
        })
    }
}

impl Drop for BatchBuilder {
    fn drop(&mut self) {
        if !self.primary {
            return;
        }
        // abandoned wave (item error / consumer hung up): recover the
        // slab so the pool doesn't leak capacity
        if let Some(buf) = self.state.owned.lock().unwrap().take() {
            self.state.clear_windows();
            self.arena.recycle_parts(buf);
            self.arena.return_state(self.state.clone());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(label: u16, raw: usize) -> ItemMeta {
        ItemMeta { label, raw_bytes: raw }
    }

    fn fill_all(b: &BatchBuilder, base: usize) {
        for pos in 0..b.len() {
            b.fill(pos, base + pos, |out| {
                out.fill((base + pos) as u8);
                Ok(meta(pos as u16, 100))
            })
            .unwrap();
        }
    }

    #[test]
    fn roundtrip_builds_correct_batch() {
        let arena = BatchArena::new(4, 3, 2);
        let b = arena.clone().checkout(7, 3);
        fill_all(&b, 10);
        let batch = b.finish().unwrap();
        assert_eq!(batch.id, 7);
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.images.shape, vec![3, 4, 4, 3]);
        assert_eq!(batch.images.data.len(), 3 * 48);
        for pos in 0..3 {
            assert!(batch.images.data[pos * 48..(pos + 1) * 48]
                .iter()
                .all(|&v| v == (10 + pos) as u8));
        }
        assert_eq!(batch.labels, vec![0, 1, 2]);
        assert_eq!(batch.indices, vec![10, 11, 12]);
        assert_eq!(batch.raw_bytes, 300);
        assert!(batch.arena.is_some());
    }

    #[test]
    fn recycle_reuses_slab_without_fresh_alloc() {
        let arena = BatchArena::new(4, 2, 2);
        for id in 0..5 {
            let b = arena.clone().checkout(id, 2);
            fill_all(&b, id);
            b.finish().unwrap().recycle();
        }
        let s = arena.stats();
        assert_eq!(s.checkouts, 5);
        assert_eq!(s.fresh, 1, "{s:?}");
        assert_eq!(s.reused, 4, "{s:?}");
        assert_eq!(s.recycled, 5, "{s:?}");
        assert_eq!(s.pooled, 1, "{s:?}");
    }

    #[test]
    fn duplicate_fill_is_an_error_not_a_panic() {
        let arena = BatchArena::new(2, 2, 1);
        let b = arena.clone().checkout(0, 2);
        b.fill(0, 0, |out| {
            out.fill(1);
            Ok(meta(0, 1))
        })
        .unwrap();
        let err = b
            .fill(0, 9, |out| {
                out.fill(2);
                Ok(meta(0, 1))
            })
            .unwrap_err();
        assert!(err.to_string().contains("filled twice"), "{err}");
        assert!(b.fill(5, 0, |_| Ok(meta(0, 1))).is_err());
    }

    #[test]
    fn finish_with_hole_errors_and_recovers_slab() {
        let arena = BatchArena::new(2, 2, 2);
        let b = arena.clone().checkout(3, 2);
        b.fill(0, 0, |out| {
            out.fill(1);
            Ok(meta(0, 1))
        })
        .unwrap();
        let err = b.finish().unwrap_err();
        assert!(err.to_string().contains("1/2 slots"), "{err}");
        // slab went back to the pool, the next checkout reuses it
        let b2 = arena.clone().checkout(4, 2);
        fill_all(&b2, 0);
        b2.finish().unwrap();
        let s = arena.stats();
        assert_eq!(s.fresh, 1, "{s:?}");
        assert_eq!(s.reused, 1, "{s:?}");
    }

    #[test]
    fn dropped_builder_recovers_slab() {
        let arena = BatchArena::new(2, 2, 2);
        let b = arena.clone().checkout(0, 2);
        let clone = b.clone();
        drop(clone); // passive handle: no recovery
        assert_eq!(arena.stats().recycled, 0);
        drop(b); // primary: recovers
        assert_eq!(arena.stats().recycled, 1);
        assert_eq!(arena.stats().pooled, 1);
    }

    #[test]
    fn partial_batch_truncates_then_regrows() {
        let arena = BatchArena::new(2, 4, 2);
        let b = arena.clone().checkout(0, 2); // partial: 2 of 4 slots
        fill_all(&b, 0);
        let batch = b.finish().unwrap();
        assert_eq!(batch.images.shape, vec![2, 2, 2, 3]);
        assert_eq!(batch.images.data.len(), 2 * 12);
        batch.recycle();
        let b2 = arena.clone().checkout(1, 4); // full batch on the recycled slab
        fill_all(&b2, 0);
        let batch2 = b2.finish().unwrap();
        assert_eq!(batch2.images.data.len(), 4 * 12);
        assert_eq!(arena.stats().reused, 1);
    }

    #[test]
    fn capacity_bounds_pool_retention() {
        let arena = BatchArena::new(2, 2, 1);
        let a = arena.clone().checkout(0, 2);
        let b = arena.clone().checkout(1, 2);
        fill_all(&a, 0);
        fill_all(&b, 0);
        a.finish().unwrap().recycle();
        b.finish().unwrap().recycle();
        let s = arena.stats();
        assert_eq!(s.recycled, 1, "{s:?}");
        assert_eq!(s.discarded, 1, "{s:?}");
        assert_eq!(s.pooled, 1, "{s:?}");
    }

    #[test]
    fn concurrent_fills_land_in_their_slots() {
        let arena = BatchArena::new(8, 16, 2);
        let b = arena.clone().checkout(0, 16);
        std::thread::scope(|s| {
            for pos in 0..16 {
                let h = b.clone();
                s.spawn(move || {
                    h.fill(pos, 100 + pos, |out| {
                        out.fill(pos as u8);
                        Ok(meta(pos as u16, 10))
                    })
                    .unwrap();
                });
            }
        });
        let batch = b.finish().unwrap();
        let per = 8 * 8 * 3;
        for pos in 0..16 {
            assert!(
                batch.images.data[pos * per..(pos + 1) * per]
                    .iter()
                    .all(|&v| v == pos as u8),
                "slot {pos} corrupted"
            );
            assert_eq!(batch.labels[pos], pos as i32);
            assert_eq!(batch.indices[pos], 100 + pos);
        }
        assert_eq!(batch.raw_bytes, 160);
    }

    #[test]
    fn pinned_arena_marks_batches_and_recycles_pinning() {
        let arena = BatchArena::new_opts(4, 2, 2, true);
        assert!(arena.pinned());
        let b = arena.clone().checkout(0, 2);
        fill_all(&b, 0);
        let batch = b.finish().unwrap();
        assert!(batch.pinned);
        batch.recycle();
        // recycled slab: still pinned, no fresh registration
        let b = arena.clone().checkout(1, 2);
        fill_all(&b, 0);
        assert!(b.finish().unwrap().pinned);
        let s = arena.stats();
        assert_eq!(s.fresh, 1, "{s:?}");
        // unpinned arena produces unpinned batches
        let plain = BatchArena::new(4, 2, 2);
        assert!(!plain.pinned());
        let b = plain.clone().checkout(0, 2);
        fill_all(&b, 0);
        assert!(!b.finish().unwrap().pinned);
    }

    #[test]
    fn fill_after_finish_fails_cleanly() {
        let arena = BatchArena::new(2, 1, 1);
        let b = arena.clone().checkout(0, 1);
        let stale = b.clone();
        b.fill(0, 0, |out| {
            out.fill(3);
            Ok(meta(0, 1))
        })
        .unwrap();
        let batch = b.finish().unwrap();
        assert!(stale.fill(0, 0, |_| Ok(meta(0, 1))).is_err());
        batch.recycle();

        // harder case: the slab is re-checked out for a NEW batch — the
        // stale clone's generation no longer matches, so it cannot
        // scribble on the new batch's slots
        let b2 = arena.clone().checkout(1, 1);
        let err = stale.fill(0, 9, |_| Ok(meta(0, 1))).unwrap_err();
        assert!(err.to_string().contains("stale builder"), "{err}");
        b2.fill(0, 5, |out| {
            out.fill(8);
            Ok(meta(1, 2))
        })
        .unwrap();
        let batch2 = b2.finish().unwrap();
        assert!(batch2.images.data.iter().all(|&v| v == 8));
        assert_eq!(batch2.indices, vec![5]);
    }
}
