//! Batch collation: assemble fetched samples (in request order) into one
//! contiguous u8 image tensor + label vector — torch's default
//! `collate_fn`, which runs inside the worker process (under its GIL).

use crate::data::U8Tensor;
use crate::dataset::Sample;

/// A collated training batch.
#[derive(Debug, Clone)]
pub struct Batch {
    pub id: usize,
    /// [B, crop, crop, 3] u8 (normalize runs on-device — L1 kernel)
    pub images: U8Tensor,
    pub labels: Vec<i32>,
    /// dataset indices in request order
    pub indices: Vec<usize>,
    /// total stored object bytes (throughput accounting)
    pub raw_bytes: u64,
    pub pinned: bool,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Host memory footprint of the collated tensor.
    pub fn tensor_bytes(&self) -> usize {
        self.images.data.len()
    }
}

/// Collate samples (already sorted to request order) into a [`Batch`].
/// Panics if crops disagree in shape — samples of one dataset always
/// share the transform output shape.
pub fn collate(id: usize, samples: Vec<Sample>) -> Batch {
    assert!(!samples.is_empty(), "collate of empty batch");
    let crop_shape = samples[0].crop.shape.clone();
    let per = samples[0].crop.data.len();
    let b = samples.len();
    let mut images = U8Tensor::zeros(&[b, crop_shape[0], crop_shape[1], crop_shape[2]]);
    let mut labels = Vec::with_capacity(b);
    let mut indices = Vec::with_capacity(b);
    let mut raw_bytes = 0u64;
    for (i, s) in samples.into_iter().enumerate() {
        assert_eq!(s.crop.shape, crop_shape, "ragged crop shapes");
        images.data[i * per..(i + 1) * per].copy_from_slice(&s.crop.data);
        labels.push(s.label as i32);
        indices.push(s.index);
        raw_bytes += s.raw_bytes as u64;
    }
    Batch { id, images, labels, indices, raw_bytes, pinned: false }
}

/// Restore request order after parallel fetch: place each sample at its
/// position, panicking on duplicates/holes (the reassembly invariant the
/// property tests check).
pub fn restore_order(n: usize, fetched: Vec<(usize, Sample)>) -> Vec<Sample> {
    assert_eq!(fetched.len(), n, "wrong sample count");
    let mut slots: Vec<Option<Sample>> = (0..n).map(|_| None).collect();
    for (pos, s) in fetched {
        assert!(pos < n, "position out of range");
        assert!(slots[pos].is_none(), "duplicate position {pos}");
        slots[pos] = Some(s);
    }
    slots.into_iter().map(|s| s.expect("hole in batch")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::U8Tensor;

    pub(crate) fn fake_sample(index: usize, label: u16, fill: u8, crop: usize) -> Sample {
        Sample {
            index,
            label,
            crop: U8Tensor {
                shape: vec![crop, crop, 3],
                data: vec![fill; crop * crop * 3],
            },
            raw_bytes: 100 + index,
            fetch_time: 0.0,
            decode_time: 0.0,
        }
    }

    #[test]
    fn collate_concatenates_in_order() {
        let samples = vec![
            fake_sample(5, 1, 10, 2),
            fake_sample(9, 2, 20, 2),
        ];
        let b = collate(3, samples);
        assert_eq!(b.id, 3);
        assert_eq!(b.len(), 2);
        assert_eq!(b.images.shape, vec![2, 2, 2, 3]);
        assert!(b.images.data[..12].iter().all(|&v| v == 10));
        assert!(b.images.data[12..].iter().all(|&v| v == 20));
        assert_eq!(b.labels, vec![1, 2]);
        assert_eq!(b.indices, vec![5, 9]);
        assert_eq!(b.raw_bytes, 105 + 109);
    }

    #[test]
    fn restore_order_sorts_arrivals() {
        let fetched = vec![
            (2, fake_sample(30, 0, 3, 1)),
            (0, fake_sample(10, 0, 1, 1)),
            (1, fake_sample(20, 0, 2, 1)),
        ];
        let sorted = restore_order(3, fetched);
        assert_eq!(
            sorted.iter().map(|s| s.index).collect::<Vec<_>>(),
            vec![10, 20, 30]
        );
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn restore_order_rejects_duplicates() {
        restore_order(
            2,
            vec![(0, fake_sample(0, 0, 0, 1)), (0, fake_sample(1, 0, 0, 1))],
        );
    }

    #[test]
    #[should_panic(expected = "wrong sample count")]
    fn restore_order_rejects_short() {
        restore_order(3, vec![(0, fake_sample(0, 0, 0, 1))]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn collate_rejects_ragged() {
        collate(0, vec![fake_sample(0, 0, 0, 2), fake_sample(1, 0, 0, 3)]);
    }
}
