//! Batch collation: assemble fetched samples (in request order) into one
//! contiguous u8 image tensor + label vector — torch's default
//! `collate_fn`, which runs inside the worker process (under its GIL).
//!
//! This is the *legacy* copying path; with a [`crate::dataloader::arena`]
//! attached the fetchers write into the batch slab directly and no
//! collate step exists. Both paths produce byte-identical batches
//! (`tests/test_hotpath.rs`).

use std::sync::Arc;

use anyhow::{bail, Result};

use super::arena::BatchArena;
use crate::data::U8Tensor;
use crate::dataset::Sample;

/// A collated training batch.
#[derive(Debug, Clone)]
pub struct Batch {
    pub id: usize,
    /// [B, crop, crop, 3] u8 (normalize runs on-device — L1 kernel)
    pub images: U8Tensor,
    pub labels: Vec<i32>,
    /// dataset indices in request order
    pub indices: Vec<usize>,
    /// total stored object bytes (throughput accounting)
    pub raw_bytes: u64,
    pub pinned: bool,
    /// the arena this batch's slab came from (None for heap batches);
    /// [`Batch::recycle`] returns the buffers there
    pub(crate) arena: Option<Arc<BatchArena>>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Host memory footprint of the collated tensor.
    pub fn tensor_bytes(&self) -> usize {
        self.images.data.len()
    }

    /// Whether this batch rides an arena slab (and should be recycled).
    pub fn is_pooled(&self) -> bool {
        self.arena.is_some()
    }

    /// Return this batch's buffers to their arena — the trainer/device
    /// side of the slab lifecycle (checkout → fill → to_device →
    /// **recycle**). A no-op for heap-allocated batches, so callers can
    /// recycle unconditionally. Forgetting to call it never breaks
    /// correctness; the pool just refills through fresh allocations.
    pub fn recycle(mut self) {
        if let Some(arena) = self.arena.take() {
            arena.recycle_batch(&mut self);
        }
    }
}

/// Collate samples (already sorted to request order) into a [`Batch`].
/// Empty and ragged inputs are *errors* (surfaced through the worker's
/// per-batch error path), not process aborts.
pub fn collate(id: usize, samples: Vec<Sample>) -> Result<Batch> {
    if samples.is_empty() {
        bail!("collate of empty batch {id}");
    }
    let crop_shape = samples[0].crop.shape.clone();
    let per = samples[0].crop.data.len();
    let b = samples.len();
    let mut images = U8Tensor::zeros(&[b, crop_shape[0], crop_shape[1], crop_shape[2]]);
    let mut labels = Vec::with_capacity(b);
    let mut indices = Vec::with_capacity(b);
    let mut raw_bytes = 0u64;
    for (i, s) in samples.into_iter().enumerate() {
        if s.crop.shape != crop_shape {
            bail!(
                "ragged crop shapes in batch {id}: {:?} vs {:?}",
                s.crop.shape,
                crop_shape
            );
        }
        images.data[i * per..(i + 1) * per].copy_from_slice(&s.crop.data);
        labels.push(s.label as i32);
        indices.push(s.index);
        raw_bytes += s.raw_bytes as u64;
    }
    Ok(Batch {
        id,
        images,
        labels,
        indices,
        raw_bytes,
        pinned: false,
        arena: None,
    })
}

/// Restore request order after parallel fetch: place each sample at its
/// position, panicking on duplicates/holes (the reassembly invariant the
/// property tests check).
pub fn restore_order(n: usize, fetched: Vec<(usize, Sample)>) -> Vec<Sample> {
    assert_eq!(fetched.len(), n, "wrong sample count");
    let mut slots: Vec<Option<Sample>> = (0..n).map(|_| None).collect();
    for (pos, s) in fetched {
        assert!(pos < n, "position out of range");
        assert!(slots[pos].is_none(), "duplicate position {pos}");
        slots[pos] = Some(s);
    }
    slots.into_iter().map(|s| s.expect("hole in batch")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::U8Tensor;

    pub(crate) fn fake_sample(index: usize, label: u16, fill: u8, crop: usize) -> Sample {
        Sample {
            index,
            label,
            crop: U8Tensor {
                shape: vec![crop, crop, 3],
                data: vec![fill; crop * crop * 3],
            },
            raw_bytes: 100 + index,
            fetch_time: 0.0,
            decode_time: 0.0,
        }
    }

    #[test]
    fn collate_concatenates_in_order() {
        let samples = vec![
            fake_sample(5, 1, 10, 2),
            fake_sample(9, 2, 20, 2),
        ];
        let b = collate(3, samples).unwrap();
        assert_eq!(b.id, 3);
        assert_eq!(b.len(), 2);
        assert_eq!(b.images.shape, vec![2, 2, 2, 3]);
        assert!(b.images.data[..12].iter().all(|&v| v == 10));
        assert!(b.images.data[12..].iter().all(|&v| v == 20));
        assert_eq!(b.labels, vec![1, 2]);
        assert_eq!(b.indices, vec![5, 9]);
        assert_eq!(b.raw_bytes, 105 + 109);
        assert!(!b.is_pooled());
        b.recycle(); // no-op for heap batches
    }

    #[test]
    fn restore_order_sorts_arrivals() {
        let fetched = vec![
            (2, fake_sample(30, 0, 3, 1)),
            (0, fake_sample(10, 0, 1, 1)),
            (1, fake_sample(20, 0, 2, 1)),
        ];
        let sorted = restore_order(3, fetched);
        assert_eq!(
            sorted.iter().map(|s| s.index).collect::<Vec<_>>(),
            vec![10, 20, 30]
        );
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn restore_order_rejects_duplicates() {
        restore_order(
            2,
            vec![(0, fake_sample(0, 0, 0, 1)), (0, fake_sample(1, 0, 0, 1))],
        );
    }

    #[test]
    #[should_panic(expected = "wrong sample count")]
    fn restore_order_rejects_short() {
        restore_order(3, vec![(0, fake_sample(0, 0, 0, 1))]);
    }

    #[test]
    fn collate_rejects_ragged_as_error() {
        let err = collate(0, vec![fake_sample(0, 0, 0, 2), fake_sample(1, 0, 0, 3)])
            .unwrap_err();
        assert!(err.to_string().contains("ragged"), "{err}");
    }

    #[test]
    fn collate_rejects_empty_as_error() {
        let err = collate(4, Vec::new()).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
    }
}
