//! Worker "processes" (Fig 3/4 of the paper): each worker pulls batch
//! work from its [`WorkSource`] — a shared per-worker static deque
//! (torch round-robin) or the shared work-stealing injector — fetches
//! batches via the configured fetcher strategy, assembles them (legacy
//! collate copy, or fused straight into an arena slab), and pushes
//! finished batches into the bounded data queue.
//!
//! A worker is an OS thread standing in for a CPython worker process:
//! it owns its own [`Gil`] (decode/augment serialize within the worker,
//! never across workers) and pays the configured process start-up cost
//! (`fork` vs `spawn`) before doing any work.
//!
//! Since PR 5 workers are **persistent across epochs**: spawned once
//! per `Dataloader`, they pull [`BatchTicket`]s off a continuous
//! generation-tagged stream. When the published stream runs dry a
//! worker does not exit — it asks the loader's [`Planner`] for more
//! work, which (with `epoch_pipeline > 0`) publishes the *next* epoch's
//! plan right there, so the fetch pipeline never goes cold at the
//! boundary; with `epoch_pipeline = 0` (legacy drain) the worker parks
//! until the consumer requests the next epoch.
//!
//! Two tail-taming behaviors (PR 4):
//!
//! * every acquisition goes through the epoch's [`CreditGate`]: a batch
//!   is only *started* while its seq is within `consumer_credit` of the
//!   consumer's in-order cursor, bounding the reorder buffer — the gate
//!   works on global seqs, so the window rolls straight across epoch
//!   seams;
//! * with `steal_items` (work-stealing dispatch + arena), a worker that
//!   cannot start a new batch — credit-blocked or out of published
//!   tickets — claims *unclaimed tail items* of siblings' in-progress
//!   batches and decodes them straight into the owners' slabs instead
//!   of idling.
//!
//! Per-batch failures (corrupt object, ragged/empty collate) are
//! surfaced on stderr and skipped — one bad batch never aborts the
//! process or the epoch.

use std::collections::VecDeque;
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::anyhow;

use crate::asyncrt;
use crate::dataloader::arena::BatchArena;
use crate::dataloader::collate::{collate, Batch};
use crate::dataloader::fetch::{
    fetch_async, fetch_async_fused_tasks, fetch_threaded, fetch_threaded_fused_tasks,
    fetch_vanilla, fetch_vanilla_fused, fill_wave_ring, fill_wave_sequential, FetchCtx,
    ThreadPool,
};
use crate::dataloader::sampler::{self, BatchInjector, BatchTicket, Claimed, CreditGate};
use crate::dataloader::{DataloaderConfig, FetchImpl, Planner};
use crate::dataset::Dataset;
use crate::gil::Gil;
use crate::storage::IoRing;
use crate::telemetry::{names, Recorder};

/// Fallback park bound for an idle item-stealing worker. The worker
/// parks on the injector's work condvar and is woken the moment new
/// work appears — a steal-task registration, a plan publication, or a
/// credit advance (the gate's waker hook bumps the same condvar) — so
/// this timeout only bounds the stall after a *lost* edge, replacing
/// the old 1 kHz `STEAL_PARK` polling loop.
const STEAL_FALLBACK_PARK: Duration = Duration::from_millis(50);

/// What a worker pushes into the data queue: a finished batch, or a
/// tombstone for a batch that failed (so the in-order consumer can
/// advance past the gap immediately instead of buffering the rest of
/// the epoch waiting for a seq that will never arrive). Both are keyed
/// by the **global dispatch seq** — unique across epochs, unlike the
/// per-epoch batch id.
pub enum WorkerMsg {
    Batch { seq: usize, batch: Batch },
    /// the batch at `seq` failed in this worker (already logged)
    Failed { seq: usize },
}

/// A per-worker static assignment queue, shared with the planner (which
/// appends each published epoch's round-robin share to it).
pub type StaticQueue = Arc<Mutex<VecDeque<BatchTicket>>>;

/// Where a worker's batches come from.
pub enum WorkSource {
    /// Shared per-worker deque (torch's static round-robin split); the
    /// planner pushes, the worker pops front in seq order.
    Static(StaticQueue),
    /// Shared injector queue — this worker steals the globally-next
    /// batch whenever it goes idle (`work_stealing` knob).
    Stealing(Arc<BatchInjector>),
}

impl WorkSource {
    /// Credit-gated wave acquisition: up to `k` batches whose seqs the
    /// gate admits.
    fn next_group(&mut self, k: usize, gate: &CreditGate) -> Claimed {
        match self {
            WorkSource::Static(q) => {
                sampler::take_admitted(&mut q.lock().unwrap(), k, gate)
            }
            WorkSource::Stealing(inj) => inj.steal_group_admitted(k, gate),
        }
    }

    fn injector(&self) -> Option<&Arc<BatchInjector>> {
        match self {
            WorkSource::Static(_) => None,
            WorkSource::Stealing(inj) => Some(inj),
        }
    }
}

/// Spawn one worker thread over its work source. `spawn_delay` is paid
/// *inside* the thread before any fetching (the interpreter start-up of
/// a `spawn`-method process, or ~0 for `fork`). With a [`Planner`] the
/// worker is persistent: it survives stream droughts and exits only on
/// planner shutdown or a dead consumer; without one (unit tests) it
/// exits when its source drains. Crate-internal: the `Planner` in the
/// signature is a loader implementation detail (`Dataloader::epoch` is
/// the public entry point).
#[allow(clippy::too_many_arguments)]
pub(crate) fn spawn_worker(
    worker_id: u32,
    dataset: Arc<dyn Dataset>,
    recorder: Arc<Recorder>,
    cfg: Arc<DataloaderConfig>,
    source: WorkSource,
    arena: Option<Arc<BatchArena>>,
    gate: Arc<CreditGate>,
    planner: Option<Arc<Planner>>,
    out: SyncSender<WorkerMsg>,
    spawn_delay: std::time::Duration,
    ring: Option<Arc<IoRing>>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("dl-worker{worker_id}"))
        .spawn(move || {
            let t0 = recorder.now();
            if !spawn_delay.is_zero() {
                std::thread::sleep(spawn_delay);
            }
            recorder.record(names::WORKER_SPAWN, worker_id, -1, t0, recorder.now());
            run_worker(
                worker_id, dataset, recorder, cfg, source, arena, gate, planner, out,
                ring,
            );
        })
        .expect("spawn dataloader worker")
}

/// Per-impl fetch machinery, built once per worker (and reused across
/// every epoch the worker serves).
enum Engine {
    Vanilla,
    Threaded(ThreadPool),
    Asyncio(Arc<asyncrt::Runtime>, Arc<asyncrt::Semaphore>),
}

#[allow(clippy::too_many_arguments)]
fn run_worker(
    worker_id: u32,
    dataset: Arc<dyn Dataset>,
    recorder: Arc<Recorder>,
    cfg: Arc<DataloaderConfig>,
    mut source: WorkSource,
    arena: Option<Arc<BatchArena>>,
    gate: Arc<CreditGate>,
    planner: Option<Arc<Planner>>,
    out: SyncSender<WorkerMsg>,
    ring: Option<Arc<IoRing>>,
) {
    let gil = Gil::new(cfg.runtime, cfg.python_tax);
    let ctx = Arc::new(FetchCtx {
        worker_id,
        dataset,
        gil: gil.clone(),
        recorder: recorder.clone(),
    });

    let engine = match cfg.fetch_impl {
        FetchImpl::Vanilla => Engine::Vanilla,
        FetchImpl::Threaded => Engine::Threaded(ThreadPool::new(
            cfg.num_fetch_workers,
            &format!("w{worker_id}"),
        )),
        FetchImpl::Asyncio => Engine::Asyncio(
            // single-threaded event loop: the asyncio worker model
            asyncrt::Runtime::new(1),
            asyncrt::Semaphore::new(cfg.num_fetch_workers.max(1)),
        ),
    };
    // batch disassembly: number of batches pulled per wave (Threaded)
    let group = match (&engine, cfg.batch_pool) {
        (Engine::Threaded(_), pool) if pool > 0 => {
            (pool / cfg.batch_size.max(1)).max(1)
        }
        _ => 1,
    };
    // item-level stealing needs both the shared injector (to find
    // siblings' in-progress batches) and the arena (whose per-slot claim
    // bits make concurrent in-place fill safe); whether a *capable*
    // worker actually steals is a live knob, re-read each acquisition
    let steal_capable = arena.is_some() && source.injector().is_some();
    let knobs = planner.as_ref().map(|p| p.knobs().clone());
    // publications this worker has observed (see Planner::wait_for_work)
    let mut seen_plans = 0usize;
    // recycled (key, buf) pairs for ring waves — grows to the largest
    // wave once, then the submission path is allocation-free
    let mut ring_scratch: Vec<(String, Vec<u8>)> = Vec::new();
    // throttle poll when this worker is parked out of the active set
    const THROTTLE_PARK: Duration = Duration::from_millis(2);

    loop {
        // the Governor benches effective parallelism by shrinking the
        // active set: a worker past the committed count parks (injector
        // dispatch only — a static queue would strand its share). It
        // keeps polling so a seam that re-widens the set revives it.
        if let Some(knobs) = &knobs {
            if source.injector().is_some()
                && (worker_id as usize) >= knobs.active_workers()
            {
                if planner.as_ref().is_some_and(|p| p.is_shutdown()) {
                    return;
                }
                std::thread::sleep(THROTTLE_PARK);
                knobs.note_throttled(THROTTLE_PARK);
                continue;
            }
        }
        let steal_items = steal_capable
            && knobs.as_ref().map_or(cfg.steal_items, |k| k.steal_items());
        let work = match source.next_group(group, &gate) {
            Claimed::Work(work) => work,
            Claimed::Blocked(head) => {
                // can't start a new batch yet: help a straggler instead
                // of idling, else park until the consumer catches up. A
                // stealing worker parks on the injector condvar (new
                // steal tasks and credit advances both signal it); a
                // non-stealing one has nothing to do but wait, so it
                // blocks on the gate outright (advance()/close() wake
                // it). Either wait books into the credit-blocked lane.
                if steal_items {
                    let inj =
                        source.injector().expect("steal_items implies injector");
                    // version-grab *before* the probes: any signal after
                    // this point cancels the park instead of being lost
                    let cur = inj.work_version();
                    if !steal_one_item(&ctx, &source) && !gate.admits(head) {
                        let t0 = Instant::now();
                        inj.wait_version(cur, STEAL_FALLBACK_PARK);
                        gate.note_blocked(t0.elapsed());
                    }
                } else {
                    gate.wait_admit(head);
                }
                continue;
            }
            Claimed::Drained => {
                // the published stream ran dry: drain any stealable tail
                // items (the last batches are exactly the stragglers),
                // then ask the planner for the next epoch's plan — under
                // `epoch_pipeline` it is published right here, keeping
                // this worker warm across the seam; in legacy drain mode
                // the worker parks until the consumer attaches the next
                // epoch. Without a planner (unit tests) the drought is
                // final: exit.
                if steal_items {
                    let inj =
                        source.injector().expect("steal_items implies injector");
                    let cur = inj.work_version();
                    if steal_one_item(&ctx, &source) {
                        continue;
                    }
                    let Some(planner) = planner.as_ref() else { return };
                    let before = seen_plans;
                    // non-blocking probe: publishes a pipelined plan or
                    // observes a fresh one without holding the worker on
                    // the planner condvar
                    if !planner.wait_for_work(
                        worker_id,
                        &mut seen_plans,
                        Some(Duration::ZERO),
                    ) {
                        return;
                    }
                    if seen_plans > before {
                        continue;
                    }
                    // nothing stealable and no new plan: park on the
                    // injector condvar and book the wait as seam idle
                    let t0 = Instant::now();
                    inj.wait_version(cur, STEAL_FALLBACK_PARK);
                    planner.add_seam_idle(worker_id, t0.elapsed());
                    continue;
                }
                let Some(planner) = planner.as_ref() else { return };
                if !planner.wait_for_work(worker_id, &mut seen_plans, None) {
                    return;
                }
                continue;
            }
        };
        let t0 = recorder.now();
        // Panic containment: a panic anywhere in the wave (e.g. the
        // fetch pool losing its last thread) must still produce one
        // message per claimed seq — under `consumer_credit` the
        // siblings are parked until these seqs deliver, so a silently
        // vanished wave would hang the whole epoch, not just lose data.
        // Unwinding drops the wave's builders (slabs recover) and any
        // held ItemClaims (reported as abandoned to their tasks).
        let wave = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_wave(
                &engine,
                &arena,
                &ctx,
                &gil,
                &source,
                steal_items,
                &work,
                &ring,
                &mut ring_scratch,
            )
        }));
        let results: Vec<(usize, anyhow::Result<Batch>)> = match wave {
            Ok(results) => results,
            Err(_) => {
                // withdraw the wave's tasks from the steal registry —
                // settle_wave never ran, and stale tasks would otherwise
                // hand thieves slots into recovered slabs all epoch
                if let Some(inj) = source.injector() {
                    for t in &work {
                        inj.unregister(t.seq);
                    }
                }
                work.iter()
                    .map(|t| (t.seq, Err(anyhow!("worker panicked mid-wave"))))
                    .collect()
            }
        };
        for (seq, res) in results {
            let msg = match res {
                Ok(batch) => {
                    let epoch = work
                        .iter()
                        .find(|t| t.seq == seq)
                        .map_or(-1, |t| t.epoch as i64);
                    recorder.record_tagged(
                        names::BATCH_INFLIGHT,
                        worker_id,
                        batch.id as i64,
                        epoch,
                        seq as i64,
                        t0,
                        recorder.now(),
                    );
                    WorkerMsg::Batch { seq, batch }
                }
                Err(e) => {
                    // the per-batch error path: log, tombstone, move on
                    let tag = work
                        .iter()
                        .find(|t| t.seq == seq)
                        .map(|t| format!("epoch {} batch {}", t.epoch, t.id))
                        .unwrap_or_else(|| format!("seq {seq}"));
                    eprintln!("worker {worker_id} {tag}: {e:#}");
                    WorkerMsg::Failed { seq }
                }
            };
            if out.send(msg).is_err() {
                return; // consumer gone
            }
        }
    }
}

/// One wave of fetching/assembly for the engine × arena combination —
/// the body `run_worker` wraps in panic containment. Results are keyed
/// by global seq. With a ring attached, the threaded/asyncio fused
/// arms submit the whole wave's reads as one batch first and only fall
/// back to their per-item engines when the dataset cannot express its
/// reads as plain descriptors.
#[allow(clippy::too_many_arguments)]
fn run_wave(
    engine: &Engine,
    arena: &Option<Arc<BatchArena>>,
    ctx: &Arc<FetchCtx>,
    gil: &Arc<Gil>,
    source: &WorkSource,
    steal_items: bool,
    work: &[BatchTicket],
    ring: &Option<Arc<IoRing>>,
    ring_scratch: &mut Vec<(String, Vec<u8>)>,
) -> Vec<(usize, anyhow::Result<Batch>)> {
    match (engine, arena) {
        // ---- fused zero-alloc paths (arena attached) -----------------
        // with steal_items, in-progress batches are registered on the
        // injector so idle siblings can claim tail items
        (Engine::Vanilla, Some(arena)) => {
            if steal_items {
                fill_wave_sequential(
                    ctx,
                    arena,
                    work,
                    source.injector().map(|a| a.as_ref()),
                )
            } else {
                work.iter()
                    .map(|t| (t.seq, fetch_vanilla_fused(ctx, arena, t)))
                    .collect()
            }
        }
        (Engine::Threaded(pool), Some(arena)) => {
            if let Some(ring) = ring {
                if let Some(results) =
                    fill_wave_ring(ctx, ring, arena, work, ring_scratch)
                {
                    return results;
                }
            }
            let registry = if steal_items { source.injector() } else { None };
            fetch_threaded_fused_tasks(
                ctx,
                pool,
                arena,
                work,
                registry.map(|a| a.as_ref()),
            )
        }
        (Engine::Asyncio(rt, sem), Some(arena)) => {
            if let Some(ring) = ring {
                if let Some(results) =
                    fill_wave_ring(ctx, ring, arena, work, ring_scratch)
                {
                    return results;
                }
            }
            let registry = if steal_items { source.injector() } else { None };
            fetch_async_fused_tasks(
                ctx,
                rt,
                sem,
                arena,
                work,
                registry.map(|a| a.as_ref()),
            )
        }
        // ---- legacy copying paths ------------------------------------
        (Engine::Vanilla, None) => work
            .iter()
            .map(|t| {
                let res = fetch_vanilla(ctx, t.epoch, t.id, &t.indices)
                    .and_then(|samples| gil.cpu(|| collate(t.id, samples)));
                (t.seq, res)
            })
            .collect(),
        (Engine::Threaded(pool), None) => match fetch_threaded(ctx, pool, work) {
            Ok(fetched) => work
                .iter()
                .zip(fetched)
                .map(|(t, samples)| (t.seq, gil.cpu(|| collate(t.id, samples))))
                .collect(),
            Err(e) => {
                // whole-wave failure: report it once per batch seq
                let msg = format!("{e:#}");
                work.iter()
                    .map(|t| (t.seq, Err(anyhow!("fetch wave failed: {msg}"))))
                    .collect()
            }
        },
        (Engine::Asyncio(rt, sem), None) => work
            .iter()
            .map(|t| {
                let res = fetch_async(ctx, rt, sem, t.epoch, t.id, &t.indices)
                    .and_then(|samples| gil.cpu(|| collate(t.id, samples)));
                (t.seq, res)
            })
            .collect(),
    }
}

/// Claim and fill one stealable tail item from a sibling's in-progress
/// batch; false when nothing is stealable right now.
fn steal_one_item(ctx: &FetchCtx, source: &WorkSource) -> bool {
    let Some(inj) = source.injector() else { return false };
    match inj.steal_item(ctx.worker_id) {
        Some(claim) => {
            ctx.run_claim(claim);
            true
        }
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_corpus, CorpusSpec};
    use crate::data::AugmentConfig;
    use crate::dataset::ImageFolderDataset;
    use crate::storage::{MemStore, ObjectStore};
    use std::sync::mpsc;

    fn ds(items: usize) -> Arc<dyn Dataset> {
        let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        generate_corpus(&mem, &CorpusSpec::tiny(items)).unwrap();
        Arc::new(ImageFolderDataset::new(
            mem,
            AugmentConfig { crop: 16, ..Default::default() },
        ))
    }

    fn static_q(assignments: Vec<(usize, Vec<usize>)>) -> WorkSource {
        let q: VecDeque<BatchTicket> = assignments
            .into_iter()
            .map(|(id, idxs)| BatchTicket::solo(id, idxs))
            .collect();
        WorkSource::Static(Arc::new(Mutex::new(q)))
    }

    fn batches_of(rx: mpsc::Receiver<WorkerMsg>) -> Vec<Batch> {
        rx.iter()
            .filter_map(|m| match m {
                WorkerMsg::Batch { batch, .. } => Some(batch),
                WorkerMsg::Failed { .. } => None,
            })
            .collect()
    }

    fn run(cfg: DataloaderConfig, assignments: Vec<(usize, Vec<usize>)>) -> Vec<Batch> {
        run_with_arena(cfg, assignments, None)
    }

    fn run_with_arena(
        cfg: DataloaderConfig,
        assignments: Vec<(usize, Vec<usize>)>,
        arena: Option<Arc<BatchArena>>,
    ) -> Vec<Batch> {
        let (tx, rx) = mpsc::sync_channel(64);
        let h = spawn_worker(
            0,
            ds(16),
            Recorder::new(),
            Arc::new(cfg),
            static_q(assignments),
            arena,
            CreditGate::new(0),
            None,
            tx,
            std::time::Duration::ZERO,
            None,
        );
        let got = batches_of(rx);
        h.join().unwrap();
        got
    }

    #[test]
    fn vanilla_worker_produces_batches() {
        let cfg = DataloaderConfig { batch_size: 4, ..Default::default() };
        let got = run(cfg, vec![(0, vec![0, 1, 2, 3]), (1, vec![4, 5, 6, 7])]);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].indices, vec![0, 1, 2, 3]);
    }

    #[test]
    fn threaded_worker_with_batch_pool() {
        let cfg = DataloaderConfig {
            batch_size: 4,
            fetch_impl: FetchImpl::Threaded,
            num_fetch_workers: 4,
            batch_pool: 8, // 2 batches per wave
            ..Default::default()
        };
        let got = run(
            cfg,
            vec![
                (0, vec![0, 1, 2, 3]),
                (1, vec![4, 5, 6, 7]),
                (2, vec![8, 9, 10, 11]),
            ],
        );
        assert_eq!(got.len(), 3);
        for (i, b) in got.iter().enumerate() {
            assert_eq!(b.id, i);
            assert_eq!(b.len(), 4);
        }
    }

    #[test]
    fn asyncio_worker_produces_ordered_batches() {
        let cfg = DataloaderConfig {
            batch_size: 4,
            fetch_impl: FetchImpl::Asyncio,
            num_fetch_workers: 8,
            ..Default::default()
        };
        let got = run(cfg, vec![(0, vec![3, 1, 2, 0])]);
        assert_eq!(got[0].indices, vec![3, 1, 2, 0]);
    }

    #[test]
    fn worker_exits_when_consumer_drops() {
        let (tx, rx) = mpsc::sync_channel(1);
        let h = spawn_worker(
            0,
            ds(16),
            Recorder::new(),
            Arc::new(DataloaderConfig { batch_size: 2, ..Default::default() }),
            static_q((0..8).map(|i| (i, vec![i, i + 1])).collect()),
            None,
            CreditGate::new(0),
            None,
            tx,
            std::time::Duration::ZERO,
            None,
        );
        let _first = rx.recv().unwrap();
        drop(rx);
        h.join().unwrap(); // must not hang
    }

    #[test]
    fn credit_blocked_worker_proceeds_as_consumer_advances() {
        // credit 1: the worker may only run one batch ahead of delivery
        let (tx, rx) = mpsc::sync_channel(64);
        let gate = CreditGate::new(1);
        let h = spawn_worker(
            0,
            ds(16),
            Recorder::new(),
            Arc::new(DataloaderConfig { batch_size: 2, ..Default::default() }),
            static_q((0..4).map(|i| (i, vec![2 * i, 2 * i + 1])).collect()),
            None,
            gate.clone(),
            None,
            tx,
            std::time::Duration::ZERO,
            None,
        );
        let mut got = Vec::new();
        for expect in 0..4usize {
            let WorkerMsg::Batch { batch: b, .. } = rx.recv().unwrap() else {
                panic!("batch {expect} failed");
            };
            assert_eq!(b.id, expect);
            got.push(b);
            gate.advance(expect + 1); // consumer delivered it in order
        }
        h.join().unwrap();
        assert_eq!(got.len(), 4);
    }

    #[test]
    fn fused_worker_emits_pooled_batches_for_every_impl() {
        for impl_ in FetchImpl::all() {
            let cfg = DataloaderConfig {
                batch_size: 4,
                fetch_impl: impl_,
                num_fetch_workers: 4,
                ..Default::default()
            };
            let arena = BatchArena::new(16, 4, 4);
            let got = run_with_arena(
                cfg,
                vec![(0, vec![0, 1, 2, 3]), (1, vec![4, 5, 6, 7])],
                Some(arena.clone()),
            );
            assert_eq!(got.len(), 2, "{impl_:?}");
            assert!(got.iter().all(|b| b.is_pooled()), "{impl_:?}");
            assert_eq!(got[0].indices, vec![0, 1, 2, 3], "{impl_:?}");
            assert_eq!(arena.stats().checkouts, 2, "{impl_:?}");
        }
    }

    #[test]
    fn stealing_workers_cover_the_epoch_between_them() {
        let plan: Vec<Vec<usize>> = (0..8).map(|b| vec![2 * b, 2 * b + 1]).collect();
        let inj = Arc::new(BatchInjector::new());
        inj.publish(BatchTicket::plan(0, 0, plan));
        let (tx, rx) = mpsc::sync_channel(64);
        let cfg = Arc::new(DataloaderConfig { batch_size: 2, ..Default::default() });
        let dataset = ds(16);
        let h1 = spawn_worker(
            0,
            dataset.clone(),
            Recorder::new(),
            cfg.clone(),
            WorkSource::Stealing(inj.clone()),
            None,
            CreditGate::new(0),
            None,
            tx.clone(),
            std::time::Duration::ZERO,
            None,
        );
        let h2 = spawn_worker(
            1,
            dataset,
            Recorder::new(),
            cfg,
            WorkSource::Stealing(inj),
            None,
            CreditGate::new(0),
            None,
            tx,
            std::time::Duration::ZERO,
            None,
        );
        let got = batches_of(rx);
        h1.join().unwrap();
        h2.join().unwrap();
        let mut ids: Vec<usize> = got.iter().map(|b| b.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        let mut seen: Vec<usize> =
            got.iter().flat_map(|b| b.indices.iter().copied()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn item_stealing_workers_fill_each_others_batches() {
        // two item-steal workers over one injector: full coverage, every
        // batch published exactly once by its owner
        let plan: Vec<Vec<usize>> = (0..6).map(|b| vec![2 * b, 2 * b + 1]).collect();
        let inj = Arc::new(BatchInjector::new());
        inj.publish(BatchTicket::plan(0, 0, plan));
        let (tx, rx) = mpsc::sync_channel(64);
        let cfg = Arc::new(DataloaderConfig {
            batch_size: 2,
            steal_items: true,
            work_stealing: true,
            ..Default::default()
        });
        let dataset = ds(16);
        let arena = BatchArena::new(16, 2, 8);
        let h1 = spawn_worker(
            0,
            dataset.clone(),
            Recorder::new(),
            cfg.clone(),
            WorkSource::Stealing(inj.clone()),
            Some(arena.clone()),
            CreditGate::new(0),
            None,
            tx.clone(),
            std::time::Duration::ZERO,
            None,
        );
        let h2 = spawn_worker(
            1,
            dataset,
            Recorder::new(),
            cfg,
            WorkSource::Stealing(inj.clone()),
            Some(arena),
            CreditGate::new(0),
            None,
            tx,
            std::time::Duration::ZERO,
            None,
        );
        let got = batches_of(rx);
        h1.join().unwrap();
        h2.join().unwrap();
        let mut ids: Vec<usize> = got.iter().map(|b| b.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
        for b in &got {
            assert_eq!(b.indices, vec![2 * b.id, 2 * b.id + 1]);
        }
        assert_eq!(inj.active_tasks(), 0, "steal registry must drain");
    }

    #[test]
    fn corrupt_item_skips_its_batch_only() {
        let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        let (keys, _) = generate_corpus(&mem, &CorpusSpec::tiny(8)).unwrap();
        mem.put(&keys[1], vec![9, 9, 9]).unwrap(); // corrupt batch 0's item
        let dataset: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
            mem,
            AugmentConfig { crop: 16, ..Default::default() },
        ));
        for arena in [None, Some(BatchArena::new(16, 4, 2))] {
            let (tx, rx) = mpsc::sync_channel(8);
            let h = spawn_worker(
                0,
                dataset.clone(),
                Recorder::new(),
                Arc::new(DataloaderConfig { batch_size: 4, ..Default::default() }),
                static_q(vec![(0, vec![0, 1, 2, 3]), (1, vec![4, 5, 6, 7])]),
                arena,
                CreditGate::new(0),
                None,
                tx,
                std::time::Duration::ZERO,
                None,
            );
            let msgs: Vec<WorkerMsg> = rx.iter().collect();
            h.join().unwrap();
            // batch 0 failed (corrupt item) and was tombstoned so the
            // consumer can advance; batch 1 delivered
            assert_eq!(msgs.len(), 2);
            assert!(matches!(msgs[0], WorkerMsg::Failed { seq: 0 }));
            match &msgs[1] {
                WorkerMsg::Batch { batch, .. } => assert_eq!(batch.id, 1),
                WorkerMsg::Failed { seq } => panic!("batch 1 failed too: {seq}"),
            }
        }
    }
}
