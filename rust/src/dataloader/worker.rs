//! Worker "processes" (Fig 3/4 of the paper): each worker owns an index
//! queue slice, fetches batches via the configured fetcher strategy,
//! collates, and pushes finished batches into the bounded data queue.
//!
//! A worker is an OS thread standing in for a CPython worker process:
//! it owns its own [`Gil`] (decode/augment serialize within the worker,
//! never across workers) and pays the configured process start-up cost
//! (`fork` vs `spawn`) before doing any work.

use std::sync::mpsc::SyncSender;
use std::sync::Arc;

use crate::asyncrt;
use crate::dataloader::collate::{collate, Batch};
use crate::dataloader::fetch::{
    fetch_async, fetch_threaded, fetch_vanilla, FetchCtx, ThreadPool,
};
use crate::dataloader::{DataloaderConfig, FetchImpl};
use crate::dataset::Dataset;
use crate::gil::Gil;
use crate::telemetry::{names, Recorder};

/// Spawn one worker thread over its assigned (batch_id, indices) list.
/// `spawn_delay` is paid *inside* the thread before any fetching (the
/// interpreter start-up of a `spawn`-method process, or ~0 for `fork`).
pub fn spawn_worker(
    worker_id: u32,
    dataset: Arc<dyn Dataset>,
    recorder: Arc<Recorder>,
    cfg: Arc<DataloaderConfig>,
    assignments: Vec<(usize, Vec<usize>)>,
    out: SyncSender<Batch>,
    spawn_delay: std::time::Duration,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("dl-worker{worker_id}"))
        .spawn(move || {
            let t0 = recorder.now();
            if !spawn_delay.is_zero() {
                std::thread::sleep(spawn_delay);
            }
            recorder.record(names::WORKER_SPAWN, worker_id, -1, t0, recorder.now());
            run_worker(worker_id, dataset, recorder, cfg, assignments, out);
        })
        .expect("spawn dataloader worker")
}

fn run_worker(
    worker_id: u32,
    dataset: Arc<dyn Dataset>,
    recorder: Arc<Recorder>,
    cfg: Arc<DataloaderConfig>,
    assignments: Vec<(usize, Vec<usize>)>,
    out: SyncSender<Batch>,
) {
    let gil = Gil::new(cfg.runtime, cfg.python_tax);
    let ctx = Arc::new(FetchCtx {
        worker_id,
        dataset,
        gil: gil.clone(),
        recorder: recorder.clone(),
    });

    match cfg.fetch_impl {
        FetchImpl::Vanilla => {
            for (batch_id, indices) in assignments {
                let t0 = recorder.now();
                let samples = match fetch_vanilla(&ctx, batch_id, &indices) {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("worker {worker_id} batch {batch_id}: {e:#}");
                        continue;
                    }
                };
                let batch = gil.cpu(|| collate(batch_id, samples));
                recorder.record(
                    names::BATCH_INFLIGHT,
                    worker_id,
                    batch_id as i64,
                    t0,
                    recorder.now(),
                );
                if out.send(batch).is_err() {
                    return; // consumer gone
                }
            }
        }
        FetchImpl::Threaded => {
            let pool = ThreadPool::new(
                cfg.num_fetch_workers,
                &format!("w{worker_id}"),
            );
            // batch disassembly: number of batches pulled per wave
            let group = if cfg.batch_pool > 0 {
                (cfg.batch_pool / cfg.batch_size.max(1)).max(1)
            } else {
                1
            };
            for chunk in assignments.chunks(group) {
                let t0 = recorder.now();
                let fetched = match fetch_threaded(&ctx, &pool, chunk) {
                    Ok(f) => f,
                    Err(e) => {
                        eprintln!("worker {worker_id}: {e:#}");
                        continue;
                    }
                };
                for (batch_id, samples) in fetched {
                    let batch = gil.cpu(|| collate(batch_id, samples));
                    recorder.record(
                        names::BATCH_INFLIGHT,
                        worker_id,
                        batch_id as i64,
                        t0,
                        recorder.now(),
                    );
                    if out.send(batch).is_err() {
                        return;
                    }
                }
            }
        }
        FetchImpl::Asyncio => {
            // single-threaded event loop: the asyncio worker model
            let rt = asyncrt::Runtime::new(1);
            let sem = asyncrt::Semaphore::new(cfg.num_fetch_workers.max(1));
            for (batch_id, indices) in assignments {
                let t0 = recorder.now();
                let samples = match fetch_async(&ctx, &rt, &sem, batch_id, &indices) {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("worker {worker_id} batch {batch_id}: {e:#}");
                        continue;
                    }
                };
                let batch = gil.cpu(|| collate(batch_id, samples));
                recorder.record(
                    names::BATCH_INFLIGHT,
                    worker_id,
                    batch_id as i64,
                    t0,
                    recorder.now(),
                );
                if out.send(batch).is_err() {
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_corpus, CorpusSpec};
    use crate::data::AugmentConfig;
    use crate::dataset::ImageFolderDataset;
    use crate::storage::{MemStore, ObjectStore};
    use std::sync::mpsc;

    fn ds(items: usize) -> Arc<dyn Dataset> {
        let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        generate_corpus(&mem, &CorpusSpec::tiny(items)).unwrap();
        Arc::new(ImageFolderDataset::new(
            mem,
            AugmentConfig { crop: 16, ..Default::default() },
        ))
    }

    fn run(cfg: DataloaderConfig, assignments: Vec<(usize, Vec<usize>)>) -> Vec<Batch> {
        let (tx, rx) = mpsc::sync_channel(64);
        let h = spawn_worker(
            0,
            ds(16),
            Recorder::new(),
            Arc::new(cfg),
            assignments,
            tx,
            std::time::Duration::ZERO,
        );
        let got: Vec<Batch> = rx.iter().collect();
        h.join().unwrap();
        got
    }

    #[test]
    fn vanilla_worker_produces_batches() {
        let cfg = DataloaderConfig { batch_size: 4, ..Default::default() };
        let got = run(cfg, vec![(0, vec![0, 1, 2, 3]), (1, vec![4, 5, 6, 7])]);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].indices, vec![0, 1, 2, 3]);
    }

    #[test]
    fn threaded_worker_with_batch_pool() {
        let cfg = DataloaderConfig {
            batch_size: 4,
            fetch_impl: FetchImpl::Threaded,
            num_fetch_workers: 4,
            batch_pool: 8, // 2 batches per wave
            ..Default::default()
        };
        let got = run(
            cfg,
            vec![
                (0, vec![0, 1, 2, 3]),
                (1, vec![4, 5, 6, 7]),
                (2, vec![8, 9, 10, 11]),
            ],
        );
        assert_eq!(got.len(), 3);
        for (i, b) in got.iter().enumerate() {
            assert_eq!(b.id, i);
            assert_eq!(b.len(), 4);
        }
    }

    #[test]
    fn asyncio_worker_produces_ordered_batches() {
        let cfg = DataloaderConfig {
            batch_size: 4,
            fetch_impl: FetchImpl::Asyncio,
            num_fetch_workers: 8,
            ..Default::default()
        };
        let got = run(cfg, vec![(0, vec![3, 1, 2, 0])]);
        assert_eq!(got[0].indices, vec![3, 1, 2, 0]);
    }

    #[test]
    fn worker_exits_when_consumer_drops() {
        let (tx, rx) = mpsc::sync_channel(1);
        let h = spawn_worker(
            0,
            ds(16),
            Recorder::new(),
            Arc::new(DataloaderConfig { batch_size: 2, ..Default::default() }),
            (0..8).map(|i| (i, vec![i, i + 1])).collect(),
            tx,
            std::time::Duration::ZERO,
        );
        let _first = rx.recv().unwrap();
        drop(rx);
        h.join().unwrap(); // must not hang
    }
}
