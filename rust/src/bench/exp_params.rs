//! Parameter-study experiments: Fig 7 (transfer time vs batch size),
//! Fig 8 (lazy init), Fig 9 (caching), Fig 10/11 (workers × fetchers
//! heatmaps), Fig 12 (Dataset pool sweep).

use anyhow::Result;

use super::rig::{self, RigSpec};
use super::{emit, Scale};
use crate::dataloader::FetchImpl;
use crate::dataset::pool::run_pool;
use crate::device::TransferModel;
use crate::gil;
use crate::util::table::{num, Table};

/// Fig 7: CPU→GPU transfer time vs batch size, pageable vs pinned.
pub fn f7_transfer_times(_scale: Scale) -> Result<()> {
    let tm = TransferModel::default();
    let mut t = Table::new(
        "Fig 7 — host→device transfer time vs batch size (224×224×3 f32)",
        &["batch", "MiB", "pageable ms", "pinned ms", "pinned speedup×"],
    );
    for batch in [16usize, 32, 64, 128, 256, 512] {
        let bytes = batch * 224 * 224 * 3 * 4;
        let pageable = tm.time(bytes, false).as_secs_f64() * 1e3;
        let pinned = tm.time(bytes, true).as_secs_f64() * 1e3;
        t.row(&[
            batch.to_string(),
            num(bytes as f64 / (1024.0 * 1024.0), 1),
            num(pageable, 2),
            num(pinned, 2),
            num(pageable / pinned, 2),
        ]);
    }
    t.note("paper: transfer time grows with batch size; pinning matters at scale");
    emit("f7", &t)
}

/// Fig 8: blocking vs lazy dataloader initialization — time to first
/// batch as worker count grows.
pub fn f8_lazy_init(scale: Scale) -> Result<()> {
    let mut t = Table::new(
        "Fig 8 — time to first batch: blocking vs lazy worker creation",
        &["workers", "blocking s", "lazy s", "speedup×"],
    );
    for workers in [2usize, 4, 8, 16] {
        let mut times = [0.0f64; 2];
        for (i, lazy) in [false, true].into_iter().enumerate() {
            let mut spec = RigSpec::quick("mem", scale.latency);
            spec.items = scale.items(64).min(128);
            spec.batch_size = 8;
            spec.num_workers = workers;
            spec.lazy_init = lazy;
            let rig = rig::build(&spec)?;
            // override spawn cost to the paper's slow-spawn regime
            let dl = crate::dataloader::Dataloader::new(
                rig.dataloader.dataset().clone(),
                crate::dataloader::DataloaderConfig {
                    spawn_cost_override: Some(std::time::Duration::from_millis(40)),
                    lazy_init: lazy,
                    num_workers: workers,
                    batch_size: 8,
                    ..rig.dataloader.config().clone()
                },
                rig.recorder.clone(),
            );
            let t0 = std::time::Instant::now();
            let mut it = dl.epoch(0);
            let _first = it.next();
            times[i] = t0.elapsed().as_secs_f64();
            drop(it);
        }
        t.row(&[
            workers.to_string(),
            num(times[0], 3),
            num(times[1], 3),
            num(times[0] / times[1], 2),
        ]);
    }
    t.note("blocking pays workers×spawn_cost before the first fetch; lazy pays ~1×");
    emit("f8", &t)
}

/// Fig 9: Varnish-like cache on/off, s3 + scratch, vanilla + threaded.
pub fn f9_caching(scale: Scale) -> Result<()> {
    let mut t = Table::new(
        "Fig 9 — byte-capped LRU cache in front of storage",
        &["config", "cache", "Mbit/s", "img/s", "hit %", "Δ vs no-cache %"],
    );
    for storage in ["s3", "scratch"] {
        for imp in [FetchImpl::Vanilla, FetchImpl::Threaded] {
            let mut base_mbit = f64::NAN;
            for cached in [false, true] {
                let mut spec = RigSpec::quick(storage, scale.latency).with_impl(imp);
                spec.items = scale.items(128);
                spec.epochs = 2; // cache only pays from epoch 2
                if cached {
                    // cache ≪ dataset, like the paper's 2 GB vs ImageNet
                    spec.cache_bytes = (spec.items * spec.mean_kb * 1024 / 4) as u64;
                }
                let (r, rig) = rig::run(&spec)?;
                let hit = rig
                    .cache
                    .as_ref()
                    .map(|c| 100.0 * c.hit_ratio())
                    .unwrap_or(0.0);
                if !cached {
                    base_mbit = r.mbit_per_s;
                }
                t.row(&[
                    format!("{storage}/{}", imp.label()),
                    if cached { "2GB-like" } else { "off" }.to_string(),
                    num(r.mbit_per_s, 1),
                    num(r.img_per_s, 1),
                    num(hit, 1),
                    num(100.0 * (r.mbit_per_s - base_mbit) / base_mbit, 1),
                ]);
            }
        }
    }
    t.note("paper: cache helps vanilla-s3 the most (+450%), ~nothing on scratch");
    emit("f9", &t)
}

fn heatmap(
    storage: &'static str,
    scale: Scale,
    workers: &[usize],
    fetchers: &[usize],
) -> Result<(Table, Table)> {
    let header: Vec<String> = std::iter::once("workers\\fetchers".to_string())
        .chain(fetchers.iter().map(|f| f.to_string()))
        .collect();
    let mut tput = Table::new_dyn(
        format!("workers × fetchers → Mbit/s ({storage}, threaded)"),
        header.clone(),
    );
    let mut reqt = Table::new_dyn(
        format!("workers × fetchers → median request ms ({storage})"),
        header,
    );
    for &w in workers {
        let mut row_t = vec![w.to_string()];
        let mut row_r = vec![w.to_string()];
        for &f in fetchers {
            let mut spec = RigSpec::quick(storage, scale.latency)
                .with_impl(FetchImpl::Threaded);
            spec.items = scale.items(96);
            spec.batch_size = 16;
            spec.num_workers = w;
            spec.num_fetch_workers = f;
            let rig = rig::build(&spec)?;
            let (secs, bytes, _) = rig::drain_epoch(&rig);
            row_t.push(format!("{:.0}", crate::util::fmt::mbit_s(bytes, secs)));
            let med = rig
                .remote
                .as_ref()
                .map(|r| r.median_request_time() * 1e3)
                .unwrap_or(f64::NAN);
            row_r.push(num(med, 1));
        }
        tput.row(&row_t);
        reqt.row(&row_r);
    }
    Ok((tput, reqt))
}

/// Fig 10: workers × fetchers heatmap on s3.
pub fn f10_heatmap_s3(scale: Scale) -> Result<()> {
    let (tput, reqt) = heatmap("s3", scale, &[1, 2, 4, 8, 16], &[1, 2, 4, 8, 16])?;
    emit("f10", &tput)?;
    emit("f10", &reqt)?;
    println!("  paper shape: ridge at many workers / few-moderate fetchers;");
    println!("  very high workers×fetchers degrades median request time");
    Ok(())
}

/// Fig 11: the same heatmap on scratch.
pub fn f11_heatmap_scratch(scale: Scale) -> Result<()> {
    let (tput, reqt) = heatmap("scratch", scale, &[1, 2, 4, 8, 16], &[1, 2, 4, 8])?;
    emit("f11", &tput)?;
    emit("f11", &reqt)?;
    println!("  paper shape: throughput much higher and less fetcher-sensitive");
    Ok(())
}

/// Fig 12: bare-Dataset multiprocessing-pool sweep.
pub fn f12_dataset_pool(scale: Scale) -> Result<()> {
    let mut t = Table::new(
        "Fig 12 — Dataset-only random loads vs multiprocessing pool size",
        &["storage", "pool", "Mbit/s", "median req ms"],
    );
    for storage in ["s3", "scratch"] {
        let spec = {
            let mut s = RigSpec::quick(storage, scale.latency);
            s.items = scale.items(96);
            s
        };
        let rig = rig::build(&spec)?;
        for pool in [1usize, 2, 4, 8, 16, 32] {
            let r = run_pool(
                rig.dataloader.dataset().clone(),
                pool,
                scale.items(96).min(160),
                gil::Runtime::Python,
                2.0,
                spec.seed ^ pool as u64,
            );
            t.row(&[
                storage.to_string(),
                pool.to_string(),
                num(r.throughput_mbit_s, 1),
                num(r.median_request_s * 1e3, 1),
            ]);
        }
    }
    t.note("paper: s3 plateaus near pool≈30 (~75 Mbit/s); scratch peaks early, higher");
    emit("f12", &t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_table_builds() {
        // smoke: no storage involved, instant
        f7_transfer_times(Scale::quick()).unwrap();
    }

    #[test]
    fn heatmap_tiny_grid() {
        let scale = Scale { latency: 0.03, items: 0.2, epochs: 1.0 };
        let (tput, reqt) = heatmap("s3", scale, &[1, 4], &[1, 8]).unwrap();
        assert_eq!(tput.rows.len(), 2);
        assert_eq!(reqt.rows.len(), 2);
        // more workers+fetchers must beat 1×1 on a latency-bound store
        let parse = |s: &str| s.parse::<f64>().unwrap();
        assert!(parse(&tput.rows[1][2]) > parse(&tput.rows[0][1]));
    }
}
