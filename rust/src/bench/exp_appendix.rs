//! Appendix experiments: A.1 storage types (Fig 16), A.2 Colab (Table
//! 10), A.3 Lightning lanes (Fig 17/19) and training-phase throughput
//! (Fig 20), A.4 GIL (Fig 21), A.5 shard loaders (Fig 22), A.6 fade
//! in/out (Fig 23).

use std::sync::Arc;

use anyhow::Result;

use super::rig::{self, RigSpec};
use super::{emit, emit_raw, Scale};
use crate::data::simg::SimgImage;
use crate::data::synth::{generate_corpus, CorpusSpec};
use crate::data::AugmentConfig;
use crate::dataloader::FetchImpl;
use crate::gil::{Gil, Runtime};
use crate::shards::{build_shards, FastAiLoader, WebDatasetLoader};
use crate::storage::{MemStore, ObjectStore, RemoteProfile, SimRemoteStore};
use crate::telemetry::names;
use crate::trainer::TrainerKind;
use crate::util::stats::Histogram;
use crate::util::table::{num, Table};

/// Fig 16 (App A.1): throughput across storage backends.
pub fn f16_storage_types(scale: Scale) -> Result<()> {
    let mut t = Table::new(
        "Fig 16 — storage types × implementations (Mbit/s)",
        &["storage", "lib", "vanilla", "asyncio", "threaded"],
    );
    for storage in ["gluster_fs", "ceph_fs", "ceph_os", "s3"] {
        for lib in [TrainerKind::Torch, TrainerKind::Lightning] {
            let mut row = vec![storage.to_string(), lib.label().to_string()];
            for imp in [FetchImpl::Vanilla, FetchImpl::Asyncio, FetchImpl::Threaded] {
                let mut spec = RigSpec::quick(storage, scale.latency)
                    .with_trainer(lib)
                    .with_impl(imp);
                spec.items = scale.items(128);
                let (r, _) = rig::run(&spec)?;
                row.push(num(r.mbit_per_s, 1));
            }
            t.row(&row);
        }
    }
    t.note("paper: ceph_os slowest by far; modifications win on every backend");
    emit("f16", &t)
}

/// Table 10 (App A.2): Colab-like constrained run.
pub fn t10_colab(scale: Scale) -> Result<()> {
    let mut t = Table::new(
        "Table 10 — Colab-like run (colab_s3 profile, torch)",
        &["impl", "time s", "images", "img/s", "Mbit/s"],
    );
    for imp in [FetchImpl::Asyncio, FetchImpl::Threaded, FetchImpl::Vanilla] {
        let mut spec = RigSpec::quick("colab_s3", scale.latency).with_impl(imp);
        spec.items = scale.items(96);
        spec.num_workers = 4;
        spec.num_fetch_workers = 16;
        let (r, _) = rig::run(&spec)?;
        t.row(&[
            imp.label().to_string(),
            num(r.runtime_s, 2),
            r.images.to_string(),
            num(r.img_per_s, 2),
            num(r.mbit_per_s, 2),
        ]);
    }
    t.note("paper: asyncio/threaded ≈ 57 img/s vs vanilla ≈ 39 img/s");
    emit("t10", &t)
}

/// Fig 17/19 (App A.3.1): Lightning lane breakdown + Torch comparison.
pub fn f17_lightning_lanes(scale: Scale) -> Result<()> {
    // Lightning with default (aggressive) logging
    let mut spec = RigSpec::quick("scratch", scale.latency)
        .with_trainer(TrainerKind::Lightning)
        .with_impl(FetchImpl::Threaded);
    spec.items = scale.items(96);
    let (_, rig_l) = rig::run(&spec)?;

    let mut t = Table::new(
        "Fig 17 — Lightning lane medians (scratch, threaded)",
        &["lane", "median ms", "count"],
    );
    for lane in [
        names::ADVANCE,
        names::PRERUN,
        names::NEXT_DATA,
        names::TO_DEVICE,
        names::PREP_TRAINING,
        names::TRAIN_BATCH,
        names::POSTRUN,
    ] {
        let d = rig_l.recorder.durations(lane);
        t.row(&[
            lane.to_string(),
            num(crate::util::stats::median(&d) * 1e3, 2),
            d.len().to_string(),
        ]);
    }
    emit("f17", &t)?;
    emit_raw("f17", "lightning_lanes.csv", &rig_l.recorder.to_csv())?;

    // Torch overlap (Fig 19): hook lanes absent, same data path
    let spec_t = spec.with_trainer(TrainerKind::Torch);
    let (rt, rig_t) = rig::run(&spec_t)?;
    let (rl_runtime, rt_runtime) = (
        rig_l.recorder.durations(names::ADVANCE).iter().sum::<f64>(),
        rt.runtime_s,
    );
    let mut t2 = Table::new(
        "Fig 19 — Lightning vs Torch on the same pipeline",
        &["harness", "runtime s", "hook overhead s"],
    );
    let hook_overhead: f64 = rig_l.recorder.durations(names::PREP_TRAINING).iter().sum::<f64>()
        + rig_l.recorder.durations(names::POSTRUN).iter().sum::<f64>();
    t2.row(&["lightning".into(), num(rl_runtime, 2), num(hook_overhead, 2)]);
    t2.row(&["torch".into(), num(rt_runtime, 2), "0.00".into()]);
    let _ = rig_t;
    t2.note("paper: pre/post hooks build up, making Lightning slightly slower");
    emit("f17", &t2)
}

/// Fig 20 (App A.3.2): training-phase throughput.
pub fn f20_train_phase(scale: Scale) -> Result<()> {
    let mut t = Table::new(
        "Fig 20 — training-phase throughput (data already in memory)",
        &["lib", "storage", "train med ms", "optim med ms", "Mbit/s through step"],
    );
    for lib in [TrainerKind::Torch, TrainerKind::Lightning] {
        for storage in ["scratch", "s3"] {
            let mut spec = RigSpec::quick(storage, scale.latency)
                .with_trainer(lib)
                .with_impl(FetchImpl::Threaded);
            spec.items = scale.items(96);
            let (r, rig) = rig::run(&spec)?;
            let train_med = rig.recorder.median(names::TRAIN_BATCH);
            let opt_med = rig.recorder.median(names::OPTIMIZER_STEP);
            // Throughput I: loaded bytes / time spent inside the step
            let step_total: f64 =
                rig.recorder.durations(names::TRAIN_BATCH).iter().sum();
            let mbit = r.bytes as f64 / (1024.0 * 1024.0) * 8.0 / step_total;
            t.row(&[
                lib.label().to_string(),
                storage.to_string(),
                num(train_med * 1e3, 2),
                num(opt_med * 1e3, 2),
                num(mbit, 0),
            ]);
        }
    }
    t.note("paper: step throughput independent of storage type (data in memory)");
    emit("f20", &t)
}

/// Fig 21 (App A.4): raw S3 download throughput, GIL-python vs native
/// (the paper's Python-vs-Java experiment).
pub fn f21_gil(scale: Scale) -> Result<()> {
    let mut t = Table::new(
        "Fig 21 — raw S3 downloads: CPython (GIL) vs native threading",
        &["runtime", "threads", "objects", "Mbit/s"],
    );
    // Per-request client CPU: boto3/urllib3 spend ~3 ms of *GIL-held*
    // python bytecode per GET (request signing, TLS record handling,
    // response parsing); a Java/rust client does the same work in a
    // fraction of that, off any global lock. This is the §A.4 ceiling.
    let request_cpu = |runtime: Runtime| match runtime {
        Runtime::Python => std::time::Duration::from_micros(3000),
        Runtime::Native => std::time::Duration::from_micros(300),
    };
    let items = scale.items(160);
    for (runtime, tax) in [(Runtime::Python, 1.0), (Runtime::Native, 1.0)] {
        for threads in [8usize, 32] {
            let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("c"));
            generate_corpus(
                &mem,
                &CorpusSpec {
                    items,
                    mean_bytes: 48 * 1024,
                    ..Default::default()
                },
            )?;
            let store: Arc<dyn ObjectStore> = SimRemoteStore::new(
                mem,
                RemoteProfile::s3().scaled(scale.latency),
                9,
            );
            let keys = store.keys();
            let t0 = std::time::Instant::now();
            let bytes = std::sync::atomic::AtomicU64::new(0);
            let next = std::sync::atomic::AtomicUsize::new(0);
            std::thread::scope(|s| {
                // one process, many threads → ONE shared GIL
                let gil = Gil::new(runtime, tax);
                for _ in 0..threads {
                    let store = store.clone();
                    let keys = &keys;
                    let bytes = &bytes;
                    let next = &next;
                    let gil = gil.clone();
                    s.spawn(move || loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                        if i >= keys.len() {
                            break;
                        }
                        let raw = gil.io(|| store.get(&keys[i])).unwrap();
                        // client request handling + decode: CPU under
                        // the GIL (python) / lock-free (native)
                        let _img = gil.cpu(|| {
                            let end = std::time::Instant::now() + request_cpu(runtime);
                            while std::time::Instant::now() < end {
                                std::hint::spin_loop();
                            }
                            SimgImage::decode(&raw).unwrap()
                        });
                        bytes.fetch_add(
                            raw.len() as u64,
                            std::sync::atomic::Ordering::Relaxed,
                        );
                    });
                }
            });
            let secs = t0.elapsed().as_secs_f64();
            t.row(&[
                runtime.label().to_string(),
                threads.to_string(),
                items.to_string(),
                num(crate::util::fmt::mbit_s(
                    bytes.load(std::sync::atomic::Ordering::Relaxed),
                    secs,
                ), 1),
            ]);
        }
    }
    t.note("paper: Java 701 Mbit/s vs Python 252 Mbit/s median (~2.8×)");
    emit("f21", &t)
}

/// Fig 22 (App A.5): concurrent loader vs FastAI vs WebDataset.
pub fn f22_shard_loaders(scale: Scale) -> Result<()> {
    let items = scale.items(96);
    let epochs = 2usize;
    let profile = RemoteProfile::s3().scaled(scale.latency);
    let aug = AugmentConfig { crop: 32, ..Default::default() };

    // shared corpus
    let corpus: Arc<dyn ObjectStore> = Arc::new(MemStore::new("c"));
    generate_corpus(
        &corpus,
        &CorpusSpec { items, mean_bytes: 48 * 1024, ..Default::default() },
    )?;

    let mut t = Table::new(
        "Fig 22 — concurrent vs FastAI vs WebDataset (s3-like storage)",
        &["loader", "total s", "per-epoch s", "samples/epoch"],
    );

    // 1. concurrent (our threaded per-item loader)
    {
        let mut spec = RigSpec::quick("s3", scale.latency).with_impl(FetchImpl::Threaded);
        spec.items = items;
        spec.epochs = epochs;
        let rig = rig::build(&spec)?;
        let t0 = std::time::Instant::now();
        let mut per_epoch = Vec::new();
        for e in 0..epochs {
            let te = std::time::Instant::now();
            let n = rig.dataloader.epoch(e).count();
            assert!(n > 0);
            per_epoch.push(te.elapsed().as_secs_f64());
        }
        t.row(&[
            "concurrent (ours)".into(),
            num(t0.elapsed().as_secs_f64(), 2),
            num(per_epoch.iter().sum::<f64>() / per_epoch.len() as f64, 2),
            (items).to_string(),
        ]);
    }

    // 2. WebDataset: stream shards each epoch
    {
        let shard_store: Arc<dyn ObjectStore> = Arc::new(MemStore::new("sh"));
        let keys = build_shards(&corpus, &shard_store, 2)?;
        let remote: Arc<dyn ObjectStore> =
            SimRemoteStore::new(shard_store, profile.clone(), 11);
        let wds = WebDatasetLoader::new(remote, keys, aug.clone());
        let gil = Gil::python();
        let t0 = std::time::Instant::now();
        let mut per_epoch = Vec::new();
        let mut samples = 0;
        for e in 0..epochs {
            let ep = wds.epoch(e, &gil, |_| {})?;
            samples = ep.samples;
            per_epoch.push(ep.wall_secs);
        }
        t.row(&[
            "webdataset (s3 stream)".into(),
            num(t0.elapsed().as_secs_f64(), 2),
            num(per_epoch.iter().sum::<f64>() / per_epoch.len() as f64, 2),
            samples.to_string(),
        ]);
    }

    // 3. FastAI: untar once, local epochs
    {
        let shard_store: Arc<dyn ObjectStore> = Arc::new(MemStore::new("sh2"));
        let keys = build_shards(&corpus, &shard_store, 1)?;
        let remote: Arc<dyn ObjectStore> =
            SimRemoteStore::new(shard_store, profile, 12);
        let t0 = std::time::Instant::now();
        let local: Arc<dyn ObjectStore> = Arc::new(MemStore::new("local"));
        let fa = FastAiLoader::untar_data(&remote, &keys, local, aug)?;
        let gil = Gil::python();
        let mut per_epoch = Vec::new();
        let mut samples = 0;
        for e in 0..epochs {
            let ep = fa.epoch(e, &gil, |_| {})?;
            samples = ep.samples;
            per_epoch.push(ep.wall_secs);
        }
        t.row(&[
            "fastai (untar+local)".into(),
            num(t0.elapsed().as_secs_f64(), 2),
            num(per_epoch.iter().sum::<f64>() / per_epoch.len() as f64, 2),
            samples.to_string(),
        ]);
    }
    t.note("paper: fastai fastest, webdataset close, per-item concurrent slowest");
    emit("f22", &t)
}

/// Fig 23 (App A.6): fade-in/fade-out of __getitem__ activity.
pub fn f23_fade(scale: Scale) -> Result<()> {
    let mut spec = RigSpec::quick("s3", scale.latency).with_impl(FetchImpl::Threaded);
    spec.items = scale.items(192);
    let rig = rig::build(&spec)?;
    let (wall, _, _) = rig::drain_epoch(&rig);

    let spans = rig.recorder.snapshot();
    let gets: Vec<_> = spans.iter().filter(|s| s.name == names::GET_ITEM).collect();
    let t_max = gets.iter().map(|s| s.t1).fold(0.0, f64::max).max(1e-9);
    let nbins = 20;
    let mut started = Histogram::new(0.0, t_max, nbins);
    let mut finished = Histogram::new(0.0, t_max, nbins);
    for s in &gets {
        started.add(s.t0);
        finished.add(s.t1);
    }
    let mut t = Table::new(
        "Fig 23 — fade-in/out: __getitem__ starts/finishes over the run",
        &["histogram", "bins (time →)"],
    );
    t.row(&["started".into(), started.sparkline()]);
    t.row(&["finished".into(), finished.sparkline()]);
    t.note(&format!(
        "{} items over {wall:.2}s — ramp-up at the start, drain at the end \
         ⇒ short experiments under-estimate steady-state throughput",
        gets.len()
    ));
    emit("f23", &t)?;
    // scatter data (start time vs duration) for plotting
    let mut csv = String::from("t_start,duration\n");
    for s in &gets {
        csv.push_str(&format!("{:.6},{:.6}\n", s.t0, s.duration()));
    }
    emit_raw("f23", "getitem_scatter.csv", &csv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gil_experiment_shows_native_advantage() {
        // tiny version of f21: native threading must beat GIL python
        let scale = Scale { latency: 0.05, items: 0.3, epochs: 1.0 };
        let items = scale.items(64);
        let run = |runtime, tax| {
            let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("c"));
            generate_corpus(
                &mem,
                &CorpusSpec { items, mean_bytes: 32 * 1024, ..Default::default() },
            )
            .unwrap();
            let store: Arc<dyn ObjectStore> =
                SimRemoteStore::new(mem, RemoteProfile::s3().scaled(0.05), 9);
            let keys = store.keys();
            let t0 = std::time::Instant::now();
            let next = std::sync::atomic::AtomicUsize::new(0);
            std::thread::scope(|s| {
                let gil = Gil::new(runtime, tax);
                for _ in 0..16 {
                    let store = store.clone();
                    let keys = &keys;
                    let next = &next;
                    let gil = gil.clone();
                    s.spawn(move || loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                        if i >= keys.len() {
                            break;
                        }
                        let raw = gil.io(|| store.get(&keys[i])).unwrap();
                        let _ = gil.cpu(|| SimgImage::decode(&raw).unwrap());
                    });
                }
            });
            t0.elapsed().as_secs_f64()
        };
        let python = run(Runtime::Python, 6.0);
        let native = run(Runtime::Native, 1.0);
        assert!(
            native < python,
            "native {native:.3}s !< python {python:.3}s"
        );
    }
}
