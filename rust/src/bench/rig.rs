//! Rig builder: assemble corpus → storage stack → dataset → dataloader →
//! device → trainer for one experiment configuration.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::data::synth::{generate_corpus, CorpusSpec};
use crate::data::AugmentConfig;
use crate::dataloader::{Dataloader, DataloaderConfig, FetchImpl};
use crate::dataset::{Dataset, ImageFolderDataset, ShardDataset};
use crate::device::Device;
use crate::gil;
use crate::governor::{Governor, GovernorConfig, KnobBounds, Signals};
use crate::prefetch::{CachePolicy, PrefetchConfig, PrefetchStore};
use crate::shards::{pack_shards, ShardManifest, ShardStore};
use crate::storage::{
    FaultInjector, FaultProfile, IoRing, MemStore, ObjectStore, RemoteProfile,
    ResilienceConfig, ResilientStore, SimRemoteStore, VarnishCache,
};
use crate::telemetry::Recorder;
use crate::trainer::{self, TrainReport, TrainerConfig, TrainerKind};
use crate::util::json::Json;

/// Everything one experiment run needs.
#[derive(Debug, Clone)]
pub struct RigSpec {
    pub storage: &'static str,
    pub latency_scale: f64,
    /// samples per tar shard (0 = per-file objects): the remote serves
    /// packed tars, read through window-granular fetches — one request
    /// amortized over `shard_size` samples
    pub shard_size: usize,
    /// two-level shard shuffle (seeded shard order + intra-shard
    /// reservoir) overriding the loader's sampler
    pub shard_shuffle: bool,
    pub cache_bytes: u64,
    /// varnish cache eviction policy (lru | 2q | s3fifo)
    pub cache_policy: CachePolicy,
    pub items: usize,
    pub mean_kb: usize,
    pub crop: usize,
    pub batch_size: usize,
    pub num_workers: usize,
    pub prefetch_factor: usize,
    pub fetch_impl: FetchImpl,
    pub num_fetch_workers: usize,
    pub batch_pool: usize,
    /// sampler-ahead readahead window in items (0 = no prefetch engine)
    pub prefetch_depth: usize,
    /// hot-tier policy for the prefetch cache
    pub prefetch_policy: CachePolicy,
    /// recycled batch-slab pool size (0 = legacy copy path)
    pub arena_slabs: usize,
    /// shared work-stealing batch injector instead of static round-robin
    pub work_stealing: bool,
    /// item-level stealing inside straggling batches (needs
    /// work_stealing + arena_slabs)
    pub steal_items: bool,
    /// reorder-buffer bound in batches (0 = unbounded)
    pub consumer_credit: usize,
    /// epochs published ahead of the consumer (0 = legacy drain):
    /// persistent workers start the next epoch's batches while the
    /// current tail delivers
    pub epoch_pipeline: usize,
    /// in-flight read budget of the batched-submission I/O ring (0 =
    /// per-item fetch paths). Per-file rigs submit each wave's item
    /// reads as one batch; shard rigs hang the ring below the shard
    /// facade so concurrent window fetches multiplex on it
    pub io_depth: usize,
    /// page-locked staging: implies the spawn start method (torch's
    /// rule), and with an arena the slabs themselves are pinned
    pub pin_memory: bool,
    pub lazy_init: bool,
    pub runtime: gil::Runtime,
    pub trainer: TrainerKind,
    pub epochs: usize,
    pub seed: u64,
    /// span-ring capacity per recorder shard group (0 = telemetry
    /// default; long traces raise it so the ring doesn't wrap)
    pub span_capacity: usize,
    /// closed-loop autotuning: attach a [`Governor`] that reads the
    /// epoch's stall signals and hill-climbs the tunable knobs
    /// (consumer_credit, prefetch_depth, io_depth, active_workers,
    /// steal/pipeline toggles) at epoch seams
    pub autotune: bool,
    /// chaos profile injected into the simulated remote (none | flaky |
    /// outage), deterministic under `seed` — every read shape of the
    /// remote rolls it, batched submission included
    pub fault_profile: &'static str,
    /// resilience: extra read attempts after the first (0 = no retry)
    pub retry_max: u32,
    /// resilience: per-request deadline bounding the retry budget in
    /// ms (0 = unbounded)
    pub request_deadline_ms: u64,
    /// resilience: hedge a ring read once it outlives this multiple of
    /// the online p95 estimate (0 = hedging off)
    pub hedge_after: f64,
}

impl RigSpec {
    /// Paper Table 2/5 shape, scaled to CI size.
    pub fn quick(storage: &'static str, latency_scale: f64) -> RigSpec {
        RigSpec {
            storage,
            latency_scale,
            shard_size: 0,
            shard_shuffle: false,
            cache_bytes: 0,
            cache_policy: CachePolicy::Lru,
            items: 192,
            mean_kb: 48,
            crop: 32,
            batch_size: 32,
            num_workers: 4,
            prefetch_factor: 2,
            fetch_impl: FetchImpl::Vanilla,
            num_fetch_workers: 16,
            batch_pool: 0,
            prefetch_depth: 0,
            prefetch_policy: CachePolicy::Lru,
            arena_slabs: 0,
            work_stealing: false,
            steal_items: false,
            consumer_credit: 0,
            epoch_pipeline: 0,
            io_depth: 0,
            pin_memory: false,
            lazy_init: true,
            runtime: gil::Runtime::Python,
            trainer: TrainerKind::Torch,
            epochs: 1,
            seed: 7,
            span_capacity: 0,
            autotune: false,
            fault_profile: "none",
            retry_max: 0,
            request_deadline_ms: 0,
            hedge_after: 0.0,
        }
    }

    pub fn with_impl(mut self, f: FetchImpl) -> RigSpec {
        self.fetch_impl = f;
        self
    }

    pub fn with_trainer(mut self, t: TrainerKind) -> RigSpec {
        self.trainer = t;
        self
    }

    pub fn label(&self) -> String {
        format!(
            "{}-{}-{}",
            self.storage,
            self.trainer.label(),
            self.fetch_impl.label()
        )
    }
}

/// Built rig, ready to train.
pub struct Rig {
    pub dataloader: Dataloader,
    pub device: Device,
    pub trainer_cfg: TrainerConfig,
    pub recorder: Arc<Recorder>,
    pub store: Arc<dyn ObjectStore>,
    pub remote: Option<Arc<SimRemoteStore>>,
    /// the chaos plane attached to the remote (`fault_profile != none`)
    pub faults: Option<Arc<FaultInjector>>,
    /// the resilience layer (`retry_max`/`request_deadline_ms`/
    /// `hedge_after` any nonzero), mounted between the cache/prefetch
    /// stack and the remote
    pub resilient: Option<Arc<ResilientStore>>,
    pub cache: Option<Arc<VarnishCache>>,
    pub prefetch: Option<Arc<PrefetchStore>>,
    pub shards: Option<Arc<ShardStore>>,
    /// the batched-submission ring (`io_depth > 0`), wherever it hangs:
    /// below the shard facade, or the loader-side wave ring
    pub ring: Option<Arc<IoRing>>,
    pub corpus_bytes: u64,
    /// the closed-loop autotuner (`autotune = true`): drive it once per
    /// finished epoch through [`autotune_tick`]
    pub autotune: Option<Mutex<AutotuneHarness>>,
}

/// The Governor plus the cumulative-counter snapshot it diffs against:
/// the rig's signals are lifetime totals, the control loop wants
/// per-epoch deltas.
pub struct AutotuneHarness {
    pub governor: Governor,
    prev: AutotuneBase,
    last_seam: Instant,
}

/// Cumulative counters at the previous epoch seam.
#[derive(Debug, Clone, Copy, Default)]
struct AutotuneBase {
    credit_blocked_s: f64,
    seam_idle_s: f64,
    storage_wait_s: f64,
    decode_s: f64,
    item_steals: u64,
    prefetch_gets: u64,
    prefetch_hits: u64,
    allocs: u64,
    resilience_ops: u64,
    resilience_retries: u64,
}

fn autotune_base(rig: &Rig) -> AutotuneBase {
    let dl = &rig.dataloader;
    let (storage_wait_s, decode_s) = dl
        .dataset()
        .lane_times()
        .map_or((0.0, 0.0), |(s, d)| (s.as_secs_f64(), d.as_secs_f64()));
    let (prefetch_gets, prefetch_hits) = rig.prefetch.as_ref().map_or((0, 0), |p| {
        let c = p.counters();
        (c.gets, c.hot_hits + c.inflight_hits)
    });
    let (resilience_ops, resilience_retries) = rig.resilient.as_ref().map_or((0, 0), |r| {
        let s = r.snapshot();
        (s.ops, s.retries)
    });
    AutotuneBase {
        credit_blocked_s: dl.credit_blocked().as_secs_f64(),
        seam_idle_s: dl.seam_idle().as_secs_f64(),
        storage_wait_s,
        decode_s,
        item_steals: dl.item_steals(),
        prefetch_gets,
        prefetch_hits,
        allocs: crate::util::alloc::counters().allocs,
        resilience_ops,
        resilience_retries,
    }
}

/// Feed the Governor one finished epoch ([`autotune_tick_p99`] with the
/// p99 guard disabled — callers that track per-batch times use that
/// variant directly).
pub fn autotune_tick(rig: &Rig, epoch: usize) {
    autotune_tick_p99(rig, epoch, 0.0);
}

/// Feed the Governor one finished epoch's signals (per-epoch deltas of
/// the cumulative plane) and let it stage at most one bounded knob
/// change for the next seam. `p99_batch_s = 0` disables the tail guard.
/// No-op without `autotune`.
pub fn autotune_tick_p99(rig: &Rig, epoch: usize, p99_batch_s: f64) {
    let Some(harness) = &rig.autotune else { return };
    let mut h = harness.lock().unwrap();
    let now = Instant::now();
    let epoch_s = now.duration_since(h.last_seam).as_secs_f64();
    h.last_seam = now;
    let cur = autotune_base(rig);
    let prev = h.prev;
    h.prev = cur;
    let dgets = cur.prefetch_gets - prev.prefetch_gets;
    let dhits = cur.prefetch_hits - prev.prefetch_hits;
    let prefetch_hit_ratio = if rig.prefetch.is_none() || dgets == 0 {
        -1.0
    } else {
        dhits as f64 / dgets as f64
    };
    let (ring_inflight_hwm, ring_queued) = rig.ring.as_ref().map_or((0, 0), |r| {
        let s = r.stats();
        (s.inflight_hwm as usize, s.queued as usize)
    });
    let dops = cur.resilience_ops - prev.resilience_ops;
    let dretries = cur.resilience_retries - prev.resilience_retries;
    let retry_rate = if dops == 0 { 0.0 } else { dretries as f64 / dops as f64 };
    let sig = Signals {
        epoch,
        batches: rig.dataloader.batches_per_epoch(),
        epoch_s,
        p99_batch_s,
        credit_blocked_s: cur.credit_blocked_s - prev.credit_blocked_s,
        seam_idle_s: cur.seam_idle_s - prev.seam_idle_s,
        reorder_hwm: 0, // per-epoch iter stat; the p99 signal covers the tail
        item_steals: cur.item_steals - prev.item_steals,
        storage_wait_s: cur.storage_wait_s - prev.storage_wait_s,
        decode_s: cur.decode_s - prev.decode_s,
        prefetch_hit_ratio,
        ring_inflight_hwm,
        ring_queued,
        allocs: cur.allocs - prev.allocs,
        retry_rate,
    };
    h.governor.end_epoch(&sig);
}

/// Assembled storage stack: the top-of-stack store plus handles into
/// each optional layer (new layers extend this struct, not every
/// `build_store` call site).
pub struct StorageStack {
    pub store: Arc<dyn ObjectStore>,
    pub remote: Option<Arc<SimRemoteStore>>,
    /// seeded fault injector rolled by every remote read shape
    pub faults: Option<Arc<FaultInjector>>,
    /// deadlines/retries/hedges/breaker between cache stack and remote
    pub resilient: Option<Arc<ResilientStore>>,
    pub cache: Option<Arc<VarnishCache>>,
    pub prefetch: Option<Arc<PrefetchStore>>,
    /// shard-window facade at the top of the stack (`shard_size > 0`)
    pub shards: Option<Arc<ShardStore>>,
    /// ring under the shard facade (`shard_size > 0 && io_depth > 0`):
    /// window fetches and prefetch speculation multiplex on it
    pub ring: Option<Arc<IoRing>>,
    pub corpus_bytes: u64,
}

/// Build the storage stack for a spec.
pub fn build_store(spec: &RigSpec) -> Result<StorageStack> {
    let corpus: Arc<dyn ObjectStore> = Arc::new(MemStore::new("corpus"));
    let (_, total) = generate_corpus(
        &corpus,
        &CorpusSpec {
            items: spec.items,
            classes: 512,
            mean_bytes: spec.mean_kb * 1024,
            sigma: 0.35,
            seed: spec.seed,
        },
    )?;
    // shard mode: the backing store (and so the simulated remote) holds
    // packed tar shards instead of per-file objects; the manifest
    // remembers every sample's exact placement for the facade on top
    let (mem, manifest): (Arc<dyn ObjectStore>, Option<ShardManifest>) =
        if spec.shard_size > 0 {
            let packed: Arc<dyn ObjectStore> = Arc::new(MemStore::new("shards"));
            let m = pack_shards(&corpus, &packed, spec.shard_size)?;
            (packed, Some(m))
        } else {
            (corpus, None)
        };
    let (store, remote): (Arc<dyn ObjectStore>, Option<Arc<SimRemoteStore>>) =
        if spec.storage == "mem" {
            (mem, None)
        } else {
            let Some(profile) = RemoteProfile::by_name(spec.storage) else {
                bail!("unknown storage profile {}", spec.storage)
            };
            let r = SimRemoteStore::new(
                mem,
                profile.scaled(spec.latency_scale),
                spec.seed ^ 0x5EED,
            );
            (r.clone() as Arc<dyn ObjectStore>, Some(r))
        };
    // chaos plane: a seeded injector every remote read shape rolls —
    // attached even when the resilience layer is off, so the bare arm
    // of the fault_table degrades honestly
    let faults = match (&remote, spec.fault_profile) {
        (Some(r), name) if name != "none" => {
            let Some(profile) = FaultProfile::by_name(name) else {
                bail!("unknown fault_profile {name} (none|flaky|outage)")
            };
            let inj = FaultInjector::new(profile, spec.seed ^ 0xFA17);
            r.set_faults(inj.clone());
            Some(inj)
        }
        _ => None,
    };
    // resilience layer between the remote and the cache/prefetch stack:
    // retries/deadlines on every read shape, hedges + breaker-gated
    // degradation on the batched-submission path
    let rcfg =
        ResilienceConfig::new(spec.retry_max, spec.request_deadline_ms, spec.hedge_after);
    let (store, resilient): (Arc<dyn ObjectStore>, Option<Arc<ResilientStore>>) =
        if rcfg.enabled() {
            let rs = ResilientStore::new(store, rcfg, spec.seed);
            (rs.clone() as Arc<dyn ObjectStore>, Some(rs))
        } else {
            (store, None)
        };
    let (store, cache): (Arc<dyn ObjectStore>, Option<Arc<VarnishCache>>) =
        if spec.cache_bytes > 0 {
            let c =
                VarnishCache::with_policy(store, spec.cache_bytes, spec.cache_policy);
            (c.clone() as Arc<dyn ObjectStore>, Some(c))
        } else {
            (store, None)
        };
    // sampler-ahead prefetch engine on top of the stack (hot tier over
    // whatever sits below as the warm tier)
    let (store, prefetch): (Arc<dyn ObjectStore>, Option<Arc<PrefetchStore>>) =
        if spec.prefetch_depth > 0 {
            let p = PrefetchStore::new(
                store,
                PrefetchConfig {
                    depth: spec.prefetch_depth,
                    policy: spec.prefetch_policy,
                    ..Default::default()
                },
            );
            (p.clone() as Arc<dyn ObjectStore>, Some(p))
        } else {
            (store, None)
        };
    // top of the stack in shard mode: the per-sample key space served
    // out of resident shard windows — one request each, hints translated
    // to shard order for the prefetch layer below
    let (store, shards, ring): (
        Arc<dyn ObjectStore>,
        Option<Arc<ShardStore>>,
        Option<Arc<IoRing>>,
    ) = if let Some(m) = manifest {
        // room for the windows the fetch pool + shuffle jitter keep
        // live at once, plus the pipelined epoch seam
        let cap = 4 + spec.num_fetch_workers / 4;
        let s = Arc::new(ShardStore::new(store, m, cap));
        let ring = if spec.io_depth > 0 {
            // the ring wraps the stack *below* the shard facade: many
            // threads' window fetches share one submission queue, and
            // the prefetch engine's speculation draws from the same
            // in-flight budget
            let ring = IoRing::new(s.inner().clone(), spec.io_depth);
            s.set_ring(ring.clone());
            if let Some(p) = &prefetch {
                p.set_ring(ring.clone());
            }
            Some(ring)
        } else {
            None
        };
        (s.clone() as Arc<dyn ObjectStore>, Some(s), ring)
    } else {
        (store, None, None)
    };
    Ok(StorageStack {
        store,
        remote,
        faults,
        resilient,
        cache,
        prefetch,
        shards,
        ring,
        corpus_bytes: total,
    })
}

/// Build the full rig.
pub fn build(spec: &RigSpec) -> Result<Rig> {
    let recorder = if spec.span_capacity > 0 {
        Recorder::with_capacity(spec.span_capacity)
    } else {
        Recorder::new()
    };
    let StorageStack {
        store,
        remote,
        faults,
        resilient,
        cache,
        prefetch,
        shards,
        ring,
        corpus_bytes,
    } = build_store(spec)?;
    if let Some(p) = &prefetch {
        p.set_recorder(recorder.clone());
    }
    if let Some(r) = &ring {
        r.set_recorder(recorder.clone());
    }
    if let Some(rs) = &resilient {
        rs.set_recorder(recorder.clone());
    }
    let augment_cfg =
        AugmentConfig { crop: spec.crop, seed: spec.seed, ..Default::default() };
    // same augment config either way: per-sample bytes are a function of
    // (seed, epoch, index) only, so shard and per-file rigs with the
    // same spec deliver byte-identical samples
    let dataset: Arc<dyn Dataset> = if let Some(s) = &shards {
        let mut ds = ShardDataset::new(s.clone(), augment_cfg);
        if spec.shard_shuffle {
            ds = ds.with_shuffle(spec.seed);
        }
        Arc::new(ds)
    } else {
        Arc::new(ImageFolderDataset::new(store.clone(), augment_cfg))
    };
    let loader_cfg = DataloaderConfig {
        batch_size: spec.batch_size,
        num_workers: spec.num_workers,
        prefetch_factor: spec.prefetch_factor,
        fetch_impl: spec.fetch_impl,
        num_fetch_workers: spec.num_fetch_workers,
        batch_pool: spec.batch_pool,
        prefetch_depth: spec.prefetch_depth,
        prefetch_policy: spec.prefetch_policy,
        arena_slabs: spec.arena_slabs,
        work_stealing: spec.work_stealing,
        steal_items: spec.steal_items,
        consumer_credit: spec.consumer_credit,
        epoch_pipeline: spec.epoch_pipeline,
        // in shard mode the ring hangs below the shard facade (wired
        // above); the loader-side wave ring only applies when items are
        // plain per-file objects the dataset can describe as descriptors
        io_depth: if shards.is_some() { 0 } else { spec.io_depth },
        pin_memory: spec.pin_memory,
        // pinning needs CUDA init, which fork forbids (torch rule)
        start_method: if spec.pin_memory {
            crate::dataloader::StartMethod::Spawn
        } else {
            crate::dataloader::StartMethod::Fork
        },
        lazy_init: spec.lazy_init,
        runtime: spec.runtime,
        seed: spec.seed,
        spawn_cost_override: Some(Duration::from_millis(4)),
        ..Default::default()
    };
    let dataloader = Dataloader::new(dataset, loader_cfg, recorder.clone());
    // one ring per rig, wherever it hangs; the loader-side wave ring
    // feeds the prefetch engine's speculation budget too
    let shard_mode = shards.is_some();
    let ring = ring.or_else(|| dataloader.ring().cloned());
    if !shard_mode {
        if let (Some(r), Some(p)) = (&ring, &prefetch) {
            p.set_ring(r.clone());
        }
    }
    // seam-committed knobs steer the rig-level layers too: the prefetch
    // engine's readahead depth, and (shard mode) the stack ring the
    // loader doesn't own — seed that knob with the ring's real depth
    // first, since the loader config carried io_depth = 0
    let knobs = dataloader.knobs().clone();
    if shard_mode {
        if let Some(r) = &ring {
            knobs.stage_io_depth(r.io_depth());
            knobs.commit();
            let r = r.clone();
            knobs.register_applier(Box::new(move |k| r.set_depth(k.io_depth())));
        }
    }
    if let Some(p) = &prefetch {
        let p = p.clone();
        knobs.register_applier(Box::new(move |k| p.set_depth(k.prefetch_depth())));
    }
    let autotune = if spec.autotune {
        let bounds = KnobBounds::derive(
            dataloader.config(),
            ring.is_some(),
            prefetch.is_some(),
            dataloader.dataset().supports_epoch_tagged(),
        );
        let governor = Governor::new(GovernorConfig::default(), knobs, bounds)
            .with_recorder(recorder.clone());
        Some(Mutex::new(AutotuneHarness {
            governor,
            prev: AutotuneBase::default(),
            last_seam: Instant::now(),
        }))
    } else {
        None
    };
    let device = Device::sim_v100(spec.batch_size, 512, recorder.clone());
    let trainer_cfg = match spec.trainer {
        TrainerKind::Torch => TrainerConfig::torch(spec.epochs),
        TrainerKind::Lightning => TrainerConfig::lightning(spec.epochs),
    };
    Ok(Rig {
        dataloader,
        device,
        trainer_cfg,
        recorder,
        store,
        remote,
        faults,
        resilient,
        cache,
        prefetch,
        shards,
        ring,
        corpus_bytes,
        autotune,
    })
}

/// Build + train + report.
pub fn run(spec: &RigSpec) -> Result<(TrainReport, Rig)> {
    let rig = build(spec)?;
    let report = trainer::train(
        &rig.dataloader,
        &rig.device,
        &rig.trainer_cfg,
        rig.recorder.clone(),
    )?;
    Ok((report, rig))
}

/// Loader-only epoch (no device): drain all batches (recycling their
/// slabs), return (wall seconds, bytes, batches).
pub fn drain_epoch(rig: &Rig) -> (f64, u64, usize) {
    drain_numbered_epoch(rig, 0)
}

/// [`drain_epoch`] for an arbitrary epoch number (arena-aware sweeps
/// measure a *steady-state* epoch, not the cold first one).
pub fn drain_numbered_epoch(rig: &Rig, epoch: usize) -> (f64, u64, usize) {
    let t0 = std::time::Instant::now();
    let mut bytes = 0u64;
    let mut n = 0usize;
    for b in rig.dataloader.epoch(epoch) {
        bytes += b.raw_bytes;
        n += 1;
        b.recycle();
    }
    (t0.elapsed().as_secs_f64(), bytes, n)
}

/// Snapshot the whole observability plane after `epoch`: absorb every
/// scattered pipeline signal — stall lanes, seam idle (aggregate and
/// per worker), arena/prefetch/cache counters, allocator totals, span
/// accounting — into the recorder's metrics hub, then render one
/// `{"epoch": N, "metrics": {...}}` object (a `--metrics` JSONL line).
/// Values are cumulative since rig construction; diff consecutive
/// lines for per-epoch movement.
pub fn metrics_snapshot(rig: &Rig, epoch: usize) -> Json {
    let hub = rig.recorder.metrics();
    let dl = &rig.dataloader;
    hub.set("loader.credit_blocked_ns", dl.credit_blocked().as_nanos() as u64);
    hub.set("loader.reorder_hold_ns", dl.reorder_hold().as_nanos() as u64);
    hub.set("loader.item_steals", dl.item_steals());
    hub.set("loader.plans_published", dl.plans_published() as u64);
    hub.set("loader.plans_revoked", dl.plans_revoked());
    hub.set("loader.knob_commits", dl.knobs().commit_count());
    hub.set("loader.throttled_ns", dl.knobs().throttled().as_nanos() as u64);
    hub.set("planner.seam_idle_ns", dl.seam_idle().as_nanos() as u64);
    for (i, d) in dl.seam_idle_per_worker().iter().enumerate() {
        hub.set(&format!("planner.seam_idle_ns.w{i}"), d.as_nanos() as u64);
    }
    if let Some((storage, decode)) = dl.dataset().lane_times() {
        hub.set("dataset.storage_wait_ns", storage.as_nanos() as u64);
        hub.set("dataset.decode_ns", decode.as_nanos() as u64);
    }
    if let Some(arena) = dl.arena() {
        let s = arena.stats();
        hub.set("arena.checkouts", s.checkouts);
        hub.set("arena.reused", s.reused);
        hub.set("arena.fresh", s.fresh);
        hub.set("arena.recycled", s.recycled);
        hub.set("arena.discarded", s.discarded);
    }
    if let Some(p) = &rig.prefetch {
        let c = p.counters();
        hub.set("prefetch.gets", c.gets);
        hub.set("prefetch.hot_hits", c.hot_hits);
        hub.set("prefetch.inflight_hits", c.inflight_hits);
        hub.set("prefetch.demand_misses", c.demand_misses);
        hub.set("prefetch.issued", c.issued);
        hub.set("prefetch.completed", c.completed);
        hub.set("prefetch.stale", c.stale);
    }
    if let Some(s) = &rig.shards {
        let (fetches, hits, waits, evictions) = s.window_stats();
        hub.set("shards.window_fetches", fetches);
        hub.set("shards.window_hits", hits);
        hub.set("shards.window_waits", waits);
        hub.set("shards.window_evictions", evictions);
    }
    if let Some(r) = &rig.ring {
        let s = r.stats();
        hub.set("ring.submitted", s.submitted);
        hub.set("ring.completed", s.completed);
        hub.set("ring.batches", s.batches);
        hub.set("ring.queued", s.queued);
        hub.set("ring.inflight", s.inflight);
        hub.set("ring.inflight_hwm", s.inflight_hwm);
        hub.set("ring.errors", s.errors);
    }
    if let Some(rs) = &rig.resilient {
        let s = rs.snapshot();
        hub.set("resilience.ops", s.ops);
        hub.set("resilience.attempts", s.attempts);
        hub.set("resilience.retries", s.retries);
        hub.set("resilience.hedges", s.hedges);
        hub.set("resilience.hedge_wins", s.hedge_wins);
        hub.set("resilience.hedge_wasted", s.hedge_wasted);
        hub.set("resilience.exhausted", s.exhausted);
        hub.set("resilience.deadline_hits", s.deadline_hits);
        hub.set("resilience.breaker_fastfail", s.breaker_fastfail);
        hub.set("resilience.breaker_opens", s.breaker_opens);
        hub.set("resilience.breaker_state", s.breaker_state);
        hub.set("resilience.p95_us", (s.p95_ms * 1e3) as u64);
    }
    if let Some(f) = &rig.faults {
        let c = f.counters();
        hub.set("faults.decisions", c.decisions);
        hub.set("faults.injected", c.injected());
        hub.set("faults.transient", c.transient);
        hub.set("faults.stalls", c.stalls);
        hub.set("faults.resets", c.resets);
        hub.set("faults.short_reads", c.short_reads);
        hub.set("faults.forced_ok", c.forced_ok);
    }
    if let Some(cache) = &rig.cache {
        let s = cache.tier_stats();
        hub.set("cache.hits", s.hits);
        hub.set("cache.misses", s.misses);
        hub.set("cache.evictions", s.evictions);
        hub.set("cache.ghost_promotions", s.ghost_promotions);
        hub.set("cache.bytes", s.bytes);
    }
    let a = crate::util::alloc::counters();
    hub.set("alloc.allocs", a.allocs);
    hub.set("alloc.frees", a.frees);
    hub.set("alloc.bytes", a.bytes);
    hub.set("spans.recorded", rig.recorder.len() as u64);
    hub.set("spans.dropped", rig.recorder.dropped());
    let mut doc = Json::obj();
    doc.set("epoch", epoch as u64).set("metrics", hub.snapshot());
    // the Governor's decision log rides the same JSONL stream: one
    // object per control-loop decision since rig construction
    if let Some(h) = &rig.autotune {
        let h = h.lock().unwrap();
        let gov = &h.governor;
        let decisions: Vec<Json> = gov
            .decisions()
            .iter()
            .map(|d| {
                let mut j = Json::obj();
                j.set("epoch", d.epoch as u64)
                    .set("knob", d.knob.label())
                    .set("action", d.action.label())
                    .set("from", d.from as u64)
                    .set("to", d.to as u64)
                    .set("bps", d.bps)
                    .set("p99_s", d.p99_s);
                j
            })
            .collect();
        let (bps, p99) = gov.baseline();
        let mut g = Json::obj();
        g.set("phase", gov.phase_label())
            .set("baseline_bps", bps)
            .set("baseline_p99_s", p99)
            .set("decisions", decisions);
        doc.set("governor", g);
    }
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_rig_builds_and_drains() {
        let mut spec = RigSpec::quick("mem", 0.1);
        spec.items = 32;
        spec.batch_size = 8;
        let rig = build(&spec).unwrap();
        let (secs, bytes, n) = drain_epoch(&rig);
        assert_eq!(n, 4);
        assert!(bytes > 0);
        assert!(secs > 0.0);
    }

    #[test]
    fn unknown_storage_errors() {
        let spec = RigSpec::quick("marsfs", 1.0);
        assert!(build(&spec).is_err());
    }

    #[test]
    fn cache_layer_attaches() {
        let mut spec = RigSpec::quick("s3", 0.02);
        spec.items = 16;
        spec.cache_bytes = 10 << 20;
        spec.cache_policy = CachePolicy::TwoQ;
        let rig = build(&spec).unwrap();
        assert!(rig.cache.is_some());
        assert!(rig.remote.is_some());
        assert!(rig.prefetch.is_none());
        assert!(rig.store.label().starts_with("varnish"));
        assert_eq!(rig.cache.as_ref().unwrap().policy(), CachePolicy::TwoQ);
    }

    #[test]
    fn prefetch_layer_attaches_and_serves_epoch() {
        let mut spec = RigSpec::quick("s3", 0.02);
        spec.items = 24;
        spec.batch_size = 8;
        spec.prefetch_depth = 16;
        let rig = build(&spec).unwrap();
        assert!(rig.prefetch.is_some());
        assert!(rig.store.label().starts_with("prefetch(s3"));
        let (_, _, n) = drain_epoch(&rig);
        assert_eq!(n, 3);
        let p = rig.prefetch.as_ref().unwrap();
        let c = p.counters();
        assert_eq!(c.gets, 24, "{c:?}");
        assert!(c.issued > 0, "engine idle: {c:?}");
    }

    #[test]
    fn arena_and_stealing_rig_drains_cleanly() {
        let mut spec = RigSpec::quick("mem", 0.1);
        spec.items = 32;
        spec.batch_size = 8;
        spec.arena_slabs = 12;
        spec.work_stealing = true;
        let rig = build(&spec).unwrap();
        let (_, _, n) = drain_epoch(&rig);
        assert_eq!(n, 4);
        let (_, _, n) = drain_numbered_epoch(&rig, 1);
        assert_eq!(n, 4);
        let s = rig.dataloader.arena().unwrap().stats();
        assert_eq!(s.checkouts, 8, "{s:?}");
        assert!(s.reused >= 4, "{s:?}");
    }

    #[test]
    fn metrics_snapshot_covers_the_plane() {
        let mut spec = RigSpec::quick("mem", 0.1);
        spec.items = 32;
        spec.batch_size = 8;
        spec.arena_slabs = 8;
        spec.work_stealing = true;
        let rig = build(&spec).unwrap();
        drain_epoch(&rig);
        let snap = metrics_snapshot(&rig, 0);
        assert_eq!(snap.at(&["epoch"]).and_then(|j| j.as_usize()), Some(0));
        let m = |k: &str| {
            snap.at(&["metrics", k])
                .and_then(|j| j.as_f64())
                .unwrap_or_else(|| panic!("missing metric {k}"))
        };
        assert_eq!(m("arena.checkouts"), 4.0);
        assert_eq!(m("loader.plans_published"), 1.0);
        assert!(m("dataset.decode_ns") > 0.0);
        assert!(m("spans.recorded") > 0.0);
        // round-trips through the hand-rolled JSON
        let text = snap.to_string();
        assert!(crate::util::json::parse(&text).is_ok());
    }

    #[test]
    fn shard_rig_attaches_and_matches_per_file_bytes() {
        let mut spec = RigSpec::quick("s3", 0.02);
        spec.items = 24;
        spec.batch_size = 8;
        spec.prefetch_depth = 4; // depth counts *shards* in shard mode
        let mut sharded = spec.clone();
        sharded.shard_size = 6;
        let per_file = build(&spec).unwrap();
        let rig = build(&sharded).unwrap();
        assert!(rig.shards.is_some());
        assert!(rig.store.label().starts_with("shards(prefetch("));
        // identical batch sequence, byte for byte
        let mut batches = Vec::new();
        for b in per_file.dataloader.epoch(0) {
            batches.push((b.images.data.clone(), b.labels.clone()));
            b.recycle();
        }
        for (i, b) in rig.dataloader.epoch(0).enumerate() {
            assert_eq!(b.images.data, batches[i].0, "batch {i}");
            assert_eq!(b.labels, batches[i].1);
            b.recycle();
        }
        let s = rig.shards.as_ref().unwrap();
        let (fetches, hits, _, _) = s.window_stats();
        assert_eq!(fetches, 4, "one request per shard window");
        assert!(hits >= 20 - 4, "samples served out of resident windows");
    }

    #[test]
    fn shard_shuffle_rig_delivers_every_sample() {
        let mut spec = RigSpec::quick("mem", 0.1);
        spec.items = 32;
        spec.batch_size = 8;
        spec.shard_size = 8;
        spec.shard_shuffle = true;
        let rig = build(&spec).unwrap();
        let mut seen = vec![0usize; 32];
        for b in rig.dataloader.epoch(0) {
            for &i in &b.indices {
                seen[i] += 1;
            }
            b.recycle();
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
    }

    #[test]
    fn ring_rig_matches_legacy_bytes_per_file() {
        // io_depth on vs off, same spec otherwise: byte-identical epoch
        let mut spec = RigSpec::quick("s3", 0.02);
        spec.items = 24;
        spec.batch_size = 8;
        spec.fetch_impl = FetchImpl::Threaded;
        spec.arena_slabs = 8;
        let mut ringed = spec.clone();
        ringed.io_depth = 64;
        let legacy = build(&spec).unwrap();
        let rig = build(&ringed).unwrap();
        assert!(rig.ring.is_some(), "loader-side ring must attach");
        let mut batches = Vec::new();
        for b in legacy.dataloader.epoch(0) {
            batches.push((b.images.data.clone(), b.labels.clone()));
            b.recycle();
        }
        for (i, b) in rig.dataloader.epoch(0).enumerate() {
            assert_eq!(b.images.data, batches[i].0, "batch {i}");
            assert_eq!(b.labels, batches[i].1);
            b.recycle();
        }
        let s = rig.ring.as_ref().unwrap().stats();
        assert_eq!(s.submitted, 24, "{s:?}");
        assert_eq!(s.completed, 24, "{s:?}");
        assert_eq!(s.errors, 0, "{s:?}");
        assert!(s.batches >= 3, "{s:?}");
    }

    #[test]
    fn ring_rig_attaches_below_shard_facade() {
        let mut spec = RigSpec::quick("s3", 0.02);
        spec.items = 24;
        spec.batch_size = 8;
        spec.shard_size = 6;
        spec.prefetch_depth = 4;
        let mut ringed = spec.clone();
        ringed.io_depth = 32;
        let legacy = build(&spec).unwrap();
        let rig = build(&ringed).unwrap();
        assert!(rig.ring.is_some(), "shard-stack ring must attach");
        // the loader side stays on the window cache: the ring serves it
        // from below, so bytes are identical to the unringed shard rig
        assert!(rig.dataloader.ring().is_none());
        let mut batches = Vec::new();
        for b in legacy.dataloader.epoch(0) {
            batches.push((b.images.data.clone(), b.labels.clone()));
            b.recycle();
        }
        for (i, b) in rig.dataloader.epoch(0).enumerate() {
            assert_eq!(b.images.data, batches[i].0, "batch {i}");
            assert_eq!(b.labels, batches[i].1);
            b.recycle();
        }
        let s = rig.ring.as_ref().unwrap().stats();
        assert!(s.submitted >= 4, "window fetches must ride the ring: {s:?}");
        assert_eq!(s.errors, 0, "{s:?}");
    }

    #[test]
    fn autotune_rig_probes_and_commits_only_at_seams() {
        let mut spec = RigSpec::quick("s3", 0.02);
        spec.items = 32;
        spec.batch_size = 8;
        spec.arena_slabs = 12;
        spec.work_stealing = true;
        spec.consumer_credit = 2;
        spec.autotune = true;
        let rig = build(&spec).unwrap();
        assert!(rig.autotune.is_some());
        assert!(rig.dataloader.knobs().governed());
        for epoch in 0..4 {
            let (_, _, n) = drain_numbered_epoch(&rig, epoch);
            assert_eq!(n, 4, "epoch {epoch}");
            autotune_tick(&rig, epoch);
        }
        let h = rig.autotune.as_ref().unwrap().lock().unwrap();
        let (probes, _, _) = h.governor.counts();
        assert!(probes >= 1, "governor must have probed");
        assert!(!h.governor.decisions().is_empty());
        // knob values only ever move through seam commits (one per
        // epoch() call; the shard-seed path adds none here)
        assert_eq!(rig.dataloader.knobs().commit_count(), 4);
        drop(h);
        let snap = metrics_snapshot(&rig, 3);
        assert!(snap.at(&["governor", "decisions"]).is_some());
        assert!(
            snap.at(&["metrics", "governor.steps"])
                .and_then(|j| j.as_f64())
                .unwrap_or(0.0)
                >= 4.0
        );
    }

    #[test]
    fn resilient_rig_drains_identically_under_flaky_faults() {
        // same spec ± chaos: flaky faults behind the resilience layer
        // must deliver the exact bytes of the fault-free rig
        let mut clean = RigSpec::quick("s3", 0.02);
        clean.items = 24;
        clean.batch_size = 8;
        let mut chaos = clean.clone();
        chaos.fault_profile = "flaky";
        chaos.retry_max = 4;
        let baseline = build(&clean).unwrap();
        let rig = build(&chaos).unwrap();
        assert!(rig.faults.is_some());
        assert!(rig.resilient.is_some());
        assert!(rig.store.label().starts_with("resilient(s3"));
        let mut batches = Vec::new();
        for b in baseline.dataloader.epoch(0) {
            batches.push((b.images.data.clone(), b.labels.clone()));
            b.recycle();
        }
        assert_eq!(batches.len(), 3);
        let mut n = 0;
        for (i, b) in rig.dataloader.epoch(0).enumerate() {
            assert_eq!(b.images.data, batches[i].0, "batch {i}");
            assert_eq!(b.labels, batches[i].1);
            n += 1;
            b.recycle();
        }
        assert_eq!(n, 3, "no batch may be lost behind the retry budget");
        let s = rig.resilient.as_ref().unwrap().snapshot();
        assert!(s.retries > 0, "flaky must have forced retries: {s:?}");
        assert_eq!(s.exhausted, 0, "{s:?}");
        let f = rig.faults.as_ref().unwrap().counters();
        assert!(f.injected() > 0, "{f:?}");
    }

    #[test]
    fn outage_rig_degrades_gracefully() {
        // hard outage with a thin retry budget: every batch tombstones,
        // the breaker opens, nothing panics or hangs
        let mut spec = RigSpec::quick("s3", 0.02);
        spec.items = 24;
        spec.batch_size = 8;
        spec.fault_profile = "outage";
        spec.retry_max = 1;
        let rig = build(&spec).unwrap();
        let (_, _, n) = drain_epoch(&rig);
        assert_eq!(n, 0, "an outage can deliver nothing");
        let s = rig.resilient.as_ref().unwrap().snapshot();
        assert!(s.exhausted > 0, "{s:?}");
        assert!(s.breaker_opens >= 1, "{s:?}");
        let snap = metrics_snapshot(&rig, 0);
        let m = |k: &str| snap.at(&["metrics", k]).and_then(|j| j.as_f64());
        assert!(m("resilience.exhausted").unwrap_or(0.0) > 0.0);
        assert!(m("faults.injected").unwrap_or(0.0) > 0.0);
    }

    #[test]
    fn run_produces_report() {
        let mut spec = RigSpec::quick("scratch", 0.2);
        spec.items = 32;
        spec.batch_size = 16;
        let (report, _rig) = run(&spec).unwrap();
        assert_eq!(report.images, 32);
        assert!(report.img_per_s > 0.0);
    }
}
