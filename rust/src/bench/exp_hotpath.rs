//! Hot-path experiments (beyond the paper's figure set): what the
//! memory path costs once storage latency is out of the picture, and
//! what batch dispatch costs when it isn't.
//!
//! * **Fused arena assembly** — `mem` storage (no latency to hide, the
//!   paper's 12× win already banked), batch 64, every fetcher × arena
//!   on/off: batches/s, p50/p99 consumer batch latency, and per-batch
//!   allocation counts from the counting global allocator. Arena-on
//!   decodes straight into recycled slabs (no decode buffer, no crop
//!   tensor, no collate copy); the allocs/batch column collapses and
//!   batches/s rises with it.
//! * **Dispatch tail** — threaded fetcher over the high-latency
//!   `s3`/`ceph_os`/`gluster_fs` profiles, static vs batch-steal vs
//!   item-steal dispatch at one worker count, all credit-bounded:
//!   p50/p99/max consumer batch latency, the reorder-buffer high-water
//!   mark (must stay ≤ `consumer_credit` — the run *fails* otherwise),
//!   and items stolen per epoch. Item stealing lets idle workers finish
//!   a straggling batch's tail, cutting the p99 beyond batch-level
//!   stealing (the MinatoLoader argument).
//! * **Epoch boundary** — the same high-latency profiles over three
//!   epochs, drained (`epoch_pipeline=0`) vs pipelined (`=1`): the
//!   inter-epoch gap (last batch of N → first batch of N+1) and the
//!   workers' cumulative idle time at the seam. Persistent workers plus
//!   a pre-published next-epoch plan keep the fetch pipeline warm
//!   across the boundary; the table *fails* if the pipelined gap is not
//!   strictly smaller than the drained gap on s3.
//! * **Pinned slabs** — `pin_memory` over an arena hands out page-locked
//!   slabs: batches are born pinned, skip the staging copy, and ride the
//!   ~2× pinned-bandwidth `to_device` path. Reported as the
//!   pageable-vs-pinned transfer delta.
//! * **`get_into` scratch reads** — `DirStore` (real files) read via the
//!   legacy `get` (one `Vec` per read) vs the zero-copy `get_into`
//!   (pread into a reused buffer): reads/s and allocs/read; the
//!   get_into row must report **0 allocs/read** in steady state (the
//!   run fails otherwise, when the counting allocator is installed).
//! * **Shard-window streaming** — per-file GETs vs tar-shard windows
//!   ([`ShardStore`](crate::shards::ShardStore), `shard_size` samples
//!   per request) over the same high-latency profiles, two pipelined
//!   epochs each: batches/s, remote request counts, and window cache
//!   hits. Delivered batches are digest-compared between the two modes
//!   (byte identity is enforced) and the run *fails* if shard
//!   streaming does not strictly beat per-file batches/s on s3 — the
//!   request-amortization payoff this crate's shard path exists for.
//! * **Batched submission** — per-call reads (a pool of sync threads,
//!   each looping `get_into`, the pre-ring fetcher shape) vs one thread
//!   driving the same reads through an [`IoRing`] in wave-sized batches
//!   with hundreds of requests in flight, over the high-latency
//!   profiles: batches/s, p50/p99 wave latency, and the in-flight
//!   high-water mark. Per-slot digests must agree exactly between the
//!   two modes, and the run *fails* on s3 if batched submission does
//!   not strictly beat per-call or if the ring's in-flight high-water
//!   mark never exceeds the per-call path's thread count — the
//!   depth-beyond-threads decoupling the ring exists for.
//! * **Closed-loop autotuning** — plain defaults vs the Governor
//!   hill-climbing the same knobs online vs a hand-tuned best, over
//!   the high-latency profiles. The run *fails* if autotune does not
//!   strictly beat the defaults on s3 or lands below 0.85× hand-tuned
//!   batches/s on any profile — the table that keeps the control loop
//!   honest.
//! * **Chaos gate** — the same s3 rig fault-free, under seeded `flaky`
//!   faults behind the resilience layer (retry budget 4), and under
//!   the identical faults bare. Delivered batches are digest-compared:
//!   the resilient arm must match the clean arm byte for byte with
//!   zero exhausted ops and a nonzero retry count, and the bare arm
//!   must demonstrably degrade (lost batches or a worse p99 than the
//!   resilient arm) — the run *fails* otherwise.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::rig::{self, RigSpec};
use super::{emit, Scale};
use crate::dataloader::FetchImpl;
use crate::dataset::Dataset;
use crate::storage::{
    get_into_vec, DirStore, IoRing, MemStore, ObjectStore, ReadOp, RemoteProfile,
    SimRemoteStore,
};
use crate::telemetry::baseline;
use crate::util::alloc;
use crate::util::stats;
use crate::util::table::{num, Table};

const BATCH: usize = 64;
const STEAL_BATCH: usize = 16;
const STEAL_PROFILES: [&str; 3] = ["s3", "ceph_os", "gluster_fs"];
/// Reorder-buffer bound used by every dispatch-tail cell.
pub const TAIL_CREDIT: usize = 6;
/// Epochs per epoch-boundary cell (gaps are measured at the seams).
pub const BOUNDARY_EPOCHS: usize = 3;
/// Storage profiles in the stall-attribution table ("mem" anchors the
/// no-latency end of the spectrum).
const STALL_PROFILES: [&str; 4] = ["mem", "s3", "ceph_os", "gluster_fs"];
/// Samples per tar shard in the shard-streaming comparison.
pub const SHARD_SIZE: usize = 24;
/// Reads per submitted wave in the batched-submission comparison.
pub const IO_BATCH: usize = 32;
/// Sync threads in the per-call arm (the pre-ring fetcher shape) — and
/// the in-flight bar the ring's high-water mark must clear on s3.
pub const IO_THREADS: usize = 4;
/// Ring depth for the batched arm: hundreds in flight from one thread.
pub const IO_DEPTH: usize = 256;
/// Gate metrics where bigger numbers are better (everything else is a
/// latency/count where smaller wins).
const HIGHER_IS_BETTER: &[&str] = &[
    "assembly.vanilla.speedup",
    "shard.s3.per_file_bps",
    "shard.s3.shard_bps",
    "shard.s3.speedup",
    "io.s3.per_call_bps",
    "io.s3.batched_bps",
    "io.s3.speedup",
    "io.s3.inflight_hwm",
    "autotune.s3.defaults_bps",
    "autotune.s3.autotuned_bps",
    "autotune.s3.speedup",
    "autotune.min_vs_hand",
    "fault.s3.resilient_batches",
];
/// Default relative tolerance for a freshly written baseline: the gate
/// exists to catch order-of-magnitude breakage, not runner jitter.
pub const BASELINE_TOLERANCE: f64 = 1.0;
/// Default absolute slack (metric units) so near-zero baselines do not
/// turn noise into failures.
pub const BASELINE_SLACK: f64 = 2.0;

/// One measured epoch of a built rig: per-batch consumer latencies,
/// wall seconds, allocation-counter delta, and the tail-taming gauges.
struct EpochMeasure {
    latencies: Vec<f64>,
    epoch_s: f64,
    allocs: u64,
    reorder_hwm: usize,
    item_steals: u64,
}

fn measure_epoch(rig: &rig::Rig, epoch: usize) -> EpochMeasure {
    let before = alloc::counters();
    let mut latencies = Vec::new();
    let t0 = Instant::now();
    let mut it = rig.dataloader.epoch(epoch);
    loop {
        let tb = Instant::now();
        let Some(b) = it.next() else { break };
        latencies.push(tb.elapsed().as_secs_f64());
        b.recycle();
    }
    let reorder_hwm = it.reorder_high_water();
    let item_steals = it.item_steals();
    drop(it);
    let epoch_s = t0.elapsed().as_secs_f64();
    let allocs = alloc::counters().since(before).allocs;
    EpochMeasure { latencies, epoch_s, allocs, reorder_hwm, item_steals }
}

fn assembly_spec(fetch: FetchImpl, arena_on: bool, scale: Scale) -> RigSpec {
    let mut spec = RigSpec::quick("mem", scale.latency);
    spec.items = scale.items(256);
    spec.batch_size = BATCH;
    spec.mean_kb = 96;
    spec.crop = 32;
    spec.num_workers = 4;
    spec.num_fetch_workers = 8;
    spec.fetch_impl = fetch;
    // native workers: measure the memory path itself, not the GIL tax
    // stretching it (the tax multiplies both cells identically)
    spec.runtime = crate::gil::Runtime::Native;
    if arena_on {
        // in-flight window: data queue (8) + one wave per worker (4) +
        // the consumer's batch, with margin
        spec.arena_slabs = 16;
    }
    spec
}

/// The fused-assembly table. Also returns the vanilla-fetcher speedup
/// (arena-on batches/s over arena-off) for the headline/tests.
pub fn assembly_table(scale: Scale) -> Result<(Table, f64)> {
    let mut t = Table::new(
        "Hot path — fused arena assembly vs legacy copy path (mem, batch 64)",
        &[
            "fetch",
            "arena",
            "batches/s",
            "p50 batch ms",
            "p99 batch ms",
            "allocs/batch",
            "speedup",
        ],
    );
    let mut vanilla_speedup = f64::NAN;
    for fetch in FetchImpl::all() {
        let mut off_bps = f64::NAN;
        for arena_on in [false, true] {
            let spec = assembly_spec(fetch, arena_on, scale);
            let rig = rig::build(&spec)?;
            // epoch 0 warms workers, slabs, and allocator pools; epoch 1
            // is the steady state we report
            rig::drain_numbered_epoch(&rig, 0);
            let m = measure_epoch(&rig, 1);
            let n = m.latencies.len();
            if n == 0 {
                anyhow::bail!(
                    "hotpath cell {}/arena={arena_on} delivered no batches",
                    fetch.label()
                );
            }
            let s = stats::Summary::of(&m.latencies);
            let bps = n as f64 / m.epoch_s;
            let speedup = if arena_on { bps / off_bps } else { f64::NAN };
            if arena_on && fetch == FetchImpl::Vanilla {
                vanilla_speedup = speedup;
            }
            if !arena_on {
                off_bps = bps;
            }
            t.row(&[
                fetch.label().to_string(),
                if arena_on { "on" } else { "off" }.to_string(),
                num(bps, 1),
                num(s.p50 * 1e3, 2),
                num(s.p99 * 1e3, 2),
                num(m.allocs as f64 / n as f64, 0),
                if arena_on { format!("{speedup:.2}x") } else { "-".to_string() },
            ]);
        }
    }
    Ok((t, vanilla_speedup))
}

/// One tail-table dispatch mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Dispatch {
    Static,
    BatchSteal,
    ItemSteal,
}

impl Dispatch {
    fn label(&self) -> &'static str {
        match self {
            Dispatch::Static => "static",
            Dispatch::BatchSteal => "batch-steal",
            Dispatch::ItemSteal => "item-steal",
        }
    }
}

fn tail_spec(storage: &'static str, dispatch: Dispatch, scale: Scale) -> RigSpec {
    let mut spec = RigSpec::quick(storage, scale.latency);
    spec.items = scale.items(384);
    spec.batch_size = STEAL_BATCH;
    spec.num_workers = 4;
    spec.fetch_impl = FetchImpl::Threaded;
    spec.num_fetch_workers = STEAL_BATCH;
    spec.arena_slabs = 32;
    spec.consumer_credit = TAIL_CREDIT;
    spec.work_stealing = dispatch != Dispatch::Static;
    spec.steal_items = dispatch == Dispatch::ItemSteal;
    spec.runtime = crate::gil::Runtime::Native;
    spec
}

/// The dispatch-tail table. Also returns (batch-steal p99, item-steal
/// p99) on the ceph_os profile — the slowest backend, where the tail is
/// fattest — for the headline/tests. Fails if any cell's
/// reorder-buffer high-water mark exceeds the credit bound.
pub fn tail_table(scale: Scale) -> Result<(Table, f64, f64)> {
    let mut t = Table::new(
        "Hot path — dispatch tail: static vs batch-steal vs item-steal \
         (threaded fetcher, credit-bounded reorder buffer)",
        &[
            "storage",
            "dispatch",
            "epoch s",
            "p50 batch ms",
            "p99 batch ms",
            "max batch ms",
            "reorder hwm",
            "steals",
        ],
    );
    let mut ceph_batch_p99 = f64::NAN;
    let mut ceph_item_p99 = f64::NAN;
    for storage in STEAL_PROFILES {
        for dispatch in [Dispatch::Static, Dispatch::BatchSteal, Dispatch::ItemSteal] {
            let spec = tail_spec(storage, dispatch, scale);
            let rig = rig::build(&spec)?;
            let m = measure_epoch(&rig, 0);
            if m.latencies.is_empty() {
                anyhow::bail!(
                    "hotpath tail cell {storage}/{} delivered no batches",
                    dispatch.label()
                );
            }
            if m.reorder_hwm > TAIL_CREDIT {
                anyhow::bail!(
                    "reorder-buffer high-water regression: {} on {storage} \
                     reached {} with consumer_credit={TAIL_CREDIT}",
                    dispatch.label(),
                    m.reorder_hwm
                );
            }
            let s = stats::Summary::of(&m.latencies);
            if storage == "ceph_os" {
                match dispatch {
                    Dispatch::BatchSteal => ceph_batch_p99 = s.p99,
                    Dispatch::ItemSteal => ceph_item_p99 = s.p99,
                    Dispatch::Static => {}
                }
            }
            t.row(&[
                storage.to_string(),
                dispatch.label().to_string(),
                num(m.epoch_s, 2),
                num(s.p50 * 1e3, 1),
                num(s.p99 * 1e3, 1),
                num(s.max * 1e3, 1),
                m.reorder_hwm.to_string(),
                m.item_steals.to_string(),
            ]);
        }
    }
    Ok((t, ceph_batch_p99, ceph_item_p99))
}

fn boundary_spec(storage: &'static str, pipelined: bool, scale: Scale) -> RigSpec {
    let mut spec = tail_spec(storage, Dispatch::ItemSteal, scale);
    spec.items = scale.items(192);
    spec.epoch_pipeline = usize::from(pipelined);
    spec
}

/// The epoch-boundary table: inter-epoch gap (last batch of epoch N →
/// first batch of epoch N+1) and cumulative worker idle time at the
/// seam, drained (`epoch_pipeline = 0`) vs pipelined (`= 1`), across
/// the three high-latency profiles. Returns the table plus the s3
/// (drained gap, pipelined gap) pair; **fails** if the pipelined gap is
/// not strictly smaller than the drained gap on s3 — the PR's
/// acceptance bar, enforced by the CI `reproduce hotpath` smoke.
pub fn boundary_table(scale: Scale) -> Result<(Table, f64, f64)> {
    let mut t = Table::new(
        "Hot path — epoch boundary: drained vs pipelined scheduling \
         (threaded fetcher, item-steal, credit-bounded, 3 epochs)",
        &[
            "storage",
            "mode",
            "total s",
            "mean gap ms",
            "max gap ms",
            "seam idle ms",
            "idle/worker ms",
            "plans",
        ],
    );
    let mut s3_drained_gap = f64::NAN;
    let mut s3_pipelined_gap = f64::NAN;
    for storage in STEAL_PROFILES {
        for pipelined in [false, true] {
            let spec = boundary_spec(storage, pipelined, scale);
            let rig = rig::build(&spec)?;
            let t0 = Instant::now();
            let mut gaps: Vec<f64> = Vec::new();
            let mut last_batch_at: Option<Instant> = None;
            for epoch in 0..BOUNDARY_EPOCHS {
                let mut it = rig.dataloader.epoch(epoch);
                let mut first = true;
                loop {
                    let Some(b) = it.next() else { break };
                    if first {
                        if let Some(prev) = last_batch_at {
                            gaps.push(prev.elapsed().as_secs_f64());
                        }
                        first = false;
                    }
                    last_batch_at = Some(Instant::now());
                    b.recycle();
                }
                if it.reorder_high_water() > TAIL_CREDIT {
                    anyhow::bail!(
                        "cross-epoch reorder-buffer regression: {storage} \
                         pipelined={pipelined} reached {} with \
                         consumer_credit={TAIL_CREDIT}",
                        it.reorder_high_water()
                    );
                }
            }
            let total_s = t0.elapsed().as_secs_f64();
            if gaps.is_empty() {
                anyhow::bail!(
                    "boundary cell {storage}/pipelined={pipelined} measured \
                     no epoch seams"
                );
            }
            let mean_gap = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let max_gap = gaps.iter().cloned().fold(f64::MIN, f64::max);
            let idle = rig.dataloader.seam_idle().as_secs_f64();
            let per_worker: Vec<String> = rig
                .dataloader
                .seam_idle_per_worker()
                .iter()
                .map(|d| format!("{:.1}", d.as_secs_f64() * 1e3))
                .collect();
            let per_worker = if per_worker.is_empty() {
                "-".to_string()
            } else {
                per_worker.join("/")
            };
            let plans = rig.dataloader.plans_published();
            if storage == "s3" {
                if pipelined {
                    s3_pipelined_gap = mean_gap;
                } else {
                    s3_drained_gap = mean_gap;
                }
            }
            t.row(&[
                storage.to_string(),
                if pipelined { "pipelined" } else { "drained" }.to_string(),
                num(total_s, 2),
                num(mean_gap * 1e3, 2),
                num(max_gap * 1e3, 2),
                num(idle * 1e3, 1),
                per_worker,
                plans.to_string(),
            ]);
        }
    }
    if !(s3_pipelined_gap < s3_drained_gap) {
        anyhow::bail!(
            "epoch-boundary regression: pipelined inter-epoch gap \
             {:.2} ms is not strictly smaller than the drained gap \
             {:.2} ms on the s3 profile",
            s3_pipelined_gap * 1e3,
            s3_drained_gap * 1e3,
        );
    }
    Ok((t, s3_drained_gap, s3_pipelined_gap))
}

fn pinned_spec(pinned: bool, scale: Scale) -> RigSpec {
    let mut spec = RigSpec::quick("mem", scale.latency);
    spec.items = scale.items(192);
    spec.batch_size = BATCH;
    spec.mean_kb = 96;
    spec.crop = 32;
    spec.num_workers = 4;
    spec.arena_slabs = 16;
    spec.pin_memory = pinned;
    spec.runtime = crate::gil::Runtime::Native;
    spec
}

/// Pageable vs pinned-slab transfer: drain a steady-state epoch through
/// `to_device`. Returns the table plus (pageable ms, pinned ms) mean
/// transfer per batch.
pub fn pinned_table(scale: Scale) -> Result<(Table, f64, f64)> {
    let mut t = Table::new(
        "Hot path — pageable vs pinned arena slabs (mem, batch 64, to_device)",
        &["slabs", "transfer ms/batch", "epoch s", "batches"],
    );
    let mut pageable_ms = f64::NAN;
    let mut pinned_ms = f64::NAN;
    for pinned in [false, true] {
        let spec = pinned_spec(pinned, scale);
        let rig = rig::build(&spec)?;
        // warm epoch: spawn-method start-up, fresh slabs, pin
        // registration — all off the measured epoch
        rig::drain_numbered_epoch(&rig, 0);
        let skip = rig
            .recorder
            .durations(crate::telemetry::names::TO_DEVICE)
            .len();
        let t0 = Instant::now();
        let mut n = 0usize;
        for b in rig.dataloader.epoch(1) {
            let db = rig.device.to_device(b);
            db.recycle();
            n += 1;
        }
        let epoch_s = t0.elapsed().as_secs_f64();
        let spans = rig.recorder.durations(crate::telemetry::names::TO_DEVICE);
        let measured = &spans[skip..];
        if measured.is_empty() {
            anyhow::bail!("pinned cell pinned={pinned} recorded no transfers");
        }
        let mean_ms = measured.iter().sum::<f64>() / measured.len() as f64 * 1e3;
        if pinned {
            pinned_ms = mean_ms;
        } else {
            pageable_ms = mean_ms;
        }
        t.row(&[
            if pinned { "pinned" } else { "pageable" }.to_string(),
            num(mean_ms, 3),
            num(epoch_s, 2),
            n.to_string(),
        ]);
    }
    Ok((t, pageable_ms, pinned_ms))
}

/// Legacy `get` (one Vec per read) vs zero-copy `get_into` (pread into
/// a reused scratch) on a real-file `DirStore`. Returns the table plus
/// the steady-state allocs/read of the get_into path (must be 0).
/// Fails on a nonzero get_into count when the counting allocator is
/// installed.
pub fn get_into_table(scale: Scale) -> Result<(Table, f64)> {
    let mut t = Table::new(
        "Hot path — DirStore read path: get (Vec per read) vs get_into (pread)",
        &["path", "reads/s", "allocs/read"],
    );
    let root = std::env::temp_dir().join(format!(
        "cdl-hotpath-getinto-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let store: Arc<dyn ObjectStore> = Arc::new(DirStore::open(&root)?);
    let items = scale.items(64);
    let (keys, _) = crate::data::synth::generate_corpus(
        &store,
        &crate::data::synth::CorpusSpec {
            items,
            classes: 16,
            mean_bytes: 24 * 1024,
            sigma: 0.3,
            seed: 11,
        },
    )?;
    let passes = 4usize;
    let mut scratch: Vec<u8> = Vec::new();
    // warm pass: handle cache + scratch growth
    for k in &keys {
        crate::storage::get_into_vec(&store, k, &mut scratch)?;
    }
    let mut into_allocs_per_read = f64::NAN;
    for use_into in [false, true] {
        let before = alloc::thread_counters();
        let t0 = Instant::now();
        for _ in 0..passes {
            for k in &keys {
                if use_into {
                    crate::storage::get_into_vec(&store, k, &mut scratch)?;
                } else {
                    store.get(k)?;
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let reads = (passes * keys.len()) as f64;
        let allocs = alloc::thread_counters().since(before).allocs as f64 / reads;
        if use_into {
            into_allocs_per_read = allocs;
        }
        t.row(&[
            if use_into { "get_into" } else { "get" }.to_string(),
            num(reads / wall, 0),
            num(allocs, 2),
        ]);
    }
    let _ = std::fs::remove_dir_all(&root);
    if alloc::counters().allocs > 0 && into_allocs_per_read != 0.0 {
        anyhow::bail!(
            "get_into DirStore path allocated in steady state: \
             {into_allocs_per_read} allocs/read (want 0)"
        );
    }
    Ok((t, into_allocs_per_read))
}

/// Stall attribution: split one steady-state epoch's time into the
/// lanes the telemetry plane now meters — storage wait and decode
/// (summed across fetch threads, so they can exceed the wall clock),
/// consumer credit-block time, and reorder-buffer hold — per storage
/// profile under item-steal dispatch. "mem" anchors the zero-latency
/// end; the high-latency profiles show the wait moving into the
/// storage lane instead of the consumer.
pub fn stall_table(scale: Scale) -> Result<Table> {
    let mut t = Table::new(
        "Hot path — stall attribution: where the epoch's time goes \
         (threaded fetcher, item-steal, per storage profile)",
        &[
            "storage",
            "wall s",
            "storage ms (Σ)",
            "decode ms (Σ)",
            "credit-blk ms",
            "reorder-hold ms",
            "batches",
        ],
    );
    for storage in STALL_PROFILES {
        let spec = tail_spec(storage, Dispatch::ItemSteal, scale);
        let rig = rig::build(&spec)?;
        let m = measure_epoch(&rig, 0);
        if m.latencies.is_empty() {
            anyhow::bail!("stall cell {storage} delivered no batches");
        }
        let ds = rig.dataloader.dataset();
        let (storage_wait, decode) = ds.lane_times().unwrap_or_default();
        let credit = rig.dataloader.credit_blocked();
        let hold = rig.dataloader.reorder_hold();
        t.row(&[
            storage.to_string(),
            num(m.epoch_s, 2),
            num(storage_wait.as_secs_f64() * 1e3, 1),
            num(decode.as_secs_f64() * 1e3, 1),
            num(credit.as_secs_f64() * 1e3, 1),
            num(hold.as_secs_f64() * 1e3, 1),
            m.latencies.len().to_string(),
        ]);
    }
    Ok(t)
}

/// FNV-1a over delivered bytes: the digest that proves shard-window
/// streaming and per-file loading hand the consumer identical batches.
fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
}

/// Per-file GETs vs shard-window streaming on the high-latency
/// profiles: the same spec, seed, and dispatch either way — the only
/// difference is `shard_size`, which makes the remote serve
/// [`SHARD_SIZE`]-sample tar windows (one request each) instead of one
/// object per image. Two pipelined epochs per cell, so windows also
/// cross an epoch seam. Every cell's delivered batches are folded into
/// a digest and the two modes must agree **exactly** (byte identity is
/// the contract, not an aspiration); the run additionally **fails** if
/// shard streaming does not strictly beat per-file batches/s on s3.
/// Returns the table plus the s3 (per-file, shard) batches/s pair.
pub fn shard_table(scale: Scale) -> Result<(Table, f64, f64)> {
    let mut t = Table::new(
        "Hot path — per-file GETs vs shard-window streaming \
         (threaded fetcher, item-steal, epoch-pipelined, 2 epochs)",
        &[
            "storage",
            "mode",
            "batches/s",
            "total s",
            "requests",
            "window hits",
        ],
    );
    let mut s3_per_file_bps = f64::NAN;
    let mut s3_shard_bps = f64::NAN;
    for storage in STEAL_PROFILES {
        let mut per_file = (f64::NAN, 0u64); // (bps, digest)
        for sharded in [false, true] {
            let mut spec = tail_spec(storage, Dispatch::ItemSteal, scale);
            // below half scale the profiles' fixed per-connection
            // bandwidth floor swamps the first-byte latency this gate is
            // about and both modes converge on pure transfer time
            spec.latency_scale = spec.latency_scale.max(0.5);
            spec.epoch_pipeline = 1;
            // full readahead horizon in both modes (positions count
            // items per-file and shard windows in shard mode)
            spec.prefetch_depth = spec.items;
            if sharded {
                spec.shard_size = SHARD_SIZE;
            }
            let rig = rig::build(&spec)?;
            let t0 = Instant::now();
            let mut digest = 0xcbf2_9ce4_8422_2325u64;
            let mut batches = 0usize;
            for epoch in 0..2 {
                for b in rig.dataloader.epoch(epoch) {
                    fnv(&mut digest, &b.images.data);
                    for &l in &b.labels {
                        fnv(&mut digest, &l.to_le_bytes());
                    }
                    batches += 1;
                    b.recycle();
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            if batches == 0 {
                anyhow::bail!(
                    "shard cell {storage}/sharded={sharded} delivered no batches"
                );
            }
            let bps = batches as f64 / wall;
            let requests = rig.remote.as_ref().map_or(0, |r| r.stats().gets);
            let window_hits = rig.shards.as_ref().map(|s| s.window_stats().1);
            if sharded {
                if digest != per_file.1 {
                    anyhow::bail!(
                        "shard-streamed batches differ from per-file on \
                         {storage}: digest {digest:016x} != {:016x}",
                        per_file.1
                    );
                }
                if storage == "s3" {
                    s3_per_file_bps = per_file.0;
                    s3_shard_bps = bps;
                }
            } else {
                per_file = (bps, digest);
            }
            t.row(&[
                storage.to_string(),
                if sharded { "shard" } else { "per-file" }.to_string(),
                num(bps, 1),
                num(wall, 2),
                requests.to_string(),
                window_hits.map_or("-".to_string(), |h| h.to_string()),
            ]);
        }
    }
    let beats = s3_shard_bps > s3_per_file_bps; // NaN-safe: NaN never beats
    if !beats {
        anyhow::bail!(
            "shard-streaming regression: {s3_shard_bps:.1} batches/s does \
             not beat the per-file path's {s3_per_file_bps:.1} on the s3 \
             profile"
        );
    }
    Ok((t, s3_per_file_bps, s3_shard_bps))
}

/// FNV-1a digest of one delivered object (per-slot byte-identity).
fn fnv_digest(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    fnv(&mut h, bytes);
    h
}

/// Per-call reads vs batched ring submission at the store level: the
/// same corpus and latency profile, read once by [`IO_THREADS`] sync
/// threads looping `get_into` (one request in flight per thread — the
/// pre-ring fetcher shape) and once by a single thread submitting
/// [`IO_BATCH`]-read waves to an [`IoRing`] with [`IO_DEPTH`] in-flight
/// slots. A "batch" is one wave either way, so batches/s and the wave
/// latency percentiles compare like for like. Per-slot digests must
/// agree exactly, and the run **fails** on s3 if batched submission
/// does not strictly beat per-call batches/s or if the ring's in-flight
/// high-water mark never exceeds [`IO_THREADS`] — proof the depth is
/// decoupled from the submitting thread count. Returns the table plus
/// the s3 (per-call bps, batched bps, in-flight hwm) triple.
pub fn io_table(scale: Scale) -> Result<(Table, f64, f64, u64)> {
    let mut t = Table::new(
        "Hot path — per-call reads vs batched ring submission \
         (whole-object GETs, one wave = one batch)",
        &[
            "storage",
            "mode",
            "batches/s",
            "p50 wave ms",
            "p99 wave ms",
            "total s",
            "in-flight hwm",
        ],
    );
    // below quarter scale the profiles' shared per-connection bandwidth
    // floor swamps the first-byte latency this gate is about, and both
    // modes converge on pure transfer time — same guard as shard_table
    let lat_scale = scale.latency.max(0.25);
    let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("io-ring"));
    let (keys, _) = crate::data::synth::generate_corpus(
        &mem,
        &crate::data::synth::CorpusSpec {
            items: scale.items(128),
            classes: 8,
            // small objects keep first-byte latency (not bandwidth)
            // the dominant cost, which is what the ring amortises
            mean_bytes: 4 * 1024,
            sigma: 0.3,
            seed: 21,
        },
    )?;
    let n_waves = keys.len().div_ceil(IO_BATCH);
    let mut s3_per_call_bps = f64::NAN;
    let mut s3_batched_bps = f64::NAN;
    let mut s3_hwm = 0u64;
    for storage in STEAL_PROFILES {
        let Some(profile) = RemoteProfile::by_name(storage) else {
            anyhow::bail!("unknown storage profile {storage}");
        };
        let profile = profile.scaled(lat_scale);

        // --- per-call arm: IO_THREADS sync threads, one read at a time
        let store: Arc<dyn ObjectStore> =
            SimRemoteStore::new(mem.clone(), profile.clone(), 0x10AD);
        let mut digests = vec![0u64; keys.len()];
        let mut buckets: Vec<Vec<(&[String], &mut [u64])>> =
            (0..IO_THREADS).map(|_| Vec::new()).collect();
        for (w, wave) in keys
            .chunks(IO_BATCH)
            .zip(digests.chunks_mut(IO_BATCH))
            .enumerate()
        {
            buckets[w % IO_THREADS].push(wave);
        }
        let mut lats: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        std::thread::scope(|s| -> Result<()> {
            let handles: Vec<_> = buckets
                .into_iter()
                .map(|bucket| {
                    let store = store.clone();
                    s.spawn(move || -> Result<Vec<f64>> {
                        let mut scratch: Vec<u8> = Vec::new();
                        let mut lats = Vec::new();
                        for (wkeys, wdig) in bucket {
                            let tw = Instant::now();
                            for (i, k) in wkeys.iter().enumerate() {
                                let n = get_into_vec(&*store, k, &mut scratch)?;
                                wdig[i] = fnv_digest(&scratch[..n]);
                            }
                            lats.push(tw.elapsed().as_secs_f64());
                        }
                        Ok(lats)
                    })
                })
                .collect();
            for h in handles {
                lats.extend(h.join().expect("per-call io thread panicked")?);
            }
            Ok(())
        })?;
        let per_call_wall = t0.elapsed().as_secs_f64();
        let per_call_bps = n_waves as f64 / per_call_wall;
        let s = stats::Summary::of(&lats);
        t.row(&[
            storage.to_string(),
            "per-call".to_string(),
            num(per_call_bps, 1),
            num(s.p50 * 1e3, 1),
            num(s.p99 * 1e3, 1),
            num(per_call_wall, 2),
            IO_THREADS.to_string(),
        ]);

        // --- batched arm: one thread, wave-sized submissions, deep ring
        let remote: Arc<dyn ObjectStore> =
            SimRemoteStore::new(mem.clone(), profile, 0x10AD);
        let ring = IoRing::new(remote, IO_DEPTH);
        let mut ring_digests = vec![0u64; keys.len()];
        let mut pool: Vec<Vec<u8>> = Vec::new();
        let mut lats: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        for (w, chunk) in keys.chunks(IO_BATCH).enumerate() {
            let base = w * IO_BATCH;
            let ops = chunk
                .iter()
                .enumerate()
                .map(|(i, k)| {
                    ReadOp::whole(base + i, k.clone(), pool.pop().unwrap_or_default())
                })
                .collect();
            let tw = Instant::now();
            let mut sub = ring.submit(ops);
            while let Some(c) = sub.next() {
                let n = c.result?;
                ring_digests[c.slot] = fnv_digest(&c.buf[..n]);
                pool.push(c.buf);
            }
            lats.push(tw.elapsed().as_secs_f64());
        }
        let batched_wall = t0.elapsed().as_secs_f64();
        let batched_bps = n_waves as f64 / batched_wall;
        let hwm = ring.stats().inflight_hwm;
        if ring_digests != digests {
            anyhow::bail!(
                "ring-batched reads differ from per-call on {storage}: \
                 per-slot digests disagree"
            );
        }
        let s = stats::Summary::of(&lats);
        if storage == "s3" {
            s3_per_call_bps = per_call_bps;
            s3_batched_bps = batched_bps;
            s3_hwm = hwm;
        }
        t.row(&[
            storage.to_string(),
            "batched".to_string(),
            num(batched_bps, 1),
            num(s.p50 * 1e3, 1),
            num(s.p99 * 1e3, 1),
            num(batched_wall, 2),
            hwm.to_string(),
        ]);
    }
    // NaN-safe: a NaN never beats, so a skipped/failed s3 cell fails too
    if !(s3_batched_bps > s3_per_call_bps) {
        anyhow::bail!(
            "batched-submission regression: {s3_batched_bps:.1} batches/s \
             does not beat the per-call path's {s3_per_call_bps:.1} on the \
             s3 profile"
        );
    }
    if s3_hwm <= IO_THREADS as u64 {
        anyhow::bail!(
            "ring depth not decoupled from thread count: in-flight \
             high-water {s3_hwm} never exceeded the per-call arm's \
             {IO_THREADS} threads on the s3 profile"
        );
    }
    Ok((t, s3_per_call_bps, s3_batched_bps, s3_hwm))
}

/// One autotune-table arm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Arm {
    /// plain defaults: shallow prefetch/io, unbounded credit, drained
    /// seams, no item stealing — the autotuner's starting point
    Defaults,
    /// same starting knobs plus the Governor hill-climbing them online
    Autotuned,
    /// the knobs a human lands on after sweeping the tail/boundary
    /// tables by hand
    HandTuned,
}

impl Arm {
    fn label(&self) -> &'static str {
        match self {
            Arm::Defaults => "defaults",
            Arm::Autotuned => "autotuned",
            Arm::HandTuned => "hand-tuned",
        }
    }
}

/// Tuning epochs the Governor gets before the autotuned arm is
/// measured (warmup + one probe decision per epoch at the default
/// settle window).
pub const AUTOTUNE_EPOCHS: usize = 10;
/// Steady-state epochs averaged for every arm's reported throughput.
const AUTOTUNE_MEASURE: usize = 2;
/// The autotuned arm must land within this fraction of hand-tuned
/// batches/s on every profile.
pub const AUTOTUNE_HAND_FRACTION: f64 = 0.85;

/// Shared structure for all three arms: threaded fetcher over an
/// arena, work-stealing injector, prefetch + ring layers attached —
/// everything the Governor's knobs act on — with the *Defaults* arm's
/// starting values.
fn autotune_spec(storage: &'static str, scale: Scale) -> RigSpec {
    let mut spec = RigSpec::quick(storage, scale.latency);
    spec.items = scale.items(256);
    spec.batch_size = STEAL_BATCH;
    spec.num_workers = 4;
    spec.fetch_impl = FetchImpl::Threaded;
    spec.num_fetch_workers = STEAL_BATCH;
    spec.arena_slabs = 16;
    spec.work_stealing = true;
    spec.runtime = crate::gil::Runtime::Native;
    // the Defaults starting point: shallow everything
    spec.consumer_credit = 0;
    spec.steal_items = false;
    spec.epoch_pipeline = 0;
    spec.prefetch_depth = 8;
    spec.io_depth = 8;
    spec
}

/// Drain one numbered epoch, returning (batches/s, p99 batch seconds).
fn timed_epoch(rig: &rig::Rig, epoch: usize) -> Result<(f64, f64)> {
    let t0 = Instant::now();
    let mut lats = Vec::new();
    let mut it = rig.dataloader.epoch(epoch);
    loop {
        let tb = Instant::now();
        let Some(b) = it.next() else { break };
        lats.push(tb.elapsed().as_secs_f64());
        b.recycle();
    }
    drop(it);
    let wall = t0.elapsed().as_secs_f64();
    if lats.is_empty() {
        anyhow::bail!("autotune epoch {epoch} delivered no batches");
    }
    Ok((lats.len() as f64 / wall, stats::Summary::of(&lats).p99))
}

/// Measure one arm: tune (autotuned) or warm (fixed arms), then
/// average [`AUTOTUNE_MEASURE`] steady epochs. Returns (batches/s,
/// p99 s, final-knobs summary, probe/keep/revert summary).
fn measure_arm(
    storage: &'static str,
    arm: Arm,
    scale: Scale,
) -> Result<(f64, f64, String, String)> {
    let mut spec = autotune_spec(storage, scale);
    match arm {
        Arm::Defaults => {}
        Arm::Autotuned => spec.autotune = true,
        Arm::HandTuned => {
            spec.consumer_credit = TAIL_CREDIT;
            spec.steal_items = true;
            spec.epoch_pipeline = 1;
            spec.prefetch_depth = 64;
            spec.io_depth = 64;
        }
    }
    let rig = rig::build(&spec)?;
    let mut epoch = 0usize;
    let warm = if arm == Arm::Autotuned { AUTOTUNE_EPOCHS } else { 1 };
    for _ in 0..warm {
        let (_, p99) = timed_epoch(&rig, epoch)?;
        if arm == Arm::Autotuned {
            rig::autotune_tick_p99(&rig, epoch, p99);
        }
        epoch += 1;
    }
    // measured epochs: knobs frozen (nothing staged changes, so the
    // seam commits are no-ops) — steady state for all three arms
    let mut bps_sum = 0.0;
    let mut worst_p99 = 0.0f64;
    for _ in 0..AUTOTUNE_MEASURE {
        let (bps, p99) = timed_epoch(&rig, epoch)?;
        bps_sum += bps;
        worst_p99 = worst_p99.max(p99);
        epoch += 1;
    }
    let k = rig.dataloader.knobs();
    let knobs = format!(
        "credit={} pf={} io={} pipe={} steal={} w={}",
        k.credit(),
        k.prefetch_depth(),
        k.io_depth(),
        k.epoch_pipeline(),
        if k.steal_items() { "on" } else { "off" },
        k.active_workers(),
    );
    let probes = match &rig.autotune {
        Some(h) => {
            let (p, keeps, reverts) = h.lock().unwrap().governor.counts();
            format!("{p}/{keeps}/{reverts}")
        }
        None => "-".to_string(),
    };
    Ok((bps_sum / AUTOTUNE_MEASURE as f64, worst_p99, knobs, probes))
}

/// Autotuned-from-defaults vs plain defaults vs hand-tuned-best across
/// the high-latency profiles. The Governor starts from the Defaults
/// arm's knobs and hill-climbs at epoch seams for [`AUTOTUNE_EPOCHS`]
/// epochs; all arms then report the mean of [`AUTOTUNE_MEASURE`]
/// steady epochs. **Fails** if autotune does not strictly beat the
/// defaults on s3, or lands below [`AUTOTUNE_HAND_FRACTION`] of the
/// hand-tuned arm's batches/s on any profile. Returns the table plus
/// the s3 (defaults bps, autotuned bps) pair and the worst
/// autotuned/hand-tuned ratio across profiles.
pub fn autotune_table(scale: Scale) -> Result<(Table, f64, f64, f64)> {
    let mut t = Table::new(
        "Hot path — closed-loop autotuning: defaults vs Governor vs \
         hand-tuned (threaded fetcher, arena, prefetch + ring layers)",
        &[
            "storage",
            "arm",
            "batches/s",
            "p99 batch ms",
            "final knobs",
            "probes k/r",
        ],
    );
    let mut s3_defaults_bps = f64::NAN;
    let mut s3_autotuned_bps = f64::NAN;
    let mut min_vs_hand = f64::INFINITY;
    for storage in STEAL_PROFILES {
        let mut defaults_bps = f64::NAN;
        let mut autotuned_bps = f64::NAN;
        for arm in [Arm::Defaults, Arm::Autotuned, Arm::HandTuned] {
            let (bps, p99, knobs, probes) = measure_arm(storage, arm, scale)?;
            match arm {
                Arm::Defaults => defaults_bps = bps,
                Arm::Autotuned => autotuned_bps = bps,
                Arm::HandTuned => {
                    let ratio = autotuned_bps / bps;
                    if !(ratio >= AUTOTUNE_HAND_FRACTION) {
                        anyhow::bail!(
                            "autotune regression: {autotuned_bps:.1} batches/s \
                             is below {AUTOTUNE_HAND_FRACTION}x the hand-tuned \
                             arm's {bps:.1} on the {storage} profile"
                        );
                    }
                    min_vs_hand = min_vs_hand.min(ratio);
                }
            }
            t.row(&[
                storage.to_string(),
                arm.label().to_string(),
                num(bps, 1),
                num(p99 * 1e3, 1),
                knobs,
                probes,
            ]);
        }
        if storage == "s3" {
            s3_defaults_bps = defaults_bps;
            s3_autotuned_bps = autotuned_bps;
        }
    }
    // NaN-safe: a NaN never beats, so a skipped/failed s3 cell fails too
    if !(s3_autotuned_bps > s3_defaults_bps) {
        anyhow::bail!(
            "autotune regression: {s3_autotuned_bps:.1} batches/s does not \
             strictly beat the defaults arm's {s3_defaults_bps:.1} on the \
             s3 profile"
        );
    }
    Ok((t, s3_defaults_bps, s3_autotuned_bps, min_vs_hand))
}

/// One chaos-gate arm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ChaosArm {
    /// no faults, no resilience — the byte/batch-count reference
    Clean,
    /// flaky faults behind the resilience layer (retry budget 4)
    Resilient,
    /// the same flaky faults with nothing between them and the loader
    Bare,
}

impl ChaosArm {
    fn label(&self) -> &'static str {
        match self {
            ChaosArm::Clean => "clean",
            ChaosArm::Resilient => "resilient",
            ChaosArm::Bare => "bare",
        }
    }
}

/// Retry budget of the chaos gate's resilient arm: flaky's
/// `max_consecutive = 2` cap means any budget ≥ 3 attempts drains.
pub const CHAOS_RETRY_MAX: u32 = 4;

fn fault_spec(scale: Scale) -> RigSpec {
    let mut spec = RigSpec::quick("s3", scale.latency);
    spec.items = scale.items(96);
    spec.batch_size = STEAL_BATCH;
    spec.num_workers = 4;
    spec.fetch_impl = FetchImpl::Threaded;
    spec.num_fetch_workers = STEAL_BATCH;
    spec.runtime = crate::gil::Runtime::Native;
    spec
}

/// The chaos gate: fault-free vs resilient-under-flaky vs
/// bare-under-flaky on the s3 profile, two epochs each, delivered
/// batches folded into a digest. The resilient arm must deliver
/// exactly the clean arm's batches, byte for byte, with zero
/// exhausted ops and a nonzero retry count; the bare arm must
/// demonstrably degrade — fewer batches than clean, or a worse p99
/// than the resilient arm — and the run **fails** on any violation.
/// Returns the table plus (clean batches, bare batches, resilient
/// retries).
pub fn fault_table(scale: Scale) -> Result<(Table, usize, usize, u64)> {
    let mut t = Table::new(
        "Hot path — chaos gate: fault-free vs resilient vs bare under \
         seeded flaky faults (s3, threaded fetcher, 2 epochs)",
        &[
            "mode",
            "batches",
            "batches/s",
            "p99 batch ms",
            "retries",
            "injected",
            "exhausted",
        ],
    );
    let mut clean = (0usize, 0u64); // (batches, digest)
    let mut bare_batches = 0usize;
    let mut bare_p99 = f64::NAN;
    let mut resilient_p99 = f64::NAN;
    let mut resilient_retries = 0u64;
    for arm in [ChaosArm::Clean, ChaosArm::Resilient, ChaosArm::Bare] {
        let mut spec = fault_spec(scale);
        if arm != ChaosArm::Clean {
            spec.fault_profile = "flaky";
        }
        if arm == ChaosArm::Resilient {
            spec.retry_max = CHAOS_RETRY_MAX;
        }
        let rig = rig::build(&spec)?;
        let t0 = Instant::now();
        let mut digest = 0xcbf2_9ce4_8422_2325u64;
        let mut lats: Vec<f64> = Vec::new();
        let mut batches = 0usize;
        for epoch in 0..2 {
            let mut it = rig.dataloader.epoch(epoch);
            loop {
                let tb = Instant::now();
                let Some(b) = it.next() else { break };
                lats.push(tb.elapsed().as_secs_f64());
                fnv(&mut digest, &b.images.data);
                for &l in &b.labels {
                    fnv(&mut digest, &l.to_le_bytes());
                }
                batches += 1;
                b.recycle();
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let p99 = if lats.is_empty() {
            f64::NAN
        } else {
            stats::Summary::of(&lats).p99
        };
        let (retries, exhausted) = rig.resilient.as_ref().map_or((0, 0), |r| {
            let s = r.snapshot();
            (s.retries, s.exhausted)
        });
        let injected = rig.faults.as_ref().map_or(0, |f| f.counters().injected());
        match arm {
            ChaosArm::Clean => {
                if batches == 0 {
                    anyhow::bail!("chaos gate clean arm delivered no batches");
                }
                clean = (batches, digest);
            }
            ChaosArm::Resilient => {
                if batches != clean.0 || digest != clean.1 {
                    anyhow::bail!(
                        "resilient arm is not fault-transparent: {batches} \
                         batches / digest {digest:016x} vs the clean arm's \
                         {} / {:016x}",
                        clean.0,
                        clean.1
                    );
                }
                if retries == 0 {
                    anyhow::bail!(
                        "chaos gate vacuous: flaky faults forced no retries"
                    );
                }
                if exhausted != 0 {
                    anyhow::bail!(
                        "resilient arm exhausted {exhausted} op(s) under \
                         flaky faults with retry_max={CHAOS_RETRY_MAX}"
                    );
                }
                resilient_p99 = p99;
                resilient_retries = retries;
            }
            ChaosArm::Bare => {
                bare_batches = batches;
                bare_p99 = p99;
            }
        }
        t.row(&[
            arm.label().to_string(),
            batches.to_string(),
            num(batches as f64 / wall, 1),
            num(p99 * 1e3, 1),
            retries.to_string(),
            injected.to_string(),
            exhausted.to_string(),
        ]);
    }
    // the bare arm must show why the layer exists: lost batches, or a
    // fatter tail than the resilient arm under identical faults
    // (NaN-safe: an empty bare arm lost batches, so it passes there)
    if !(bare_batches < clean.0 || bare_p99 > resilient_p99) {
        anyhow::bail!(
            "bare arm did not degrade under flaky faults: {bare_batches}/{} \
             batches, p99 {:.1} ms vs resilient {:.1} ms",
            clean.0,
            bare_p99 * 1e3,
            resilient_p99 * 1e3,
        );
    }
    Ok((t, clean.0, bare_batches, resilient_retries))
}

/// Insert a gate metric, skipping non-finite values (a NaN would both
/// corrupt the JSON baseline and be meaningless to band-check).
fn put(m: &mut BTreeMap<String, f64>, name: &str, v: f64) {
    if v.is_finite() {
        m.insert(name.to_string(), v);
    }
}

/// Run every hotpath table, print the headlines, and return the flat
/// gate-metric map consumed by the `--baseline` write/check paths.
pub fn collect(scale: Scale) -> Result<BTreeMap<String, f64>> {
    let (assembly, vanilla_speedup) = assembly_table(scale)?;
    emit("hotpath", &assembly)?;
    println!(
        "  arena-on vanilla assembly is {vanilla_speedup:.2}x the legacy \
         copy path (batches/s, steady-state epoch)"
    );
    let (tail, batch_p99, item_p99) = tail_table(scale)?;
    emit("hotpath", &tail)?;
    println!(
        "  ceph_os p99 consumer batch latency: batch-steal {:.1} ms vs \
         item-steal {:.1} ms (reorder buffer ≤ {TAIL_CREDIT} everywhere)",
        batch_p99 * 1e3,
        item_p99 * 1e3,
    );
    let (boundary, drained_gap, pipelined_gap) = boundary_table(scale)?;
    emit("hotpath", &boundary)?;
    println!(
        "  s3 inter-epoch gap: drained {:.2} ms vs pipelined {:.2} ms \
         (persistent workers, epoch_pipeline=1)",
        drained_gap * 1e3,
        pipelined_gap * 1e3,
    );
    let stalls = stall_table(scale)?;
    emit("hotpath", &stalls)?;
    let (pin, pageable_ms, pinned_ms) = pinned_table(scale)?;
    emit("hotpath", &pin)?;
    println!(
        "  to_device transfer: pageable {pageable_ms:.3} ms vs pinned \
         {pinned_ms:.3} ms per batch"
    );
    let (gi, into_allocs) = get_into_table(scale)?;
    emit("hotpath", &gi)?;
    println!(
        "  DirStore get_into steady state: {into_allocs:.0} allocs/read"
    );
    let (shard, per_file_bps, shard_bps) = shard_table(scale)?;
    emit("hotpath", &shard)?;
    println!(
        "  s3 shard-window streaming: {shard_bps:.1} batches/s vs \
         {per_file_bps:.1} per-file ({:.2}x, byte-identical)",
        shard_bps / per_file_bps
    );
    let (io, per_call_bps, batched_bps, io_hwm) = io_table(scale)?;
    emit("hotpath", &io)?;
    println!(
        "  s3 batched submission: {batched_bps:.1} batches/s vs \
         {per_call_bps:.1} per-call ({:.2}x, in-flight high-water \
         {io_hwm} from one thread, byte-identical)",
        batched_bps / per_call_bps
    );
    let (auto, defaults_bps, autotuned_bps, min_vs_hand) = autotune_table(scale)?;
    emit("hotpath", &auto)?;
    println!(
        "  s3 autotune: {autotuned_bps:.1} batches/s from the defaults' \
         {defaults_bps:.1} ({:.2}x, Governor only; worst profile lands at \
         {min_vs_hand:.2}x hand-tuned)",
        autotuned_bps / defaults_bps
    );
    let (chaos, clean_batches, bare_batches, chaos_retries) = fault_table(scale)?;
    emit("hotpath", &chaos)?;
    println!(
        "  s3 chaos gate: resilient arm delivered all {clean_batches} \
         batches byte-identical under flaky faults ({chaos_retries} \
         retries); the bare arm delivered {bare_batches}"
    );
    let mut m = BTreeMap::new();
    put(&mut m, "assembly.vanilla.speedup", vanilla_speedup);
    put(&mut m, "tail.ceph_os.batch_steal_p99_ms", batch_p99 * 1e3);
    put(&mut m, "tail.ceph_os.item_steal_p99_ms", item_p99 * 1e3);
    put(&mut m, "boundary.s3.drained_gap_ms", drained_gap * 1e3);
    put(&mut m, "boundary.s3.pipelined_gap_ms", pipelined_gap * 1e3);
    put(&mut m, "pinned.pageable_ms", pageable_ms);
    put(&mut m, "pinned.pinned_ms", pinned_ms);
    put(&mut m, "get_into.allocs_per_read", into_allocs);
    put(&mut m, "shard.s3.per_file_bps", per_file_bps);
    put(&mut m, "shard.s3.shard_bps", shard_bps);
    put(&mut m, "shard.s3.speedup", shard_bps / per_file_bps);
    put(&mut m, "io.s3.per_call_bps", per_call_bps);
    put(&mut m, "io.s3.batched_bps", batched_bps);
    put(&mut m, "io.s3.speedup", batched_bps / per_call_bps);
    put(&mut m, "io.s3.inflight_hwm", io_hwm as f64);
    put(&mut m, "autotune.s3.defaults_bps", defaults_bps);
    put(&mut m, "autotune.s3.autotuned_bps", autotuned_bps);
    put(&mut m, "autotune.s3.speedup", autotuned_bps / defaults_bps);
    put(&mut m, "autotune.min_vs_hand", min_vs_hand);
    put(&mut m, "fault.s3.resilient_batches", clean_batches as f64);
    put(&mut m, "fault.s3.bare_batches", bare_batches as f64);
    put(&mut m, "fault.s3.retries", chaos_retries as f64);
    Ok(m)
}

/// Experiment entry point (id "hotpath"): fused assembly sweep,
/// dispatch-tail comparison, epoch-boundary seams, stall attribution,
/// pinned-slab transfer delta, the DirStore zero-copy read path, the
/// per-file vs shard-window streaming gate, the per-call vs
/// batched-submission ring gate, the closed-loop autotuning gate, and
/// the chaos gate (fault injection vs the resilience layer).
pub fn hotpath(scale: Scale) -> Result<()> {
    collect(scale).map(|_| ())
}

/// `cdl reproduce hotpath --baseline <path> [--check]`: run the full
/// experiment, then either write the gate metrics as a fresh baseline
/// file or compare against the committed one and fail on any metric
/// outside its tolerance band (the CI gate).
pub fn run_with_baseline(scale: Scale, path: &str, check: bool) -> Result<()> {
    let metrics = collect(scale)?;
    if check {
        let out = baseline::check(path, &metrics)?;
        for note in &out.notes {
            println!("  baseline note: {note}");
        }
        if !out.passed() {
            for r in &out.regressions {
                println!("  baseline REGRESSION: {r}");
            }
            anyhow::bail!(
                "hotpath baseline gate failed: {} regression(s) vs {path}",
                out.regressions.len()
            );
        }
        println!(
            "  baseline gate passed: {} metric(s) within band of {path}",
            out.checked
        );
    } else {
        baseline::write(
            path,
            &metrics,
            HIGHER_IS_BETTER,
            BASELINE_TOLERANCE,
            BASELINE_SLACK,
        )?;
        println!("  baseline written: {} metric(s) -> {path}", metrics.len());
    }
    Ok(())
}

// The throughput / allocation / tail assertions for this experiment
// live in `tests/test_hotpath_exp.rs` — a deliberately single-test
// integration binary, because they read wall clocks and the
// process-wide allocation counters, which the parallel lib-test
// harness would pollute.
