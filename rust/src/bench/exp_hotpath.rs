//! Hot-path experiments (beyond the paper's figure set): what the
//! memory path costs once storage latency is out of the picture, and
//! what batch dispatch costs when it isn't.
//!
//! * **Fused arena assembly** — `mem` storage (no latency to hide, the
//!   paper's 12× win already banked), batch 64, every fetcher × arena
//!   on/off: batches/s, p50/p99 consumer batch latency, and per-batch
//!   allocation counts from the counting global allocator. Arena-on
//!   decodes straight into recycled slabs (no decode buffer, no crop
//!   tensor, no collate copy); the allocs/batch column collapses and
//!   batches/s rises with it.
//! * **Work stealing vs static assignment** — threaded fetcher over the
//!   high-latency `s3`/`ceph_os`/`gluster_fs` profiles: the shared
//!   injector lets idle workers pick up the globally-next batch, so one
//!   slow wave no longer pins the batches behind it to a busy worker
//!   (the Versaci & Busonera straggler tail). Reported as epoch wall
//!   time plus p50/p99 consumer batch latency.

use std::time::Instant;

use anyhow::Result;

use super::rig::{self, RigSpec};
use super::{emit, Scale};
use crate::dataloader::FetchImpl;
use crate::util::alloc;
use crate::util::stats;
use crate::util::table::{num, Table};

const BATCH: usize = 64;
const STEAL_BATCH: usize = 16;
const STEAL_PROFILES: [&str; 3] = ["s3", "ceph_os", "gluster_fs"];

/// One measured epoch of a built rig: per-batch consumer latencies,
/// wall seconds, and the allocation-counter delta.
struct EpochMeasure {
    latencies: Vec<f64>,
    epoch_s: f64,
    allocs: u64,
}

fn measure_epoch(rig: &rig::Rig, epoch: usize) -> EpochMeasure {
    let before = alloc::counters();
    let mut latencies = Vec::new();
    let t0 = Instant::now();
    let mut it = rig.dataloader.epoch(epoch);
    loop {
        let tb = Instant::now();
        let Some(b) = it.next() else { break };
        latencies.push(tb.elapsed().as_secs_f64());
        b.recycle();
    }
    drop(it);
    let epoch_s = t0.elapsed().as_secs_f64();
    let allocs = alloc::counters().since(before).allocs;
    EpochMeasure { latencies, epoch_s, allocs }
}

fn assembly_spec(fetch: FetchImpl, arena_on: bool, scale: Scale) -> RigSpec {
    let mut spec = RigSpec::quick("mem", scale.latency);
    spec.items = scale.items(256);
    spec.batch_size = BATCH;
    spec.mean_kb = 96;
    spec.crop = 32;
    spec.num_workers = 4;
    spec.num_fetch_workers = 8;
    spec.fetch_impl = fetch;
    // native workers: measure the memory path itself, not the GIL tax
    // stretching it (the tax multiplies both cells identically)
    spec.runtime = crate::gil::Runtime::Native;
    if arena_on {
        // in-flight window: data queue (8) + one wave per worker (4) +
        // the consumer's batch, with margin
        spec.arena_slabs = 16;
    }
    spec
}

/// The fused-assembly table. Also returns the vanilla-fetcher speedup
/// (arena-on batches/s over arena-off) for the headline/tests.
pub fn assembly_table(scale: Scale) -> Result<(Table, f64)> {
    let mut t = Table::new(
        "Hot path — fused arena assembly vs legacy copy path (mem, batch 64)",
        &[
            "fetch",
            "arena",
            "batches/s",
            "p50 batch ms",
            "p99 batch ms",
            "allocs/batch",
            "speedup",
        ],
    );
    let mut vanilla_speedup = f64::NAN;
    for fetch in FetchImpl::all() {
        let mut off_bps = f64::NAN;
        for arena_on in [false, true] {
            let spec = assembly_spec(fetch, arena_on, scale);
            let rig = rig::build(&spec)?;
            // epoch 0 warms workers, slabs, and allocator pools; epoch 1
            // is the steady state we report
            rig::drain_numbered_epoch(&rig, 0);
            let m = measure_epoch(&rig, 1);
            let n = m.latencies.len();
            if n == 0 {
                anyhow::bail!(
                    "hotpath cell {}/arena={arena_on} delivered no batches",
                    fetch.label()
                );
            }
            let s = stats::Summary::of(&m.latencies);
            let bps = n as f64 / m.epoch_s;
            let speedup = if arena_on { bps / off_bps } else { f64::NAN };
            if arena_on && fetch == FetchImpl::Vanilla {
                vanilla_speedup = speedup;
            }
            if !arena_on {
                off_bps = bps;
            }
            t.row(&[
                fetch.label().to_string(),
                if arena_on { "on" } else { "off" }.to_string(),
                num(bps, 1),
                num(s.p50 * 1e3, 2),
                num(s.p99 * 1e3, 2),
                num(m.allocs as f64 / n as f64, 0),
                if arena_on { format!("{speedup:.2}x") } else { "-".to_string() },
            ]);
        }
    }
    Ok((t, vanilla_speedup))
}

fn stealing_spec(storage: &'static str, stealing: bool, scale: Scale) -> RigSpec {
    let mut spec = RigSpec::quick(storage, scale.latency);
    spec.items = scale.items(384);
    spec.batch_size = STEAL_BATCH;
    spec.num_workers = 4;
    spec.fetch_impl = FetchImpl::Threaded;
    spec.num_fetch_workers = STEAL_BATCH;
    spec.arena_slabs = 32;
    spec.work_stealing = stealing;
    spec.runtime = crate::gil::Runtime::Native;
    spec
}

/// The dispatch table. Also returns (static p99, stealing p99) on the
/// s3 profile for the headline/tests.
pub fn stealing_table(scale: Scale) -> Result<(Table, f64, f64)> {
    let mut t = Table::new(
        "Hot path — work stealing vs static round-robin (threaded fetcher)",
        &[
            "storage",
            "dispatch",
            "epoch s",
            "p50 batch ms",
            "p99 batch ms",
        ],
    );
    let mut s3_static_p99 = f64::NAN;
    let mut s3_steal_p99 = f64::NAN;
    for storage in STEAL_PROFILES {
        for stealing in [false, true] {
            let spec = stealing_spec(storage, stealing, scale);
            let rig = rig::build(&spec)?;
            let m = measure_epoch(&rig, 0);
            if m.latencies.is_empty() {
                anyhow::bail!(
                    "hotpath dispatch cell {storage}/stealing={stealing} \
                     delivered no batches"
                );
            }
            let s = stats::Summary::of(&m.latencies);
            if storage == "s3" {
                if stealing {
                    s3_steal_p99 = s.p99;
                } else {
                    s3_static_p99 = s.p99;
                }
            }
            t.row(&[
                storage.to_string(),
                if stealing { "stealing" } else { "static" }.to_string(),
                num(m.epoch_s, 2),
                num(s.p50 * 1e3, 1),
                num(s.p99 * 1e3, 1),
            ]);
        }
    }
    Ok((t, s3_static_p99, s3_steal_p99))
}

/// Experiment entry point (id "hotpath"): fused assembly sweep + work
/// stealing dispatch comparison.
pub fn hotpath(scale: Scale) -> Result<()> {
    let (assembly, vanilla_speedup) = assembly_table(scale)?;
    emit("hotpath", &assembly)?;
    println!(
        "  arena-on vanilla assembly is {vanilla_speedup:.2}x the legacy \
         copy path (batches/s, steady-state epoch)"
    );
    let (dispatch, static_p99, steal_p99) = stealing_table(scale)?;
    emit("hotpath", &dispatch)?;
    println!(
        "  s3 p99 consumer batch latency: static {:.1} ms vs stealing {:.1} ms",
        static_p99 * 1e3,
        steal_p99 * 1e3,
    );
    Ok(())
}

// The throughput / allocation / tail assertions for this experiment
// live in `tests/test_hotpath_exp.rs` — a deliberately single-test
// integration binary, because they read wall clocks and the
// process-wide allocation counters, which the parallel lib-test
// harness would pollute.
