//! Prefetch-engine experiments (beyond the paper's figure set): how much
//! batch latency the sampler-ahead engine hides on each high-latency
//! storage profile, and how the hot-tier policies compare under
//! capacity pressure.
//!
//! * **Depth sweep** — vanilla fetcher over `s3` / `ceph_os` /
//!   `gluster_fs`, sweeping `prefetch_depth` from 0 (engine off) to
//!   4×batch: mean/p90 batch latency, epoch wall time, and per-tier hit
//!   rates. The headline: depth ≥ 2×batch cuts mean batch latency by
//!   well over 2× on `s3`.
//! * **Policy comparison** — LRU vs 2Q vs S3-FIFO hot tier at 25% of
//!   corpus capacity over two shuffled epochs: per-epoch hit rate,
//!   evictions, ghost promotions.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::rig::{self, RigSpec};
use super::{emit, Scale};
use crate::data::synth::{generate_corpus, CorpusSpec};
use crate::dataloader::Sampler;
use crate::prefetch::{CachePolicy, PrefetchConfig, PrefetchStore};
use crate::storage::{MemStore, ObjectStore, RemoteProfile, SimRemoteStore};
use crate::util::stats;
use crate::util::table::{num, Table};

const PROFILES: [&str; 3] = ["s3", "ceph_os", "gluster_fs"];
const BATCH: usize = 16;

/// One sweep cell: drain an epoch, timing each `next()`.
fn run_cell(
    storage: &'static str,
    scale: Scale,
    depth: usize,
) -> Result<(Vec<f64>, f64, Option<Arc<PrefetchStore>>)> {
    let mut spec = RigSpec::quick(storage, scale.latency);
    spec.items = scale.items(96);
    spec.batch_size = BATCH;
    spec.num_workers = 2;
    spec.prefetch_depth = depth;
    // native workers: isolate what the *storage* layer hides (the GIL
    // tax would add the same CPU floor to every cell)
    spec.runtime = crate::gil::Runtime::Native;
    let rig = rig::build(&spec)?;
    let mut latencies = Vec::new();
    let t0 = Instant::now();
    let mut it = rig.dataloader.epoch(0);
    loop {
        let tb = Instant::now();
        if it.next().is_none() {
            break;
        }
        latencies.push(tb.elapsed().as_secs_f64());
    }
    drop(it);
    let epoch_s = t0.elapsed().as_secs_f64();
    Ok((latencies, epoch_s, rig.prefetch.clone()))
}

/// The depth sweep table (also returns the s3 speedup at depth=2×batch
/// over depth=0 so tests can assert the headline).
pub fn depth_sweep(scale: Scale) -> Result<(Table, f64)> {
    let mut t = Table::new(
        "Prefetch — batch latency vs readahead depth (vanilla fetcher)",
        &[
            "storage",
            "depth",
            "mean batch ms",
            "p90 batch ms",
            "epoch s",
            "hot hit %",
            "issued",
            "stale",
        ],
    );
    let mut s3_mean_off = f64::NAN;
    let mut s3_mean_2x = f64::NAN;
    for storage in PROFILES {
        for mult in [0usize, 1, 2, 4] {
            let depth = mult * BATCH;
            let (lat, epoch_s, prefetch) = run_cell(storage, scale, depth)?;
            let s = stats::Summary::of(&lat);
            if storage == "s3" && mult == 0 {
                s3_mean_off = s.mean;
            }
            if storage == "s3" && mult == 2 {
                s3_mean_2x = s.mean;
            }
            let (hit_pct, issued, stale) = match &prefetch {
                Some(p) => {
                    let c = p.counters();
                    (100.0 * c.hit_ratio(), c.issued, c.stale)
                }
                None => (0.0, 0, 0),
            };
            t.row(&[
                storage.to_string(),
                depth.to_string(),
                num(s.mean * 1e3, 1),
                num(s.p90 * 1e3, 1),
                num(epoch_s, 2),
                num(hit_pct, 1),
                issued.to_string(),
                stale.to_string(),
            ]);
        }
    }
    Ok((t, s3_mean_off / s3_mean_2x))
}

/// Every hot-tier policy (LRU, 2Q, S3-FIFO) under capacity pressure,
/// at the store level.
pub fn policy_comparison(scale: Scale) -> Result<Table> {
    let mut t = Table::new(
        "Prefetch — hot-tier policy under capacity pressure (s3, 2 shuffled epochs)",
        &[
            "policy",
            "epoch0 hit %",
            "epoch1 hit %",
            "evictions",
            "ghost promotions",
        ],
    );
    let items = scale.items(96);
    for policy in CachePolicy::ALL {
        let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("corpus"));
        let (keys, total) = generate_corpus(
            &mem,
            &CorpusSpec {
                items,
                classes: 64,
                mean_bytes: 24 * 1024,
                sigma: 0.35,
                seed: 7,
            },
        )?;
        let remote = SimRemoteStore::new(
            mem,
            RemoteProfile::s3().scaled(scale.latency * 0.25),
            41,
        );
        let store = PrefetchStore::new(
            remote,
            PrefetchConfig {
                depth: 2 * BATCH,
                hot_bytes: total / 4, // force eviction churn
                policy,
                ..Default::default()
            },
        );
        let mut epoch_hits = Vec::new();
        for epoch in 0..2usize {
            let order = Sampler::Random { seed: 3 }.order(keys.len(), epoch);
            let ordered: Vec<String> =
                order.iter().map(|&i| keys[i].clone()).collect();
            store.hint_order(epoch, &ordered);
            let before = store.counters();
            for k in &ordered {
                store.get(k)?;
            }
            let after = store.counters();
            let gets = (after.gets - before.gets).max(1);
            let hits =
                after.hot_hits + after.inflight_hits - before.hot_hits - before.inflight_hits;
            epoch_hits.push(100.0 * hits as f64 / gets as f64);
        }
        let r = store.report();
        t.row(&[
            policy.label().to_string(),
            num(epoch_hits[0], 1),
            num(epoch_hits[1], 1),
            r.hot.evictions.to_string(),
            r.hot.ghost_promotions.to_string(),
        ]);
    }
    Ok(t)
}

/// Experiment entry point: depth sweep + policy comparison.
pub fn prefetch_sweep(scale: Scale) -> Result<()> {
    let (sweep, s3_speedup) = depth_sweep(scale)?;
    emit("prefetch", &sweep)?;
    println!(
        "  s3 mean batch latency: depth 2×batch is {s3_speedup:.1}× lower \
         than depth 0"
    );
    let policies = policy_comparison(scale)?;
    emit("prefetch", &policies)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Scale {
        // latency high enough that the expected speedup (≈5×) leaves a
        // wide margin over the 2× assertion on noisy shared runners
        Scale { latency: 0.15, items: 0.25, epochs: 1.0 }
    }

    /// The acceptance headline: depth ≥ 2×batch cuts mean s3 batch
    /// latency by ≥ 2× vs the engine disabled.
    #[test]
    fn s3_speedup_at_least_2x() {
        let (_, speedup) = depth_sweep(tiny()).unwrap();
        assert!(speedup >= 2.0, "s3 prefetch speedup only {speedup:.2}×");
    }

    #[test]
    fn policy_table_has_every_policy() {
        let t = policy_comparison(tiny()).unwrap();
        assert_eq!(t.rows.len(), CachePolicy::ALL.len());
        assert_eq!(t.rows[0][0], "lru");
        assert_eq!(t.rows[1][0], "2q");
        assert_eq!(t.rows[2][0], "s3fifo");
    }
}
