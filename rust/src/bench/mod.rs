//! Benchmark harness: one runner per table/figure of the paper's
//! evaluation (see DESIGN.md §3 for the full index). Each experiment
//! builds its rig from [`rig`], runs it, prints the paper-shaped table,
//! and saves CSV + JSON under `results/`.
//!
//! All experiments honor the [`Scale`] knob (`CDL_SCALE` env var or
//! `--scale`): latencies, dataset sizes and epoch counts shrink together
//! so the *shape* of every result survives at CI speed. `Scale::paper()`
//! approaches the paper's actual parameters (Table 2/5) — slow.

pub mod exp_appendix;
pub mod exp_core;
pub mod exp_hotpath;
pub mod exp_params;
pub mod exp_prefetch;
pub mod rig;

use std::path::PathBuf;

use anyhow::Result;

use crate::util::table::Table;

/// Global experiment scaling.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    /// multiplies all storage latencies
    pub latency: f64,
    /// multiplies dataset sizes
    pub items: f64,
    /// multiplies epoch counts (min 1)
    pub epochs: f64,
}

impl Scale {
    /// CI-speed default: every experiment finishes in seconds.
    pub fn quick() -> Scale {
        Scale { latency: 0.20, items: 1.0, epochs: 1.0 }
    }

    /// Closer to the paper's parameters (minutes per experiment).
    pub fn paper() -> Scale {
        Scale { latency: 1.0, items: 8.0, epochs: 2.0 }
    }

    /// From the environment (`CDL_SCALE=quick|paper|<float>`), default
    /// quick. A float multiplies the quick item count.
    pub fn from_env() -> Scale {
        match std::env::var("CDL_SCALE").ok().as_deref() {
            Some("paper") => Scale::paper(),
            Some("quick") | None => Scale::quick(),
            Some(s) => match s.parse::<f64>() {
                Ok(f) => Scale { items: f, ..Scale::quick() },
                Err(_) => Scale::quick(),
            },
        }
    }

    pub fn items(&self, base: usize) -> usize {
        ((base as f64 * self.items) as usize).max(8)
    }

    pub fn epochs(&self, base: usize) -> usize {
        ((base as f64 * self.epochs) as usize).max(1)
    }
}

/// Where experiment outputs land.
pub fn results_dir(exp: &str) -> PathBuf {
    let dir = PathBuf::from("results").join(exp);
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Print a table and persist it as CSV under `results/<exp>/`.
pub fn emit(exp: &str, table: &Table) -> Result<()> {
    println!("{}", table.render());
    let file = results_dir(exp).join(format!(
        "{}.csv",
        table
            .title
            .to_lowercase()
            .replace([' ', '/', ':', ','], "_")
            .chars()
            .take(60)
            .collect::<String>()
    ));
    std::fs::write(&file, table.to_csv())?;
    Ok(())
}

/// Persist raw text (timeline CSVs etc.).
pub fn emit_raw(exp: &str, name: &str, content: &str) -> Result<()> {
    std::fs::write(results_dir(exp).join(name), content)?;
    Ok(())
}

/// All experiment ids: the paper's figures in paper order, then the
/// repo's own extensions ("prefetch": sampler-ahead engine sweep;
/// "hotpath": fused arena assembly + work-stealing dispatch).
pub const ALL_EXPERIMENTS: &[&str] = &[
    "t3", "f2", "f5", "f6", "f7", "f8", "f9", "f10", "f11", "f12", "f13",
    "f14", "f15", "f16", "t10", "f17", "f20", "f21", "f22", "f23",
    "prefetch", "hotpath",
];

/// Dispatch one experiment by id.
pub fn run_experiment(id: &str, scale: Scale) -> Result<()> {
    match id {
        "t3" => exp_core::t3_motivational(scale),
        "f2" => exp_core::f2_timeline(scale),
        "f5" => exp_core::f5_fetcher_comparison(scale),
        "f6" => exp_core::f6_batch_disassembly(scale),
        "f7" => exp_params::f7_transfer_times(scale),
        "f8" => exp_params::f8_lazy_init(scale),
        "f9" => exp_params::f9_caching(scale),
        "f10" => exp_params::f10_heatmap_s3(scale),
        "f11" => exp_params::f11_heatmap_scratch(scale),
        "f12" => exp_params::f12_dataset_pool(scale),
        "f13" => exp_core::f13_endtoend(scale),
        "f14" => exp_core::f14_function_medians(scale),
        "f15" => exp_core::f15_layer_throughput(scale),
        "f16" => exp_appendix::f16_storage_types(scale),
        "t10" => exp_appendix::t10_colab(scale),
        "f17" => exp_appendix::f17_lightning_lanes(scale),
        "f20" => exp_appendix::f20_train_phase(scale),
        "f21" => exp_appendix::f21_gil(scale),
        "f22" => exp_appendix::f22_shard_loaders(scale),
        "f23" => exp_appendix::f23_fade(scale),
        "prefetch" => exp_prefetch::prefetch_sweep(scale),
        "hotpath" => exp_hotpath::hotpath(scale),
        "all" => {
            for id in ALL_EXPERIMENTS {
                println!("\n━━━ experiment {id} ━━━");
                run_experiment(id, scale)?;
            }
            Ok(())
        }
        _ => anyhow::bail!(
            "unknown experiment {id}; known: {ALL_EXPERIMENTS:?} or 'all'"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_from_env_default_quick() {
        let s = Scale::quick();
        assert!(s.latency < 1.0);
        assert_eq!(s.items(100), 100);
        assert_eq!(s.epochs(1), 1);
    }

    #[test]
    fn scale_floors() {
        let s = Scale { latency: 1.0, items: 0.001, epochs: 0.1 };
        assert_eq!(s.items(100), 8);
        assert_eq!(s.epochs(5), 1);
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run_experiment("zzz", Scale::quick()).is_err());
    }
}
