//! Core experiments: Table 3 (motivational), Fig 2 (timeline), Fig 5/6
//! (fetcher comparison, batch disassembly), Fig 13/14/15 (end-to-end
//! with all modifications, function medians, per-layer throughput).

use anyhow::Result;

use super::rig::{self, RigSpec};
use super::{emit, emit_raw, Scale};
use crate::dataloader::FetchImpl;
use crate::dataset::pool::run_pool;
use crate::gil;
#[cfg(test)]
use crate::telemetry::names;
use crate::trainer::{TrainReport, TrainerKind};
use crate::util::table::{num, Table};

const STORAGES: [&str; 2] = ["scratch", "s3"];
const LIBS: [TrainerKind; 2] = [TrainerKind::Torch, TrainerKind::Lightning];

fn base_spec(storage: &'static str, scale: Scale) -> RigSpec {
    let mut s = RigSpec::quick(storage, scale.latency);
    s.items = scale.items(192);
    s.epochs = scale.epochs(1);
    s
}

fn report_row(label: &str, r: &TrainReport) -> Vec<String> {
    vec![
        label.to_string(),
        num(r.util.util_zero_pct, 1),
        num(r.util.util_nonzero_mean, 1),
        num(r.util.mem_zero_pct, 1),
        num(r.util.mem_nonzero_mean, 1),
        num(r.runtime_s, 2),
        num(r.img_per_s, 1),
        num(r.mbit_per_s, 1),
    ]
}

/// Table 3: vanilla loaders, Torch vs Lightning × scratch vs s3.
pub fn t3_motivational(scale: Scale) -> Result<()> {
    let mut t = Table::new(
        "Table 3 — motivational: vanilla loader, GPU utilization & throughput",
        &[
            "storage/lib",
            "util=0 %",
            "util>0 %",
            "mem=0 %",
            "mem>0 %",
            "runtime s",
            "img/s",
            "Mbit/s",
        ],
    );
    for storage in STORAGES {
        for lib in LIBS {
            let spec = base_spec(storage, scale).with_trainer(lib);
            let (r, _) = rig::run(&spec)?;
            t.row(&report_row(&format!("{storage}/{}", lib.label()), &r));
        }
    }
    t.note(
        "paper shape: s3 ≫ scratch runtime; lightning slower than torch; \
         GPU idle fraction largest for s3",
    );
    emit("t3", &t)
}

/// Fig 2: function-call timeline + per-call medians for the s3 vanilla
/// run (dumped as CSV for plotting).
pub fn f2_timeline(scale: Scale) -> Result<()> {
    let spec = base_spec("s3", scale);
    let (_, rig) = rig::run(&spec)?;
    emit_raw("f2", "timeline_s3_torch_vanilla.csv", &rig.recorder.to_csv())?;
    let t = rig.recorder.summary_table(
        "Fig 2 — span medians, s3/torch/vanilla (full timeline in results/f2)",
    );
    emit("f2", &t)
}

/// Fig 5: vanilla vs asyncio vs threaded × storage × lib.
pub fn f5_fetcher_comparison(scale: Scale) -> Result<()> {
    let mut t = Table::new(
        "Fig 5 — fetcher implementations: throughput",
        &["config", "runtime s", "img/s", "Mbit/s", "× vs vanilla"],
    );
    for storage in STORAGES {
        for lib in LIBS {
            let mut vanilla_mbit = f64::NAN;
            for imp in FetchImpl::all() {
                let spec = base_spec(storage, scale)
                    .with_trainer(lib)
                    .with_impl(imp);
                let (r, _) = rig::run(&spec)?;
                if imp == FetchImpl::Vanilla {
                    vanilla_mbit = r.mbit_per_s;
                }
                t.row(&[
                    format!("{storage}/{}/{}", lib.label(), imp.label()),
                    num(r.runtime_s, 2),
                    num(r.img_per_s, 1),
                    num(r.mbit_per_s, 1),
                    num(r.mbit_per_s / vanilla_mbit, 2),
                ]);
            }
        }
    }
    t.note("paper: ~11× (torch/s3), ~33-39× (lightning/s3), ~1.5-4× (scratch)");
    emit("f5", &t)
}

/// Fig 6: threaded ± batch disassembly vs asyncio (s3/torch).
pub fn f6_batch_disassembly(scale: Scale) -> Result<()> {
    let mut t = Table::new(
        "Fig 6 — batch disassembly (batch_pool) comparison, s3/torch",
        &["variant", "runtime s", "img/s", "Mbit/s"],
    );
    let variants: [(&str, FetchImpl, usize); 3] = [
        ("threaded, no pool", FetchImpl::Threaded, 0),
        ("threaded, batch_pool", FetchImpl::Threaded, 1),
        ("asyncio", FetchImpl::Asyncio, 0),
    ];
    for (label, imp, pool_on) in variants {
        let mut spec = base_spec("s3", scale).with_impl(imp);
        spec.batch_pool = if pool_on > 0 { spec.batch_size * 4 } else { 0 };
        let (r, _) = rig::run(&spec)?;
        t.row(&[
            label.to_string(),
            num(r.runtime_s, 2),
            num(r.img_per_s, 1),
            num(r.mbit_per_s, 1),
        ]);
    }
    t.note("paper: no significant improvement from disassembly");
    emit("f6", &t)
}

/// The "all modifications on" spec (threaded fetcher, lazy init).
fn modified_spec(storage: &'static str, scale: Scale, lib: TrainerKind) -> RigSpec {
    let mut s = base_spec(storage, scale)
        .with_trainer(lib)
        .with_impl(FetchImpl::Threaded);
    s.lazy_init = true;
    s
}

/// Fig 13: the initial experiment repeated with all modifications.
pub fn f13_endtoend(scale: Scale) -> Result<()> {
    let mut t = Table::new(
        "Fig 13 — end-to-end with all modifications (threaded, lazy init)",
        &[
            "storage/lib/impl",
            "util=0 %",
            "util>0 %",
            "mem=0 %",
            "mem>0 %",
            "runtime s",
            "img/s",
            "Mbit/s",
        ],
    );
    let mut scratch_vanilla_torch = f64::NAN;
    let mut s3_threaded_torch = f64::NAN;
    let mut s3_vanilla_torch = f64::NAN;
    for storage in STORAGES {
        for lib in LIBS {
            for imp in [FetchImpl::Vanilla, FetchImpl::Asyncio, FetchImpl::Threaded] {
                let spec = match imp {
                    FetchImpl::Vanilla => base_spec(storage, scale).with_trainer(lib),
                    _ => modified_spec(storage, scale, lib).with_impl(imp),
                };
                let (r, _) = rig::run(&spec)?;
                if storage == "scratch"
                    && lib == TrainerKind::Torch
                    && imp == FetchImpl::Vanilla
                {
                    scratch_vanilla_torch = r.mbit_per_s;
                }
                if storage == "s3" && lib == TrainerKind::Torch {
                    match imp {
                        FetchImpl::Threaded => s3_threaded_torch = r.mbit_per_s,
                        FetchImpl::Vanilla => s3_vanilla_torch = r.mbit_per_s,
                        _ => {}
                    }
                }
                t.row(&report_row(
                    &format!("{storage}/{}/{}", lib.label(), imp.label()),
                    &r,
                ));
            }
        }
    }
    t.note(&format!(
        "headline: s3-threaded/torch = {:.2}× s3-vanilla, reaching {:.0}% of \
         scratch-vanilla (paper: 15.5×, 67%)",
        s3_threaded_torch / s3_vanilla_torch,
        100.0 * s3_threaded_torch / scratch_vanilla_torch
    ));
    emit("f13", &t)
}

/// Fig 14: median get_batch / to_device / train — vanilla vs modified.
pub fn f14_function_medians(scale: Scale) -> Result<()> {
    let mut t = Table::new(
        "Fig 14 — median function durations, before (vanilla) vs after (threaded)",
        &["storage", "variant", "get_batch s", "to_device s", "train s", "speedup×"],
    );
    for storage in STORAGES {
        let (before, _) = rig::run(&base_spec(storage, scale))?;
        let (after, _) =
            rig::run(&modified_spec(storage, scale, TrainerKind::Torch))?;
        t.row(&[
            storage.to_string(),
            "vanilla".to_string(),
            num(before.median_get_batch, 3),
            num(before.median_to_device, 4),
            num(before.median_train, 4),
            "1.00".to_string(),
        ]);
        t.row(&[
            storage.to_string(),
            "threaded".to_string(),
            num(after.median_get_batch, 3),
            num(after.median_to_device, 4),
            num(after.median_train, 4),
            num(before.median_get_batch / after.median_get_batch, 2),
        ]);
    }
    t.note("paper: batch loading reduced up to 12× (s3) and 3× (scratch)");
    emit("f14", &t)
}

/// Fig 15: throughput ranges per data-loading layer.
pub fn f15_layer_throughput(scale: Scale) -> Result<()> {
    let mut t = Table::new(
        "Fig 15 — throughput per layer (Mbit/s, min..max over impls)",
        &["layer", "s3", "scratch"],
    );
    let mut per_layer: Vec<(String, Vec<f64>, Vec<f64>)> = Vec::new();

    // Layer 1: bare Dataset with multiprocessing pool
    let mut ds_rates: [Vec<f64>; 2] = [Vec::new(), Vec::new()];
    for (si, storage) in STORAGES.iter().enumerate() {
        let spec = base_spec(if si == 0 { "scratch" } else { "s3" }, scale);
        let _ = storage;
        let rig = rig::build(&spec)?;
        for pool in [1usize, 8, 24] {
            let r = run_pool(
                rig.dataloader.dataset().clone(),
                pool,
                spec.items.min(64),
                gil::Runtime::Python,
                2.0,
                spec.seed,
            );
            ds_rates[si].push(r.throughput_mbit_s);
        }
    }
    per_layer.push((
        "Dataset (mp pool)".into(),
        ds_rates[1].clone(),
        ds_rates[0].clone(),
    ));

    // Layer 2: Dataloader only (drained epochs)
    let mut dl_rates: [Vec<f64>; 2] = [Vec::new(), Vec::new()];
    for (si, storage) in ["scratch", "s3"].iter().enumerate() {
        for imp in FetchImpl::all() {
            let spec = base_spec(if si == 0 { "scratch" } else { "s3" }, scale)
                .with_impl(imp);
            let _ = storage;
            let rig = rig::build(&spec)?;
            let (secs, bytes, _) = rig::drain_epoch(&rig);
            dl_rates[si].push(crate::util::fmt::mbit_s(bytes, secs));
        }
    }
    per_layer.push((
        "Dataloader".into(),
        dl_rates[1].clone(),
        dl_rates[0].clone(),
    ));

    // Layer 3: end-to-end training
    let mut e2e_rates: [Vec<f64>; 2] = [Vec::new(), Vec::new()];
    for (si, _) in ["scratch", "s3"].iter().enumerate() {
        for imp in [FetchImpl::Vanilla, FetchImpl::Threaded] {
            let spec = base_spec(if si == 0 { "scratch" } else { "s3" }, scale)
                .with_impl(imp);
            let (r, _) = rig::run(&spec)?;
            e2e_rates[si].push(r.mbit_per_s);
        }
    }
    per_layer.push((
        "End-to-end".into(),
        e2e_rates[1].clone(),
        e2e_rates[0].clone(),
    ));

    for (layer, s3, scratch) in per_layer {
        let rng = |v: &[f64]| {
            let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = v.iter().cloned().fold(0.0, f64::max);
            format!("{lo:.0}..{hi:.0}")
        };
        t.row(&[layer, rng(&s3), rng(&scratch)]);
    }
    t.note("paper: dataset 4–79 / 73–304; dataloader 5–293 / 121–2159; e2e 314–338 / 520–822 (Mbit/s)");
    emit("f15", &t)
}

/// Shared check used by integration tests: the headline factor.
pub fn headline_factor(scale: Scale) -> Result<(f64, f64)> {
    let (vanilla, _) = rig::run(&base_spec("s3", scale))?;
    let (threaded, _) =
        rig::run(&modified_spec("s3", scale, TrainerKind::Torch))?;
    let (scratch, _) = rig::run(&base_spec("scratch", scale))?;
    Ok((
        threaded.mbit_per_s / vanilla.mbit_per_s,
        threaded.mbit_per_s / scratch.mbit_per_s,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_scale() -> Scale {
        Scale { latency: 0.04, items: 0.35, epochs: 1.0 }
    }

    #[test]
    fn headline_shape_holds_at_tiny_scale() {
        let (speedup, vs_scratch) = headline_factor(tiny_scale()).unwrap();
        // paper: 15.5× and 0.67; at tiny scale we only require the shape
        assert!(speedup > 2.0, "threaded only {speedup:.2}× over vanilla");
        assert!(vs_scratch > 0.15, "s3-threaded {vs_scratch:.2} of scratch");
    }

    #[test]
    fn fig14_get_batch_improves() {
        let scale = tiny_scale();
        let (before, _) = rig::run(&base_spec("s3", scale)).unwrap();
        let (after, _) =
            rig::run(&modified_spec("s3", scale, TrainerKind::Torch)).unwrap();
        assert!(
            after.median_get_batch < before.median_get_batch,
            "no improvement: {} vs {}",
            after.median_get_batch,
            before.median_get_batch
        );
    }

    #[test]
    fn span_names_used_by_reports_exist() {
        let scale = tiny_scale();
        let (_, rig) = rig::run(&base_spec("scratch", scale)).unwrap();
        for n in [names::GET_BATCH, names::GET_ITEM, names::TO_DEVICE, names::TRAIN_BATCH] {
            assert!(
                !rig.recorder.durations(n).is_empty(),
                "missing span {n}"
            );
        }
    }
}
