//! Synthetic ImageNet-like corpus generator.
//!
//! Produces a seeded set of SIMG images whose *byte-size distribution*
//! matches the paper's ImageNet working set (average ~115 kB per object,
//! lognormal spread, mild aspect-ratio variation) and whose pixel
//! content is structured (gradients + class-dependent texture + noise)
//! so that bilinear crops do real arithmetic.

use std::sync::Arc;

use anyhow::Result;

use super::simg::SimgImage;
use crate::storage::ObjectStore;
use crate::util::rng::Rng;

/// Corpus parameters.
#[derive(Debug, Clone)]
pub struct CorpusSpec {
    pub items: usize,
    pub classes: usize,
    /// mean object size in bytes (ImageNet JPEG avg ≈ 115 kB)
    pub mean_bytes: usize,
    /// lognormal sigma of the size distribution
    pub sigma: f64,
    pub seed: u64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec {
            items: 2048,
            classes: 512,
            mean_bytes: 115 * 1024,
            sigma: 0.35,
            seed: 7,
        }
    }
}

impl CorpusSpec {
    /// Small preset for unit tests / CI-speed runs.
    pub fn tiny(items: usize) -> CorpusSpec {
        CorpusSpec { items, mean_bytes: 12 * 1024, ..Default::default() }
    }

    /// Key of item `i` (classful layout, like ImageNet folders).
    pub fn key(&self, i: usize) -> String {
        format!("cls{:03}/img_{:06}.simg", i % self.classes, i)
    }
}

/// Generate one image deterministically from (spec.seed, index).
pub fn generate_image(spec: &CorpusSpec, index: usize) -> SimgImage {
    let mut rng = Rng::new(spec.seed ^ (index as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let label = (index % spec.classes) as u16;

    // lognormal byte size -> pixel dims with random aspect ratio
    // mean of lognormal = median * exp(sigma^2/2); invert for the median.
    let median = spec.mean_bytes as f64 / (spec.sigma * spec.sigma / 2.0).exp();
    let bytes = rng.lognormal(median, spec.sigma).max(3.0 * 16.0 * 16.0);
    let pixels_n = bytes / 3.0;
    let ar = rng.uniform(0.75, 1.35); // height/width
    let width = (pixels_n / ar).sqrt().round().max(16.0) as usize;
    let height = (pixels_n / width as f64).round().max(16.0) as usize;
    let (width, height) = (width.min(2048), height.min(2048));

    // structured content: per-class palette + gradients + noise
    let base = [
        (label as u32 * 37 % 256) as u8,
        (label as u32 * 101 % 256) as u8,
        (label as u32 * 197 % 256) as u8,
    ];
    let fx = rng.uniform(0.5, 4.0);
    let fy = rng.uniform(0.5, 4.0);
    let mut pixels = vec![0u8; height * width * 3];
    for y in 0..height {
        let wy = (y as f64 / height as f64 * fy * std::f64::consts::TAU).sin();
        for x in 0..width {
            let wx = (x as f64 / width as f64 * fx * std::f64::consts::TAU).cos();
            let wave = (wx * wy * 60.0) as i32;
            let noise = (rng.next_u32() & 0x1F) as i32 - 16;
            let off = (y * width + x) * 3;
            for c in 0..3 {
                let v = base[c] as i32 + wave + noise + (c as i32 * 9);
                pixels[off + c] = v.clamp(0, 255) as u8;
            }
        }
    }
    SimgImage::new(height, width, label, pixels)
}

/// Generate the full corpus into a store. Returns (keys, total_bytes).
pub fn generate_corpus(
    store: &Arc<dyn ObjectStore>,
    spec: &CorpusSpec,
) -> Result<(Vec<String>, u64)> {
    let mut keys = Vec::with_capacity(spec.items);
    let mut total = 0u64;
    for i in 0..spec.items {
        let img = generate_image(spec, i);
        let buf = img.encode();
        total += buf.len() as u64;
        let key = spec.key(i);
        store.put(&key, buf)?;
        keys.push(key);
    }
    Ok((keys, total))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;

    #[test]
    fn deterministic_generation() {
        let spec = CorpusSpec::tiny(4);
        let a = generate_image(&spec, 2);
        let b = generate_image(&spec, 2);
        assert_eq!(a, b);
        let c = generate_image(&spec, 3);
        assert_ne!(a.pixels, c.pixels);
    }

    #[test]
    fn size_distribution_centered_on_mean() {
        let spec = CorpusSpec { items: 200, mean_bytes: 30_000, ..Default::default() };
        let sizes: Vec<f64> = (0..spec.items)
            .map(|i| generate_image(&spec, i).encoded_len() as f64)
            .collect();
        let mean = crate::util::stats::mean(&sizes);
        assert!(
            (mean - 30_000.0).abs() < 6_000.0,
            "mean size {mean} far from 30000"
        );
    }

    #[test]
    fn labels_cycle_classes() {
        let spec = CorpusSpec { classes: 10, ..CorpusSpec::tiny(25) };
        for i in 0..25 {
            assert_eq!(generate_image(&spec, i).label as usize, i % 10);
        }
    }

    #[test]
    fn corpus_lands_in_store_decodable() {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        let spec = CorpusSpec::tiny(6);
        let (keys, total) = generate_corpus(&store, &spec).unwrap();
        assert_eq!(keys.len(), 6);
        assert!(total > 0);
        for k in &keys {
            let buf = store.get(k).unwrap();
            SimgImage::decode(&buf).unwrap();
        }
    }
}
