//! Data substrate: the SIMG image codec, the synthetic ImageNet-like
//! corpus generator, pixel-level augmentation ops, and a tiny tensor
//! type for collated batches.
//!
//! The paper uses ImageNet JPEGs (avg ~115 kB, ~469×387). Offline we
//! generate a seeded corpus of SIMG images whose byte-size distribution
//! matches, and whose decode+augment CPU cost stands in for JPEG decode
//! (DESIGN.md substitution table).

pub mod augment;
pub mod simg;
pub mod synth;

pub use augment::{Augment, AugmentConfig};
pub use simg::SimgImage;
pub use synth::{generate_corpus, CorpusSpec};

/// A dense f32 tensor (row-major) — the collated batch payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// A u8 tensor (raw image crops shipped to the device — the L1
/// normalize kernel converts on-device).
#[derive(Debug, Clone, PartialEq)]
pub struct U8Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl U8Tensor {
    pub fn zeros(shape: &[usize]) -> U8Tensor {
        U8Tensor { shape: shape.to_vec(), data: vec![0; shape.iter().product()] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shapes() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert_eq!(t.bytes(), 96);
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_len() {
        Tensor::from_vec(&[2, 2], vec![0.0; 3]);
    }
}
