//! CPU-side augmentation pipeline (the paper's Dataset transform):
//!
//! 1. RandomResizedCrop(crop×crop) — random area/aspect crop, bilinear
//!    resize (real per-pixel arithmetic, the CPU hot-spot);
//! 2. RandomHorizontalFlip(p=0.5);
//! 3. ToTensor + Normalize — **not here**: in the three-layer port this
//!    final per-pixel math runs on-device as the L1 Pallas kernel, so
//!    the loader ships u8 crops. A [`Augment::to_f32_normalized`] path
//!    is kept for the CPU-only comparisons and cross-checks.
//!
//! Deterministic: each item's randomness derives from (seed, epoch,
//! index).

use super::simg::{SimgImage, SimgRef};
use super::{Tensor, U8Tensor};
use crate::util::rng::Rng;

use std::cell::RefCell;

/// ImageNet channel statistics (same constants as the python side).
pub const MEAN: [f32; 3] = [0.485, 0.456, 0.406];
pub const STD: [f32; 3] = [0.229, 0.224, 0.225];

/// Augmentation parameters.
#[derive(Debug, Clone)]
pub struct AugmentConfig {
    /// output side (paper: 224; scaled default: 64 to match artifacts)
    pub crop: usize,
    /// RandomResizedCrop area range (torchvision default 0.08..1.0)
    pub area_range: (f64, f64),
    /// aspect-ratio range (torchvision default 3/4..4/3)
    pub ratio_range: (f64, f64),
    pub flip_p: f64,
    pub seed: u64,
}

impl Default for AugmentConfig {
    fn default() -> Self {
        AugmentConfig {
            crop: 64,
            area_range: (0.3, 1.0),
            ratio_range: (0.75, 4.0 / 3.0),
            flip_p: 0.5,
            seed: 11,
        }
    }
}

/// The transform pipeline.
#[derive(Debug, Clone)]
pub struct Augment {
    pub cfg: AugmentConfig,
}

impl Augment {
    pub fn new(cfg: AugmentConfig) -> Augment {
        Augment { cfg }
    }

    fn item_rng(&self, epoch: usize, index: usize) -> Rng {
        Rng::new(
            self.cfg
                .seed
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add((epoch as u64) << 32)
                .wrapping_add(index as u64),
        )
    }

    /// Apply crop+flip, returning a u8 HWC tensor (crop, crop, 3).
    pub fn apply_u8(&self, img: &SimgImage, epoch: usize, index: usize) -> U8Tensor {
        let c = self.cfg.crop;
        let mut out = U8Tensor::zeros(&[c, c, 3]);
        self.apply_u8_into(&img.as_view(), epoch, index, &mut out.data);
        out
    }

    /// Fused-path variant of [`Augment::apply_u8`]: write the augmented
    /// crop directly into `out` (length `crop × crop × 3`, e.g. one
    /// slot of a batch-arena slab), allocating nothing. Byte-identical
    /// to `apply_u8` for the same (seed, epoch, index).
    pub fn apply_u8_into(
        &self,
        img: &SimgRef<'_>,
        epoch: usize,
        index: usize,
        out: &mut [u8],
    ) {
        let c = self.cfg.crop;
        assert_eq!(out.len(), c * c * 3, "output slot is not crop×crop×3");
        let mut rng = self.item_rng(epoch, index);
        let (y0, x0, ch, cw) = sample_crop(
            &mut rng,
            img.height,
            img.width,
            self.cfg.area_range,
            self.cfg.ratio_range,
        );
        let flip = rng.chance(self.cfg.flip_p);
        bilinear_resize_region(img, y0, x0, ch, cw, c, c, flip, out);
    }

    /// CPU ToTensor+Normalize (reference / CPU-only comparisons); CHW f32.
    pub fn to_f32_normalized(&self, crop: &U8Tensor) -> Tensor {
        let (h, w) = (crop.shape[0], crop.shape[1]);
        let mut t = Tensor::zeros(&[3, h, w]);
        for c in 0..3 {
            let (m, s) = (MEAN[c], STD[c]);
            for y in 0..h {
                for x in 0..w {
                    let v = crop.data[(y * w + x) * 3 + c] as f32 / 255.0;
                    t.data[c * h * w + y * w + x] = (v - m) / s;
                }
            }
        }
        t
    }
}

/// Sample a RandomResizedCrop region (torchvision algorithm: try 10
/// area/ratio draws, fall back to center crop).
fn sample_crop(
    rng: &mut Rng,
    height: usize,
    width: usize,
    area_range: (f64, f64),
    ratio_range: (f64, f64),
) -> (usize, usize, usize, usize) {
    let area = (height * width) as f64;
    for _ in 0..10 {
        let target = area * rng.uniform(area_range.0, area_range.1);
        let log_r = rng.uniform(ratio_range.0.ln(), ratio_range.1.ln());
        let ratio = log_r.exp();
        let cw = (target * ratio).sqrt().round() as usize;
        let ch = (target / ratio).sqrt().round() as usize;
        if cw > 0 && ch > 0 && cw <= width && ch <= height {
            let y0 = rng.below(height - ch + 1);
            let x0 = rng.below(width - cw + 1);
            return (y0, x0, ch, cw);
        }
    }
    // fallback: biggest centered square
    let side = height.min(width);
    ((height - side) / 2, (width - side) / 2, side, side)
}

thread_local! {
    /// Reusable column-LUT scratch for [`bilinear_resize_region`]: the
    /// fused hot path must not allocate per item, so the LUT buffer is
    /// grown once per thread and reused for every crop after that.
    static COL_LUT: RefCell<Vec<(usize, usize, f32)>> = const { RefCell::new(Vec::new()) };
}

/// Bilinear-resize a source region (y0,x0,ch,cw) to (oh,ow), optional
/// horizontal flip, writing u8 HWC into `out`.
#[allow(clippy::too_many_arguments)]
fn bilinear_resize_region(
    img: &SimgRef<'_>,
    y0: usize,
    x0: usize,
    ch: usize,
    cw: usize,
    oh: usize,
    ow: usize,
    flip: bool,
    out: &mut [u8],
) {
    debug_assert_eq!(out.len(), oh * ow * 3);
    let sy = ch as f32 / oh as f32;
    let sx = cw as f32 / ow as f32;
    let stride = img.width * 3;
    let px = img.pixels;
    // column LUT: the x-interpolation pattern is identical for every
    // output row — precompute (byte offsets, weight) once (§Perf:
    // ~2× on the crop hot path vs recomputing per pixel). The buffer
    // is thread-local so steady-state crops allocate nothing.
    COL_LUT.with(|lut| {
        let mut cols = lut.borrow_mut();
        cols.clear();
        cols.extend((0..ow).map(|ox| {
            let fx = ((ox as f32 + 0.5) * sx - 0.5).max(0.0);
            let ix = (fx as usize).min(cw - 1);
            let ix1 = (ix + 1).min(cw - 1);
            ((x0 + ix) * 3, (x0 + ix1) * 3, fx - ix as f32)
        }));
        resize_rows(px, stride, y0, ch, sy, oh, ow, flip, &cols[..], out);
    });
}

/// Row loop of the bilinear resize (split out so the column LUT borrow
/// stays scoped).
#[allow(clippy::too_many_arguments)]
fn resize_rows(
    px: &[u8],
    stride: usize,
    y0: usize,
    ch: usize,
    sy: f32,
    oh: usize,
    ow: usize,
    flip: bool,
    cols: &[(usize, usize, f32)],
    out: &mut [u8],
) {
    for oy in 0..oh {
        let fy = ((oy as f32 + 0.5) * sy - 0.5).max(0.0);
        let iy = (fy as usize).min(ch - 1);
        let iy1 = (iy + 1).min(ch - 1);
        let wy = fy - iy as f32;
        let row0 = &px[(y0 + iy) * stride..];
        let row1 = &px[(y0 + iy1) * stride..];
        let out_row = &mut out[oy * ow * 3..(oy + 1) * ow * 3];
        for (ox, &(c0, c1, wx)) in cols.iter().enumerate() {
            let out_x = if flip { ow - 1 - ox } else { ox };
            let o = out_x * 3;
            for c in 0..3 {
                let v00 = row0[c0 + c] as f32;
                let v01 = row0[c1 + c] as f32;
                let v10 = row1[c0 + c] as f32;
                let v11 = row1[c1 + c] as f32;
                let top = v00 + (v01 - v00) * wx;
                let bot = v10 + (v11 - v10) * wx;
                let v = top + (bot - top) * wy;
                out_row[o + c] = (v + 0.5) as u8;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_image(h: usize, w: usize, val: u8) -> SimgImage {
        SimgImage::new(h, w, 0, vec![val; h * w * 3])
    }

    fn gradient_image(h: usize, w: usize) -> SimgImage {
        let mut px = vec![0u8; h * w * 3];
        for y in 0..h {
            for x in 0..w {
                let o = (y * w + x) * 3;
                px[o] = (x * 255 / w.max(1)) as u8; // R encodes x
                px[o + 1] = (y * 255 / h.max(1)) as u8; // G encodes y
                px[o + 2] = 128;
            }
        }
        SimgImage::new(h, w, 0, px)
    }

    #[test]
    fn output_shape_and_determinism() {
        let aug = Augment::new(AugmentConfig { crop: 32, ..Default::default() });
        let img = gradient_image(100, 80);
        let a = aug.apply_u8(&img, 0, 5);
        let b = aug.apply_u8(&img, 0, 5);
        assert_eq!(a.shape, vec![32, 32, 3]);
        assert_eq!(a, b);
        // different epoch -> different crop
        let c = aug.apply_u8(&img, 1, 5);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn apply_into_matches_allocating_path_byte_for_byte() {
        let aug = Augment::new(AugmentConfig { crop: 24, ..Default::default() });
        let img = gradient_image(90, 70);
        for (epoch, index) in [(0usize, 0usize), (0, 7), (3, 7), (5, 123)] {
            let owned = aug.apply_u8(&img, epoch, index);
            let mut slot = vec![0xAAu8; 24 * 24 * 3];
            aug.apply_u8_into(&img.as_view(), epoch, index, &mut slot);
            assert_eq!(owned.data, slot, "epoch {epoch} index {index}");
        }
    }

    #[test]
    #[should_panic(expected = "crop×crop×3")]
    fn apply_into_checks_slot_length() {
        let aug = Augment::new(AugmentConfig { crop: 8, ..Default::default() });
        let img = gradient_image(16, 16);
        let mut slot = vec![0u8; 7];
        aug.apply_u8_into(&img.as_view(), 0, 0, &mut slot);
    }

    #[test]
    fn flat_image_stays_flat() {
        let aug = Augment::new(AugmentConfig { crop: 16, ..Default::default() });
        let img = flat_image(50, 70, 93);
        let out = aug.apply_u8(&img, 0, 0);
        assert!(out.data.iter().all(|&v| v == 93));
    }

    #[test]
    fn flip_mirrors_r_channel_gradient() {
        // with flip_p = 1.0, the x-gradient in R must be descending
        let aug = Augment::new(AugmentConfig {
            crop: 16,
            flip_p: 1.0,
            area_range: (1.0, 1.0),
            ratio_range: (1.0, 1.0),
            seed: 3,
        });
        let img = gradient_image(64, 64);
        let out = aug.apply_u8(&img, 0, 0);
        let first_r = out.data[0] as i32;
        let last_r = out.data[(15) * 3] as i32;
        assert!(first_r > last_r, "not flipped: {first_r} vs {last_r}");
    }

    #[test]
    fn crop_region_within_bounds_many_seeds() {
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let h = rng.range(16, 300);
            let w = rng.range(16, 300);
            let (y0, x0, ch, cw) =
                sample_crop(&mut rng, h, w, (0.08, 1.0), (0.75, 4.0 / 3.0));
            assert!(y0 + ch <= h);
            assert!(x0 + cw <= w);
            assert!(ch > 0 && cw > 0);
        }
    }

    #[test]
    fn normalize_matches_formula() {
        let aug = Augment::new(AugmentConfig { crop: 4, ..Default::default() });
        let crop = U8Tensor {
            shape: vec![2, 2, 3],
            data: vec![128; 12],
        };
        let t = aug.to_f32_normalized(&crop);
        assert_eq!(t.shape, vec![3, 2, 2]);
        for c in 0..3 {
            let want = (128.0 / 255.0 - MEAN[c]) / STD[c];
            assert!((t.data[c * 4] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn tiny_source_image_upscales() {
        let aug = Augment::new(AugmentConfig { crop: 64, ..Default::default() });
        let img = gradient_image(16, 16);
        let out = aug.apply_u8(&img, 0, 0);
        assert_eq!(out.numel(), 64 * 64 * 3);
    }
}
