//! SIMG — the repo's raw image format.
//!
//! Layout (little-endian):
//! ```text
//! magic    [4]  = b"SIMG"
//! version  u8   = 1
//! channels u8   = 3
//! height   u16
//! width    u16
//! label    u16            (class id baked into the object, like an
//!                          ImageNet folder name)
//! crc32    u32            (over the pixel payload)
//! pixels   h*w*c u8       (HWC, RGB)
//! ```
//!
//! Decode validates the CRC — a real pass over every payload byte, which
//! stands in for JPEG entropy-decode cost at the same order of
//! magnitude per byte (the augment stage dominates CPU anyway).

use anyhow::{bail, Result};

pub const MAGIC: &[u8; 4] = b"SIMG";
pub const HEADER_LEN: usize = 4 + 1 + 1 + 2 + 2 + 2 + 4;

/// A decoded image: HWC u8 pixels plus its label.
#[derive(Debug, Clone, PartialEq)]
pub struct SimgImage {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub label: u16,
    pub pixels: Vec<u8>,
}

/// A zero-copy view of a SIMG object: header fields plus a borrow of the
/// pixel payload inside the encoded buffer. The fused hot path
/// ([`crate::dataloader::arena`]) parses straight off the storage bytes
/// and augments into a batch slab, so no decode buffer is ever
/// allocated; [`SimgImage::decode`] is the owning wrapper around it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimgRef<'a> {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub label: u16,
    pub pixels: &'a [u8],
}

impl<'a> SimgRef<'a> {
    /// Parse and CRC-validate a SIMG buffer without copying the payload.
    pub fn parse(buf: &'a [u8]) -> Result<SimgRef<'a>> {
        if buf.len() < HEADER_LEN {
            bail!("SIMG too short: {} bytes", buf.len());
        }
        if &buf[0..4] != MAGIC {
            bail!("bad SIMG magic");
        }
        let version = buf[4];
        if version != 1 {
            bail!("unsupported SIMG version {version}");
        }
        let channels = buf[5] as usize;
        if channels != 3 {
            bail!("unsupported channel count {channels}");
        }
        let height = u16::from_le_bytes([buf[6], buf[7]]) as usize;
        let width = u16::from_le_bytes([buf[8], buf[9]]) as usize;
        let label = u16::from_le_bytes([buf[10], buf[11]]);
        let crc = u32::from_le_bytes([buf[12], buf[13], buf[14], buf[15]]);
        let want = height * width * channels;
        let pixels = &buf[HEADER_LEN..];
        if pixels.len() != want {
            bail!("SIMG payload {} != {}", pixels.len(), want);
        }
        if crc32(pixels) != crc {
            bail!("SIMG CRC mismatch");
        }
        Ok(SimgRef { height, width, channels, label, pixels })
    }

    /// Copy into an owning [`SimgImage`] (the legacy decode path).
    pub fn to_image(&self) -> SimgImage {
        SimgImage {
            height: self.height,
            width: self.width,
            channels: self.channels,
            label: self.label,
            pixels: self.pixels.to_vec(),
        }
    }
}

impl SimgImage {
    pub fn new(height: usize, width: usize, label: u16, pixels: Vec<u8>) -> SimgImage {
        assert_eq!(pixels.len(), height * width * 3);
        SimgImage { height, width, channels: 3, label, pixels }
    }

    /// Pixel at (y, x, c).
    #[inline]
    pub fn at(&self, y: usize, x: usize, c: usize) -> u8 {
        self.pixels[(y * self.width + x) * self.channels + c]
    }

    /// Encode to the SIMG byte format.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + self.pixels.len());
        out.extend_from_slice(MAGIC);
        out.push(1u8);
        out.push(self.channels as u8);
        out.extend_from_slice(&(self.height as u16).to_le_bytes());
        out.extend_from_slice(&(self.width as u16).to_le_bytes());
        out.extend_from_slice(&self.label.to_le_bytes());
        out.extend_from_slice(&crc32(&self.pixels).to_le_bytes());
        out.extend_from_slice(&self.pixels);
        out
    }

    /// Decode and CRC-validate a SIMG buffer (owning copy of the
    /// payload; the fused path uses [`SimgRef::parse`] instead).
    pub fn decode(buf: &[u8]) -> Result<SimgImage> {
        Ok(SimgRef::parse(buf)?.to_image())
    }

    /// Borrowed view of this image (for the write-into augment APIs).
    pub fn as_view(&self) -> SimgRef<'_> {
        SimgRef {
            height: self.height,
            width: self.width,
            channels: self.channels,
            label: self.label,
            pixels: &self.pixels,
        }
    }

    pub fn encoded_len(&self) -> usize {
        HEADER_LEN + self.pixels.len()
    }
}

/// CRC-32 (IEEE), slicing-by-8 (≈6× over the classic byte-at-a-time
/// loop — decode is the loader's per-item CPU hot path, see
/// EXPERIMENTS.md §Perf).
pub fn crc32(data: &[u8]) -> u32 {
    static TABLES: std::sync::OnceLock<[[u32; 256]; 8]> = std::sync::OnceLock::new();
    let t = TABLES.get_or_init(|| {
        let mut t = [[0u32; 256]; 8];
        for i in 0..256usize {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            }
            t[0][i] = c;
        }
        for i in 0..256usize {
            let mut c = t[0][i];
            for k in 1..8 {
                c = t[0][(c & 0xFF) as usize] ^ (c >> 8);
                t[k][i] = c;
            }
        }
        t
    });
    let mut crc = !0u32;
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        let lo = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) ^ crc;
        let hi = u32::from_le_bytes([c[4], c[5], c[6], c[7]]);
        crc = t[7][(lo & 0xFF) as usize]
            ^ t[6][((lo >> 8) & 0xFF) as usize]
            ^ t[5][((lo >> 16) & 0xFF) as usize]
            ^ t[4][(lo >> 24) as usize]
            ^ t[3][(hi & 0xFF) as usize]
            ^ t[2][((hi >> 8) & 0xFF) as usize]
            ^ t[1][((hi >> 16) & 0xFF) as usize]
            ^ t[0][(hi >> 24) as usize];
    }
    for &b in chunks.remainder() {
        crc = t[0][((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(h: usize, w: usize) -> SimgImage {
        let pixels: Vec<u8> =
            (0..h * w * 3).map(|i| (i * 31 % 256) as u8).collect();
        SimgImage::new(h, w, 7, pixels)
    }

    #[test]
    fn roundtrip() {
        let img = sample(13, 9);
        let buf = img.encode();
        assert_eq!(buf.len(), img.encoded_len());
        let back = SimgImage::decode(&buf).unwrap();
        assert_eq!(back, img);
    }

    #[test]
    fn crc_detects_corruption() {
        let img = sample(8, 8);
        let mut buf = img.encode();
        let last = buf.len() - 1;
        buf[last] ^= 0xFF;
        assert!(SimgImage::decode(&buf).is_err());
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let img = sample(4, 4);
        let mut buf = img.encode();
        buf[0] = b'X';
        assert!(SimgImage::decode(&buf).is_err());
        let buf = img.encode();
        assert!(SimgImage::decode(&buf[..10]).is_err());
        assert!(SimgImage::decode(&buf[..buf.len() - 1]).is_err());
    }

    #[test]
    fn parse_view_matches_decode_zero_copy() {
        let img = sample(11, 7);
        let buf = img.encode();
        let v = SimgRef::parse(&buf).unwrap();
        assert_eq!(v.height, 11);
        assert_eq!(v.width, 7);
        assert_eq!(v.label, 7);
        assert_eq!(v.pixels, &img.pixels[..]);
        // the view borrows the encoded buffer, no copy
        assert!(std::ptr::eq(v.pixels.as_ptr(), buf[HEADER_LEN..].as_ptr()));
        assert_eq!(v.to_image(), img);
        assert_eq!(img.as_view(), v);
    }

    #[test]
    fn parse_rejects_corruption_like_decode() {
        let img = sample(6, 6);
        let mut buf = img.encode();
        let last = buf.len() - 1;
        buf[last] ^= 0x55;
        assert!(SimgRef::parse(&buf).is_err());
        assert!(SimgRef::parse(&buf[..8]).is_err());
    }

    #[test]
    fn crc32_known_value() {
        // "123456789" -> 0xCBF43926 (standard check value)
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn at_indexes_hwc() {
        let img = sample(2, 3);
        assert_eq!(img.at(0, 0, 0), img.pixels[0]);
        assert_eq!(img.at(1, 2, 1), img.pixels[(1 * 3 + 2) * 3 + 1]);
    }
}
