//! `asyncrt` — a small, std-only async runtime.
//!
//! Tokio is not in the offline vendor set, and this reproduction *needs*
//! an asyncio analogue: the paper's `_AsyncMapDatasetFetcher` runs an
//! asyncio event loop inside each worker process, overlapping the I/O
//! latencies of all items of a batch within one thread. `asyncrt` is the
//! same shape: an executor with N worker threads (N=1 reproduces the
//! single-threaded asyncio loop), a timer driver for simulated I/O
//! waits, an async semaphore (`num_fetch_workers` concurrency control),
//! and an async mpsc channel.
//!
//! Components:
//! * [`Runtime`] — executor with `spawn`, `block_on`.
//! * [`sleep`] — timer future driven by a shared timer thread.
//! * [`Semaphore`] — async counting semaphore.
//! * [`channel`] — bounded async mpsc.
//! * [`yield_now`] — cooperative reschedule point.

use std::collections::{BinaryHeap, VecDeque};
use std::future::Future;
use std::pin::Pin;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::task::{Context, Poll, Wake, Waker};
use std::time::{Duration, Instant};

type BoxFuture = Pin<Box<dyn Future<Output = ()> + Send + 'static>>;

// ---------------------------------------------------------------------------
// Executor
// ---------------------------------------------------------------------------

struct Injector {
    queue: Mutex<VecDeque<Arc<Task>>>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// tasks spawned and not yet finished (for graceful drop)
    live: AtomicUsize,
}

struct Task {
    future: Mutex<Option<BoxFuture>>,
    injector: Arc<Injector>,
    /// prevents double-scheduling between wake() and poll completion
    scheduled: AtomicBool,
}

impl Wake for Task {
    fn wake(self: Arc<Self>) {
        self.wake_by_ref();
    }

    fn wake_by_ref(self: &Arc<Self>) {
        if self
            .scheduled
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            let mut q = self.injector.queue.lock().unwrap();
            q.push_back(self.clone());
            self.injector.cv.notify_one();
        }
    }
}

/// Multi-threaded (or single-threaded) async executor.
pub struct Runtime {
    injector: Arc<Injector>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Runtime {
    /// `n_threads = 1` gives asyncio semantics (one event loop thread:
    /// CPU sections serialize, I/O waits overlap).
    pub fn new(n_threads: usize) -> Arc<Runtime> {
        let injector = Arc::new(Injector {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            live: AtomicUsize::new(0),
        });
        let threads = (0..n_threads.max(1))
            .map(|i| {
                let inj = injector.clone();
                std::thread::Builder::new()
                    .name(format!("asyncrt-{i}"))
                    .spawn(move || worker_loop(inj))
                    .expect("spawn asyncrt worker")
            })
            .collect();
        Arc::new(Runtime { injector, threads })
    }

    /// Spawn a future onto the runtime; returns a handle to await/join
    /// its output from sync or async code.
    pub fn spawn<F, T>(&self, fut: F) -> JoinHandle<T>
    where
        F: Future<Output = T> + Send + 'static,
        T: Send + 'static,
    {
        let state = Arc::new(JoinState::<T>::default());
        let st = state.clone();
        self.injector.live.fetch_add(1, Ordering::AcqRel);
        let inj = self.injector.clone();
        let wrapped: BoxFuture = Box::pin(async move {
            let out = fut.await;
            st.complete(out);
            inj.live.fetch_sub(1, Ordering::AcqRel);
        });
        let task = Arc::new(Task {
            future: Mutex::new(Some(wrapped)),
            injector: self.injector.clone(),
            scheduled: AtomicBool::new(false),
        });
        // initial schedule
        task.clone().wake();
        JoinHandle { state }
    }

    /// Drive a future to completion on the *current* thread (parking).
    pub fn block_on<F: Future>(&self, fut: F) -> F::Output {
        block_on(fut)
    }

    /// Number of spawned-but-unfinished tasks.
    pub fn live_tasks(&self) -> usize {
        self.injector.live.load(Ordering::Acquire)
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        self.injector.shutdown.store(true, Ordering::Release);
        self.injector.cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn worker_loop(inj: Arc<Injector>) {
    loop {
        let task = {
            let mut q = inj.queue.lock().unwrap();
            loop {
                if inj.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = inj.cv.wait(q).unwrap();
            }
        };
        task.scheduled.store(false, Ordering::Release);
        let waker = Waker::from(task.clone());
        let mut cx = Context::from_waker(&waker);
        let mut slot = task.future.lock().unwrap();
        if let Some(mut fut) = slot.take() {
            match fut.as_mut().poll(&mut cx) {
                Poll::Pending => *slot = Some(fut),
                Poll::Ready(()) => {}
            }
        }
    }
}

/// Block the current thread on a future (thread-parking waker).
pub fn block_on<F: Future>(mut fut: F) -> F::Output {
    struct ThreadWaker(std::thread::Thread);
    impl Wake for ThreadWaker {
        fn wake(self: Arc<Self>) {
            self.0.unpark();
        }
        fn wake_by_ref(self: &Arc<Self>) {
            self.0.unpark();
        }
    }
    // SAFETY: fut is shadowed and never moved after pinning.
    let mut fut = unsafe { Pin::new_unchecked(&mut fut) };
    let waker = Waker::from(Arc::new(ThreadWaker(std::thread::current())));
    let mut cx = Context::from_waker(&waker);
    loop {
        match fut.as_mut().poll(&mut cx) {
            Poll::Ready(v) => return v,
            Poll::Pending => std::thread::park(),
        }
    }
}

// ---------------------------------------------------------------------------
// JoinHandle
// ---------------------------------------------------------------------------

struct JoinState<T> {
    slot: Mutex<(Option<T>, Option<Waker>, bool)>,
    cv: Condvar,
}

impl<T> Default for JoinState<T> {
    fn default() -> Self {
        Self { slot: Mutex::new((None, None, false)), cv: Condvar::new() }
    }
}

impl<T> JoinState<T> {
    fn complete(&self, v: T) {
        let mut s = self.slot.lock().unwrap();
        s.0 = Some(v);
        s.2 = true;
        if let Some(w) = s.1.take() {
            w.wake();
        }
        self.cv.notify_all();
    }
}

/// Handle to a spawned task's output. Await it (async) or `join` it
/// (blocking).
pub struct JoinHandle<T> {
    state: Arc<JoinState<T>>,
}

impl<T> JoinHandle<T> {
    /// Blocking join (for sync callers).
    pub fn join(self) -> T {
        let mut s = self.state.slot.lock().unwrap();
        loop {
            if let Some(v) = s.0.take() {
                return v;
            }
            s = self.state.cv.wait(s).unwrap();
        }
    }

    pub fn is_finished(&self) -> bool {
        self.state.slot.lock().unwrap().2
    }
}

impl<T> Future for JoinHandle<T> {
    type Output = T;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<T> {
        let mut s = self.state.slot.lock().unwrap();
        if let Some(v) = s.0.take() {
            Poll::Ready(v)
        } else {
            s.1 = Some(cx.waker().clone());
            Poll::Pending
        }
    }
}

// ---------------------------------------------------------------------------
// Timer driver
// ---------------------------------------------------------------------------

struct TimerEntry {
    deadline: Instant,
    seq: u64,
    waker: Waker,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.deadline == other.deadline && self.seq == other.seq
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimerEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap via reversal
        other
            .deadline
            .cmp(&self.deadline)
            .then(other.seq.cmp(&self.seq))
    }
}

struct TimerDriver {
    heap: Mutex<BinaryHeap<TimerEntry>>,
    cv: Condvar,
    seq: AtomicU64,
}

static TIMER: std::sync::OnceLock<Arc<TimerDriver>> = std::sync::OnceLock::new();

/// Lazily-started shared timer driver (std-only `Lazy` replacement).
fn timer() -> &'static Arc<TimerDriver> {
    TIMER.get_or_init(|| {
        let d = Arc::new(TimerDriver {
            heap: Mutex::new(BinaryHeap::new()),
            cv: Condvar::new(),
            seq: AtomicU64::new(0),
        });
        let dd = d.clone();
        std::thread::Builder::new()
            .name("asyncrt-timer".into())
            .spawn(move || timer_loop(dd))
            .expect("spawn timer thread");
        d
    })
}

fn timer_loop(d: Arc<TimerDriver>) {
    let mut heap = d.heap.lock().unwrap();
    loop {
        let now = Instant::now();
        while heap.peek().map_or(false, |e| e.deadline <= now) {
            let e = heap.pop().unwrap();
            e.waker.wake();
        }
        match heap.peek().map(|e| e.deadline) {
            Some(dl) => {
                let wait = dl.saturating_duration_since(Instant::now());
                let (h, _) = d.cv.wait_timeout(heap, wait).unwrap();
                heap = h;
            }
            None => {
                heap = d.cv.wait(heap).unwrap();
            }
        }
    }
}

/// Future that resolves after `dur` (simulated I/O latency lives here).
pub fn sleep(dur: Duration) -> Sleep {
    Sleep { deadline: Instant::now() + dur, registered: false }
}

/// Future that resolves at `deadline`.
pub fn sleep_until(deadline: Instant) -> Sleep {
    Sleep { deadline, registered: false }
}

pub struct Sleep {
    deadline: Instant,
    registered: bool,
}

impl Future for Sleep {
    type Output = ();

    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if Instant::now() >= self.deadline {
            return Poll::Ready(());
        }
        // (Re-)register; registering on every poll is correct (the stale
        // entry just fires a spurious wake) and keeps the code simple.
        let d = timer();
        let entry = TimerEntry {
            deadline: self.deadline,
            seq: d.seq.fetch_add(1, Ordering::Relaxed),
            waker: cx.waker().clone(),
        };
        d.heap.lock().unwrap().push(entry);
        d.cv.notify_one();
        self.registered = true;
        Poll::Pending
    }
}

/// Yield back to the executor once (lets same-thread siblings run).
pub fn yield_now() -> YieldNow {
    YieldNow { polled: false }
}

pub struct YieldNow {
    polled: bool,
}

impl Future for YieldNow {
    type Output = ();

    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.polled {
            Poll::Ready(())
        } else {
            self.polled = true;
            cx.waker().wake_by_ref();
            Poll::Pending
        }
    }
}

// ---------------------------------------------------------------------------
// Semaphore
// ---------------------------------------------------------------------------

struct SemState {
    permits: usize,
    /// permits removed while checked out: returning holders pay the
    /// debt instead of freeing a permit (live downsizing)
    debt: usize,
    waiters: VecDeque<Waker>,
}

/// Async counting semaphore — the `num_fetch_workers` /
/// max-connections concurrency limiter. The budget can be resized
/// while permits are checked out: [`Semaphore::remove_permits`] books
/// any shortfall as debt that returning holders pay down, so shrinking
/// never blocks and never strands a waiter.
pub struct Semaphore {
    state: Mutex<SemState>,
}

impl Semaphore {
    pub fn new(permits: usize) -> Arc<Semaphore> {
        Arc::new(Semaphore {
            state: Mutex::new(SemState {
                permits,
                debt: 0,
                waiters: VecDeque::new(),
            }),
        })
    }

    pub fn available(&self) -> usize {
        self.state.lock().unwrap().permits
    }

    /// Acquire one permit; the returned guard releases on drop.
    pub fn acquire(self: &Arc<Self>) -> Acquire {
        Acquire { sem: self.clone() }
    }

    /// Grow the budget by `n`: outstanding debt is forgiven first, the
    /// remainder becomes available permits and wakes that many waiters.
    pub fn add_permits(&self, n: usize) {
        let mut wake = Vec::new();
        {
            let mut s = self.state.lock().unwrap();
            let forgiven = n.min(s.debt);
            s.debt -= forgiven;
            let fresh = n - forgiven;
            s.permits += fresh;
            for _ in 0..fresh.min(s.waiters.len()) {
                if let Some(w) = s.waiters.pop_front() {
                    wake.push(w);
                }
            }
        }
        for w in wake {
            w.wake();
        }
    }

    /// Shrink the budget by `n`: takes from the available pool first;
    /// whatever is currently checked out becomes debt, repaid as those
    /// permits come home.
    pub fn remove_permits(&self, n: usize) {
        let mut s = self.state.lock().unwrap();
        let taken = n.min(s.permits);
        s.permits -= taken;
        s.debt += n - taken;
    }

    fn release(&self) {
        let mut s = self.state.lock().unwrap();
        if s.debt > 0 {
            s.debt -= 1;
            return;
        }
        s.permits += 1;
        if let Some(w) = s.waiters.pop_front() {
            w.wake();
        }
    }
}

pub struct Acquire {
    sem: Arc<Semaphore>,
}

impl Future for Acquire {
    type Output = Permit;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Permit> {
        let mut s = self.sem.state.lock().unwrap();
        if s.permits > 0 {
            s.permits -= 1;
            drop(s);
            Poll::Ready(Permit { sem: self.sem.clone() })
        } else {
            s.waiters.push_back(cx.waker().clone());
            Poll::Pending
        }
    }
}

/// RAII permit.
pub struct Permit {
    sem: Arc<Semaphore>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.sem.release();
    }
}

// ---------------------------------------------------------------------------
// Bounded async mpsc channel
// ---------------------------------------------------------------------------

struct ChanState<T> {
    buf: VecDeque<T>,
    cap: usize,
    senders: usize,
    recv_wakers: VecDeque<Waker>,
    send_wakers: VecDeque<Waker>,
}

struct Chan<T> {
    state: Mutex<ChanState<T>>,
}

/// Create a bounded async channel (the data_queue between fetch tasks
/// and the worker).
pub fn channel<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let chan = Arc::new(Chan {
        state: Mutex::new(ChanState {
            buf: VecDeque::new(),
            cap: cap.max(1),
            senders: 1,
            recv_wakers: VecDeque::new(),
            send_wakers: VecDeque::new(),
        }),
    });
    (Sender { chan: chan.clone() }, Receiver { chan })
}

pub struct Sender<T> {
    chan: Arc<Chan<T>>,
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.chan.state.lock().unwrap().senders += 1;
        Sender { chan: self.chan.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut s = self.chan.state.lock().unwrap();
        s.senders -= 1;
        if s.senders == 0 {
            for w in s.recv_wakers.drain(..) {
                w.wake();
            }
        }
    }
}

impl<T> Sender<T> {
    /// Async send with backpressure (waits while the buffer is full).
    pub fn send(&self, value: T) -> SendFut<'_, T> {
        SendFut { sender: self, value: Some(value) }
    }

    /// Non-blocking send attempt.
    pub fn try_send(&self, value: T) -> Result<(), T> {
        let mut s = self.chan.state.lock().unwrap();
        if s.buf.len() >= s.cap {
            return Err(value);
        }
        s.buf.push_back(value);
        if let Some(w) = s.recv_wakers.pop_front() {
            w.wake();
        }
        Ok(())
    }
}

pub struct SendFut<'a, T> {
    sender: &'a Sender<T>,
    value: Option<T>,
}

impl<T> Future for SendFut<'_, T> {
    type Output = ();

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        // SAFETY: we never move out of self except through the Option.
        let this = unsafe { self.get_unchecked_mut() };
        let mut s = this.sender.chan.state.lock().unwrap();
        if s.buf.len() < s.cap {
            s.buf.push_back(this.value.take().expect("polled after ready"));
            if let Some(w) = s.recv_wakers.pop_front() {
                w.wake();
            }
            Poll::Ready(())
        } else {
            s.send_wakers.push_back(cx.waker().clone());
            Poll::Pending
        }
    }
}

pub struct Receiver<T> {
    chan: Arc<Chan<T>>,
}

impl<T> Receiver<T> {
    /// Async receive; resolves to `None` when all senders are dropped
    /// and the buffer is drained.
    pub fn recv(&self) -> RecvFut<'_, T> {
        RecvFut { recv: self }
    }

    /// Blocking receive for sync consumers.
    pub fn recv_blocking(&self) -> Option<T> {
        block_on(self.recv())
    }

    pub fn len(&self) -> usize {
        self.chan.state.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

pub struct RecvFut<'a, T> {
    recv: &'a Receiver<T>,
}

impl<T> Future for RecvFut<'_, T> {
    type Output = Option<T>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Option<T>> {
        let mut s = self.recv.chan.state.lock().unwrap();
        if let Some(v) = s.buf.pop_front() {
            if let Some(w) = s.send_wakers.pop_front() {
                w.wake();
            }
            return Poll::Ready(Some(v));
        }
        if s.senders == 0 {
            return Poll::Ready(None);
        }
        s.recv_wakers.push_back(cx.waker().clone());
        Poll::Pending
    }
}

/// Await all handles, returning outputs in order.
pub async fn join_all<T>(handles: Vec<JoinHandle<T>>) -> Vec<T> {
    let mut out = Vec::with_capacity(handles.len());
    for h in handles {
        out.push(h.await);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn block_on_ready() {
        assert_eq!(block_on(async { 41 + 1 }), 42);
    }

    #[test]
    fn spawn_and_join() {
        let rt = Runtime::new(2);
        let h = rt.spawn(async { 7 * 6 });
        assert_eq!(h.join(), 42);
    }

    #[test]
    fn sleep_resolves_and_orders() {
        let rt = Runtime::new(1);
        let t0 = Instant::now();
        let h = rt.spawn(async {
            sleep(Duration::from_millis(30)).await;
            Instant::now()
        });
        let end = h.join();
        assert!(end - t0 >= Duration::from_millis(28), "{:?}", end - t0);
    }

    #[test]
    fn single_thread_overlaps_sleeps() {
        // the asyncio property: N concurrent sleeps on ONE thread take
        // ~max, not ~sum.
        let rt = Runtime::new(1);
        let t0 = Instant::now();
        let handles: Vec<_> = (0..8)
            .map(|_| rt.spawn(async { sleep(Duration::from_millis(40)).await }))
            .collect();
        for h in handles {
            h.join();
        }
        let dt = t0.elapsed();
        assert!(dt < Duration::from_millis(200), "not overlapped: {dt:?}");
    }

    #[test]
    fn semaphore_limits_concurrency() {
        let rt = Runtime::new(4);
        let sem = Semaphore::new(2);
        let peak = Arc::new(AtomicUsize::new(0));
        let cur = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..12)
            .map(|_| {
                let sem = sem.clone();
                let peak = peak.clone();
                let cur = cur.clone();
                rt.spawn(async move {
                    let _p = sem.acquire().await;
                    let c = cur.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(c, Ordering::SeqCst);
                    sleep(Duration::from_millis(10)).await;
                    cur.fetch_sub(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.join();
        }
        assert!(peak.load(Ordering::SeqCst) <= 2);
    }

    #[test]
    fn semaphore_resizes_with_debt() {
        let rt = Runtime::new(1);
        let sem = Semaphore::new(2);
        // check both permits out
        let p1 = rt.block_on({
            let sem = sem.clone();
            async move { sem.acquire().await }
        });
        let p2 = rt.block_on({
            let sem = sem.clone();
            async move { sem.acquire().await }
        });
        // shrink to 1 while both are held: shortfall becomes debt
        sem.remove_permits(1);
        assert_eq!(sem.available(), 0);
        drop(p1); // pays the debt — no permit freed
        assert_eq!(sem.available(), 0);
        drop(p2); // debt clear — permit comes home
        assert_eq!(sem.available(), 1);
        // grow back to 3
        sem.add_permits(2);
        assert_eq!(sem.available(), 3);
        // shrink below zero available: all debt
        let p = rt.block_on({
            let sem = sem.clone();
            async move { sem.acquire().await }
        });
        sem.remove_permits(3);
        assert_eq!(sem.available(), 0);
        // growing forgives debt before freeing permits
        sem.add_permits(1);
        assert_eq!(sem.available(), 0);
        drop(p);
        assert_eq!(sem.available(), 1);
    }

    #[test]
    fn channel_backpressure_and_close() {
        let rt = Runtime::new(2);
        let (tx, rx) = channel::<usize>(2);
        let h = rt.spawn(async move {
            for i in 0..10 {
                tx.send(i).await;
            }
        });
        let mut got = Vec::new();
        while let Some(v) = rx.recv_blocking() {
            got.push(v);
        }
        h.join();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn try_send_full() {
        let (tx, rx) = channel::<u8>(1);
        assert!(tx.try_send(1).is_ok());
        assert!(tx.try_send(2).is_err());
        assert_eq!(rx.recv_blocking(), Some(1));
    }

    #[test]
    fn join_all_preserves_order() {
        let rt = Runtime::new(4);
        let handles: Vec<_> = (0..6)
            .map(|i| {
                rt.spawn(async move {
                    sleep(Duration::from_millis((6 - i) * 5)).await;
                    i
                })
            })
            .collect();
        let out = rt.block_on(join_all(handles));
        assert_eq!(out, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn yield_now_completes() {
        block_on(async {
            for _ in 0..100 {
                yield_now().await;
            }
        });
    }

    #[test]
    fn runtime_drop_joins_threads() {
        let rt = Runtime::new(3);
        let h = rt.spawn(async { 1 });
        assert_eq!(h.join(), 1);
        drop(rt); // must not hang
    }
}
