//! Network/storage timing substrate: latency distributions, a shared-link
//! bandwidth model, and a deterministic virtual-time FIFO queue.
//!
//! The paper's experiments are entirely driven by the latency/bandwidth
//! structure of the storage backend (S3 ≈ 100ms first-byte RTTs, NVMe ≈
//! sub-ms). We reproduce that structure with seeded distributions so the
//! who-wins shape of every figure replays deterministically.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::rng::Rng;

/// First-byte latency model for one request.
#[derive(Debug, Clone)]
pub enum LatencyModel {
    Zero,
    /// Fixed latency (seconds).
    Const(f64),
    /// Lognormal with given median (seconds) and shape sigma — the classic
    /// long-tail model for object-storage request latency.
    LogNormal { median: f64, sigma: f64 },
    /// Mixture: base lognormal plus occasional slow outliers
    /// (p_slow probability of multiplying by slow_factor) — matches the
    /// paper's observation of 0.01–0.43 s request times on S3.
    Mixture { median: f64, sigma: f64, p_slow: f64, slow_factor: f64 },
}

impl LatencyModel {
    pub fn sample(&self, rng: &mut Rng) -> Duration {
        let secs = match *self {
            LatencyModel::Zero => 0.0,
            LatencyModel::Const(s) => s,
            LatencyModel::LogNormal { median, sigma } => rng.lognormal(median, sigma),
            LatencyModel::Mixture { median, sigma, p_slow, slow_factor } => {
                let base = rng.lognormal(median, sigma);
                if rng.chance(p_slow) {
                    base * slow_factor
                } else {
                    base
                }
            }
        };
        Duration::from_secs_f64(secs.max(0.0))
    }

    /// Scale all latencies (the benchmark `Scale` knob).
    pub fn scaled(&self, f: f64) -> LatencyModel {
        match *self {
            LatencyModel::Zero => LatencyModel::Zero,
            LatencyModel::Const(s) => LatencyModel::Const(s * f),
            LatencyModel::LogNormal { median, sigma } => {
                LatencyModel::LogNormal { median: median * f, sigma }
            }
            LatencyModel::Mixture { median, sigma, p_slow, slow_factor } => {
                LatencyModel::Mixture { median: median * f, p_slow, sigma, slow_factor }
            }
        }
    }

    /// The distribution median in seconds (for reports).
    pub fn median_secs(&self) -> f64 {
        match *self {
            LatencyModel::Zero => 0.0,
            LatencyModel::Const(s) => s,
            LatencyModel::LogNormal { median, .. } => median,
            LatencyModel::Mixture { median, .. } => median,
        }
    }
}

/// A shared transmission link modeled as a virtual-time FIFO: each
/// reservation occupies `bytes / rate` of link time, reservations queue
/// behind each other. `reserve` returns how long the caller must wait
/// until its transfer completes — concurrency-safe and deterministic
/// given arrival order.
#[derive(Debug)]
pub struct Link {
    /// bytes per second
    rate: f64,
    next_free: Mutex<Option<Instant>>,
}

impl Link {
    pub fn new_mbit_s(mbit_s: f64) -> Link {
        Link {
            rate: mbit_s * 1024.0 * 1024.0 / 8.0,
            next_free: Mutex::new(None),
        }
    }

    pub fn rate_mbit_s(&self) -> f64 {
        self.rate * 8.0 / (1024.0 * 1024.0)
    }

    /// Reserve link time for `bytes`; returns the wait until completion.
    pub fn reserve(&self, bytes: u64) -> Duration {
        let now = Instant::now();
        let busy = Duration::from_secs_f64(bytes as f64 / self.rate);
        let mut nf = self.next_free.lock().unwrap();
        let start = match *nf {
            Some(t) if t > now => t,
            _ => now,
        };
        let done = start + busy;
        *nf = Some(done);
        done.saturating_duration_since(now)
    }

    /// Pure transfer time for `bytes` with no queueing.
    pub fn nominal(&self, bytes: u64) -> Duration {
        Duration::from_secs_f64(bytes as f64 / self.rate)
    }
}

/// Per-request total service time for a simulated remote store:
/// `first_byte + max(per_connection_stream_time, shared_link_time)`.
pub fn service_time(
    first_byte: Duration,
    per_conn: &Link,
    nic: &Link,
    bytes: u64,
) -> Duration {
    let stream = per_conn.nominal(bytes);
    let shared = nic.reserve(bytes);
    first_byte + stream.max(shared)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_latency() {
        let mut rng = Rng::new(1);
        let m = LatencyModel::Const(0.05);
        assert_eq!(m.sample(&mut rng), Duration::from_millis(50));
    }

    #[test]
    fn lognormal_median_close() {
        let mut rng = Rng::new(2);
        let m = LatencyModel::LogNormal { median: 0.120, sigma: 0.6 };
        let mut xs: Vec<f64> =
            (0..20001).map(|_| m.sample(&mut rng).as_secs_f64()).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[xs.len() / 2];
        assert!((med - 0.120).abs() < 0.015, "median {med}");
    }

    #[test]
    fn mixture_has_tail() {
        let mut rng = Rng::new(3);
        let m = LatencyModel::Mixture {
            median: 0.1,
            sigma: 0.3,
            p_slow: 0.05,
            slow_factor: 4.0,
        };
        let xs: Vec<f64> = (0..5000).map(|_| m.sample(&mut rng).as_secs_f64()).collect();
        let slow = xs.iter().filter(|x| **x > 0.3).count();
        assert!(slow > 50, "tail too small: {slow}");
    }

    #[test]
    fn scaling_scales_median() {
        let m = LatencyModel::LogNormal { median: 0.2, sigma: 0.5 }.scaled(0.25);
        assert!((m.median_secs() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn link_serializes_transfers() {
        // 8 Mbit/s = 1 MiB/s; two back-to-back 1 MiB reservations finish at
        // ~1 s and ~2 s.
        let link = Link::new_mbit_s(8.0);
        let w1 = link.reserve(1024 * 1024);
        let w2 = link.reserve(1024 * 1024);
        assert!((w1.as_secs_f64() - 1.0).abs() < 0.05, "{w1:?}");
        assert!((w2.as_secs_f64() - 2.0).abs() < 0.05, "{w2:?}");
    }

    #[test]
    fn link_idle_resets() {
        let link = Link::new_mbit_s(8000.0);
        let w1 = link.reserve(1024);
        std::thread::sleep(Duration::from_millis(5));
        let w2 = link.reserve(1024);
        assert!(w2 <= w1 + Duration::from_micros(100));
    }

    #[test]
    fn concurrent_ring_reads_queue_on_the_shared_link() {
        use std::sync::Arc;

        use crate::storage::{
            IoRing, MemStore, ObjectStore, ReadOp, RemoteProfile, SimRemoteStore,
        };

        // zero first-byte latency and a negligible per-stream time: every
        // request's service is pure shared-NIC transfer, so queueing on
        // the shared Link is the only thing this test can observe
        let profile = RemoteProfile {
            name: "nic-bound",
            first_byte: LatencyModel::Zero,
            per_conn_mbit_s: 80_000.0,
            nic_mbit_s: 8.0, // 1 MiB/s shared: 16 KiB ≈ 16 ms each
            max_conns: 64,
        };
        let n = 8usize;
        let mem = Arc::new(MemStore::new("m"));
        for i in 0..n {
            mem.put(&format!("k{i}"), vec![i as u8; 16 * 1024]).unwrap();
        }

        // sequential arm: the link drains between reads, so each read's
        // reservation starts on an idle link and never queues
        let store: Arc<dyn ObjectStore> =
            SimRemoteStore::new(mem.clone(), profile.clone(), 7);
        let seq = IoRing::new(store, n);
        // warm read: executor spawn-up stays off the measured reads
        let mut sub = seq.submit(vec![ReadOp::whole(0, "k0".into(), Vec::new())]);
        sub.next().unwrap().result.unwrap();
        let mut seq_max = 0.0f64;
        for i in 0..n {
            let t0 = Instant::now();
            let mut sub =
                seq.submit(vec![ReadOp::whole(0, format!("k{i}"), Vec::new())]);
            sub.next().unwrap().result.unwrap();
            seq_max = seq_max.max(t0.elapsed().as_secs_f64());
        }

        // concurrent arm: one batch, all n arrive at once and stack up
        // in the link's virtual-time FIFO — the last completion pays
        // ~n transfer times even though nothing else changed
        let store: Arc<dyn ObjectStore> = SimRemoteStore::new(mem, profile, 7);
        let ring = IoRing::new(store, n);
        let mut sub = ring.submit(vec![ReadOp::whole(0, "k0".into(), Vec::new())]);
        sub.next().unwrap().result.unwrap();
        let ops = (0..n)
            .map(|i| ReadOp::whole(i, format!("k{i}"), Vec::new()))
            .collect();
        let t0 = Instant::now();
        let mut sub = ring.submit(ops);
        let mut conc_max = 0.0f64;
        let mut reaped = 0;
        while let Some(c) = sub.next() {
            c.result.unwrap();
            conc_max = conc_max.max(t0.elapsed().as_secs_f64());
            reaped += 1;
        }
        assert_eq!(reaped, n);
        assert!(
            conc_max > seq_max * 3.0,
            "no shared-link queueing: concurrent max {conc_max:.3}s vs \
             sequential max {seq_max:.3}s over {n} reads"
        );
    }

    #[test]
    fn service_time_takes_max() {
        let per_conn = Link::new_mbit_s(8.0); // 1 MiB/s -> 1 s for 1 MiB
        let nic = Link::new_mbit_s(8000.0); // effectively instant
        let t = service_time(
            Duration::from_millis(100),
            &per_conn,
            &nic,
            1024 * 1024,
        );
        assert!(t >= Duration::from_millis(1050), "{t:?}");
    }
}
