//! Sampler-ahead prefetch engine with tiered caching.
//!
//! The paper hides *in-batch* latency (threaded / asyncio fetchers), but
//! nothing in the seed pipeline fetches **ahead of demand**: every epoch
//! still pays full first-byte latency on cold keys. This subsystem adds
//! the missing layer, following the design argument of "Hiding Latencies
//! in Network-Based Image Loading for Deep Learning" (Versaci &
//! Busonera, 2025) and MinatoLoader (Nouaji et al., 2025): the sampler
//! already fixes the epoch's access order, so a loader on high-latency
//! storage should be fetching the *next* items while the trainer consumes
//! the current ones.
//!
//! Components:
//!
//! * [`PrefetchStore`] — a composable [`ObjectStore`] wrapper. Stack it
//!   over any store (`SimRemoteStore`, `VarnishCache`, `DirStore`); the
//!   wrapped store becomes the **warm tier**, and speculative fetches
//!   land in an in-memory **hot tier** ([`tier::HotTier`]).
//! * [`engine`] — the background scheduler: consumes the epoch order
//!   published by `dataloader::sampler` (via `ObjectStore::hint_order`),
//!   issues GETs on an `asyncrt` runtime through a bounded in-flight
//!   window, preempts speculation while demand misses are outstanding,
//!   and ages the gate so speculation is never starved.
//! * [`tier`] — the hot tier: a facade over the unified O(1) eviction
//!   core (`crate::storage::evict`) with pluggable policies — LRU, 2Q
//!   with a ghost list, and a simplified S3-FIFO.
//!
//! Wiring: `DataloaderConfig { prefetch_depth, prefetch_policy, .. }`
//! selects the engine from experiment configs (`prefetch_depth = 0`
//! disables speculation; the hot tier still caches demand fetches).
//! `Dataloader::epoch` publishes the sampler order each epoch, so
//! shuffled epochs re-steer the engine automatically. Per-tier hit/miss
//! and engine counters surface through [`PrefetchStore::report`] /
//! [`PrefetchStore::summary_table`] and, when a `telemetry::Recorder` is
//! attached, as `prefetch_fetch` / `prefetch_wait` spans.

pub mod engine;
pub mod tier;

pub use engine::CounterSnapshot;
pub use tier::{CachePolicy, TierStats};

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::asyncrt;
use crate::storage::{BoxFut, Bytes, IoRing, ObjectStore, ReadOp, RingCtx, StoreStats};
use crate::telemetry::{names, Recorder};
use crate::util::table::Table;

use engine::Shared;

/// Prefetch engine configuration.
#[derive(Debug, Clone)]
pub struct PrefetchConfig {
    /// readahead window in sampler positions (0 = no speculation; the
    /// hot tier still caches demand fetches)
    pub depth: usize,
    /// max concurrent background GETs
    pub max_inflight: usize,
    /// hot-tier capacity in bytes
    pub hot_bytes: u64,
    /// hot-tier admission/eviction policy (lru | 2q | s3fifo)
    pub policy: CachePolicy,
    /// 2Q ghost-list capacity (keys remembered after probation eviction)
    pub ghost_capacity: usize,
    /// threads backing the engine's async runtime (GETs overlap via the
    /// async path, so a couple of threads drive many in-flight requests)
    pub runtime_threads: usize,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        PrefetchConfig {
            depth: 64,
            max_inflight: 16,
            hot_bytes: 256 << 20,
            policy: CachePolicy::Lru,
            ghost_capacity: 4096,
            runtime_threads: 2,
        }
    }
}

/// Per-tier view of a running [`PrefetchStore`] (hot = in-memory tier,
/// warm = the wrapped store's own counters).
#[derive(Debug, Clone)]
pub struct PrefetchReport {
    pub engine: CounterSnapshot,
    pub hot: TierStats,
    pub warm: StoreStats,
    pub warm_label: String,
    pub inflight_now: usize,
    pub queued_now: usize,
}

/// A composable `ObjectStore` that prefetches the sampler's upcoming
/// keys into a tiered cache. See the module docs.
pub struct PrefetchStore {
    shared: Arc<Shared>,
    /// keep-alive handle for the engine's runtime; dropped (joining the
    /// runtime workers) after the scheduler thread is joined in `Drop`
    _rt: Arc<asyncrt::Runtime>,
    scheduler: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl PrefetchStore {
    pub fn new(inner: Arc<dyn ObjectStore>, cfg: PrefetchConfig) -> Arc<PrefetchStore> {
        let shared = Arc::new(Shared {
            inner,
            state: Mutex::new(engine::State::new(&cfg)),
            cv: std::sync::Condvar::new(),
            counters: engine::Counters::default(),
            depth: std::sync::atomic::AtomicUsize::new(cfg.depth),
            cfg: cfg.clone(),
            recorder: Mutex::new(None),
            ring: Mutex::new(None),
        });
        let rt = asyncrt::Runtime::new(cfg.runtime_threads.max(1));
        let scheduler = engine::spawn_scheduler(shared.clone(), rt.clone());
        Arc::new(PrefetchStore {
            shared,
            _rt: rt,
            scheduler: Mutex::new(Some(scheduler)),
        })
    }

    /// Attach a span recorder (`prefetch_fetch` / `prefetch_wait`).
    pub fn set_recorder(&self, recorder: Arc<Recorder>) {
        *self.shared.recorder.lock().unwrap() = Some(recorder);
    }

    /// Route speculative fetches through a shared [`IoRing`]: the
    /// engine's background GETs then run on the ring's executor, gated
    /// by its `io_depth` semaphore and counted in its in-flight
    /// gauges, instead of drawing on a private runtime budget.
    pub fn set_ring(&self, ring: Arc<IoRing>) {
        *self.shared.ring.lock().unwrap() = Some(ring);
    }

    pub fn config(&self) -> &PrefetchConfig {
        &self.shared.cfg
    }

    /// Live readahead depth (items). Seeded from `cfg.depth`.
    pub fn depth(&self) -> usize {
        self.shared.depth()
    }

    /// Resize the readahead window live (the Governor's epoch-seam
    /// `prefetch_depth` applier). Deepening lets the scheduler issue
    /// further ahead on its next pass; narrowing just stops new issues
    /// past the tighter horizon — in-flight fetches are unaffected.
    pub fn set_depth(&self, depth: usize) {
        self.shared.depth.store(depth, std::sync::atomic::Ordering::Relaxed);
        // the scheduler may be parked Idle against the old horizon
        self.shared.cv.notify_all();
    }

    /// Engine counter snapshot (cheap; atomics).
    pub fn counters(&self) -> CounterSnapshot {
        self.shared.counters.snapshot()
    }

    /// Fraction of demand lookups served without paying warm-tier
    /// latency in the caller (hot hits + waits on in-flight fetches).
    pub fn hit_ratio(&self) -> f64 {
        self.counters().hit_ratio()
    }

    /// Re-verify the hot tier's eviction-core accounting (O(entries);
    /// for tests and stress suites).
    pub fn audit(&self) -> std::result::Result<(), String> {
        self.shared.state.lock().unwrap().hot.audit()
    }

    /// Full per-tier report.
    pub fn report(&self) -> PrefetchReport {
        let st = self.shared.state.lock().unwrap();
        PrefetchReport {
            engine: self.shared.counters.snapshot(),
            hot: st.hot.stats(),
            warm: self.shared.inner.stats(),
            warm_label: self.shared.inner.label(),
            inflight_now: st.inflight.len(),
            queued_now: st.queue.len(),
        }
    }

    /// Per-tier hit/miss/in-flight counter table for reports.
    pub fn summary_table(&self, title: &str) -> Table {
        let r = self.report();
        let mut t = Table::new(
            title,
            &["tier", "gets", "hits", "misses", "hit %", "evictions", "notes"],
        );
        t.row(&[
            "hot (mem)".to_string(),
            r.engine.gets.to_string(),
            (r.engine.hot_hits + r.engine.inflight_hits).to_string(),
            r.engine.demand_misses.to_string(),
            format!("{:.1}", 100.0 * r.engine.hit_ratio()),
            r.hot.evictions.to_string(),
            format!(
                "{} prefetched, {} in flight, {} stale, {} ghosts, \
                 {} ghost promotions",
                r.engine.completed, r.inflight_now, r.engine.stale,
                r.hot.ghost_entries, r.hot.ghost_promotions
            ),
        ]);
        let warm_total = r.warm.hits + r.warm.misses;
        t.row(&[
            format!("warm ({})", r.warm_label),
            r.warm.gets.to_string(),
            r.warm.hits.to_string(),
            r.warm.misses.to_string(),
            if warm_total > 0 {
                format!("{:.1}", 100.0 * r.warm.hits as f64 / warm_total as f64)
            } else {
                "-".to_string()
            },
            r.warm.evictions.to_string(),
            String::new(),
        ]);
        t
    }

    /// Advance the sampler cursor for a demanded key (wakes the
    /// scheduler so the readahead window slides forward). With a
    /// pipelined horizon a key can appear once per hinted epoch; the
    /// cursor moves toward just past the *earliest position not yet
    /// passed* — the one this demand access corresponds to. Each
    /// advance is **clamped to one readahead window**: a straggling
    /// out-of-order demand whose own-epoch position was already passed
    /// would otherwise match its *next-epoch* position and catapult the
    /// cursor across the seam, mass-staling the current tail's
    /// readahead. Clamping (rather than refusing) keeps progress
    /// monotone — every demand at or past the cursor moves it, so a
    /// demand burst wider than the window can never freeze it; the
    /// cursor just converges over the next few demands.
    fn advance_cursor(st: &mut engine::State, key: &str, depth: usize) {
        if let Some(positions) = st.pos_of.get(key) {
            if let Some(&pos) = positions.iter().find(|&&p| p >= st.cursor) {
                st.cursor = (pos + 1).min(st.cursor + depth.max(1));
            }
        }
    }

    fn served(&self, data: &Bytes) {
        self.shared.counters.bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
    }

    /// Append `keys` to the speculation horizon at the next free
    /// positions (position space is continuous across appended epochs).
    fn extend_horizon(st: &mut engine::State, keys: &[String]) {
        let base = st.horizon;
        for (i, key) in keys.iter().enumerate() {
            let pos = base + i;
            st.pos_of.entry(key.clone()).or_default().push(pos);
            st.seq += 1;
            let seq = st.seq;
            st.queue.push(std::cmp::Reverse((pos, seq, key.clone())));
        }
        st.horizon = base + keys.len();
    }
}

/// RAII decrement for `pending_demand`: the increment happens under the
/// state lock, but the demand fetch itself runs unlocked (and, on the
/// async path, across an await where the caller may drop the future) —
/// the guard guarantees the speculation gate reopens on every exit path.
struct DemandGuard<'a> {
    sh: &'a Shared,
}

impl Drop for DemandGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.sh.state.lock().unwrap();
        st.pending_demand -= 1;
        drop(st);
        self.sh.cv.notify_all();
    }
}

impl ObjectStore for PrefetchStore {
    fn get(&self, key: &str) -> Result<Bytes> {
        let sh = &self.shared;
        sh.counters.gets.fetch_add(1, Ordering::Relaxed);
        let recorder = sh.recorder();

        let mut st = sh.state.lock().unwrap();
        Self::advance_cursor(&mut st, key, sh.depth());
        if let Some(hit) = st.hot.get(key) {
            sh.counters.hot_hits.fetch_add(1, Ordering::Relaxed);
            drop(st);
            sh.cv.notify_all(); // cursor moved: window may slide
            self.served(&hit);
            return Ok(hit);
        }
        if st.inflight.contains(key) {
            // a speculative fetch is already paying this latency — wait
            // for it instead of issuing a duplicate GET
            let t0 = recorder.as_ref().map(|r| r.now());
            while st.inflight.contains(key) && !st.shutdown {
                st = sh.cv.wait(st).unwrap();
            }
            // uncounted: still the same logical lookup as the miss above
            if let Some(hit) = st.hot.peek(key) {
                sh.counters.inflight_hits.fetch_add(1, Ordering::Relaxed);
                drop(st);
                if let (Some(r), Some(t0)) = (&recorder, t0) {
                    r.record(names::PREFETCH_WAIT, engine::ENGINE_WORKER, -1, t0, r.now());
                }
                sh.cv.notify_all();
                self.served(&hit);
                return Ok(hit);
            }
            // the background fetch errored (or the entry was rejected /
            // already evicted): fall through to a demand fetch
        }
        sh.counters.demand_misses.fetch_add(1, Ordering::Relaxed);
        st.pending_demand += 1; // preempts speculative issuance
        drop(st);
        let guard = DemandGuard { sh };
        let res = sh.inner.get(key);
        if let Ok(data) = &res {
            let mut st = sh.state.lock().unwrap();
            st.hot.insert(key, data.clone());
        }
        drop(guard); // reopen the speculation gate (+ notify)
        if let Ok(data) = &res {
            self.served(data);
        }
        res
    }

    fn get_async<'a>(&'a self, key: &'a str) -> BoxFut<'a, Result<Bytes>> {
        Box::pin(async move {
            let sh = &self.shared;
            sh.counters.gets.fetch_add(1, Ordering::Relaxed);
            {
                let mut st = sh.state.lock().unwrap();
                Self::advance_cursor(&mut st, key, sh.depth());
            }
            sh.cv.notify_all();

            enum Step {
                Hit(Bytes),
                Wait,
                Fetch,
            }
            let mut waited = false;
            loop {
                let step = {
                    let mut st = sh.state.lock().unwrap();
                    // count the tier lookup once; poll iterations re-check
                    // the same logical lookup uncounted
                    let hit = if waited { st.hot.peek(key) } else { st.hot.get(key) };
                    if let Some(hit) = hit {
                        Step::Hit(hit)
                    } else if st.inflight.contains(key) {
                        Step::Wait
                    } else {
                        st.pending_demand += 1;
                        Step::Fetch
                    }
                };
                match step {
                    Step::Hit(hit) => {
                        let ctr = if waited {
                            &sh.counters.inflight_hits
                        } else {
                            &sh.counters.hot_hits
                        };
                        ctr.fetch_add(1, Ordering::Relaxed);
                        self.served(&hit);
                        return Ok(hit);
                    }
                    Step::Wait => {
                        // async demand wait: poll the in-flight set on the
                        // timer (the engine has no per-key future to await)
                        waited = true;
                        asyncrt::sleep(Duration::from_micros(500)).await;
                    }
                    Step::Fetch => {
                        sh.counters.demand_misses.fetch_add(1, Ordering::Relaxed);
                        // the guard reopens the gate even if this future
                        // is dropped mid-await (timeout/select)
                        let guard = DemandGuard { sh };
                        let res = sh.inner.get_async(key).await;
                        if let Ok(data) = &res {
                            let mut st = sh.state.lock().unwrap();
                            st.hot.insert(key, data.clone());
                        }
                        drop(guard);
                        if let Ok(data) = &res {
                            self.served(data);
                        }
                        return res;
                    }
                }
            }
        })
    }

    fn get_into(&self, key: &str, out: &mut [u8]) -> Result<usize> {
        let sh = &self.shared;
        sh.counters.gets.fetch_add(1, Ordering::Relaxed);

        let mut st = sh.state.lock().unwrap();
        Self::advance_cursor(&mut st, key, sh.depth());
        // hot hit (or an in-flight speculative fetch about to become
        // one): serve by copy-out of the tier's shared Bytes
        let hit = if let Some(hit) = st.hot.get(key) {
            sh.counters.hot_hits.fetch_add(1, Ordering::Relaxed);
            Some(hit)
        } else if st.inflight.contains(key) {
            while st.inflight.contains(key) && !st.shutdown {
                st = sh.cv.wait(st).unwrap();
            }
            let hit = st.hot.peek(key);
            if hit.is_some() {
                sh.counters.inflight_hits.fetch_add(1, Ordering::Relaxed);
            }
            hit
        } else {
            None
        };
        if let Some(hit) = hit {
            drop(st);
            sh.cv.notify_all(); // cursor moved: window may slide
            let n = hit.len();
            if n <= out.len() {
                out[..n].copy_from_slice(&hit);
                self.served(&hit);
            }
            return Ok(n);
        }
        // demand miss: delegate straight down into the caller's buffer,
        // then admit the object into the hot tier from the borrowed
        // slice (the tier copies once for itself; the caller's scratch
        // stays caller-owned). Size probes transfer nothing and admit
        // nothing.
        sh.counters.demand_misses.fetch_add(1, Ordering::Relaxed);
        st.pending_demand += 1; // preempts speculative issuance
        drop(st);
        let guard = DemandGuard { sh };
        let res = sh.inner.get_into(key, out);
        drop(guard); // reopen the speculation gate (+ notify)
        if let Ok(n) = &res {
            if *n <= out.len() {
                sh.counters.bytes.fetch_add(*n as u64, Ordering::Relaxed);
                let mut st = sh.state.lock().unwrap();
                st.hot.insert(key, Bytes::new(out[..*n].to_vec()));
            }
        }
        res
    }

    fn get_range_into(&self, key: &str, offset: u64, out: &mut [u8]) -> Result<usize> {
        // the shard-window path: speculative whole-object fetches land
        // in the hot tier via `hint_order`, and a ranged demand read of
        // a resident (or in-flight) object is served by slicing the
        // tier's shared Bytes — no warm-tier round trip. A true miss
        // delegates the range straight down; the partial bytes are NOT
        // admitted (a range under the full-object key would poison
        // later full reads).
        let sh = &self.shared;
        sh.counters.gets.fetch_add(1, Ordering::Relaxed);
        let mut st = sh.state.lock().unwrap();
        Self::advance_cursor(&mut st, key, sh.depth());
        let hit = if let Some(hit) = st.hot.get(key) {
            sh.counters.hot_hits.fetch_add(1, Ordering::Relaxed);
            Some(hit)
        } else if st.inflight.contains(key) {
            while st.inflight.contains(key) && !st.shutdown {
                st = sh.cv.wait(st).unwrap();
            }
            let hit = st.hot.peek(key);
            if hit.is_some() {
                sh.counters.inflight_hits.fetch_add(1, Ordering::Relaxed);
            }
            hit
        } else {
            None
        };
        if let Some(hit) = hit {
            drop(st);
            sh.cv.notify_all(); // cursor moved: window may slide
            let n = crate::storage::range_from_bytes(&hit, key, offset, out)?;
            sh.counters.bytes.fetch_add(n as u64, Ordering::Relaxed);
            return Ok(n);
        }
        sh.counters.demand_misses.fetch_add(1, Ordering::Relaxed);
        st.pending_demand += 1; // preempts speculative issuance
        drop(st);
        let guard = DemandGuard { sh };
        let res = sh.inner.get_range_into(key, offset, out);
        drop(guard); // reopen the speculation gate (+ notify)
        if let Ok(n) = &res {
            sh.counters.bytes.fetch_add(*n as u64, Ordering::Relaxed);
        }
        res
    }

    fn native_get_into(&self) -> bool {
        // forwarded since the `get_into` miss path now admits from the
        // caller's borrowed slice: a dir-backed stack keeps the
        // zero-copy pread read *and* warms the hot tier on demand, not
        // only via speculation.
        self.shared.inner.native_get_into()
    }

    /// Ring path: serve hot-tier hits by copy immediately, delegate the
    /// remaining descriptors down the stack as one (smaller) batch so
    /// misses keep their concurrency. Batch completions are reaped
    /// asynchronously, so misses do NOT raise `pending_demand` (there
    /// is no per-op completion hook to lower it) — the ring's own
    /// `io_depth` semaphore bounds how hard a batch can compete with
    /// speculation. In-flight speculative fetches are likewise not
    /// awaited (blocking a submit on the scheduler would serialize the
    /// whole batch); the key is simply fetched again below, and the
    /// miss bytes are not admitted here — blocking demand traffic and
    /// speculation keep the tier warm.
    fn submit_batch(self: Arc<Self>, ops: Vec<ReadOp>, ctx: RingCtx) {
        let sh = &self.shared;
        let mut misses: Vec<ReadOp> = Vec::new();
        let mut moved = false;
        for op in ops {
            sh.counters.gets.fetch_add(1, Ordering::Relaxed);
            let hit = {
                let mut st = sh.state.lock().unwrap();
                Self::advance_cursor(&mut st, &op.key, sh.depth());
                moved = true;
                st.hot.get(&op.key)
            };
            match hit {
                Some(hit) => {
                    sh.counters.hot_hits.fetch_add(1, Ordering::Relaxed);
                    let ReadOp { slot, key, offset, len, mut buf } = op;
                    ctx.begin();
                    let res = if len > 0 {
                        buf.resize(len, 0);
                        crate::storage::range_from_bytes(&hit, &key, offset, &mut buf)
                    } else {
                        buf.clear();
                        buf.extend_from_slice(&hit);
                        Ok(hit.len())
                    };
                    if let Ok(n) = &res {
                        sh.counters.bytes.fetch_add(*n as u64, Ordering::Relaxed);
                    }
                    ctx.complete(slot, key, buf, res);
                }
                None => {
                    sh.counters.demand_misses.fetch_add(1, Ordering::Relaxed);
                    misses.push(op);
                }
            }
        }
        if moved {
            sh.cv.notify_all(); // cursor moved: window may slide
        }
        if !misses.is_empty() {
            sh.inner.clone().submit_batch(misses, ctx);
        }
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.shared.inner.put(key, data)?;
        // best-effort invalidation of any speculative/hot copy (an
        // in-flight fetch or racing demand miss may still land the old
        // bytes; that is the usual cache/write race, not lost accounting)
        self.shared.state.lock().unwrap().hot.remove(key);
        Ok(())
    }

    fn keys(&self) -> Vec<String> {
        self.shared.inner.keys()
    }

    fn contains(&self, key: &str) -> bool {
        self.shared.state.lock().unwrap().hot.contains(key)
            || self.shared.inner.contains(key)
    }

    fn label(&self) -> String {
        format!("prefetch({})", self.shared.inner.label())
    }

    fn stats(&self) -> StoreStats {
        let c = self.counters();
        let hot = self.shared.state.lock().unwrap().hot.stats();
        StoreStats {
            gets: c.gets,
            bytes: c.bytes,
            hits: c.hot_hits + c.inflight_hits,
            misses: c.demand_misses,
            evictions: hot.evictions,
        }
    }

    fn hint_order(&self, epoch: usize, keys: &[String]) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.cursor = 0;
            st.horizon = 0;
            st.pos_of.clear();
            st.queue.clear();
            Self::extend_horizon(&mut st, keys);
        }
        self.shared.cv.notify_all();
        // forward down the stack (harmless for plain stores, lets a
        // nested prefetch layer see the order too)
        self.shared.inner.hint_order(epoch, keys);
    }

    fn hint_order_append(&self, epoch: usize, keys: &[String]) {
        {
            let mut st = self.shared.state.lock().unwrap();
            // prune positions the consumer has already passed so the
            // per-key lists stay O(epochs in flight), not O(all epochs)
            let cursor = st.cursor;
            st.pos_of.retain(|_, positions| {
                positions.retain(|&p| p >= cursor);
                !positions.is_empty()
            });
            Self::extend_horizon(&mut st, keys);
        }
        self.shared.cv.notify_all();
        self.shared.inner.hint_order_append(epoch, keys);
    }
}

impl Drop for PrefetchStore {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        if let Some(h) = self.scheduler.lock().unwrap().take() {
            let _ = h.join();
        }
        // self._rt drops afterwards on this thread, joining the runtime
        // workers; in-flight tasks hold Shared but never the runtime.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{MemStore, RemoteProfile, SimRemoteStore};
    use std::time::Instant;

    fn corpus(n: usize, size: usize) -> Arc<MemStore> {
        let m = Arc::new(MemStore::new("backing"));
        for i in 0..n {
            m.put(&key(i), vec![i as u8; size]).unwrap();
        }
        m
    }

    fn key(i: usize) -> String {
        format!("k{i:03}")
    }

    fn order(n: usize) -> Vec<String> {
        (0..n).map(key).collect()
    }

    fn wait_until(deadline_ms: u64, mut done: impl FnMut() -> bool) -> bool {
        let t0 = Instant::now();
        while t0.elapsed() < Duration::from_millis(deadline_ms) {
            if done() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        done()
    }

    #[test]
    fn demand_path_works_without_hints() {
        let p = PrefetchStore::new(corpus(4, 100), PrefetchConfig::default());
        let d = p.get(&key(0)).unwrap();
        assert_eq!(d.len(), 100);
        // second access is a hot hit (tiered-cache-only mode)
        p.get(&key(0)).unwrap();
        let c = p.counters();
        assert_eq!(c.gets, 2);
        assert_eq!(c.demand_misses, 1);
        assert_eq!(c.hot_hits, 1);
        assert!(p.get("missing").is_err());
    }

    #[test]
    fn hint_order_prefetches_ahead() {
        let p = PrefetchStore::new(
            corpus(16, 64),
            PrefetchConfig { depth: 16, ..Default::default() },
        );
        p.hint_order(0, &order(16));
        assert!(
            wait_until(2000, || p.counters().completed >= 16),
            "engine never prefetched: {:?}",
            p.counters()
        );
        // every demand access is now a hot hit
        for i in 0..16 {
            p.get(&key(i)).unwrap();
        }
        let c = p.counters();
        assert_eq!(c.hot_hits, 16, "{c:?}");
        assert_eq!(c.demand_misses, 0, "{c:?}");
    }

    #[test]
    fn depth_zero_never_speculates() {
        let p = PrefetchStore::new(
            corpus(8, 64),
            PrefetchConfig { depth: 0, ..Default::default() },
        );
        p.hint_order(0, &order(8));
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(p.counters().issued, 0);
    }

    #[test]
    fn window_limits_speculation() {
        let p = PrefetchStore::new(
            corpus(32, 64),
            PrefetchConfig { depth: 4, ..Default::default() },
        );
        p.hint_order(0, &order(32));
        assert!(wait_until(2000, || p.counters().completed >= 4));
        // without cursor movement, only [0, 4) may be fetched
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(p.counters().issued, 4, "{:?}", p.counters());
        // consuming position 0 slides the window by one
        p.get(&key(0)).unwrap();
        assert!(
            wait_until(2000, || p.counters().issued >= 5),
            "window did not slide: {:?}",
            p.counters()
        );
    }

    #[test]
    fn hides_simulated_remote_latency() {
        let remote = SimRemoteStore::new(
            corpus(24, 10 * 1024),
            RemoteProfile::s3().scaled(0.25),
            11,
        );
        let p = PrefetchStore::new(
            remote,
            PrefetchConfig { depth: 24, max_inflight: 24, ..Default::default() },
        );
        p.hint_order(0, &order(24));
        assert!(wait_until(10_000, || p.counters().completed >= 24));
        let t0 = Instant::now();
        for i in 0..24 {
            p.get(&key(i)).unwrap();
        }
        // 24 sequential s3 GETs at scale 0.25 would be ≫ 500 ms
        assert!(
            t0.elapsed() < Duration::from_millis(100),
            "hot drain too slow: {:?}",
            t0.elapsed()
        );
        assert!(p.hit_ratio() > 0.9, "{:?}", p.counters());
    }

    #[test]
    fn async_demand_path_matches_sync() {
        let p = PrefetchStore::new(corpus(4, 128), PrefetchConfig::default());
        let via_async =
            crate::asyncrt::block_on(p.get_async(&key(1))).unwrap();
        let via_sync = p.get(&key(1)).unwrap();
        assert_eq!(via_async, via_sync);
        let c = p.counters();
        assert_eq!(c.gets, 2);
        assert_eq!(c.hot_hits, 1);
    }

    #[test]
    fn stats_and_label_compose() {
        let p = PrefetchStore::new(corpus(2, 50), PrefetchConfig::default());
        p.get(&key(0)).unwrap();
        p.get(&key(0)).unwrap();
        let s = p.stats();
        assert_eq!(s.gets, 2);
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
        assert_eq!(s.bytes, 100);
        assert_eq!(p.label(), "prefetch(backing)");
        assert!(p.contains(&key(0)));
        assert!(!p.contains("nope"));
    }

    #[test]
    fn summary_table_has_both_tiers() {
        let p = PrefetchStore::new(corpus(2, 50), PrefetchConfig::default());
        p.get(&key(0)).unwrap();
        let t = p.summary_table("tiers");
        assert_eq!(t.rows.len(), 2);
        assert!(t.rows[0][0].starts_with("hot"));
        assert!(t.rows[1][0].starts_with("warm"));
    }

    #[test]
    fn hint_order_append_extends_the_horizon_across_epochs() {
        // epoch 0 hinted, partially consumed; appending epoch 1's order
        // must extend the position space past epoch 0's tail — not
        // reset the cursor or drop the tail
        let p = PrefetchStore::new(
            corpus(8, 64),
            PrefetchConfig { depth: 6, ..Default::default() },
        );
        p.hint_order(0, &order(8));
        assert!(wait_until(2000, || p.counters().completed >= 6));
        // consume the first half of epoch 0: cursor lands on 4
        for i in 0..4 {
            p.get(&key(i)).unwrap();
        }
        // next epoch's order arrives while epoch 0 is still in flight
        // (reversed, so every key holds a different position per epoch)
        let mut next: Vec<String> = order(8);
        next.reverse();
        p.hint_order_append(1, &next);
        {
            let st = p.shared.state.lock().unwrap();
            assert_eq!(st.horizon, 16, "appended epoch must extend positions");
            assert_eq!(st.cursor, 4, "append must not reset the cursor");
            // key 7 keeps its un-passed epoch-0 position and gains its
            // epoch-1 one; key 0's passed position is pruned
            assert_eq!(st.pos_of[&key(7)], vec![7, 8]);
            assert_eq!(st.pos_of[&key(0)], vec![15]);
        }
        // the rolling window now reaches epoch 0's tail keys — wait for
        // them, then drain both epochs entirely from the hot tier
        assert!(
            wait_until(2000, || p.counters().completed >= 8),
            "horizon did not extend: {:?}",
            p.counters()
        );
        for i in 4..8 {
            p.get(&key(i)).unwrap();
        }
        for k in &next {
            p.get(k).unwrap();
        }
        let c = p.counters();
        assert_eq!(c.gets, 16, "{c:?}");
        assert_eq!(c.demand_misses, 0, "append reset the engine: {c:?}");
    }

    #[test]
    fn get_into_miss_admits_from_borrowed_slice() {
        let p = PrefetchStore::new(corpus(2, 100), PrefetchConfig::default());
        let mut buf = vec![0u8; 128];
        assert_eq!(p.get_into(&key(0), &mut buf).unwrap(), 100);
        // the miss populated the hot tier from the caller's scratch:
        // the next lookup is a hit
        assert_eq!(p.get_into(&key(0), &mut buf).unwrap(), 100);
        let c = p.counters();
        assert_eq!(c.demand_misses, 1, "{c:?}");
        assert_eq!(c.hot_hits, 1, "{c:?}");
        // size probes (too-small buffer) admit nothing
        let mut tiny = vec![0u8; 4];
        assert_eq!(p.get_into(&key(1), &mut tiny).unwrap(), 100);
        assert!(!p.shared.state.lock().unwrap().hot.contains(&key(1)));
    }

    #[test]
    fn ranged_read_slices_the_hot_tier_without_warm_round_trips() {
        let p = PrefetchStore::new(
            corpus(4, 100),
            PrefetchConfig { depth: 4, ..Default::default() },
        );
        p.hint_order(0, &order(4));
        assert!(wait_until(2000, || p.counters().completed >= 4));
        let warm_gets_before = p.report().warm.gets;
        let mut out = vec![0u8; 10];
        assert_eq!(p.get_range_into(&key(1), 20, &mut out).unwrap(), 10);
        assert!(out.iter().all(|&b| b == 1), "wrong window bytes: {out:?}");
        assert_eq!(
            p.report().warm.gets,
            warm_gets_before,
            "resident ranged read paid a warm-tier round trip"
        );
        assert_eq!(p.counters().hot_hits, 1);
        // a miss delegates the range down without admitting the partial
        let p = PrefetchStore::new(corpus(2, 100), PrefetchConfig::default());
        assert_eq!(p.get_range_into(&key(0), 50, &mut out).unwrap(), 10);
        assert_eq!(p.counters().demand_misses, 1);
        assert!(!p.shared.state.lock().unwrap().hot.contains(&key(0)));
    }

    #[test]
    fn native_get_into_forwards_from_the_inner_store() {
        // shared-Bytes backing (MemStore): no native path, so the
        // facade reports none either; the admission change makes
        // forwarding safe for stores that do have one (DirStore)
        let p = PrefetchStore::new(corpus(1, 10), PrefetchConfig::default());
        assert!(!p.native_get_into());
        let root = std::env::temp_dir()
            .join(format!("cdl-prefetch-native-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let dir = Arc::new(crate::storage::DirStore::open(&root).unwrap());
        dir.put("k", vec![5u8; 32]).unwrap();
        let p = PrefetchStore::new(dir, PrefetchConfig::default());
        assert_eq!(p.native_get_into(), cfg!(unix));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn drop_shuts_down_cleanly_with_queued_work() {
        let p = PrefetchStore::new(
            corpus(64, 256),
            PrefetchConfig { depth: 64, max_inflight: 2, ..Default::default() },
        );
        p.hint_order(0, &order(64));
        drop(p); // must not hang or panic
    }
}
