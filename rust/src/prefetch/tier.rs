//! Hot cache tier with pluggable admission/eviction policies.
//!
//! The prefetch engine lands speculative fetches in a byte-capped
//! in-memory **hot tier**; whatever store it wraps (a `VarnishCache`, a
//! `DirStore`, a bare `SimRemoteStore`) acts as the warm tier below it.
//!
//! The tier is a thin facade over the unified eviction core
//! ([`crate::storage::evict::EvictCore`]) — the same intrusive O(1)
//! doubly-linked-list structure that backs `VarnishCache` — so victim
//! selection costs O(1) regardless of resident entry count (the old
//! per-eviction O(n) `min_by_key` scan over `last_used` ticks is gone).
//! Policies ([`CachePolicy`]): LRU, 2Q with a ghost list, and a
//! simplified S3-FIFO; see the core's module docs for the exact
//! semantics. Under the loader's shuffled scans the ghost-list policies
//! keep one-touch speculative fills from flushing genuinely re-used
//! objects — the standard scan-resistance argument.
//!
//! The tier is a plain (non-thread-safe) structure; the engine guards it
//! with its state mutex.

pub use crate::storage::evict::{CachePolicy, CoreStats as TierStats};

use crate::storage::evict::EvictCore;
use crate::storage::Bytes;

/// Byte-capped in-memory cache tier (see module docs for the policies).
pub struct HotTier {
    core: EvictCore,
}

impl HotTier {
    pub fn new(policy: CachePolicy, capacity_bytes: u64) -> HotTier {
        HotTier { core: EvictCore::new(policy, capacity_bytes) }
    }

    /// Cap the ghost list (keys remembered after probation eviction).
    pub fn with_ghost_capacity(mut self, n: usize) -> HotTier {
        self.core = self.core.with_ghost_capacity(n);
        self
    }

    pub fn contains(&self, key: &str) -> bool {
        self.core.contains(key)
    }

    pub fn len(&self) -> usize {
        self.core.len()
    }

    pub fn is_empty(&self) -> bool {
        self.core.is_empty()
    }

    pub fn bytes(&self) -> u64 {
        self.core.bytes()
    }

    pub fn capacity(&self) -> u64 {
        self.core.capacity()
    }

    pub fn stats(&self) -> TierStats {
        self.core.stats()
    }

    /// Counted lookup; a hit refreshes recency.
    pub fn get(&mut self, key: &str) -> Option<Bytes> {
        self.core.get(key)
    }

    /// Uncounted lookup for pollers re-checking the *same* logical
    /// lookup (a demand wait on an in-flight fetch): refreshes recency
    /// on hit but leaves the hit/miss counters alone, so tier stats
    /// stay one-count-per-lookup.
    pub fn peek(&mut self, key: &str) -> Option<Bytes> {
        self.core.peek(key)
    }

    /// Admit an object; returns the number of evictions performed.
    /// Objects larger than the whole tier are rejected outright.
    pub fn insert(&mut self, key: &str, data: Bytes) -> u64 {
        self.core.insert(key, data)
    }

    /// Forget `key` (invalidation on overwrite); returns whether an
    /// entry was removed.
    pub fn remove(&mut self, key: &str) -> bool {
        self.core.remove(key)
    }

    /// Re-verify the eviction core's internal accounting (O(entries);
    /// for tests and stress suites).
    pub fn audit(&self) -> Result<(), String> {
        self.core.audit()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(n: usize, fill: u8) -> Bytes {
        Bytes::new(vec![fill; n])
    }

    #[test]
    fn lru_eviction_order_respects_recency() {
        let mut t = HotTier::new(CachePolicy::Lru, 300);
        t.insert("k0", blob(100, 0));
        t.insert("k1", blob(100, 1));
        t.insert("k2", blob(100, 2));
        assert!(t.get("k0").is_some()); // k0 becomes most recent
        let evicted = t.insert("k3", blob(100, 3));
        assert_eq!(evicted, 1);
        assert!(t.contains("k0"), "recently-used survivor evicted");
        assert!(!t.contains("k1"), "LRU victim should be k1");
        assert!(t.contains("k2") && t.contains("k3"));
        assert!(t.bytes() <= 300);
    }

    #[test]
    fn never_exceeds_capacity_and_counts_evictions() {
        let mut t = HotTier::new(CachePolicy::Lru, 350);
        for i in 0..20 {
            t.insert(&format!("k{i}"), blob(100, i as u8));
            assert!(t.bytes() <= 350, "over cap: {}", t.bytes());
        }
        let s = t.stats();
        assert_eq!(s.insertions, 20);
        assert_eq!(s.evictions, 17); // 3 fit at a time
        assert_eq!(s.entries, 3);
        t.audit().unwrap();
    }

    #[test]
    fn oversized_object_rejected() {
        let mut t = HotTier::new(CachePolicy::Lru, 100);
        t.insert("big", blob(500, 9));
        assert!(!t.contains("big"));
        assert_eq!(t.bytes(), 0);
        assert_eq!(t.stats().insertions, 0);
    }

    #[test]
    fn hit_miss_counters() {
        let mut t = HotTier::new(CachePolicy::Lru, 1000);
        t.insert("a", blob(10, 0));
        assert!(t.get("a").is_some());
        assert!(t.get("a").is_some());
        assert!(t.get("b").is_none());
        let s = t.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert!((s.hit_ratio() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn reinsert_updates_in_place() {
        let mut t = HotTier::new(CachePolicy::Lru, 1000);
        t.insert("a", blob(100, 1));
        t.insert("a", blob(200, 2));
        assert_eq!(t.bytes(), 200);
        assert_eq!(t.len(), 1);
        assert_eq!(t.get("a").unwrap().len(), 200);
    }

    #[test]
    fn twoq_ghost_promotion() {
        // capacity fits two 100-byte objects
        let mut t = HotTier::new(CachePolicy::TwoQ, 200);
        t.insert("g", blob(100, 0)); // probation
        t.insert("a", blob(100, 1)); // probation
        t.insert("b", blob(100, 2)); // evicts g (LRU probation) → ghost
        assert!(!t.contains("g"));
        // re-admission hits the ghost list → promoted to main
        t.insert("g", blob(100, 3));
        assert_eq!(t.stats().ghost_promotions, 1);
        // further probation churn must not evict the promoted key:
        // probation ("a" or "b" whichever survived) drains first
        t.insert("c", blob(100, 4));
        t.insert("d", blob(100, 5));
        assert!(t.contains("g"), "main-queue key evicted before probation");
    }

    #[test]
    fn twoq_ghost_list_is_bounded() {
        let mut t = HotTier::new(CachePolicy::TwoQ, 100).with_ghost_capacity(2);
        for i in 0..6 {
            t.insert(&format!("k{i}"), blob(100, i as u8));
        }
        assert!(t.stats().ghost_entries <= 2);
        t.audit().unwrap();
    }

    #[test]
    fn lru_has_no_ghost_promotions() {
        let mut t = HotTier::new(CachePolicy::Lru, 100);
        t.insert("a", blob(100, 0));
        t.insert("b", blob(100, 1)); // evicts a
        t.insert("a", blob(100, 2)); // plain re-admission
        assert_eq!(t.stats().ghost_promotions, 0);
    }

    #[test]
    fn s3fifo_policy_runs_on_the_tier() {
        let mut t = HotTier::new(CachePolicy::S3Fifo, 300);
        for i in 0..8 {
            t.insert(&format!("k{i}"), blob(100, i as u8));
            assert!(t.bytes() <= 300);
        }
        assert!(t.stats().evictions > 0);
        t.audit().unwrap();
    }
}
