//! Hot cache tier with pluggable admission/eviction policies.
//!
//! The prefetch engine lands speculative fetches in a byte-capped
//! in-memory **hot tier**; whatever store it wraps (a `VarnishCache`, a
//! `DirStore`, a bare `SimRemoteStore`) acts as the warm tier below it.
//! Two policies are provided:
//!
//! * [`CachePolicy::Lru`] — plain least-recently-used eviction.
//! * [`CachePolicy::TwoQ`] — a simplified 2Q: new keys enter a
//!   *probation* queue; keys evicted from probation leave their name on a
//!   **ghost list** (no payload); a re-admitted ghost key is promoted
//!   straight to the *main* queue. Under the loader's shuffled scans this
//!   keeps one-touch speculative fills from flushing genuinely re-used
//!   objects — the standard scan-resistance argument.
//!
//! The tier is a plain (non-thread-safe) structure; the engine guards it
//! with its state mutex. Victim selection is an O(n) minimum scan over
//! `last_used` ticks — at loader scale (thousands of keys) this is far
//! cheaper than the storage latencies being hidden, and it keeps the
//! recency bookkeeping trivially correct.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::storage::Bytes;

/// Hot-tier admission/eviction policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePolicy {
    /// Least-recently-used over a single queue.
    Lru,
    /// Two-queue with a ghost list (probation → ghost → main promotion).
    TwoQ,
}

impl CachePolicy {
    pub fn by_name(name: &str) -> Option<CachePolicy> {
        match name {
            "lru" => Some(CachePolicy::Lru),
            "2q" | "twoq" => Some(CachePolicy::TwoQ),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            CachePolicy::Lru => "lru",
            CachePolicy::TwoQ => "2q",
        }
    }
}

/// Cumulative hot-tier counters plus current occupancy.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TierStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// 2Q only: re-admissions that hit the ghost list and went straight
    /// to the main queue
    pub ghost_promotions: u64,
    pub bytes: u64,
    pub capacity: u64,
    pub entries: u64,
}

impl TierStats {
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Queue {
    Probation,
    Main,
}

struct Slot {
    data: Bytes,
    last_used: u64,
    queue: Queue,
}

/// Byte-capped in-memory cache tier (see module docs for the policies).
pub struct HotTier {
    policy: CachePolicy,
    capacity: u64,
    bytes: u64,
    tick: u64,
    map: HashMap<String, Slot>,
    /// 2Q ghost list: keys (not payloads) recently evicted from probation
    ghost: VecDeque<String>,
    ghost_set: HashSet<String>,
    ghost_cap: usize,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
    ghost_promotions: u64,
}

impl HotTier {
    pub fn new(policy: CachePolicy, capacity_bytes: u64) -> HotTier {
        HotTier {
            policy,
            capacity: capacity_bytes,
            bytes: 0,
            tick: 0,
            map: HashMap::new(),
            ghost: VecDeque::new(),
            ghost_set: HashSet::new(),
            ghost_cap: 4096,
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
            ghost_promotions: 0,
        }
    }

    /// Cap the ghost list (keys remembered after probation eviction).
    pub fn with_ghost_capacity(mut self, n: usize) -> HotTier {
        self.ghost_cap = n;
        self
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn stats(&self) -> TierStats {
        TierStats {
            hits: self.hits,
            misses: self.misses,
            insertions: self.insertions,
            evictions: self.evictions,
            ghost_promotions: self.ghost_promotions,
            bytes: self.bytes,
            capacity: self.capacity,
            entries: self.map.len() as u64,
        }
    }

    /// Counted lookup; a hit refreshes recency.
    pub fn get(&mut self, key: &str) -> Option<Bytes> {
        match self.peek(key) {
            Some(data) => {
                self.hits += 1;
                Some(data)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Uncounted lookup for pollers re-checking the *same* logical
    /// lookup (a demand wait on an in-flight fetch): refreshes recency
    /// on hit but leaves the hit/miss counters alone, so tier stats
    /// stay one-count-per-lookup.
    pub fn peek(&mut self, key: &str) -> Option<Bytes> {
        self.tick += 1;
        let tick = self.tick;
        let slot = self.map.get_mut(key)?;
        slot.last_used = tick;
        Some(slot.data.clone())
    }

    /// Admit an object; returns the number of evictions performed.
    /// Objects larger than the whole tier are rejected outright.
    pub fn insert(&mut self, key: &str, data: Bytes) -> u64 {
        if data.len() as u64 > self.capacity {
            return 0;
        }
        self.tick += 1;
        if let Some(slot) = self.map.get_mut(key) {
            self.bytes -= slot.data.len() as u64;
            self.bytes += data.len() as u64;
            slot.data = data;
            slot.last_used = self.tick;
            return self.evict_to_fit();
        }
        let queue = match self.policy {
            CachePolicy::Lru => Queue::Main,
            CachePolicy::TwoQ => {
                if self.ghost_set.remove(key) {
                    self.ghost.retain(|k| k != key);
                    self.ghost_promotions += 1;
                    Queue::Main
                } else {
                    Queue::Probation
                }
            }
        };
        self.insertions += 1;
        self.bytes += data.len() as u64;
        self.map.insert(
            key.to_string(),
            Slot { data, last_used: self.tick, queue },
        );
        self.evict_to_fit()
    }

    fn evict_to_fit(&mut self) -> u64 {
        let mut evicted = 0;
        while self.bytes > self.capacity {
            let Some(victim) = self.pick_victim() else { break };
            let slot = self.map.remove(&victim).expect("victim present");
            self.bytes -= slot.data.len() as u64;
            self.evictions += 1;
            evicted += 1;
            if self.policy == CachePolicy::TwoQ && slot.queue == Queue::Probation {
                self.ghost.push_back(victim.clone());
                self.ghost_set.insert(victim);
                while self.ghost.len() > self.ghost_cap {
                    if let Some(old) = self.ghost.pop_front() {
                        self.ghost_set.remove(&old);
                    }
                }
            }
        }
        evicted
    }

    fn least_recent_in(&self, queue: Queue) -> Option<String> {
        self.map
            .iter()
            .filter(|(_, s)| s.queue == queue)
            .min_by_key(|(_, s)| s.last_used)
            .map(|(k, _)| k.clone())
    }

    fn pick_victim(&self) -> Option<String> {
        match self.policy {
            CachePolicy::Lru => self
                .map
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone()),
            // 2Q: probation drains before the main queue is touched
            CachePolicy::TwoQ => self
                .least_recent_in(Queue::Probation)
                .or_else(|| self.least_recent_in(Queue::Main)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(n: usize, fill: u8) -> Bytes {
        Bytes::new(vec![fill; n])
    }

    #[test]
    fn policy_names() {
        assert_eq!(CachePolicy::by_name("lru"), Some(CachePolicy::Lru));
        assert_eq!(CachePolicy::by_name("2q"), Some(CachePolicy::TwoQ));
        assert_eq!(CachePolicy::by_name("twoq"), Some(CachePolicy::TwoQ));
        assert_eq!(CachePolicy::by_name("arc"), None);
        assert_eq!(CachePolicy::TwoQ.label(), "2q");
    }

    #[test]
    fn lru_eviction_order_respects_recency() {
        let mut t = HotTier::new(CachePolicy::Lru, 300);
        t.insert("k0", blob(100, 0));
        t.insert("k1", blob(100, 1));
        t.insert("k2", blob(100, 2));
        assert!(t.get("k0").is_some()); // k0 becomes most recent
        let evicted = t.insert("k3", blob(100, 3));
        assert_eq!(evicted, 1);
        assert!(t.contains("k0"), "recently-used survivor evicted");
        assert!(!t.contains("k1"), "LRU victim should be k1");
        assert!(t.contains("k2") && t.contains("k3"));
        assert!(t.bytes() <= 300);
    }

    #[test]
    fn never_exceeds_capacity_and_counts_evictions() {
        let mut t = HotTier::new(CachePolicy::Lru, 350);
        for i in 0..20 {
            t.insert(&format!("k{i}"), blob(100, i as u8));
            assert!(t.bytes() <= 350, "over cap: {}", t.bytes());
        }
        let s = t.stats();
        assert_eq!(s.insertions, 20);
        assert_eq!(s.evictions, 17); // 3 fit at a time
        assert_eq!(s.entries, 3);
    }

    #[test]
    fn oversized_object_rejected() {
        let mut t = HotTier::new(CachePolicy::Lru, 100);
        t.insert("big", blob(500, 9));
        assert!(!t.contains("big"));
        assert_eq!(t.bytes(), 0);
        assert_eq!(t.stats().insertions, 0);
    }

    #[test]
    fn hit_miss_counters() {
        let mut t = HotTier::new(CachePolicy::Lru, 1000);
        t.insert("a", blob(10, 0));
        assert!(t.get("a").is_some());
        assert!(t.get("a").is_some());
        assert!(t.get("b").is_none());
        let s = t.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert!((s.hit_ratio() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn reinsert_updates_in_place() {
        let mut t = HotTier::new(CachePolicy::Lru, 1000);
        t.insert("a", blob(100, 1));
        t.insert("a", blob(200, 2));
        assert_eq!(t.bytes(), 200);
        assert_eq!(t.len(), 1);
        assert_eq!(t.get("a").unwrap().len(), 200);
    }

    #[test]
    fn twoq_ghost_promotion() {
        // capacity fits two 100-byte objects
        let mut t = HotTier::new(CachePolicy::TwoQ, 200);
        t.insert("g", blob(100, 0)); // probation
        t.insert("a", blob(100, 1)); // probation
        t.insert("b", blob(100, 2)); // evicts g (LRU probation) → ghost
        assert!(!t.contains("g"));
        // re-admission hits the ghost list → promoted to main
        t.insert("g", blob(100, 3));
        assert_eq!(t.stats().ghost_promotions, 1);
        // further probation churn must not evict the promoted key:
        // probation ("a" or "b" whichever survived) drains first
        t.insert("c", blob(100, 4));
        t.insert("d", blob(100, 5));
        assert!(t.contains("g"), "main-queue key evicted before probation");
    }

    #[test]
    fn twoq_ghost_list_is_bounded() {
        let mut t = HotTier::new(CachePolicy::TwoQ, 100).with_ghost_capacity(2);
        for i in 0..6 {
            t.insert(&format!("k{i}"), blob(100, i as u8));
        }
        assert!(t.ghost.len() <= 2);
        assert_eq!(t.ghost.len(), t.ghost_set.len());
    }

    #[test]
    fn lru_has_no_ghost_promotions() {
        let mut t = HotTier::new(CachePolicy::Lru, 100);
        t.insert("a", blob(100, 0));
        t.insert("b", blob(100, 1)); // evicts a
        t.insert("a", blob(100, 2)); // plain re-admission
        assert_eq!(t.stats().ghost_promotions, 0);
    }
}
