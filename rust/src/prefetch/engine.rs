//! Sampler-ahead scheduling engine.
//!
//! The engine receives the epoch's key order (via
//! `ObjectStore::hint_order`), keeps a **cursor** at the consumer's
//! position in that order, and speculatively fetches keys inside the
//! window `[cursor, cursor + depth)` in background tasks on an `asyncrt`
//! runtime. Three mechanisms bound and prioritize the speculation:
//!
//! * **in-flight window** — at most `max_inflight` background GETs at
//!   once (the storage connection budget speculation may consume);
//! * **demand preemption** — while any consumer thread is paying a
//!   demand miss (`pending_demand > 0`), no new speculative fetch is
//!   issued, so misses never queue behind speculation;
//! * **priority aging** — a demand burst delays speculation but must not
//!   starve it: after [`AGING`] behind the gate the scheduler issues one
//!   speculative fetch anyway, then re-enters the gate.
//!
//! Within the window, fetches issue closest-to-cursor first (min-heap on
//! the sampler position); entries whose position the consumer has
//! already passed are dropped as *stale*.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::asyncrt;
use crate::storage::{IoRing, ObjectStore};
use crate::telemetry::{names, Recorder};

use super::tier::HotTier;
use super::PrefetchConfig;

/// After this long gated behind demand misses the scheduler issues one
/// speculative fetch anyway (aging: speculation is delayed, not starved).
const AGING: Duration = Duration::from_millis(5);
/// Condvar re-check period (also the liveness backstop: the scheduler can
/// never deadlock on a missed wakeup).
const TICK: Duration = Duration::from_millis(2);
/// Telemetry worker id for background engine activity.
pub const ENGINE_WORKER: u32 = u32::MAX;

/// Cumulative engine counters.
#[derive(Debug, Default)]
pub struct Counters {
    /// demand lookups through the store
    pub gets: AtomicU64,
    /// bytes served to demand lookups
    pub bytes: AtomicU64,
    /// demand lookups answered from the hot tier immediately
    pub hot_hits: AtomicU64,
    /// demand lookups that waited on an in-flight speculative fetch
    pub inflight_hits: AtomicU64,
    /// demand lookups that had to fetch from the warm tier themselves
    pub demand_misses: AtomicU64,
    /// speculative fetches issued
    pub issued: AtomicU64,
    /// speculative fetches landed in the hot tier
    pub completed: AtomicU64,
    /// queued entries dropped because the consumer passed them
    pub stale: AtomicU64,
    /// speculative fetches that errored
    pub errors: AtomicU64,
}

/// Plain-value snapshot of [`Counters`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CounterSnapshot {
    pub gets: u64,
    pub bytes: u64,
    pub hot_hits: u64,
    pub inflight_hits: u64,
    pub demand_misses: u64,
    pub issued: u64,
    pub completed: u64,
    pub stale: u64,
    pub errors: u64,
}

impl Counters {
    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            gets: self.gets.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            hot_hits: self.hot_hits.load(Ordering::Relaxed),
            inflight_hits: self.inflight_hits.load(Ordering::Relaxed),
            demand_misses: self.demand_misses.load(Ordering::Relaxed),
            issued: self.issued.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            stale: self.stale.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
        }
    }
}

impl CounterSnapshot {
    /// Fraction of demand lookups the engine hid from the warm tier
    /// (immediate hot hits plus waits on in-flight speculation).
    pub fn hit_ratio(&self) -> f64 {
        if self.gets == 0 {
            return 0.0;
        }
        (self.hot_hits + self.inflight_hits) as f64 / self.gets as f64
    }
}

/// Everything behind the engine's single state mutex.
pub(super) struct State {
    pub hot: HotTier,
    /// keys with a background fetch in progress
    pub inflight: HashSet<String>,
    /// speculation queue: (sampler position, tiebreak seq, key)
    pub queue: BinaryHeap<Reverse<(usize, u64, String)>>,
    /// key → positions in the hinted horizon, ascending. With a single
    /// epoch hinted each key has one position; when the epoch-pipelined
    /// loader *appends* the next epoch's order (`hint_order_append`) a
    /// key briefly carries one position per hinted epoch — positions
    /// already passed by the cursor are pruned on the next append.
    pub pos_of: HashMap<String, Vec<usize>>,
    /// consumer position in the hinted horizon (continuous across
    /// appended epochs; reset by a fresh `hint_order`)
    pub cursor: usize,
    /// total positions hinted so far — the next append starts here
    pub horizon: usize,
    /// demand misses currently paying warm-tier latency
    pub pending_demand: usize,
    pub seq: u64,
    pub shutdown: bool,
}

impl State {
    pub fn new(cfg: &PrefetchConfig) -> State {
        State {
            hot: HotTier::new(cfg.policy, cfg.hot_bytes)
                .with_ghost_capacity(cfg.ghost_capacity),
            inflight: HashSet::new(),
            queue: BinaryHeap::new(),
            pos_of: HashMap::new(),
            cursor: 0,
            horizon: 0,
            pending_demand: 0,
            seq: 0,
            shutdown: false,
        }
    }
}

/// State shared between the store facade, the scheduler thread and the
/// background fetch tasks. Deliberately does NOT hold the `asyncrt`
/// runtime: background tasks own an `Arc<Shared>`, and keeping the
/// runtime out of it guarantees the runtime is never dropped (and thus
/// never self-joined) from one of its own worker threads.
pub(super) struct Shared {
    pub inner: Arc<dyn ObjectStore>,
    pub state: Mutex<State>,
    pub cv: Condvar,
    pub counters: Counters,
    pub cfg: PrefetchConfig,
    /// live readahead depth in items — seeded from `cfg.depth`,
    /// resizable at epoch seams (the Governor's `prefetch_depth`
    /// applier); every windowing decision reads this, never `cfg.depth`
    pub depth: AtomicUsize,
    pub recorder: Mutex<Option<Arc<Recorder>>>,
    /// when set, speculative fetches ride the shared [`IoRing`] — its
    /// executor, `io_depth` semaphore and in-flight gauges — instead of
    /// the engine's private runtime, so speculation and batched demand
    /// reads draw from one submission budget
    pub ring: Mutex<Option<Arc<IoRing>>>,
}

impl Shared {
    pub fn recorder(&self) -> Option<Arc<Recorder>> {
        self.recorder.lock().unwrap().clone()
    }

    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }
}

enum Pick {
    Issue(String),
    /// speculation gated behind an active demand miss
    DemandGate,
    /// nothing issuable right now (empty queue, window full, or the
    /// whole readahead window is already hot/in flight)
    Idle,
}

fn pick_next(st: &mut State, shared: &Shared, aged: bool) -> Pick {
    if st.inflight.len() >= shared.cfg.max_inflight.max(1) {
        return Pick::Idle;
    }
    if st.pending_demand > 0 && !aged {
        return Pick::DemandGate;
    }
    loop {
        let Some(Reverse((pos, _seq, key))) = st.queue.peek().cloned() else {
            return Pick::Idle;
        };
        if pos < st.cursor {
            st.queue.pop();
            shared.counters.stale.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        if pos >= st.cursor + shared.depth() {
            return Pick::Idle; // beyond the readahead window
        }
        st.queue.pop();
        if st.hot.contains(&key) || st.inflight.contains(&key) {
            continue;
        }
        st.inflight.insert(key.clone());
        return Pick::Issue(key);
    }
}

fn issue(shared: &Arc<Shared>, rt: &asyncrt::Runtime, key: String) {
    shared.counters.issued.fetch_add(1, Ordering::Relaxed);
    let sh = shared.clone();
    if let Some(ring) = shared.ring.lock().unwrap().clone() {
        // ride the shared submission ring: the fetch queues behind the
        // same `io_depth` semaphore as batched demand reads and moves
        // the ring's in-flight gauge while it runs
        let ring_rt = ring.runtime().clone();
        ring_rt.spawn(async move {
            let _depth = ring.depth_sem().acquire().await;
            let _inflight = ring.track();
            fetch_into_hot(sh, key).await;
        });
        return;
    }
    rt.spawn(async move {
        fetch_into_hot(sh, key).await;
    });
}

/// Body of one speculative fetch: GET through the warm tier, land the
/// bytes in the hot tier, wake any demand waiters.
async fn fetch_into_hot(sh: Arc<Shared>, key: String) {
    let recorder = sh.recorder();
    let t0 = recorder.as_ref().map(|r| r.now());
    let res = sh.inner.get_async(&key).await;
    if let (Some(r), Some(t0)) = (&recorder, t0) {
        r.record(names::PREFETCH_FETCH, ENGINE_WORKER, -1, t0, r.now());
    }
    let mut st = sh.state.lock().unwrap();
    st.inflight.remove(&key);
    match res {
        Ok(data) => {
            st.hot.insert(&key, data);
            sh.counters.completed.fetch_add(1, Ordering::Relaxed);
        }
        Err(_) => {
            // demand waiters fall back to their own fetch, which
            // surfaces the error to the caller properly
            sh.counters.errors.fetch_add(1, Ordering::Relaxed);
        }
    }
    drop(st);
    sh.cv.notify_all();
}

fn scheduler_loop(shared: Arc<Shared>, rt: Arc<asyncrt::Runtime>) {
    loop {
        let key = {
            let mut st = shared.state.lock().unwrap();
            let mut gated_since: Option<Instant> = None;
            loop {
                if st.shutdown {
                    return;
                }
                let aged = gated_since.is_some_and(|t| t.elapsed() >= AGING);
                match pick_next(&mut st, &shared, aged) {
                    Pick::Issue(key) => break key,
                    Pick::DemandGate => {
                        gated_since.get_or_insert_with(Instant::now);
                        st = shared.cv.wait_timeout(st, TICK).unwrap().0;
                    }
                    Pick::Idle => {
                        gated_since = None;
                        st = shared.cv.wait_timeout(st, TICK).unwrap().0;
                    }
                }
            }
        };
        issue(&shared, &rt, key);
    }
}

pub(super) fn spawn_scheduler(
    shared: Arc<Shared>,
    rt: Arc<asyncrt::Runtime>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("prefetch-sched".into())
        .spawn(move || scheduler_loop(shared, rt))
        .expect("spawn prefetch scheduler")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prefetch::tier::CachePolicy;
    use crate::storage::{Bytes, MemStore};

    fn shared(depth: usize, max_inflight: usize) -> Shared {
        let cfg = PrefetchConfig {
            depth,
            max_inflight,
            ..Default::default()
        };
        Shared {
            inner: Arc::new(MemStore::new("m")),
            state: Mutex::new(State::new(&cfg)),
            cv: Condvar::new(),
            counters: Counters::default(),
            depth: AtomicUsize::new(cfg.depth),
            cfg,
            recorder: Mutex::new(None),
            ring: Mutex::new(None),
        }
    }

    fn enqueue(st: &mut State, items: &[(usize, &str)]) {
        for &(pos, key) in items {
            st.seq += 1;
            let seq = st.seq;
            st.pos_of.entry(key.to_string()).or_default().push(pos);
            st.queue.push(Reverse((pos, seq, key.to_string())));
            st.horizon = st.horizon.max(pos + 1);
        }
    }

    #[test]
    fn picks_closest_to_cursor_first() {
        let sh = shared(100, 4);
        let mut st = sh.state.lock().unwrap();
        enqueue(&mut st, &[(5, "e"), (1, "b"), (9, "f"), (0, "a")]);
        match pick_next(&mut st, &sh, false) {
            Pick::Issue(k) => assert_eq!(k, "a"),
            _ => panic!("expected issue"),
        }
        match pick_next(&mut st, &sh, false) {
            Pick::Issue(k) => assert_eq!(k, "b"),
            _ => panic!("expected issue"),
        }
    }

    #[test]
    fn respects_window_and_inflight_cap() {
        let sh = shared(2, 1);
        let mut st = sh.state.lock().unwrap();
        enqueue(&mut st, &[(0, "a"), (1, "b"), (5, "far")]);
        assert!(matches!(pick_next(&mut st, &sh, false), Pick::Issue(_)));
        // window full (max_inflight = 1)
        assert!(matches!(pick_next(&mut st, &sh, false), Pick::Idle));
        st.inflight.clear();
        assert!(matches!(pick_next(&mut st, &sh, false), Pick::Issue(_)));
        st.inflight.clear();
        // "far" is outside [cursor, cursor+depth)
        assert!(matches!(pick_next(&mut st, &sh, false), Pick::Idle));
        st.cursor = 4;
        assert!(matches!(pick_next(&mut st, &sh, false), Pick::Issue(_)));
    }

    #[test]
    fn demand_gate_and_aging() {
        let sh = shared(10, 4);
        let mut st = sh.state.lock().unwrap();
        enqueue(&mut st, &[(0, "a")]);
        st.pending_demand = 1;
        assert!(matches!(pick_next(&mut st, &sh, false), Pick::DemandGate));
        // aged: issues despite the gate
        assert!(matches!(pick_next(&mut st, &sh, true), Pick::Issue(_)));
    }

    #[test]
    fn stale_entries_dropped() {
        let sh = shared(10, 4);
        let mut st = sh.state.lock().unwrap();
        enqueue(&mut st, &[(0, "a"), (1, "b"), (2, "c")]);
        st.cursor = 2; // consumer already passed a and b
        match pick_next(&mut st, &sh, false) {
            Pick::Issue(k) => assert_eq!(k, "c"),
            _ => panic!("expected issue"),
        }
        assert_eq!(sh.counters.stale.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn hot_or_inflight_keys_skipped() {
        let sh = shared(10, 4);
        let mut st = sh.state.lock().unwrap();
        enqueue(&mut st, &[(0, "hot"), (1, "fly"), (2, "new")]);
        st.hot = {
            let mut h = HotTier::new(CachePolicy::Lru, 1 << 20);
            h.insert("hot", Bytes::new(vec![1]));
            h
        };
        st.inflight.insert("fly".to_string());
        match pick_next(&mut st, &sh, false) {
            Pick::Issue(k) => assert_eq!(k, "new"),
            _ => panic!("expected issue"),
        }
    }

    #[test]
    fn counter_snapshot_roundtrip() {
        let c = Counters::default();
        c.gets.store(10, Ordering::Relaxed);
        c.hot_hits.store(4, Ordering::Relaxed);
        c.inflight_hits.store(2, Ordering::Relaxed);
        let s = c.snapshot();
        assert_eq!(s.gets, 10);
        assert!((s.hit_ratio() - 0.6).abs() < 1e-12);
    }
}
