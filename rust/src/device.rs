//! The training device ("GPU") — consumer side of the pipeline.
//!
//! Two backends:
//! * [`Backend::Xla`] — executes the real AOT-compiled JAX/Pallas train
//!   step through PJRT (the e2e example path; CPU execution time *is*
//!   the device-busy time).
//! * [`Backend::Sim`] — a V100-calibrated cost model (the benchmark
//!   path: the paper's ResNet-18/batch-256 step ≈ 110 ms) with a
//!   synthetic declining loss.
//!
//! Plus the host→device **transfer model** of §2.4/Fig 7: per-copy setup
//! cost + bytes/bandwidth, with pinned (page-locked) memory roughly
//! doubling bandwidth and halving setup.
//!
//! The device exports busy/memory gauges that the 10 Hz
//! [`crate::telemetry::UtilSampler`] samples to produce the Table 3
//! GPU-utilization columns.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::dataloader::Batch;
use crate::runtime::{HostTensor, XlaEngine};
use crate::telemetry::{names, DeviceGauges, Recorder};
use crate::util::rng::Rng;

/// Transfer-path timing model (Fig 7).
#[derive(Debug, Clone)]
pub struct TransferModel {
    /// pageable-copy bandwidth, bytes/s (≈6 GB/s on PCIe3 with staging)
    pub pageable_bps: f64,
    /// pinned-copy bandwidth (≈12 GB/s)
    pub pinned_bps: f64,
    pub pageable_setup: Duration,
    pub pinned_setup: Duration,
}

impl Default for TransferModel {
    fn default() -> Self {
        TransferModel {
            pageable_bps: 6.0e9,
            pinned_bps: 12.0e9,
            pageable_setup: Duration::from_micros(400),
            pinned_setup: Duration::from_micros(100),
        }
    }
}

impl TransferModel {
    pub fn time(&self, bytes: usize, pinned: bool) -> Duration {
        let (bw, setup) = if pinned {
            (self.pinned_bps, self.pinned_setup)
        } else {
            (self.pageable_bps, self.pageable_setup)
        };
        setup + Duration::from_secs_f64(bytes as f64 / bw)
    }
}

/// Device compute backend.
pub enum Backend {
    /// Cost model: fixed step time (scaled by batch fill) + synthetic
    /// loss curve.
    Sim {
        /// step time for a full batch
        step_time: Duration,
        /// initial loss (≈ ln(num_classes))
        loss0: f64,
        decay: f64,
    },
    /// Real XLA execution of a train_step artifact.
    Xla { engine: Arc<XlaEngine>, variant: String },
}

/// Device configuration.
pub struct DeviceConfig {
    pub transfer: TransferModel,
    /// mean GPU utilization while busy, percent (Table 3: ~65–75 %)
    pub util_level: f64,
    /// memory utilization once the model+batch are resident, percent
    pub mem_level: f64,
    pub seed: u64,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig {
            transfer: TransferModel::default(),
            util_level: 72.0,
            mem_level: 41.0,
            seed: 99,
        }
    }
}

/// A batch resident "on device".
pub struct DeviceBatch {
    pub batch: Batch,
    pub transfer_time: Duration,
}

impl DeviceBatch {
    /// Release the host-side buffers back to their batch arena (the
    /// trainer calls this once the training step no longer needs the
    /// host copy) — the `recycle` leg of the slab lifecycle. No-op for
    /// heap batches.
    pub fn recycle(self) {
        self.batch.recycle();
    }
}

/// The simulated training device.
pub struct Device {
    backend: Backend,
    cfg: DeviceConfig,
    gauges: Arc<DeviceGauges>,
    recorder: Arc<Recorder>,
    steps: AtomicU64,
    rng: Mutex<Rng>,
}

impl Device {
    pub fn new(backend: Backend, cfg: DeviceConfig, recorder: Arc<Recorder>) -> Device {
        let seed = cfg.seed;
        Device {
            backend,
            cfg,
            gauges: Arc::new(DeviceGauges::default()),
            recorder,
            steps: AtomicU64::new(0),
            rng: Mutex::new(Rng::new(seed)),
        }
    }

    /// V100-calibrated simulated device (paper setup: ResNet-18, batch
    /// 256 ⇒ ~110 ms/step; we scale by batch size).
    pub fn sim_v100(batch_size: usize, num_classes: usize, recorder: Arc<Recorder>) -> Device {
        let step = Duration::from_secs_f64(0.110 * batch_size as f64 / 256.0);
        Device::new(
            Backend::Sim {
                step_time: step,
                loss0: (num_classes as f64).ln(),
                decay: 0.004,
            },
            DeviceConfig::default(),
            recorder,
        )
    }

    /// Real-XLA device over a train_step variant.
    pub fn xla(engine: Arc<XlaEngine>, variant: &str, recorder: Arc<Recorder>) -> Device {
        Device::new(
            Backend::Xla { engine, variant: variant.to_string() },
            DeviceConfig::default(),
            recorder,
        )
    }

    pub fn gauges(&self) -> Arc<DeviceGauges> {
        self.gauges.clone()
    }

    pub fn steps_done(&self) -> u64 {
        self.steps.load(Ordering::Relaxed)
    }

    /// Host→device copy (`training_batch_to_device` span).
    pub fn to_device(&self, batch: Batch) -> DeviceBatch {
        let t0 = self.recorder.now();
        let dt = self.cfg.transfer.time(batch.tensor_bytes(), batch.pinned);
        std::thread::sleep(dt);
        // model + batch now resident
        self.gauges
            .mem_x100
            .store((self.cfg.mem_level * 100.0) as u64, Ordering::Relaxed);
        self.recorder.record(
            names::TO_DEVICE,
            0,
            batch.id as i64,
            t0,
            self.recorder.now(),
        );
        DeviceBatch { batch, transfer_time: dt }
    }

    /// Run one training step (`run_training_batch` span); returns loss.
    pub fn train_batch(&self, db: &DeviceBatch) -> Result<f32> {
        let t0 = self.recorder.now();
        let jitter = {
            let mut r = self.rng.lock().unwrap();
            r.uniform(0.97, 1.03)
        };
        let util = (self.cfg.util_level * jitter * 100.0) as u64;
        self.gauges.util_x100.store(util, Ordering::Relaxed);
        let step = self.steps.fetch_add(1, Ordering::Relaxed);

        let loss = match &self.backend {
            Backend::Sim { step_time, loss0, decay } => {
                let dt = step_time.mul_f64(
                    db.batch.len() as f64
                        / db.batch.images.shape[0].max(1) as f64,
                );
                std::thread::sleep(dt.mul_f64(jitter));
                let noise = {
                    let mut r = self.rng.lock().unwrap();
                    r.uniform(-0.05, 0.05)
                };
                (loss0 * (-decay * step as f64).exp() + noise) as f32
            }
            Backend::Xla { engine, variant } => {
                let b = db.batch.len();
                let shape = &db.batch.images.shape;
                let images = HostTensor::from_u8(
                    &[b, shape[1], shape[2], shape[3]],
                    db.batch.images.data.clone(),
                );
                let labels = HostTensor::from_i32(&[b], &db.batch.labels);
                match engine.train_step(variant, images, labels) {
                    Ok(l) => l,
                    Err(e) => {
                        self.gauges.util_x100.store(0, Ordering::Relaxed);
                        bail!("xla train step: {e}");
                    }
                }
            }
        };
        self.gauges.util_x100.store(0, Ordering::Relaxed);
        self.recorder.record(
            names::TRAIN_BATCH,
            0,
            db.batch.id as i64,
            t0,
            self.recorder.now(),
        );
        // optimizer step is fused into the train step in both backends;
        // record it as a sub-span for the Fig 20 breakdown.
        self.recorder.record(
            names::OPTIMIZER_STEP,
            0,
            db.batch.id as i64,
            t0,
            self.recorder.now(),
        );
        Ok(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::U8Tensor;

    fn batch(id: usize, b: usize, crop: usize) -> Batch {
        Batch {
            id,
            images: U8Tensor::zeros(&[b, crop, crop, 3]),
            labels: vec![0; b],
            indices: (0..b).collect(),
            raw_bytes: (b * 1000) as u64,
            pinned: false,
            arena: None,
        }
    }

    #[test]
    fn transfer_model_pinned_faster() {
        let tm = TransferModel::default();
        let bytes = 64 * 1024 * 1024;
        assert!(tm.time(bytes, true) < tm.time(bytes, false));
    }

    #[test]
    fn transfer_grows_with_bytes() {
        let tm = TransferModel::default();
        assert!(tm.time(100 << 20, false) > tm.time(1 << 20, false));
    }

    #[test]
    fn sim_device_declining_loss() {
        let rec = Recorder::new();
        let dev = Device::new(
            Backend::Sim {
                step_time: Duration::from_millis(1),
                loss0: 6.0,
                decay: 0.1,
            },
            DeviceConfig::default(),
            rec.clone(),
        );
        let mut losses = Vec::new();
        for i in 0..20 {
            let db = dev.to_device(batch(i, 4, 8));
            losses.push(dev.train_batch(&db).unwrap());
        }
        assert!(losses[19] < losses[0]);
        assert_eq!(dev.steps_done(), 20);
        assert_eq!(rec.durations(names::TRAIN_BATCH).len(), 20);
        assert_eq!(rec.durations(names::TO_DEVICE).len(), 20);
    }

    #[test]
    fn gauges_toggle() {
        let rec = Recorder::new();
        let dev = Device::new(
            Backend::Sim {
                step_time: Duration::from_millis(5),
                loss0: 1.0,
                decay: 0.0,
            },
            DeviceConfig::default(),
            rec,
        );
        let g = dev.gauges();
        assert_eq!(g.util_x100.load(Ordering::Relaxed), 0);
        let db = dev.to_device(batch(0, 2, 8));
        dev.train_batch(&db).unwrap();
        // after the step, util back to 0, memory stays resident
        assert_eq!(g.util_x100.load(Ordering::Relaxed), 0);
        assert!(g.mem_x100.load(Ordering::Relaxed) > 0);
    }
}
