//! First-class shard streaming — the per-sample key space served out of
//! tar shard *windows*.
//!
//! The per-file hot path pays one remote request per image; on a
//! high-latency store that request is almost all first-byte wait. This
//! module flips the unit of I/O: [`pack_shards`] packs the corpus into
//! fixed-size tar shards **without renaming** the members and records
//! each sample's exact byte placement in a [`ShardManifest`];
//! [`ShardStore`] then fronts the shard objects with the *original*
//! per-sample key space — `keys()` is identical to the source corpus, so
//! the index → sample mapping (and therefore the augmentation stream) is
//! unchanged — while fulfilling every read from a bounded cache of
//! resident shard windows fetched with **one request each**. Sample-order
//! hints are translated to shard-order hints and forwarded down the
//! stack, so a prefetch layer below pipelines whole windows across epoch
//! seams exactly like it pipelines per-file keys.
//!
//! Stacks whose bottom store reads natively into caller buffers
//! ([`crate::storage::DirStore`]) fetch windows with one
//! [`ObjectStore::get_range_into`] into a recycled buffer; shared-`Bytes`
//! stacks (`MemStore` under a simulated remote and/or prefetch tier)
//! fetch with one [`ObjectStore::get`], which hands back the tier's `Arc`
//! without copying. Either way the remote's first-byte latency is paid
//! once per window, amortized over every sample inside it.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Context, Result};

use super::tar::{write_tar, TarEntry};
use crate::storage::{Bytes, IoRing, ObjectStore, StatCounters, StoreStats};

const BLOCK: u64 = 512;

/// Byte placement of one sample inside its shard: the data payload of
/// its tar entry (header excluded).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardLoc {
    /// shard index (into [`ShardManifest::shard_keys`])
    pub shard: u32,
    /// byte offset of the sample's data within the shard archive
    pub offset: u64,
    /// data length in bytes
    pub len: u32,
}

/// Where every sample lives: the map from the corpus' per-file key space
/// to `(shard, offset, len)` placements, built by [`pack_shards`].
#[derive(Debug, Clone)]
pub struct ShardManifest {
    /// all sample keys, sorted — identical to the source corpus manifest
    sample_keys: Vec<String>,
    /// per-sample placement, parallel to `sample_keys`
    locs: Vec<ShardLoc>,
    /// sample key → index into `sample_keys` / `locs`
    index_of: HashMap<String, usize>,
    /// shard object keys, in shard order
    shard_keys: Vec<String>,
    /// total archive size of each shard (trailer blocks included)
    shard_bytes: Vec<usize>,
    /// contiguous sample-index range of each shard
    members: Vec<std::ops::Range<usize>>,
}

impl ShardManifest {
    pub fn n_samples(&self) -> usize {
        self.sample_keys.len()
    }

    pub fn n_shards(&self) -> usize {
        self.shard_keys.len()
    }

    pub fn sample_keys(&self) -> &[String] {
        &self.sample_keys
    }

    pub fn shard_keys(&self) -> &[String] {
        &self.shard_keys
    }

    pub fn shard_bytes(&self, shard: usize) -> usize {
        self.shard_bytes[shard]
    }

    /// Placement of sample `index`.
    pub fn loc(&self, index: usize) -> ShardLoc {
        self.locs[index]
    }

    /// Shard holding sample `index`.
    pub fn shard_of(&self, index: usize) -> usize {
        self.locs[index].shard as usize
    }

    /// Sample-index range packed into shard `shard` (contiguous: shards
    /// chunk the sorted key manifest).
    pub fn members(&self, shard: usize) -> std::ops::Range<usize> {
        self.members[shard].clone()
    }

    pub fn index_of(&self, key: &str) -> Option<usize> {
        self.index_of.get(key).copied()
    }
}

/// Pack the source corpus into tar shards of `shard_size` samples each
/// on `dst`, keeping the **original key names** as member names and
/// recording exact byte placements. Shards chunk the sorted key
/// manifest, so sample index `i` lands in shard `i / shard_size`.
pub fn pack_shards(
    src: &Arc<dyn ObjectStore>,
    dst: &Arc<dyn ObjectStore>,
    shard_size: usize,
) -> Result<ShardManifest> {
    let sample_keys = src.keys();
    let shard_size = shard_size.max(1);
    let mut locs = Vec::with_capacity(sample_keys.len());
    let mut shard_keys = Vec::new();
    let mut shard_bytes = Vec::new();
    let mut members = Vec::new();
    for (si, chunk) in sample_keys.chunks(shard_size).enumerate() {
        let mut entries = Vec::with_capacity(chunk.len());
        let mut pos = 0u64; // archive length so far
        for k in chunk {
            let data = src.get(k).with_context(|| k.clone())?.to_vec();
            let len = data.len();
            // the entry's data starts right after its 512-byte header
            locs.push(ShardLoc {
                shard: si as u32,
                offset: pos + BLOCK,
                len: len as u32,
            });
            pos += BLOCK + (len as u64).div_ceil(BLOCK) * BLOCK;
            entries.push(TarEntry { name: k.clone(), data });
        }
        let archive = write_tar(&entries)?;
        debug_assert_eq!(archive.len() as u64, pos + 2 * BLOCK);
        let key = format!("shards/shard_{si:05}.tar");
        shard_bytes.push(archive.len());
        dst.put(&key, archive)?;
        shard_keys.push(key);
        members.push(si * shard_size..si * shard_size + chunk.len());
    }
    let index_of = sample_keys
        .iter()
        .enumerate()
        .map(|(i, k)| (k.clone(), i))
        .collect();
    Ok(ShardManifest {
        sample_keys,
        locs,
        index_of,
        shard_keys,
        shard_bytes,
        members,
    })
}

/// A resident-or-inflight shard window set: the single-flight state
/// behind [`ShardStore`].
struct Windows {
    /// shard → resident window bytes
    resident: HashMap<usize, Bytes>,
    /// recency queue over `resident` (front = coldest)
    lru: VecDeque<usize>,
    /// shards currently being fetched by some thread
    fetching: Vec<usize>,
    /// recycled window buffers (ranged-read path only)
    pool: Vec<Vec<u8>>,
}

/// [`ObjectStore`] facade that serves the per-sample key space out of
/// shard windows. See the module docs for the design; the key contract
/// is that `keys()`, `get()`, and `get_into()` behave byte-identically
/// to the source corpus the shards were packed from.
pub struct ShardStore {
    inner: Arc<dyn ObjectStore>,
    manifest: ShardManifest,
    windows: Mutex<Windows>,
    cv: Condvar,
    /// max resident windows
    window_cap: usize,
    /// fetch windows with one ranged read into a recycled buffer
    /// (stacks with a native scratch path) instead of one shared-`Bytes`
    /// `get`
    ranged_windows: bool,
    /// when set, window fetches go through the shared submission ring:
    /// concurrent fetches (worker demand + speculation) multiplex over
    /// its executor instead of each occupying a blocking thread below
    ring: Mutex<Option<Arc<IoRing>>>,
    stats: StatCounters,
    window_fetches: AtomicU64,
    window_hits: AtomicU64,
    window_waits: AtomicU64,
    window_evictions: AtomicU64,
}

impl ShardStore {
    pub fn new(
        inner: Arc<dyn ObjectStore>,
        manifest: ShardManifest,
        window_cap: usize,
    ) -> ShardStore {
        let ranged_windows = inner.native_get_into();
        ShardStore {
            inner,
            manifest,
            windows: Mutex::new(Windows {
                resident: HashMap::new(),
                lru: VecDeque::new(),
                fetching: Vec::new(),
                pool: Vec::new(),
            }),
            cv: Condvar::new(),
            window_cap: window_cap.max(1),
            ranged_windows,
            ring: Mutex::new(None),
            stats: StatCounters::default(),
            window_fetches: AtomicU64::new(0),
            window_hits: AtomicU64::new(0),
            window_waits: AtomicU64::new(0),
            window_evictions: AtomicU64::new(0),
        }
    }

    pub fn manifest(&self) -> &ShardManifest {
        &self.manifest
    }

    pub fn inner(&self) -> &Arc<dyn ObjectStore> {
        &self.inner
    }

    /// Route window fetches through a shared [`IoRing`]. The ring
    /// should wrap the same stack as `inner` (conventionally the store
    /// below this facade) so window reads and per-sample traffic hit
    /// identical tiers.
    pub fn set_ring(&self, ring: Arc<IoRing>) {
        *self.ring.lock().unwrap() = Some(ring);
    }

    fn pooled_windows(&self) -> bool {
        // both the direct ranged path and the ring path read into an
        // owned buffer we can recycle through the pool
        self.ranged_windows || self.ring.lock().unwrap().is_some()
    }

    /// `(fetches, hits, waits, evictions)` of the window cache.
    pub fn window_stats(&self) -> (u64, u64, u64, u64) {
        (
            self.window_fetches.load(Ordering::Relaxed),
            self.window_hits.load(Ordering::Relaxed),
            self.window_waits.load(Ordering::Relaxed),
            self.window_evictions.load(Ordering::Relaxed),
        )
    }

    /// Currently resident windows (≤ the cap).
    pub fn resident_windows(&self) -> usize {
        self.windows.lock().unwrap().resident.len()
    }

    /// The resident window of shard `si`, fetching it (single-flight)
    /// if needed.
    fn window(&self, si: usize) -> Result<Bytes> {
        let mut st = self.windows.lock().unwrap();
        loop {
            if let Some(b) = st.resident.get(&si) {
                let b = b.clone();
                // touch recency: move to the back of the queue
                if let Some(p) = st.lru.iter().position(|&x| x == si) {
                    st.lru.remove(p);
                    st.lru.push_back(si);
                }
                self.window_hits.fetch_add(1, Ordering::Relaxed);
                return Ok(b);
            }
            if st.fetching.contains(&si) {
                // another thread is on it — wait for resolution, then
                // re-check (on a failed fetch we retry ourselves)
                self.window_waits.fetch_add(1, Ordering::Relaxed);
                st = self.cv.wait(st).unwrap();
                continue;
            }
            st.fetching.push(si);
            break;
        }
        let recycled = st.pool.pop();
        drop(st);

        let fetched = self.fetch_window(si, recycled);

        let mut st = self.windows.lock().unwrap();
        st.fetching.retain(|&x| x != si);
        if let Ok(b) = &fetched {
            st.resident.insert(si, b.clone());
            st.lru.push_back(si);
            while st.resident.len() > self.window_cap {
                let victim = st.lru.pop_front().expect("lru tracks resident");
                if let Some(old) = st.resident.remove(&victim) {
                    self.window_evictions.fetch_add(1, Ordering::Relaxed);
                    // reclaim the buffer for the next ranged fetch if no
                    // decode still borrows it
                    if self.pooled_windows() && st.pool.len() < self.window_cap {
                        if let Ok(v) = Arc::try_unwrap(old) {
                            st.pool.push(v);
                        }
                    }
                }
            }
        }
        drop(st);
        self.cv.notify_all();
        fetched
    }

    /// One request for the whole shard window.
    fn fetch_window(&self, si: usize, recycled: Option<Vec<u8>>) -> Result<Bytes> {
        let key = &self.manifest.shard_keys[si];
        let size = self.manifest.shard_bytes[si];
        self.window_fetches.fetch_add(1, Ordering::Relaxed);
        let ring = self.ring.lock().unwrap().clone();
        if let Some(ring) = ring {
            // one ranged descriptor through the submission ring — this
            // thread blocks on its own completion, but the request
            // itself multiplexes with every other outstanding window
            // and speculative fetch on the ring's executor
            let buf = recycled.unwrap_or_default();
            let (buf, res) = ring.read_range(key, 0, size, buf);
            let n = res?;
            if n != size {
                bail!("shard {key} truncated: read {n} of {size} bytes");
            }
            return Ok(Arc::new(buf));
        }
        if self.ranged_windows {
            let mut buf = recycled.unwrap_or_default();
            buf.resize(size, 0);
            let n = self.inner.get_range_into(key, 0, &mut buf)?;
            if n != size {
                bail!("shard {key} truncated: read {n} of {size} bytes");
            }
            Ok(Arc::new(buf))
        } else {
            let b = self.inner.get(key)?;
            if b.len() != size {
                bail!("shard {key} truncated: holds {} of {size} bytes", b.len());
            }
            Ok(b)
        }
    }

    /// The window bytes and `[offset, offset+len)` range of sample
    /// `index` — the zero-copy decode surface ([`crate::data::simg::SimgRef`]
    /// parses straight off the returned `Bytes`).
    pub fn sample_window_at(&self, index: usize) -> Result<(Bytes, usize, usize)> {
        let loc = self.manifest.locs[index];
        let win = self.window(loc.shard as usize)?;
        let (off, len) = (loc.offset as usize, loc.len as usize);
        if off + len > win.len() {
            bail!(
                "shard {} truncated: sample {} wants [{off}, {}) of {} bytes",
                self.manifest.shard_keys[loc.shard as usize],
                self.manifest.sample_keys[index],
                off + len,
                win.len()
            );
        }
        Ok((win, off, len))
    }

    /// Key-addressed variant of [`ShardStore::sample_window_at`].
    pub fn sample_window(&self, key: &str) -> Result<(Bytes, usize, usize)> {
        let i = self
            .manifest
            .index_of(key)
            .with_context(|| format!("no such sample in shard manifest: {key}"))?;
        self.sample_window_at(i)
    }

    /// Translate a sample-index access order into a deduped shard-order
    /// hint (first occurrence wins) and forward it down the stack, so a
    /// prefetch layer below fetches whole windows ahead of demand.
    pub fn hint_sample_indices(&self, epoch: usize, order: &[usize], append: bool) {
        let mut seen = vec![false; self.manifest.n_shards()];
        let mut shard_keys = Vec::new();
        for &i in order {
            if let Some(loc) = self.manifest.locs.get(i) {
                let si = loc.shard as usize;
                if !seen[si] {
                    seen[si] = true;
                    shard_keys.push(self.manifest.shard_keys[si].clone());
                }
            }
        }
        if append {
            self.inner.hint_order_append(epoch, &shard_keys);
        } else {
            self.inner.hint_order(epoch, &shard_keys);
        }
    }

    fn hint_keys(&self, epoch: usize, keys: &[String], append: bool) {
        let mut seen = vec![false; self.manifest.n_shards()];
        let mut shard_keys = Vec::new();
        for k in keys {
            if let Some(i) = self.manifest.index_of(k) {
                let si = self.manifest.locs[i].shard as usize;
                if !seen[si] {
                    seen[si] = true;
                    shard_keys.push(self.manifest.shard_keys[si].clone());
                }
            }
        }
        if append {
            self.inner.hint_order_append(epoch, &shard_keys);
        } else {
            self.inner.hint_order(epoch, &shard_keys);
        }
    }
}

impl ObjectStore for ShardStore {
    fn get(&self, key: &str) -> Result<Bytes> {
        let (win, off, len) = self.sample_window(key)?;
        self.stats.record_get(len as u64);
        Ok(Arc::new(win[off..off + len].to_vec()))
    }

    fn get_into(&self, key: &str, out: &mut [u8]) -> Result<usize> {
        let i = self
            .manifest
            .index_of(key)
            .with_context(|| format!("no such sample in shard manifest: {key}"))?;
        let len = self.manifest.locs[i].len as usize;
        if len > out.len() {
            return Ok(len); // size probe: no window fetch, nothing written
        }
        let (win, off, _) = self.sample_window_at(i)?;
        out[..len].copy_from_slice(&win[off..off + len]);
        self.stats.record_get(len as u64);
        Ok(len)
    }

    fn get_range_into(&self, key: &str, offset: u64, out: &mut [u8]) -> Result<usize> {
        let (win, off, len) = self.sample_window(key)?;
        let n = crate::storage::range_from_bytes(
            &win[off..off + len],
            key,
            offset,
            out,
        )?;
        self.stats.record_get(n as u64);
        Ok(n)
    }

    fn native_get_into(&self) -> bool {
        // reading into a caller buffer skips the per-sample Vec the
        // `get` path must allocate out of the window
        true
    }

    fn put(&self, key: &str, _data: Vec<u8>) -> Result<()> {
        bail!("ShardStore is a read-only view over packed shards (put {key})")
    }

    fn keys(&self) -> Vec<String> {
        self.manifest.sample_keys.clone()
    }

    fn contains(&self, key: &str) -> bool {
        self.manifest.index_of.contains_key(key)
    }

    fn hint_order(&self, epoch: usize, keys: &[String]) {
        self.hint_keys(epoch, keys, false);
    }

    fn hint_order_append(&self, epoch: usize, keys: &[String]) {
        self.hint_keys(epoch, keys, true);
    }

    fn label(&self) -> String {
        format!("shards({})", self.inner.label())
    }

    fn stats(&self) -> StoreStats {
        let s = self.stats.snapshot();
        StoreStats {
            gets: s.gets,
            bytes: s.bytes,
            hits: self.window_hits.load(Ordering::Relaxed),
            misses: self.window_fetches.load(Ordering::Relaxed),
            evictions: self.window_evictions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_corpus, CorpusSpec};
    use crate::shards::read_tar;
    use crate::storage::MemStore;

    fn corpus(items: usize) -> Arc<dyn ObjectStore> {
        let m: Arc<dyn ObjectStore> = Arc::new(MemStore::new("src"));
        generate_corpus(&m, &CorpusSpec::tiny(items)).unwrap();
        m
    }

    #[test]
    fn pack_preserves_names_and_records_exact_offsets() {
        let src = corpus(10);
        let dst: Arc<dyn ObjectStore> = Arc::new(MemStore::new("dst"));
        let m = pack_shards(&src, &dst, 4).unwrap();
        assert_eq!(m.n_samples(), 10);
        assert_eq!(m.n_shards(), 3); // 4 + 4 + 2
        assert_eq!(m.sample_keys(), src.keys().as_slice());
        assert_eq!(m.members(2), 8..10);
        // member names are the original keys (no renaming), and every
        // recorded (offset, len) slices the exact object bytes
        for (si, sk) in m.shard_keys().iter().enumerate() {
            let archive = dst.get(sk).unwrap();
            assert_eq!(archive.len(), m.shard_bytes(si));
            let names: Vec<String> =
                read_tar(&archive).unwrap().into_iter().map(|e| e.name).collect();
            let want: Vec<String> = m.members(si)
                .map(|i| m.sample_keys()[i].clone())
                .collect();
            assert_eq!(names, want);
            for i in m.members(si) {
                let loc = m.loc(i);
                assert_eq!(loc.shard as usize, si);
                let got = &archive[loc.offset as usize..loc.offset as usize + loc.len as usize];
                let orig = src.get(&m.sample_keys()[i]).unwrap();
                assert_eq!(got, &orig[..], "sample {i}");
            }
        }
    }

    #[test]
    fn shard_store_is_byte_identical_to_the_source_corpus() {
        let src = corpus(9);
        let dst: Arc<dyn ObjectStore> = Arc::new(MemStore::new("dst"));
        let m = pack_shards(&src, &dst, 3).unwrap();
        let st = ShardStore::new(dst, m, 2);
        assert_eq!(st.keys(), src.keys());
        assert!(st.native_get_into());
        for k in src.keys() {
            let orig = src.get(&k).unwrap();
            assert_eq!(&*st.get(&k).unwrap(), &*orig, "{k}");
            // get_into: snprintf contract
            let mut buf = vec![0u8; orig.len()];
            assert_eq!(st.get_into(&k, &mut buf).unwrap(), orig.len());
            assert_eq!(buf, *orig);
            let mut small = [0u8; 4];
            assert_eq!(st.get_into(&k, &mut small).unwrap(), orig.len());
            // ranged read inside the sample
            let mut r = [0u8; 8];
            let n = st.get_range_into(&k, 2, &mut r).unwrap();
            assert_eq!(&r[..n], &orig[2..2 + n]);
            assert!(st.contains(&k));
        }
        assert!(!st.contains("ghost"));
        assert!(st.get("ghost").is_err());
        assert!(st.put("x", vec![1]).is_err());
    }

    #[test]
    fn window_cache_fetches_each_shard_once_and_stays_bounded() {
        let src = corpus(12);
        let dst: Arc<dyn ObjectStore> = Arc::new(MemStore::new("dst"));
        let m = pack_shards(&src, &dst, 4).unwrap();
        let st = ShardStore::new(dst.clone(), m, 2);
        let keys = st.keys();
        // sweep shard 0's samples: one window fetch, then pure hits
        for k in &keys[..4] {
            st.get(k).unwrap();
        }
        let (fetches, hits, _, _) = st.window_stats();
        assert_eq!(fetches, 1);
        assert_eq!(hits, 3);
        assert_eq!(dst.stats().gets, 1, "one request for the whole window");
        // touching all 3 shards with cap 2 evicts one window
        for k in &keys {
            st.get(k).unwrap();
        }
        let (fetches, _, _, evictions) = st.window_stats();
        assert_eq!(fetches, 3);
        assert_eq!(evictions, 1);
        assert_eq!(st.resident_windows(), 2);
        // re-sweeping re-fetches only what was evicted
        for k in &keys {
            st.get(k).unwrap();
        }
        assert!(st.window_stats().0 <= 5);
    }

    #[test]
    fn truncated_shard_object_is_an_error_not_garbage() {
        let src = corpus(4);
        let dst: Arc<dyn ObjectStore> = Arc::new(MemStore::new("dst"));
        let m = pack_shards(&src, &dst, 4).unwrap();
        let shard_key = m.shard_keys()[0].clone();
        let whole = dst.get(&shard_key).unwrap().to_vec();
        // chop the archive mid-way through the member data
        dst.put(&shard_key, whole[..whole.len() / 2].to_vec()).unwrap();
        let st = ShardStore::new(dst, m, 2);
        let err = st.get(&st.keys()[0]).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn hints_translate_to_deduped_shard_order() {
        struct Recording {
            inner: MemStore,
            hints: Mutex<Vec<(usize, Vec<String>, bool)>>,
        }
        impl ObjectStore for Recording {
            fn get(&self, key: &str) -> Result<Bytes> {
                self.inner.get(key)
            }
            fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
                self.inner.put(key, data)
            }
            fn keys(&self) -> Vec<String> {
                self.inner.keys()
            }
            fn label(&self) -> String {
                "rec".into()
            }
            fn hint_order(&self, epoch: usize, keys: &[String]) {
                self.hints.lock().unwrap().push((epoch, keys.to_vec(), false));
            }
            fn hint_order_append(&self, epoch: usize, keys: &[String]) {
                self.hints.lock().unwrap().push((epoch, keys.to_vec(), true));
            }
        }
        let src = corpus(8);
        let rec = Arc::new(Recording {
            inner: MemStore::new("dst"),
            hints: Mutex::new(Vec::new()),
        });
        let dst: Arc<dyn ObjectStore> = rec.clone();
        let m = pack_shards(&src, &dst, 4).unwrap();
        let st = ShardStore::new(dst, m, 2);
        let keys = st.keys();
        // interleaved sample order hitting shard 1 first
        let order = [keys[5].clone(), keys[1].clone(), keys[6].clone(), keys[0].clone()];
        st.hint_order(3, &order);
        st.hint_sample_indices(4, &[0, 1, 4, 5], true);
        let hints = rec.hints.lock().unwrap();
        assert_eq!(
            *hints,
            vec![
                (
                    3,
                    vec![
                        "shards/shard_00001.tar".to_string(),
                        "shards/shard_00000.tar".to_string(),
                    ],
                    false
                ),
                (
                    4,
                    vec![
                        "shards/shard_00000.tar".to_string(),
                        "shards/shard_00001.tar".to_string(),
                    ],
                    true
                ),
            ]
        );
    }

    #[test]
    fn ring_routed_window_fetch_is_byte_identical() {
        let src = corpus(9);
        let dst: Arc<dyn ObjectStore> = Arc::new(MemStore::new("dst"));
        let m = pack_shards(&src, &dst, 3).unwrap();
        let st = ShardStore::new(dst.clone(), m, 2);
        st.set_ring(crate::storage::IoRing::new(dst, 8));
        for k in src.keys() {
            let orig = src.get(&k).unwrap();
            assert_eq!(&*st.get(&k).unwrap(), &*orig, "{k}");
            let mut buf = vec![0u8; orig.len()];
            assert_eq!(st.get_into(&k, &mut buf).unwrap(), orig.len());
            assert_eq!(buf, *orig);
        }
        // 3 shards, cap 2: at least one eviction recycled a ring buffer
        assert!(st.window_stats().0 >= 3);
        assert!(st.window_stats().3 >= 1);
    }

    #[cfg(unix)]
    #[test]
    fn ranged_window_path_over_dirstore_matches_corpus() {
        let root = std::env::temp_dir()
            .join(format!("cdl-shardstore-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let src = corpus(6);
        let dst: Arc<dyn ObjectStore> =
            Arc::new(crate::storage::DirStore::open(&root).unwrap());
        let m = pack_shards(&src, &dst, 2).unwrap();
        let st = ShardStore::new(dst, m, 2);
        assert!(st.ranged_windows, "DirStore stack takes the ranged path");
        for k in src.keys() {
            assert_eq!(&*st.get(&k).unwrap(), &*src.get(&k).unwrap(), "{k}");
        }
        // windows were evicted (3 shards, cap 2) — the ranged path
        // recycles buffers through the pool without corruption
        assert!(st.window_stats().3 >= 1);
        let _ = std::fs::remove_dir_all(&root);
    }
}
