//! Shard-based loading.
//!
//! The first-class path (`store`) plugs shards straight into the main
//! dataloader: [`pack_shards`] records every sample's byte placement and
//! [`ShardStore`] serves the original per-sample key space out of shard
//! *windows* fetched one request each — see `crate::dataset::ShardDataset`
//! for the loader-facing half.
//!
//! The §A.5 comparison systems live alongside it:
//!
//! * [`WebDatasetLoader`]: data lives in tar *shards*; an epoch streams
//!   each shard (one remote request per shard, sequential bandwidth) and
//!   unpacks items on the fly. No per-item RTT — the decisive advantage
//!   over per-item object GETs.
//! * [`FastAiLoader`]: `untar_data` downloads the full tar once to local
//!   scratch, unpacks, and all epochs read locally.
//!
//! All of them yield the same decoded/augmented samples as the map-style
//! dataset, so epoch runtimes are directly comparable (Fig 22).

pub mod store;
pub mod tar;

pub use store::{pack_shards, ShardLoc, ShardManifest, ShardStore};
pub use tar::{read_tar, write_tar, TarEntry, TarStream};

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::{Augment, AugmentConfig, SimgImage};
use crate::dataset::Sample;
use crate::gil::Gil;
use crate::storage::ObjectStore;

/// Pack corpus objects into `n_shards` tar shards on `dst`.
/// Returns the shard keys.
pub fn build_shards(
    src: &Arc<dyn ObjectStore>,
    dst: &Arc<dyn ObjectStore>,
    n_shards: usize,
) -> Result<Vec<String>> {
    let keys = src.keys();
    let n_shards = n_shards.max(1);
    let per = keys.len().div_ceil(n_shards);
    let mut shard_keys = Vec::new();
    for (si, chunk) in keys.chunks(per.max(1)).enumerate() {
        let entries: Vec<TarEntry> = chunk
            .iter()
            .map(|k| {
                Ok(TarEntry {
                    name: k.replace('/', "_"),
                    data: src.get(k)?.to_vec(),
                })
            })
            .collect::<Result<_>>()?;
        let shard = write_tar(&entries)?;
        let key = format!("shards/shard_{si:05}.tar");
        dst.put(&key, shard)?;
        shard_keys.push(key);
    }
    Ok(shard_keys)
}

/// Common result of one shard-loader epoch.
#[derive(Debug, Clone)]
pub struct ShardEpoch {
    pub samples: usize,
    pub bytes: u64,
    pub wall_secs: f64,
}

/// WebDataset-style streaming shard loader.
pub struct WebDatasetLoader {
    store: Arc<dyn ObjectStore>,
    shard_keys: Vec<String>,
    augment: Augment,
}

impl WebDatasetLoader {
    pub fn new(
        store: Arc<dyn ObjectStore>,
        shard_keys: Vec<String>,
        augment_cfg: AugmentConfig,
    ) -> WebDatasetLoader {
        WebDatasetLoader { store, shard_keys, augment: Augment::new(augment_cfg) }
    }

    /// Stream one epoch: fetch each shard (sequential bandwidth, one
    /// request), unpack on the fly, decode+augment each item under the
    /// GIL. Calls `sink` for every sample.
    pub fn epoch(
        &self,
        epoch: usize,
        gil: &Gil,
        mut sink: impl FnMut(Sample),
    ) -> Result<ShardEpoch> {
        let t0 = std::time::Instant::now();
        let mut samples = 0usize;
        let mut bytes = 0u64;
        let mut index = 0usize;
        for key in &self.shard_keys {
            let shard = gil.io(|| self.store.get(key))?;
            bytes += shard.len() as u64;
            for entry in TarStream::new(&shard) {
                let entry = entry?;
                let sample = gil.cpu(|| -> Result<Sample> {
                    let img = SimgImage::decode(&entry.data)?;
                    let crop = self.augment.apply_u8(&img, epoch, index);
                    Ok(Sample {
                        index,
                        label: img.label,
                        crop,
                        raw_bytes: entry.data.len(),
                        fetch_time: 0.0,
                        decode_time: 0.0,
                    })
                })?;
                sink(sample);
                samples += 1;
                index += 1;
            }
        }
        Ok(ShardEpoch { samples, bytes, wall_secs: t0.elapsed().as_secs_f64() })
    }
}

/// FastAI-style loader: download+unpack the archive once, then all
/// epochs read the unpacked local copy.
pub struct FastAiLoader {
    local: Arc<dyn ObjectStore>,
    augment: Augment,
    keys: Vec<String>,
    /// wall time of the one-off untar_data
    pub untar_secs: f64,
    pub downloaded_bytes: u64,
}

impl FastAiLoader {
    /// `untar_data`: pull every shard from `remote`, unpack into `local`.
    pub fn untar_data(
        remote: &Arc<dyn ObjectStore>,
        shard_keys: &[String],
        local: Arc<dyn ObjectStore>,
        augment_cfg: AugmentConfig,
    ) -> Result<FastAiLoader> {
        let t0 = std::time::Instant::now();
        let mut downloaded = 0u64;
        for key in shard_keys {
            let shard = remote.get(key).with_context(|| key.clone())?;
            downloaded += shard.len() as u64;
            for entry in read_tar(&shard)? {
                local.put(&entry.name, entry.data)?;
            }
        }
        let keys = local.keys();
        Ok(FastAiLoader {
            local,
            augment: Augment::new(augment_cfg),
            keys,
            untar_secs: t0.elapsed().as_secs_f64(),
            downloaded_bytes: downloaded,
        })
    }

    /// One local epoch over the unpacked data.
    pub fn epoch(
        &self,
        epoch: usize,
        gil: &Gil,
        mut sink: impl FnMut(Sample),
    ) -> Result<ShardEpoch> {
        let t0 = std::time::Instant::now();
        let mut samples = 0usize;
        let mut bytes = 0u64;
        for (index, key) in self.keys.iter().enumerate() {
            let raw = gil.io(|| self.local.get(key))?;
            bytes += raw.len() as u64;
            let sample = gil.cpu(|| -> Result<Sample> {
                let img = SimgImage::decode(&raw)?;
                let crop = self.augment.apply_u8(&img, epoch, index);
                Ok(Sample {
                    index,
                    label: img.label,
                    crop,
                    raw_bytes: raw.len(),
                    fetch_time: 0.0,
                    decode_time: 0.0,
                })
            })?;
            sink(sample);
            samples += 1;
        }
        Ok(ShardEpoch { samples, bytes, wall_secs: t0.elapsed().as_secs_f64() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_corpus, CorpusSpec};
    use crate::storage::{MemStore, RemoteProfile, SimRemoteStore};

    fn corpus(items: usize) -> Arc<dyn ObjectStore> {
        let m: Arc<dyn ObjectStore> = Arc::new(MemStore::new("src"));
        generate_corpus(&m, &CorpusSpec::tiny(items)).unwrap();
        m
    }

    #[test]
    fn build_shards_covers_all_items() {
        let src = corpus(10);
        let dst: Arc<dyn ObjectStore> = Arc::new(MemStore::new("dst"));
        let keys = build_shards(&src, &dst, 3).unwrap();
        assert_eq!(keys.len(), 3);
        let total: usize = keys
            .iter()
            .map(|k| read_tar(&dst.get(k).unwrap()).unwrap().len())
            .sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn webdataset_epoch_yields_all_samples() {
        let src = corpus(8);
        let dst: Arc<dyn ObjectStore> = Arc::new(MemStore::new("dst"));
        let keys = build_shards(&src, &dst, 2).unwrap();
        let wds = WebDatasetLoader::new(
            dst,
            keys,
            AugmentConfig { crop: 16, ..Default::default() },
        );
        let gil = Gil::native();
        let mut seen = 0;
        let ep = wds
            .epoch(0, &gil, |s| {
                assert_eq!(s.crop.shape, vec![16, 16, 3]);
                seen += 1;
            })
            .unwrap();
        assert_eq!(seen, 8);
        assert_eq!(ep.samples, 8);
        assert!(ep.bytes > 0);
    }

    #[test]
    fn fastai_untar_then_local_epochs() {
        let src = corpus(6);
        let remote_mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("r"));
        let keys = build_shards(&src, &remote_mem, 1).unwrap();
        let remote: Arc<dyn ObjectStore> =
            SimRemoteStore::new(remote_mem, RemoteProfile::s3().scaled(0.1), 1);
        let local: Arc<dyn ObjectStore> = Arc::new(MemStore::new("l"));
        let fa = FastAiLoader::untar_data(
            &remote,
            &keys,
            local,
            AugmentConfig { crop: 16, ..Default::default() },
        )
        .unwrap();
        assert!(fa.untar_secs > 0.0);
        assert!(fa.downloaded_bytes > 0);
        let gil = Gil::native();
        let ep = fa.epoch(0, &gil, |_| {}).unwrap();
        assert_eq!(ep.samples, 6);
        // local epochs don't pay the remote latency
        assert!(ep.wall_secs < fa.untar_secs + 1.0);
    }

    #[test]
    fn webdataset_beats_per_item_on_s3() {
        // 12 items in 1 shard: one shard RTT vs 12 per-item RTTs
        let src = corpus(12);
        let dst_mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("d"));
        let keys = build_shards(&src, &dst_mem, 1).unwrap();
        let profile = RemoteProfile::s3().scaled(0.2);
        let remote_shards: Arc<dyn ObjectStore> =
            SimRemoteStore::new(dst_mem, profile.clone(), 1);
        let wds = WebDatasetLoader::new(
            remote_shards,
            keys,
            AugmentConfig { crop: 16, ..Default::default() },
        );
        let gil = Gil::native();
        let ep = wds.epoch(0, &gil, |_| {}).unwrap();

        // per-item path on the same latency profile
        let remote_items: Arc<dyn ObjectStore> =
            SimRemoteStore::new(corpus(12), profile, 2);
        let t0 = std::time::Instant::now();
        for k in remote_items.keys() {
            remote_items.get(&k).unwrap();
        }
        let per_item = t0.elapsed().as_secs_f64();
        assert!(
            ep.wall_secs < per_item,
            "wds {} !< per-item {per_item}",
            ep.wall_secs
        );
    }
}
