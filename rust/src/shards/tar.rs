//! Minimal POSIX-ustar tar writer/reader — the container format of
//! WebDataset shards (§A.5). Only regular files, only the fields the
//! loaders need; round-trips anything this repo writes and validates
//! header checksums on read.

use anyhow::{bail, Result};

const BLOCK: usize = 512;

/// One archive member.
#[derive(Debug, Clone, PartialEq)]
pub struct TarEntry {
    pub name: String,
    pub data: Vec<u8>,
}

fn octal_field(buf: &mut [u8], value: u64) {
    // NUL-terminated octal, width-1 digits
    let s = format!("{:0width$o}\0", value, width = buf.len() - 1);
    buf.copy_from_slice(s.as_bytes());
}

fn parse_octal(field: &[u8]) -> Result<u64> {
    let s: String = field
        .iter()
        .take_while(|&&b| b != 0 && b != b' ')
        .map(|&b| b as char)
        .collect();
    if s.is_empty() {
        return Ok(0);
    }
    u64::from_str_radix(&s, 8).map_err(|e| anyhow::anyhow!("bad octal {s:?}: {e}"))
}

fn header_for(name: &str, size: usize) -> Result<[u8; BLOCK]> {
    if name.len() > 100 {
        bail!("tar name too long: {name}");
    }
    let mut h = [0u8; BLOCK];
    h[..name.len()].copy_from_slice(name.as_bytes()); // name
    octal_field(&mut h[100..108], 0o644); // mode
    octal_field(&mut h[108..116], 0); // uid
    octal_field(&mut h[116..124], 0); // gid
    octal_field(&mut h[124..136], size as u64); // size
    octal_field(&mut h[136..148], 0); // mtime
    h[156] = b'0'; // typeflag: regular file
    h[257..262].copy_from_slice(b"ustar"); // magic
    h[263..265].copy_from_slice(b"00"); // version
    // checksum: spaces while computing
    for b in &mut h[148..156] {
        *b = b' ';
    }
    let sum: u64 = h.iter().map(|&b| b as u64).sum();
    let s = format!("{sum:06o}\0 ");
    h[148..156].copy_from_slice(s.as_bytes());
    Ok(h)
}

/// Serialize entries into a tar archive (with the closing zero blocks).
pub fn write_tar(entries: &[TarEntry]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    for e in entries {
        out.extend_from_slice(&header_for(&e.name, e.data.len())?);
        out.extend_from_slice(&e.data);
        let pad = (BLOCK - e.data.len() % BLOCK) % BLOCK;
        out.extend(std::iter::repeat(0u8).take(pad));
    }
    out.extend(std::iter::repeat(0u8).take(2 * BLOCK));
    Ok(out)
}

/// Parse a tar archive, validating checksums.
pub fn read_tar(buf: &[u8]) -> Result<Vec<TarEntry>> {
    let mut entries = Vec::new();
    let mut off = 0usize;
    while off + BLOCK <= buf.len() {
        let h = &buf[off..off + BLOCK];
        if h.iter().all(|&b| b == 0) {
            break; // end-of-archive
        }
        // checksum check
        let stored = parse_octal(&h[148..156])?;
        let computed: u64 = h
            .iter()
            .enumerate()
            .map(|(i, &b)| if (148..156).contains(&i) { b' ' as u64 } else { b as u64 })
            .sum();
        if stored != computed {
            bail!("tar checksum mismatch at offset {off}");
        }
        let name: String = h[..100]
            .iter()
            .take_while(|&&b| b != 0)
            .map(|&b| b as char)
            .collect();
        let size = parse_octal(&h[124..136])? as usize;
        let data_start = off + BLOCK;
        if data_start + size > buf.len() {
            bail!("tar truncated: {name} wants {size} bytes");
        }
        if h[156] == b'0' || h[156] == 0 {
            entries.push(TarEntry {
                name,
                data: buf[data_start..data_start + size].to_vec(),
            });
        }
        off = data_start + size.div_ceil(BLOCK) * BLOCK;
    }
    Ok(entries)
}

/// Iterate entries *incrementally* from a byte stream — WebDataset-style
/// unpack-on-the-fly (the consumer can process entry k while the rest of
/// the shard is still in flight in a real network setting).
pub struct TarStream<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> TarStream<'a> {
    pub fn new(buf: &'a [u8]) -> TarStream<'a> {
        TarStream { buf, off: 0 }
    }
}

impl<'a> Iterator for TarStream<'a> {
    type Item = Result<TarEntry>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.off + BLOCK > self.buf.len() {
            return None;
        }
        let h = &self.buf[self.off..self.off + BLOCK];
        if h.iter().all(|&b| b == 0) {
            return None;
        }
        let name: String = h[..100]
            .iter()
            .take_while(|&&b| b != 0)
            .map(|&b| b as char)
            .collect();
        let size = match parse_octal(&h[124..136]) {
            Ok(s) => s as usize,
            Err(e) => return Some(Err(e)),
        };
        let start = self.off + BLOCK;
        if start + size > self.buf.len() {
            return Some(Err(anyhow::anyhow!("truncated entry {name}")));
        }
        self.off = start + size.div_ceil(BLOCK) * BLOCK;
        Some(Ok(TarEntry { name, data: self.buf[start..start + size].to_vec() }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_entries() -> Vec<TarEntry> {
        vec![
            TarEntry { name: "a.simg".into(), data: vec![1; 700] },
            TarEntry { name: "dir/b.simg".into(), data: vec![2; 512] },
            TarEntry { name: "c.simg".into(), data: vec![] },
        ]
    }

    #[test]
    fn roundtrip() {
        let tar = write_tar(&sample_entries()).unwrap();
        assert_eq!(tar.len() % BLOCK, 0);
        let back = read_tar(&tar).unwrap();
        assert_eq!(back, sample_entries());
    }

    #[test]
    fn stream_iterates_same() {
        let tar = write_tar(&sample_entries()).unwrap();
        let streamed: Vec<TarEntry> =
            TarStream::new(&tar).map(|e| e.unwrap()).collect();
        assert_eq!(streamed, sample_entries());
    }

    #[test]
    fn checksum_detects_corruption() {
        let mut tar = write_tar(&sample_entries()).unwrap();
        tar[0] ^= 0x7F;
        assert!(read_tar(&tar).is_err());
    }

    #[test]
    fn system_tar_can_be_parsed_back() {
        // cross-check against GNU/busybox tar if available
        let dir = std::env::temp_dir().join(format!("cdl-tar-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let tar_path = dir.join("x.tar");
        std::fs::write(&tar_path, write_tar(&sample_entries()).unwrap()).unwrap();
        let out = std::process::Command::new("tar")
            .args(["-tf", tar_path.to_str().unwrap()])
            .output();
        if let Ok(out) = out {
            if out.status.success() {
                let listing = String::from_utf8_lossy(&out.stdout);
                assert!(listing.contains("a.simg"), "{listing}");
                assert!(listing.contains("dir/b.simg"));
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_truncated() {
        let tar = write_tar(&sample_entries()).unwrap();
        assert!(read_tar(&tar[..600]).is_err());
    }
}
