//! `IoRing` — an io_uring-shaped batched submission/completion queue
//! over any [`ObjectStore`].
//!
//! The per-thread fetch model caps storage concurrency at the OS thread
//! count: one outstanding request per thread, and the queueing behavior
//! that dominates real S3-like backends (`simnet::Link` reproduces it
//! faithfully) stays invisible because requests are never actually
//! concurrent. The ring decouples the two. Callers build a *batch* of
//! ranged read descriptors ([`ReadOp`]) and [`IoRing::submit`] it; one
//! executor thread multiplexes every in-flight request as futures, and
//! the caller reaps [`Completion`]s **out of order** as they land — a
//! single worker thread can keep hundreds of reads in flight, bounded
//! only by the `io_depth` permit budget.
//!
//! Dispatch goes through [`ObjectStore::submit_batch`]: the default
//! implementation loops the blocking `get`/`get_range_into` path inside
//! one executor task (correct everywhere, concurrent nowhere), while
//! native implementations ([`super::SimRemoteStore`], [`super::DirStore`],
//! [`super::VarnishCache`], [`crate::prefetch::PrefetchStore`]) spawn or
//! partition so independent ops genuinely overlap.
//!
//! Buffer discipline: every [`ReadOp`] carries an owned `(key, buf)`
//! pair and every [`Completion`] hands both back, so callers recycle
//! them through a scratch pool and the submitting thread's steady-state
//! cost per wave is a handful of queue-plumbing allocations, independent
//! of how many reads the wave carries (`tests/test_alloc.rs` pins this).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::asyncrt::{Runtime, Semaphore};
use crate::telemetry::{names, Recorder, RING_WORKER};

use super::ObjectStore;

/// One ranged read descriptor in a submission batch.
#[derive(Debug)]
pub struct ReadOp {
    /// caller-chosen destination slot, echoed back on the completion —
    /// this is how out-of-order reaps find their place in the wave
    pub slot: usize,
    pub key: String,
    pub offset: u64,
    /// bytes to read from `offset`; `0` means the whole object
    /// (`offset` must then be 0 too)
    pub len: usize,
    /// owned destination buffer, resized by the store and returned on
    /// the completion for recycling
    pub buf: Vec<u8>,
}

impl ReadOp {
    /// Whole-object read into `buf`.
    pub fn whole(slot: usize, key: String, buf: Vec<u8>) -> ReadOp {
        ReadOp { slot, key, offset: 0, len: 0, buf }
    }

    /// Ranged read of `len` bytes at `offset`.
    pub fn range(slot: usize, key: String, offset: u64, len: usize, buf: Vec<u8>) -> ReadOp {
        ReadOp { slot, key, offset, len, buf }
    }
}

/// One completed read, reaped from a [`Submission`].
#[derive(Debug)]
pub struct Completion {
    /// the originating [`ReadOp::slot`]
    pub slot: usize,
    /// key handed back for recycling
    pub key: String,
    /// buffer holding the read bytes (`buf[..n]` where `n` is the Ok
    /// result), handed back for recycling either way
    pub buf: Vec<u8>,
    /// bytes read, or the op's error
    pub result: Result<usize>,
}

/// Completion side of one submission: a small MPSC queue the executor
/// pushes into and the submitting thread reaps from.
struct CqState {
    done: VecDeque<Completion>,
    /// ops submitted and not yet pushed
    outstanding: usize,
}

pub struct CompletionQueue {
    state: Mutex<CqState>,
    cv: Condvar,
}

/// Anything a [`RingCtx`] can push completions into. The ring's own
/// [`CompletionQueue`] is the normal sink; interposing layers (the
/// resilience layer re-drives retries and hedges) substitute their own
/// sink via [`RingCtx::sub`] to observe raw attempt results before
/// deciding what the submitter finally sees.
pub trait CompletionSink: Send + Sync {
    fn push(&self, c: Completion);
}

impl CompletionSink for CompletionQueue {
    fn push(&self, c: Completion) {
        CompletionQueue::push(self, c)
    }
}

impl CompletionQueue {
    fn new(outstanding: usize) -> Arc<CompletionQueue> {
        Arc::new(CompletionQueue {
            state: Mutex::new(CqState { done: VecDeque::with_capacity(outstanding), outstanding }),
            cv: Condvar::new(),
        })
    }

    fn push(&self, c: Completion) {
        let mut st = self.state.lock().unwrap();
        st.done.push_back(c);
        st.outstanding -= 1;
        drop(st);
        self.cv.notify_all();
    }

    /// Blocking reap; `None` once every outstanding op has been reaped.
    fn pop(&self) -> Option<Completion> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(c) = st.done.pop_front() {
                return Some(c);
            }
            if st.outstanding == 0 {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }
}

/// Cumulative ring gauges. `inflight` counts ops between
/// [`RingCtx::begin`] and [`RingCtx::complete`] — i.e. *in service*, past
/// the depth/connection gates — and its high-water mark is the proof
/// that submission depth decoupled from thread count.
#[derive(Debug, Default)]
pub struct RingStats {
    submitted: AtomicU64,
    completed: AtomicU64,
    batches: AtomicU64,
    inflight: AtomicU64,
    inflight_hwm: AtomicU64,
    errors: AtomicU64,
}

impl RingStats {
    fn enter(&self) {
        let now = self.inflight.fetch_add(1, Ordering::Relaxed) + 1;
        self.inflight_hwm.fetch_max(now, Ordering::Relaxed);
    }

    fn exit(&self) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> RingSnapshot {
        let submitted = self.submitted.load(Ordering::Relaxed);
        let completed = self.completed.load(Ordering::Relaxed);
        RingSnapshot {
            submitted,
            completed,
            batches: self.batches.load(Ordering::Relaxed),
            queued: submitted.saturating_sub(completed),
            inflight: self.inflight.load(Ordering::Relaxed),
            inflight_hwm: self.inflight_hwm.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
        }
    }
}

/// Plain-value snapshot of [`RingStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RingSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub batches: u64,
    /// submitted and not yet completed (queue depth, gates included)
    pub queued: u64,
    /// currently in service (past the gates)
    pub inflight: u64,
    pub inflight_hwm: u64,
    pub errors: u64,
}

/// Everything an [`ObjectStore::submit_batch`] implementation needs:
/// the completion sink, the shared gauges, the ring executor to spawn
/// per-op futures onto, and the `io_depth` permit budget.
///
/// Contract per op: call [`RingCtx::begin`] exactly once when the op
/// enters service (past any permit gates), then [`RingCtx::complete`]
/// exactly once with the op's slot, recycled key/buf, and result.
///
/// Interposing layers split one logical op into several physical
/// *attempts* (retries, hedges): they hand the backing store an attempt
/// context from [`RingCtx::sub`] — whose `complete` reports into the
/// layer's own sink without counting the logical op done — and call
/// [`RingCtx::deliver`] exactly once per logical op with the final
/// verdict. The in-flight gauge then counts physical attempts while
/// `submitted`/`completed`/`errors` stay logical.
#[derive(Clone)]
pub struct RingCtx {
    sink: Arc<dyn CompletionSink>,
    stats: Arc<RingStats>,
    rt: Arc<Runtime>,
    depth: Arc<Semaphore>,
    /// true for contexts minted by [`RingCtx::sub`]: completions are
    /// raw attempt results, not logical-op verdicts
    attempt: bool,
}

impl RingCtx {
    /// The ring executor — native impls spawn one future per op here.
    pub fn rt(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// The `io_depth` budget — native impls acquire one permit per op
    /// before entering service.
    pub fn depth(&self) -> &Arc<Semaphore> {
        &self.depth
    }

    /// Mark one op as entering service.
    pub fn begin(&self) {
        self.stats.enter();
    }

    /// Deliver one op's completion (releases its in-service slot). On an
    /// attempt context (see [`RingCtx::sub`]) this only reports the raw
    /// attempt — the logical counters move when the interposing layer
    /// calls [`RingCtx::deliver`].
    pub fn complete(&self, slot: usize, key: String, buf: Vec<u8>, result: Result<usize>) {
        self.stats.exit();
        if !self.attempt {
            self.stats.completed.fetch_add(1, Ordering::Relaxed);
            if result.is_err() {
                self.stats.errors.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.sink.push(Completion { slot, key, buf, result });
    }

    /// Derive an *attempt* context that reports into `sink` instead of
    /// the submitter's completion queue. Shares the executor, the
    /// `io_depth` budget, and the in-flight gauge — a retry or hedge is
    /// a real in-service op competing for the same permits.
    pub fn sub(&self, sink: Arc<dyn CompletionSink>) -> RingCtx {
        RingCtx {
            sink,
            stats: self.stats.clone(),
            rt: self.rt.clone(),
            depth: self.depth.clone(),
            attempt: true,
        }
    }

    /// Final verdict for one logical op, pushed to the original sink.
    /// Counterpart of [`RingCtx::sub`]: the interposing layer's attempts
    /// each paid their own [`RingCtx::begin`]/[`RingCtx::complete`], so
    /// this moves only the logical `completed`/`errors` counters.
    pub fn deliver(&self, slot: usize, key: String, buf: Vec<u8>, result: Result<usize>) {
        self.stats.completed.fetch_add(1, Ordering::Relaxed);
        if result.is_err() {
            self.stats.errors.fetch_add(1, Ordering::Relaxed);
        }
        self.sink.push(Completion { slot, key, buf, result });
    }
}

/// RAII in-flight marker for ring-adjacent work that bypasses the
/// submission queue (the prefetch engine's speculative fetches ride the
/// ring executor and depth budget but deliver through the hot tier, not
/// a completion queue) — keeps the in-flight gauge truthful.
pub struct InflightGuard {
    stats: Arc<RingStats>,
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.stats.exit();
    }
}

/// The submission/completion ring over one store.
pub struct IoRing {
    store: Arc<dyn ObjectStore>,
    rt: Arc<Runtime>,
    depth: Arc<Semaphore>,
    io_depth: AtomicUsize,
    stats: Arc<RingStats>,
    recorder: Mutex<Option<Arc<Recorder>>>,
}

impl IoRing {
    /// One executor thread, `io_depth` in-flight permits.
    pub fn new(store: Arc<dyn ObjectStore>, io_depth: usize) -> Arc<IoRing> {
        let io_depth = io_depth.max(1);
        Arc::new(IoRing {
            store,
            rt: Runtime::new(1),
            depth: Semaphore::new(io_depth),
            io_depth: AtomicUsize::new(io_depth),
            stats: Arc::new(RingStats::default()),
            recorder: Mutex::new(None),
        })
    }

    pub fn set_recorder(&self, rec: Arc<Recorder>) {
        *self.recorder.lock().unwrap() = Some(rec);
    }

    pub fn io_depth(&self) -> usize {
        self.io_depth.load(Ordering::Relaxed)
    }

    /// Resize the in-flight budget live (the Governor's epoch-seam
    /// `io_depth` applier). Growing frees permits immediately; shrinking
    /// books the shortfall as semaphore debt that in-flight ops repay
    /// as they land — submissions already past the gate are unaffected.
    pub fn set_depth(&self, depth: usize) {
        let depth = depth.max(1);
        let prev = self.io_depth.swap(depth, Ordering::Relaxed);
        if depth > prev {
            self.depth.add_permits(depth - prev);
        } else if depth < prev {
            self.depth.remove_permits(prev - depth);
        }
    }

    pub fn store(&self) -> &Arc<dyn ObjectStore> {
        &self.store
    }

    /// The ring executor (shared with riders like the prefetch engine).
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// The shared `io_depth` permit budget.
    pub fn depth_sem(&self) -> &Arc<Semaphore> {
        &self.depth
    }

    pub fn stats(&self) -> RingSnapshot {
        self.stats.snapshot()
    }

    /// Count one bypass op (see [`InflightGuard`]) as in service.
    pub fn track(&self) -> InflightGuard {
        self.stats.enter();
        InflightGuard { stats: self.stats.clone() }
    }

    /// Submit a batch; completions are reaped from the returned
    /// [`Submission`] in whatever order the ops finish.
    pub fn submit(&self, ops: Vec<ReadOp>) -> Submission {
        let n = ops.len();
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        self.stats.submitted.fetch_add(n as u64, Ordering::Relaxed);
        let sink = CompletionQueue::new(n);
        let recorder = self.recorder.lock().unwrap().clone();
        let t0 = recorder.as_ref().map(|r| r.now());
        if n > 0 {
            let ctx = RingCtx {
                sink: sink.clone() as Arc<dyn CompletionSink>,
                stats: self.stats.clone(),
                rt: self.rt.clone(),
                depth: self.depth.clone(),
                attempt: false,
            };
            let store = self.store.clone();
            // one detached dispatch task; native submit_batch impls fan
            // out into per-op futures from inside it
            drop(self.rt.spawn(async move {
                store.submit_batch(ops, ctx);
            }));
        }
        Submission { sink, expected: n, reaped: 0, recorder, t0 }
    }

    /// Single-op convenience: one ranged read through the ring, blocking
    /// until it lands. Used by `ShardStore` window fetches, where each
    /// calling thread wants one window but many threads' windows should
    /// multiplex on the ring together.
    pub fn read_range(&self, key: &str, offset: u64, len: usize, buf: Vec<u8>) -> (Vec<u8>, Result<usize>) {
        let mut sub = self.submit(vec![ReadOp::range(0, key.to_string(), offset, len, buf)]);
        match sub.next() {
            Some(c) => (c.buf, c.result),
            None => (Vec::new(), Err(anyhow::anyhow!("ring dropped the read of {key}"))),
        }
    }
}

/// Handle to one in-flight batch: reap completions (out of order) until
/// `None`.
pub struct Submission {
    sink: Arc<CompletionQueue>,
    expected: usize,
    reaped: usize,
    recorder: Option<Arc<Recorder>>,
    t0: Option<f64>,
}

impl Submission {
    pub fn expected(&self) -> usize {
        self.expected
    }

    /// Blocking reap of the next completion; `None` once all have been
    /// reaped. Order is completion order, not submission order.
    pub fn next(&mut self) -> Option<Completion> {
        let c = self.sink.pop()?;
        self.reaped += 1;
        if self.reaped == self.expected {
            if let (Some(r), Some(t0)) = (&self.recorder, self.t0) {
                r.record(names::RING_BATCH, RING_WORKER, self.expected as i64, t0, r.now());
            }
        }
        Some(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{MemStore, RemoteProfile, SimRemoteStore};

    fn mem(n: usize) -> Arc<dyn ObjectStore> {
        let m = MemStore::new("m");
        for i in 0..n {
            m.put(&format!("k{i}"), vec![i as u8; 64 + i]).unwrap();
        }
        Arc::new(m)
    }

    #[test]
    fn whole_object_batch_matches_get() {
        let store = mem(8);
        let ring = IoRing::new(store.clone(), 4);
        let ops = (0..8)
            .map(|i| ReadOp::whole(i, format!("k{i}"), Vec::new()))
            .collect();
        let mut sub = ring.submit(ops);
        let mut seen = vec![false; 8];
        while let Some(c) = sub.next() {
            let n = c.result.unwrap();
            let want = store.get(&c.key).unwrap();
            assert_eq!(&c.buf[..n], &want[..], "{}", c.key);
            assert_eq!(n, want.len());
            assert!(!seen[c.slot]);
            seen[c.slot] = true;
        }
        assert!(seen.iter().all(|&s| s));
        let s = ring.stats();
        assert_eq!(s.submitted, 8);
        assert_eq!(s.completed, 8);
        assert_eq!(s.batches, 1);
        assert_eq!(s.queued, 0);
        assert_eq!(s.inflight, 0);
        assert_eq!(s.errors, 0);
    }

    #[test]
    fn ranged_batch_matches_get_range_into() {
        let store = mem(4);
        let ring = IoRing::new(store.clone(), 4);
        let ops = (0..4)
            .map(|i| ReadOp::range(i, format!("k{i}"), 3, 16, Vec::new()))
            .collect();
        let mut sub = ring.submit(ops);
        while let Some(c) = sub.next() {
            let n = c.result.unwrap();
            assert_eq!(n, 16);
            let mut want = vec![0u8; 16];
            store.get_range_into(&c.key, 3, &mut want).unwrap();
            assert_eq!(&c.buf[..n], &want[..]);
        }
    }

    #[test]
    fn errors_surface_per_op_not_per_batch() {
        let store = mem(2);
        let ring = IoRing::new(store, 2);
        let ops = vec![
            ReadOp::whole(0, "k0".into(), Vec::new()),
            ReadOp::whole(1, "ghost".into(), Vec::new()),
        ];
        let mut sub = ring.submit(ops);
        let mut ok = 0;
        let mut err = 0;
        while let Some(c) = sub.next() {
            match c.result {
                Ok(_) => ok += 1,
                Err(_) => {
                    err += 1;
                    assert_eq!(c.slot, 1);
                }
            }
        }
        assert_eq!((ok, err), (1, 1));
        assert_eq!(ring.stats().errors, 1);
    }

    #[test]
    fn empty_submission_reaps_nothing() {
        let ring = IoRing::new(mem(1), 1);
        let mut sub = ring.submit(Vec::new());
        assert!(sub.next().is_none());
        assert_eq!(ring.stats().batches, 1);
        assert_eq!(ring.stats().submitted, 0);
    }

    #[test]
    fn read_range_convenience_roundtrips_buffer() {
        let store = mem(2);
        let ring = IoRing::new(store.clone(), 2);
        let scratch = vec![0u8; 999]; // recycled capacity survives
        let (buf, res) = ring.read_range("k1", 0, 65, scratch);
        assert_eq!(res.unwrap(), 65);
        assert_eq!(&buf[..65], &store.get("k1").unwrap()[..]);
    }

    #[test]
    fn inflight_high_water_exceeds_submitter_thread_count() {
        // one submitting thread, 32 ops through a simulated remote: the
        // native impl must drive them concurrently, so the in-service
        // high-water mark rises far above 1 (the whole point of the ring)
        let m = MemStore::new("b");
        for i in 0..32 {
            m.put(&format!("k{i}"), vec![7u8; 32 * 1024]).unwrap();
        }
        let remote = SimRemoteStore::new(
            Arc::new(m),
            RemoteProfile::s3().scaled(0.05),
            11,
        );
        let ring = IoRing::new(remote, 64);
        let ops = (0..32)
            .map(|i| ReadOp::whole(i, format!("k{i}"), Vec::new()))
            .collect();
        let mut sub = ring.submit(ops);
        let mut n = 0;
        while let Some(c) = sub.next() {
            c.result.unwrap();
            n += 1;
        }
        assert_eq!(n, 32);
        let s = ring.stats();
        assert!(s.inflight_hwm > 8, "no decoupling: hwm {}", s.inflight_hwm);
        assert_eq!(s.inflight, 0);
    }

    #[test]
    fn attempt_ctx_counts_physical_deliver_counts_logical() {
        struct Trap(Mutex<Vec<Completion>>);
        impl CompletionSink for Trap {
            fn push(&self, c: Completion) {
                self.0.lock().unwrap().push(c);
            }
        }
        let ring = IoRing::new(mem(2), 4);
        // drive submit_batch by hand through an interposing sink: two
        // attempts for one logical op, then one final deliver
        let mut sub = ring.submit(vec![ReadOp::whole(0, "k0".into(), Vec::new())]);
        let c = sub.next().unwrap();
        c.result.unwrap();
        assert!(sub.next().is_none());
        let base = ring.stats();
        assert_eq!((base.submitted, base.completed, base.errors), (1, 1, 0));

        let trap = Arc::new(Trap(Mutex::new(Vec::new())));
        let mut outer = ring.submit(vec![ReadOp::whole(0, "k1".into(), Vec::new())]);
        // steal the logical ctx shape: build attempt ctx off a fresh
        // submission's dispatch is internal, so emulate via sub() from a
        // hand-rolled parent — reap the completion the normal path
        // produced first, then check sub()/deliver() arithmetic directly.
        let c = outer.next().unwrap();
        let parent = RingCtx {
            sink: trap.clone() as Arc<dyn CompletionSink>,
            stats: ring.stats.clone(),
            rt: ring.rt.clone(),
            depth: ring.depth.clone(),
            attempt: false,
        };
        let attempt = parent.sub(trap.clone());
        let before = ring.stats();
        attempt.begin();
        attempt.complete(0, "k1".into(), Vec::new(), Err(anyhow::anyhow!("boom")));
        let mid = ring.stats();
        // a failed attempt moves neither completed nor errors
        assert_eq!(mid.completed, before.completed);
        assert_eq!(mid.errors, before.errors);
        assert_eq!(mid.inflight, before.inflight);
        parent.deliver(0, c.key, c.buf, c.result);
        let after = ring.stats();
        assert_eq!(after.completed, before.completed + 1);
        assert_eq!(after.errors, before.errors);
        assert_eq!(trap.0.lock().unwrap().len(), 2);
    }

    #[test]
    fn track_guard_moves_the_gauge() {
        let ring = IoRing::new(mem(1), 4);
        {
            let _g1 = ring.track();
            let _g2 = ring.track();
            assert_eq!(ring.stats().inflight, 2);
        }
        assert_eq!(ring.stats().inflight, 0);
        assert!(ring.stats().inflight_hwm >= 2);
    }
}
