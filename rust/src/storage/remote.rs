//! Simulated remote object stores.
//!
//! [`SimRemoteStore`] wraps any backing store with the timing structure
//! of a remote storage service: per-request first-byte latency, a
//! per-connection stream bandwidth, a shared NIC link, and a maximum
//! connection count. Both a blocking path (thread sleeps — what the
//! threaded/vanilla fetchers see) and an async path (`asyncrt` timer
//! sleeps — what the asyncio fetcher sees) are provided; both go through
//! the same connection-limit semaphore and the same NIC FIFO.
//!
//! [`RemoteProfile`] carries the calibrated presets per storage type
//! (DESIGN.md §4): `s3`, `scratch`, `ceph_os`, `ceph_fs`, `gluster_fs`,
//! plus `colab_s3` for the §A.2 sanity check.

use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use anyhow::Result;

use super::fault::FaultInjector;
use super::{BoxFut, Bytes, ObjectStore, ReadOp, RingCtx, StatCounters, StoreStats};
use crate::asyncrt;
use crate::simnet::{Link, LatencyModel};
use crate::util::rng::Rng;

/// Timing profile of a remote storage service.
#[derive(Debug, Clone)]
pub struct RemoteProfile {
    pub name: &'static str,
    pub first_byte: LatencyModel,
    /// single-connection stream bandwidth
    pub per_conn_mbit_s: f64,
    /// aggregate NIC / service bandwidth
    pub nic_mbit_s: f64,
    /// maximum concurrent connections before requests queue
    pub max_conns: usize,
}

impl RemoteProfile {
    /// AWS-S3-like object storage (the paper's high-latency case:
    /// ~120 ms median first byte, long tail, modest per-stream rate).
    pub fn s3() -> RemoteProfile {
        RemoteProfile {
            name: "s3",
            first_byte: LatencyModel::Mixture {
                median: 0.120,
                sigma: 0.55,
                p_slow: 0.03,
                slow_factor: 3.0,
            },
            per_conn_mbit_s: 25.0,
            nic_mbit_s: 800.0,
            max_conns: 128,
        }
    }

    /// Local NVMe "scratch": sub-ms access, very high stream rate.
    pub fn scratch() -> RemoteProfile {
        RemoteProfile {
            name: "scratch",
            first_byte: LatencyModel::LogNormal { median: 0.00035, sigma: 0.4 },
            per_conn_mbit_s: 4000.0,
            nic_mbit_s: 16000.0,
            max_conns: 4096,
        }
    }

    /// Ceph object store — the slowest backend in the paper's App A.1.
    pub fn ceph_os() -> RemoteProfile {
        RemoteProfile {
            name: "ceph_os",
            first_byte: LatencyModel::Mixture {
                median: 0.300,
                sigma: 0.6,
                p_slow: 0.05,
                slow_factor: 3.0,
            },
            per_conn_mbit_s: 15.0,
            nic_mbit_s: 400.0,
            max_conns: 128,
        }
    }

    /// Ceph FS mounted over the datacenter network.
    pub fn ceph_fs() -> RemoteProfile {
        RemoteProfile {
            name: "ceph_fs",
            first_byte: LatencyModel::LogNormal { median: 0.0012, sigma: 0.5 },
            per_conn_mbit_s: 1500.0,
            nic_mbit_s: 8000.0,
            max_conns: 1024,
        }
    }

    /// Gluster FS mounted over the datacenter network.
    pub fn gluster_fs() -> RemoteProfile {
        RemoteProfile {
            name: "gluster_fs",
            first_byte: LatencyModel::LogNormal { median: 0.0018, sigma: 0.5 },
            per_conn_mbit_s: 1200.0,
            nic_mbit_s: 6000.0,
            max_conns: 1024,
        }
    }

    /// S3 reached from a constrained Colab-like VM (§A.2): higher RTT,
    /// lower aggregate bandwidth, few cores.
    pub fn colab_s3() -> RemoteProfile {
        RemoteProfile {
            name: "colab_s3",
            first_byte: LatencyModel::Mixture {
                median: 0.180,
                sigma: 0.6,
                p_slow: 0.05,
                slow_factor: 3.0,
            },
            per_conn_mbit_s: 15.0,
            nic_mbit_s: 120.0,
            max_conns: 64,
        }
    }

    pub fn by_name(name: &str) -> Option<RemoteProfile> {
        Some(match name {
            "s3" => Self::s3(),
            "scratch" => Self::scratch(),
            "ceph_os" => Self::ceph_os(),
            "ceph_fs" => Self::ceph_fs(),
            "gluster_fs" => Self::gluster_fs(),
            "colab_s3" => Self::colab_s3(),
            _ => return None,
        })
    }

    /// Scale all latencies (benchmark `Scale` knob); bandwidths are left
    /// alone (scaling them would change *which* resource saturates).
    pub fn scaled(mut self, f: f64) -> RemoteProfile {
        self.first_byte = self.first_byte.scaled(f);
        self
    }
}

/// A store wrapped with remote-service timing.
pub struct SimRemoteStore {
    inner: Arc<dyn ObjectStore>,
    profile: RemoteProfile,
    per_conn: Link,
    nic: Link,
    conns: Arc<asyncrt::Semaphore>,
    rng: Mutex<Rng>,
    stats: StatCounters,
    /// recorded per-request service times (seconds) for report medians
    request_times: Mutex<Vec<f64>>,
    /// optional chaos plane: every read shape (blocking, async, and the
    /// batched-submission path) rolls this injector after taking its
    /// connection slot — exactly where a real remote would fail
    faults: OnceLock<Arc<FaultInjector>>,
}

impl SimRemoteStore {
    pub fn new(
        inner: Arc<dyn ObjectStore>,
        profile: RemoteProfile,
        seed: u64,
    ) -> Arc<SimRemoteStore> {
        Arc::new(SimRemoteStore {
            per_conn: Link::new_mbit_s(profile.per_conn_mbit_s),
            nic: Link::new_mbit_s(profile.nic_mbit_s),
            conns: asyncrt::Semaphore::new(profile.max_conns),
            profile,
            inner,
            rng: Mutex::new(Rng::new(seed)),
            stats: StatCounters::default(),
            request_times: Mutex::new(Vec::new()),
            faults: OnceLock::new(),
        })
    }

    pub fn profile(&self) -> &RemoteProfile {
        &self.profile
    }

    /// Attach a fault injector (set once at rig build time; an inert
    /// `FaultProfile::none()` injector costs one `OnceLock` load).
    pub fn set_faults(&self, injector: Arc<FaultInjector>) {
        let _ = self.faults.set(injector);
    }

    pub fn faults(&self) -> Option<&Arc<FaultInjector>> {
        self.faults.get()
    }

    /// Roll the chaos plane for one request on the blocking path:
    /// error-kind faults bail, stalls sleep on the calling thread.
    fn inject_blocking(&self, key: &str) -> Result<()> {
        if let Some(inj) = self.faults.get() {
            if let Some(stall) = inj.roll(key)? {
                std::thread::sleep(stall);
            }
        }
        Ok(())
    }

    /// Async twin of [`Self::inject_blocking`]: returns any stall delay
    /// for the caller to `asyncrt::sleep` (so the executor thread is
    /// never blocked).
    fn inject_planned(&self, key: &str) -> Result<Option<Duration>> {
        match self.faults.get() {
            Some(inj) => inj.roll(key),
            None => Ok(None),
        }
    }

    /// Compute this request's service time (latency draw + bandwidth
    /// reservation). Shared by the sync and async paths.
    fn plan(&self, bytes: u64) -> Duration {
        let fb = {
            let mut rng = self.rng.lock().unwrap();
            self.profile.first_byte.sample(&mut rng)
        };
        let stream = self.per_conn.nominal(bytes);
        let shared = self.nic.reserve(bytes);
        fb + stream.max(shared)
    }

    fn record(&self, bytes: u64, service: Duration) {
        self.stats.record_get(bytes);
        self.request_times.lock().unwrap().push(service.as_secs_f64());
    }

    /// Median observed request time so far (the paper's right-heatmap
    /// metric).
    pub fn median_request_time(&self) -> f64 {
        crate::util::stats::median(&self.request_times.lock().unwrap())
    }

    pub fn request_times(&self) -> Vec<f64> {
        self.request_times.lock().unwrap().clone()
    }
}

impl ObjectStore for SimRemoteStore {
    fn get(&self, key: &str) -> Result<Bytes> {
        // connection slot (blocking acquire via block_on)
        let _permit = asyncrt::block_on(self.conns.acquire());
        self.inject_blocking(key)?;
        let data = self.inner.get(key)?;
        let service = self.plan(data.len() as u64);
        std::thread::sleep(service);
        self.record(data.len() as u64, service);
        Ok(data)
    }

    fn get_async<'a>(&'a self, key: &'a str) -> BoxFut<'a, Result<Bytes>> {
        Box::pin(async move {
            let _permit = self.conns.acquire().await;
            if let Some(stall) = self.inject_planned(key)? {
                asyncrt::sleep(stall).await;
            }
            let data = self.inner.get(key)?;
            let service = self.plan(data.len() as u64);
            asyncrt::sleep(service).await;
            self.record(data.len() as u64, service);
            Ok(data)
        })
    }

    fn get_into(&self, key: &str, out: &mut [u8]) -> Result<usize> {
        let _permit = asyncrt::block_on(self.conns.acquire());
        self.inject_blocking(key)?;
        let n = self.inner.get_into(key, out)?;
        if n > out.len() {
            // size probe (buffer too small, nothing transferred): no
            // latency draw, like `contains` — the caller retries with a
            // grown buffer and pays the service time then
            return Ok(n);
        }
        let service = self.plan(n as u64);
        std::thread::sleep(service);
        self.record(n as u64, service);
        Ok(n)
    }

    fn get_range_into(&self, key: &str, offset: u64, out: &mut [u8]) -> Result<usize> {
        // one connection, one first-byte latency draw, bandwidth charged
        // over the *range* — this is what makes a single shard-window
        // read amortize the round trip over hundreds of samples instead
        // of paying it once per image
        let _permit = asyncrt::block_on(self.conns.acquire());
        self.inject_blocking(key)?;
        let n = self.inner.get_range_into(key, offset, out)?;
        let service = self.plan(n as u64);
        std::thread::sleep(service);
        self.record(n as u64, service);
        Ok(n)
    }

    fn native_get_into(&self) -> bool {
        self.inner.native_get_into()
    }

    /// Native batched submission: one future per op on the ring
    /// executor, so every op past the `io_depth` and connection gates is
    /// genuinely concurrent — the NIC FIFO sees the whole batch at once
    /// and real queueing emerges, which the one-request-per-thread model
    /// structurally hides.
    fn submit_batch(self: Arc<Self>, ops: Vec<ReadOp>, ctx: RingCtx) {
        for mut op in ops {
            let this = self.clone();
            let c = ctx.clone();
            drop(ctx.rt().spawn(async move {
                let _depth = c.depth().acquire().await;
                let _conn = this.conns.acquire().await;
                c.begin();
                match this.inject_planned(&op.key) {
                    Ok(None) => {}
                    Ok(Some(stall)) => asyncrt::sleep(stall).await,
                    Err(e) => {
                        c.complete(op.slot, op.key, op.buf, Err(e));
                        return;
                    }
                }
                let res = if op.len > 0 {
                    op.buf.resize(op.len, 0);
                    this.inner.get_range_into(&op.key, op.offset, &mut op.buf)
                } else {
                    this.inner.get(&op.key).map(|data| {
                        op.buf.clear();
                        op.buf.extend_from_slice(&data);
                        data.len()
                    })
                };
                match res {
                    Ok(n) => {
                        let service = this.plan(n as u64);
                        asyncrt::sleep(service).await;
                        this.record(n as u64, service);
                        c.complete(op.slot, op.key, op.buf, Ok(n));
                    }
                    Err(e) => c.complete(op.slot, op.key, op.buf, Err(e)),
                }
            }));
        }
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.inner.put(key, data)
    }

    fn keys(&self) -> Vec<String> {
        self.inner.keys()
    }

    fn contains(&self, key: &str) -> bool {
        // metadata lookup: no latency draw, no bandwidth reservation
        self.inner.contains(key)
    }

    fn hint_order(&self, epoch: usize, keys: &[String]) {
        self.inner.hint_order(epoch, keys)
    }

    fn hint_order_append(&self, epoch: usize, keys: &[String]) {
        self.inner.hint_order_append(epoch, keys)
    }

    fn label(&self) -> String {
        self.profile.name.to_string()
    }

    fn stats(&self) -> StoreStats {
        self.stats.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;
    use std::time::Instant;

    fn mk(profile: RemoteProfile) -> Arc<SimRemoteStore> {
        let mem = Arc::new(MemStore::new("backing"));
        mem.put("k", vec![0u8; 100 * 1024]).unwrap();
        SimRemoteStore::new(mem, profile, 42)
    }

    #[test]
    fn s3_get_pays_latency() {
        let s = mk(RemoteProfile::s3().scaled(0.25)); // ~30 ms median
        let t0 = Instant::now();
        let data = s.get("k").unwrap();
        assert_eq!(data.len(), 100 * 1024);
        assert!(t0.elapsed() >= Duration::from_millis(5), "{:?}", t0.elapsed());
        assert!(s.median_request_time() > 0.0);
    }

    #[test]
    fn scratch_get_is_fast() {
        let s = mk(RemoteProfile::scratch());
        let t0 = Instant::now();
        s.get("k").unwrap();
        assert!(t0.elapsed() < Duration::from_millis(50), "{:?}", t0.elapsed());
    }

    #[test]
    fn async_path_overlaps_on_one_thread() {
        // 8 concurrent async gets on a 1-thread runtime should take ~max
        // service time, not ~sum — the asyncio win the paper reports.
        let s = mk(RemoteProfile::s3().scaled(0.25));
        let rt = asyncrt::Runtime::new(1);
        let t0 = Instant::now();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let s = s.clone();
                rt.spawn(async move { s.get_async("k").await.unwrap().len() })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join(), 100 * 1024);
        }
        let seq_estimate: f64 = s.request_times().iter().sum();
        assert!(
            t0.elapsed().as_secs_f64() < 0.7 * seq_estimate,
            "no overlap: wall {:?} vs sum {seq_estimate}",
            t0.elapsed()
        );
    }

    #[test]
    fn ranged_read_pays_one_latency_over_the_range() {
        let s = mk(RemoteProfile::s3().scaled(0.1));
        let mut out = vec![0u8; 4 * 1024];
        let t0 = Instant::now();
        assert_eq!(s.get_range_into("k", 8 * 1024, &mut out).unwrap(), 4 * 1024);
        assert!(t0.elapsed() >= Duration::from_millis(2), "{:?}", t0.elapsed());
        // exactly one request, charged only the range bytes
        assert_eq!(s.stats().gets, 1);
        assert_eq!(s.stats().bytes as usize, 4 * 1024);
        assert!(s.get_range_into("k", 200 * 1024, &mut out).is_err());
    }

    #[test]
    fn profiles_by_name() {
        for n in ["s3", "scratch", "ceph_os", "ceph_fs", "gluster_fs", "colab_s3"] {
            assert_eq!(RemoteProfile::by_name(n).unwrap().name, n);
        }
        assert!(RemoteProfile::by_name("nope").is_none());
    }

    #[test]
    fn contains_pays_no_latency_or_stats() {
        let s = mk(RemoteProfile::s3()); // full 120 ms median latency
        let t0 = Instant::now();
        assert!(s.contains("k"));
        assert!(!s.contains("nope"));
        assert!(
            t0.elapsed() < Duration::from_millis(20),
            "contains hit the data path: {:?}",
            t0.elapsed()
        );
        assert_eq!(s.stats().gets, 0);
        assert_eq!(s.stats().bytes, 0);
    }

    #[test]
    fn fault_injection_rides_every_remote_path() {
        use crate::storage::fault::{FaultInjector, FaultProfile};
        use crate::storage::{IoRing, ReadOp};
        let s = mk(RemoteProfile::scratch());
        let inj = FaultInjector::new(FaultProfile::outage(), 7);
        s.set_faults(inj.clone());
        let mut out = vec![0u8; 100 * 1024];
        assert!(s.get("k").is_err());
        assert!(s.get_into("k", &mut out).is_err());
        assert!(s.get_range_into("k", 0, &mut out[..1024]).is_err());
        assert!(asyncrt::block_on(s.get_async("k")).is_err());
        // batched-submission path injects per op too
        let ring = IoRing::new(s.clone(), 4);
        let mut sub = ring.submit(vec![ReadOp::whole(0, "k".into(), Vec::new())]);
        assert!(sub.next().unwrap().result.is_err());
        assert_eq!(inj.counters().injected(), 5);
        // healing the profile heals the store (and nothing was recorded
        // for the failed requests)
        assert_eq!(s.stats().gets, 0);
        inj.set_profile(FaultProfile::none());
        assert_eq!(s.get("k").unwrap().len(), 100 * 1024);
    }

    #[test]
    fn stats_accumulate() {
        let s = mk(RemoteProfile::scratch());
        s.get("k").unwrap();
        s.get("k").unwrap();
        assert_eq!(s.stats().gets, 2);
        assert_eq!(s.stats().bytes as usize, 2 * 100 * 1024);
    }
}
