//! Varnish-like byte-capped LRU cache in front of any store (§2.4
//! "Caching" of the paper). The paper caps the cache at 2 GB — far below
//! dataset size — so random access produces mostly misses; the cache
//! helps exactly the configurations the paper says it helps (slow
//! vanilla loaders) and we reproduce that in `bench_cache`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::{BoxFut, Bytes, ObjectStore, StatCounters, StoreStats};

struct Entry {
    key: String,
    data: Bytes,
    prev: usize,
    next: usize,
}

const NIL: usize = usize::MAX;

/// Intrusive-list LRU keyed by object, capped by total payload bytes.
struct Lru {
    map: HashMap<String, usize>,
    slab: Vec<Entry>,
    free: Vec<usize>,
    head: usize, // most recent
    tail: usize, // least recent
    bytes: u64,
    capacity: u64,
}

impl Lru {
    fn new(capacity: u64) -> Lru {
        Lru {
            map: HashMap::new(),
            slab: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            bytes: 0,
            capacity,
        }
    }

    fn unlink(&mut self, i: usize) {
        let (p, n) = (self.slab[i].prev, self.slab[i].next);
        if p != NIL {
            self.slab[p].next = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.slab[n].prev = p;
        } else {
            self.tail = p;
        }
    }

    fn push_front(&mut self, i: usize) {
        self.slab[i].prev = NIL;
        self.slab[i].next = self.head;
        if self.head != NIL {
            self.slab[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    fn get(&mut self, key: &str) -> Option<Bytes> {
        let &i = self.map.get(key)?;
        self.unlink(i);
        self.push_front(i);
        Some(self.slab[i].data.clone())
    }

    /// Insert; returns number of evictions performed.
    fn insert(&mut self, key: &str, data: Bytes) -> u64 {
        if data.len() as u64 > self.capacity {
            return 0; // object larger than the whole cache: don't cache
        }
        if let Some(&i) = self.map.get(key) {
            self.bytes -= self.slab[i].data.len() as u64;
            self.bytes += data.len() as u64;
            self.slab[i].data = data;
            self.unlink(i);
            self.push_front(i);
            return self.evict_to_fit();
        }
        let entry = Entry {
            key: key.to_string(),
            data: data.clone(),
            prev: NIL,
            next: NIL,
        };
        let i = if let Some(i) = self.free.pop() {
            self.slab[i] = entry;
            i
        } else {
            self.slab.push(entry);
            self.slab.len() - 1
        };
        self.map.insert(key.to_string(), i);
        self.bytes += data.len() as u64;
        self.push_front(i);
        self.evict_to_fit()
    }

    fn evict_to_fit(&mut self) -> u64 {
        let mut evicted = 0;
        while self.bytes > self.capacity && self.tail != NIL {
            let i = self.tail;
            self.unlink(i);
            self.bytes -= self.slab[i].data.len() as u64;
            let key = std::mem::take(&mut self.slab[i].key);
            self.slab[i].data = Bytes::new(Vec::new());
            self.map.remove(&key);
            self.free.push(i);
            evicted += 1;
        }
        evicted
    }
}

/// Byte-capped LRU cache wrapping a (typically remote) store.
pub struct VarnishCache {
    inner: Arc<dyn ObjectStore>,
    lru: Mutex<Lru>,
    stats: StatCounters,
}

impl VarnishCache {
    pub fn new(inner: Arc<dyn ObjectStore>, capacity_bytes: u64) -> Arc<VarnishCache> {
        Arc::new(VarnishCache {
            inner,
            lru: Mutex::new(Lru::new(capacity_bytes)),
            stats: StatCounters::default(),
        })
    }

    pub fn cached_bytes(&self) -> u64 {
        self.lru.lock().unwrap().bytes
    }

    pub fn capacity(&self) -> u64 {
        self.lru.lock().unwrap().capacity
    }

    /// hit ratio so far
    pub fn hit_ratio(&self) -> f64 {
        let s = self.stats.snapshot();
        if s.gets == 0 {
            return 0.0;
        }
        s.hits as f64 / s.gets as f64
    }

    fn lookup(&self, key: &str) -> Option<Bytes> {
        let mut lru = self.lru.lock().unwrap();
        lru.get(key)
    }

    fn fill(&self, key: &str, data: Bytes) {
        let evicted = self.lru.lock().unwrap().insert(key, data);
        self.stats
            .evictions
            .fetch_add(evicted, std::sync::atomic::Ordering::Relaxed);
    }
}

impl ObjectStore for VarnishCache {
    fn get(&self, key: &str) -> Result<Bytes> {
        if let Some(hit) = self.lookup(key) {
            self.stats.hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.stats.record_get(hit.len() as u64);
            return Ok(hit);
        }
        self.stats.misses.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let data = self.inner.get(key)?; // pays the remote cost
        self.stats.record_get(data.len() as u64);
        self.fill(key, data.clone());
        Ok(data)
    }

    fn get_async<'a>(&'a self, key: &'a str) -> BoxFut<'a, Result<Bytes>> {
        Box::pin(async move {
            if let Some(hit) = self.lookup(key) {
                self.stats
                    .hits
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.stats.record_get(hit.len() as u64);
                return Ok(hit);
            }
            self.stats
                .misses
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let data = self.inner.get_async(key).await?;
            self.stats.record_get(data.len() as u64);
            self.fill(key, data.clone());
            Ok(data)
        })
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.inner.put(key, data)
    }

    fn keys(&self) -> Vec<String> {
        self.inner.keys()
    }

    fn contains(&self, key: &str) -> bool {
        self.lru.lock().unwrap().map.contains_key(key) || self.inner.contains(key)
    }

    fn hint_order(&self, epoch: usize, keys: &[String]) {
        self.inner.hint_order(epoch, keys)
    }

    fn label(&self) -> String {
        format!("varnish({})", self.inner.label())
    }

    fn stats(&self) -> StoreStats {
        self.stats.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;

    fn backing(n: usize, size: usize) -> Arc<MemStore> {
        let m = Arc::new(MemStore::new("b"));
        for i in 0..n {
            m.put(&format!("k{i}"), vec![i as u8; size]).unwrap();
        }
        m
    }

    #[test]
    fn hit_after_miss() {
        let c = VarnishCache::new(backing(4, 100), 1000);
        c.get("k0").unwrap();
        c.get("k0").unwrap();
        let s = c.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 1);
        assert!((c.hit_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn never_exceeds_capacity() {
        let c = VarnishCache::new(backing(20, 100), 350);
        for i in 0..20 {
            c.get(&format!("k{i}")).unwrap();
            assert!(c.cached_bytes() <= 350, "over cap: {}", c.cached_bytes());
        }
        assert!(c.stats().evictions > 0);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let c = VarnishCache::new(backing(3, 100), 200); // fits 2
        c.get("k0").unwrap();
        c.get("k1").unwrap();
        c.get("k0").unwrap(); // k0 now MRU
        c.get("k2").unwrap(); // evicts k1
        let before = c.stats().misses;
        c.get("k0").unwrap(); // hit
        assert_eq!(c.stats().misses, before);
        c.get("k1").unwrap(); // miss again
        assert_eq!(c.stats().misses, before + 1);
    }

    #[test]
    fn oversized_object_not_cached() {
        let m = Arc::new(MemStore::new("b"));
        m.put("big", vec![0; 1000]).unwrap();
        let c = VarnishCache::new(m, 100);
        c.get("big").unwrap();
        c.get("big").unwrap();
        assert_eq!(c.stats().hits, 0);
        assert_eq!(c.cached_bytes(), 0);
    }

    #[test]
    fn async_path_caches_too() {
        let c = VarnishCache::new(backing(2, 50), 1000);
        crate::asyncrt::block_on(async {
            c.get_async("k0").await.unwrap();
            c.get_async("k0").await.unwrap();
        });
        assert_eq!(c.stats().hits, 1);
    }
}
