//! Varnish-like byte-capped cache in front of any store (§2.4
//! "Caching" of the paper). The paper caps the cache at 2 GB — far below
//! dataset size — so random access produces mostly misses; the cache
//! helps exactly the configurations the paper says it helps (slow
//! vanilla loaders) and we reproduce that in `bench_cache`.
//!
//! Eviction runs on the unified O(1) core ([`super::evict::EvictCore`]);
//! the policy defaults to LRU (matching Varnish) but any
//! [`CachePolicy`] can be selected via [`VarnishCache::with_policy`]
//! (config knob `cache_policy`).

use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::evict::{CachePolicy, CoreStats, EvictCore};
use super::{BoxFut, Bytes, ObjectStore, ReadOp, RingCtx, StatCounters, StoreStats};

/// Byte-capped cache wrapping a (typically remote) store.
pub struct VarnishCache {
    inner: Arc<dyn ObjectStore>,
    core: Mutex<EvictCore>,
    stats: StatCounters,
}

impl VarnishCache {
    /// LRU cache (Varnish's default behavior).
    pub fn new(inner: Arc<dyn ObjectStore>, capacity_bytes: u64) -> Arc<VarnishCache> {
        VarnishCache::with_policy(inner, capacity_bytes, CachePolicy::Lru)
    }

    /// Cache with an explicit eviction policy.
    pub fn with_policy(
        inner: Arc<dyn ObjectStore>,
        capacity_bytes: u64,
        policy: CachePolicy,
    ) -> Arc<VarnishCache> {
        Arc::new(VarnishCache {
            inner,
            core: Mutex::new(EvictCore::new(policy, capacity_bytes)),
            stats: StatCounters::default(),
        })
    }

    pub fn cached_bytes(&self) -> u64 {
        self.core.lock().unwrap().bytes()
    }

    pub fn capacity(&self) -> u64 {
        self.core.lock().unwrap().capacity()
    }

    pub fn policy(&self) -> CachePolicy {
        self.core.lock().unwrap().policy()
    }

    /// Unified per-tier counters from the eviction core.
    pub fn tier_stats(&self) -> CoreStats {
        self.core.lock().unwrap().stats()
    }

    /// Hit ratio so far; 0.0 (not NaN) before any lookup has occurred.
    pub fn hit_ratio(&self) -> f64 {
        self.tier_stats().hit_ratio()
    }

    /// Re-verify the eviction core's internal accounting (O(entries);
    /// for tests and stress suites).
    pub fn audit(&self) -> std::result::Result<(), String> {
        self.core.lock().unwrap().audit()
    }

    fn lookup(&self, key: &str) -> Option<Bytes> {
        self.core.lock().unwrap().get(key)
    }

    fn fill(&self, key: &str, data: Bytes) {
        self.core.lock().unwrap().insert(key, data);
    }

    /// Borrow-based admission: cache `data` without taking ownership of
    /// the caller's buffer (the cache makes its own copy). This is the
    /// admission route for the zero-copy `get_into` path, whose callers
    /// read into reused scratch buffers they cannot hand over — before
    /// this API, scratch-path misses bypassed the cache entirely and a
    /// `get_into`-routed dataset could never warm it. The copy happens
    /// once per *admission* (miss), not per read; hits stay copy-out.
    pub fn admit(&self, key: &str, data: &[u8]) {
        self.fill(key, Bytes::new(data.to_vec()));
    }
}

impl ObjectStore for VarnishCache {
    fn get(&self, key: &str) -> Result<Bytes> {
        // the core counts the hit/miss; StatCounters only tracks volume
        if let Some(hit) = self.lookup(key) {
            self.stats.record_get(hit.len() as u64);
            return Ok(hit);
        }
        let data = self.inner.get(key)?; // pays the remote cost
        self.stats.record_get(data.len() as u64);
        self.fill(key, data.clone());
        Ok(data)
    }

    fn get_async<'a>(&'a self, key: &'a str) -> BoxFut<'a, Result<Bytes>> {
        Box::pin(async move {
            if let Some(hit) = self.lookup(key) {
                self.stats.record_get(hit.len() as u64);
                return Ok(hit);
            }
            let data = self.inner.get_async(key).await?;
            self.stats.record_get(data.len() as u64);
            self.fill(key, data.clone());
            Ok(data)
        })
    }

    fn get_into(&self, key: &str, out: &mut [u8]) -> Result<usize> {
        // hit: copy out of the cached Bytes (the core still counts it)
        if let Some(hit) = self.lookup(key) {
            let n = hit.len();
            if n <= out.len() {
                out[..n].copy_from_slice(&hit);
                self.stats.record_get(n as u64);
            }
            return Ok(n);
        }
        // miss: delegate down into the caller's buffer, then admit the
        // object from the borrowed slice (the cache copies once for
        // itself; the caller's scratch is untouched and never owned).
        // Size probes (buffer too small) transfer nothing and admit
        // nothing — the grow-and-retry pass pays the fill.
        let n = self.inner.get_into(key, out)?;
        if n <= out.len() {
            self.stats.record_get(n as u64);
            self.admit(key, &out[..n]);
        }
        Ok(n)
    }

    fn native_get_into(&self) -> bool {
        // forwarded since admission works on the `get_into` miss path
        // too (`VarnishCache::admit`): a dir-backed stack keeps its
        // zero-copy pread reads *and* still warms the cache, so hits
        // skip the file read entirely on the next epoch.
        self.inner.native_get_into()
    }

    /// Native batched submission: hits complete inline out of the
    /// cached `Bytes`; the miss set delegates to the inner store's own
    /// native path as one smaller batch, so misses keep the remote-side
    /// concurrency the ring exists for. Ring misses are deliberately
    /// *not* admitted: admission here would mean reaping inner
    /// completions on the dispatch task (serializing the batch behind
    /// its own tail) — demand traffic through the blocking paths still
    /// warms the cache as before.
    fn submit_batch(self: Arc<Self>, ops: Vec<ReadOp>, ctx: RingCtx) {
        let mut misses = Vec::new();
        for op in ops {
            let Some(hit) = self.lookup(&op.key) else {
                misses.push(op);
                continue;
            };
            let ReadOp { slot, key, offset, len, mut buf } = op;
            ctx.begin();
            let res = if len > 0 {
                buf.resize(len, 0);
                super::range_from_bytes(&hit, &key, offset, &mut buf)
            } else {
                buf.clear();
                buf.extend_from_slice(&hit);
                Ok(hit.len())
            };
            if let Ok(n) = &res {
                self.stats.record_get(*n as u64);
            }
            ctx.complete(slot, key, buf, res);
        }
        if !misses.is_empty() {
            self.inner.clone().submit_batch(misses, ctx);
        }
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.inner.put(key, data)?;
        // best-effort invalidation: drop any cached copy so later reads
        // see the new object (a get() racing this put can still re-fill
        // the old bytes — the usual cache/write race)
        self.core.lock().unwrap().remove(key);
        Ok(())
    }

    fn keys(&self) -> Vec<String> {
        self.inner.keys()
    }

    fn contains(&self, key: &str) -> bool {
        self.core.lock().unwrap().contains(key) || self.inner.contains(key)
    }

    fn hint_order(&self, epoch: usize, keys: &[String]) {
        self.inner.hint_order(epoch, keys)
    }

    fn hint_order_append(&self, epoch: usize, keys: &[String]) {
        self.inner.hint_order_append(epoch, keys)
    }

    fn label(&self) -> String {
        format!("varnish({})", self.inner.label())
    }

    fn stats(&self) -> StoreStats {
        // gets/bytes from the transfer counters; hit/miss/eviction truth
        // lives in the eviction core
        let s = self.stats.snapshot();
        let t = self.core.lock().unwrap().stats();
        StoreStats {
            gets: s.gets,
            bytes: s.bytes,
            hits: t.hits,
            misses: t.misses,
            evictions: t.evictions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;

    fn backing(n: usize, size: usize) -> Arc<MemStore> {
        let m = Arc::new(MemStore::new("b"));
        for i in 0..n {
            m.put(&format!("k{i}"), vec![i as u8; size]).unwrap();
        }
        m
    }

    #[test]
    fn hit_after_miss() {
        let c = VarnishCache::new(backing(4, 100), 1000);
        c.get("k0").unwrap();
        c.get("k0").unwrap();
        let s = c.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 1);
        assert!((c.hit_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn hit_ratio_defined_before_any_lookup() {
        let c = VarnishCache::new(backing(1, 10), 100);
        let r = c.hit_ratio();
        assert!(!r.is_nan(), "hit_ratio must never be NaN");
        assert_eq!(r, 0.0);
    }

    #[test]
    fn never_exceeds_capacity() {
        let c = VarnishCache::new(backing(20, 100), 350);
        for i in 0..20 {
            c.get(&format!("k{i}")).unwrap();
            assert!(c.cached_bytes() <= 350, "over cap: {}", c.cached_bytes());
        }
        assert!(c.stats().evictions > 0);
        c.audit().unwrap();
    }

    #[test]
    fn lru_evicts_least_recent() {
        let c = VarnishCache::new(backing(3, 100), 200); // fits 2
        c.get("k0").unwrap();
        c.get("k1").unwrap();
        c.get("k0").unwrap(); // k0 now MRU
        c.get("k2").unwrap(); // evicts k1
        let before = c.stats().misses;
        c.get("k0").unwrap(); // hit
        assert_eq!(c.stats().misses, before);
        c.get("k1").unwrap(); // miss again
        assert_eq!(c.stats().misses, before + 1);
    }

    #[test]
    fn twoq_policy_selectable() {
        let c = VarnishCache::with_policy(backing(3, 100), 200, CachePolicy::TwoQ);
        assert_eq!(c.policy(), CachePolicy::TwoQ);
        c.get("k0").unwrap();
        c.get("k1").unwrap();
        c.get("k2").unwrap(); // evicts k0 from probation → ghost
        c.get("k0").unwrap(); // refill: ghost promotion to main
        assert_eq!(c.tier_stats().ghost_promotions, 1);
        c.audit().unwrap();
    }

    #[test]
    fn put_invalidates_cached_copy() {
        let c = VarnishCache::new(backing(1, 100), 1000);
        c.get("k0").unwrap(); // cached at 100 bytes
        c.put("k0", vec![7u8; 40]).unwrap();
        let fresh = c.get("k0").unwrap();
        assert_eq!(fresh.len(), 40, "stale cached payload served");
        assert_eq!(fresh[0], 7);
        c.audit().unwrap();
    }

    #[test]
    fn oversized_object_not_cached() {
        let m = Arc::new(MemStore::new("b"));
        m.put("big", vec![0; 1000]).unwrap();
        let c = VarnishCache::new(m, 100);
        c.get("big").unwrap();
        c.get("big").unwrap();
        assert_eq!(c.stats().hits, 0);
        assert_eq!(c.cached_bytes(), 0);
    }

    #[test]
    fn get_into_miss_admits_from_borrowed_slice() {
        let c = VarnishCache::new(backing(2, 100), 1000);
        let mut buf = vec![0u8; 128];
        assert_eq!(c.get_into("k0", &mut buf).unwrap(), 100);
        // the miss admitted the object from the caller's scratch: the
        // next read — via either path — is a hit
        assert_eq!(c.cached_bytes(), 100);
        let before = c.stats().hits;
        assert_eq!(c.get_into("k0", &mut buf).unwrap(), 100);
        c.get("k0").unwrap();
        assert_eq!(c.stats().hits, before + 2);
        // a size probe (too-small buffer) transfers nothing and admits
        // nothing
        let mut tiny = vec![0u8; 8];
        assert_eq!(c.get_into("k1", &mut tiny).unwrap(), 100);
        assert_eq!(c.cached_bytes(), 100);
        c.audit().unwrap();
    }

    #[test]
    fn admit_api_populates_without_ownership() {
        let c = VarnishCache::new(backing(1, 10), 1000);
        let scratch = vec![7u8; 64];
        c.admit("kx", &scratch[..32]);
        drop(scratch); // cache owns its own copy
        assert_eq!(c.cached_bytes(), 32);
        assert!(c.contains("kx"));
    }

    #[test]
    fn native_get_into_forwards_from_the_inner_store() {
        // MemStore backing: no native scratch path → the facade reports
        // none; a DirStore backing forwards true (on unix), since the
        // admission change means routing reads through get_into no
        // longer starves the cache
        let c = VarnishCache::new(backing(1, 10), 100);
        assert!(!c.native_get_into());
        let root = std::env::temp_dir()
            .join(format!("cdl-varnish-native-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let dir = Arc::new(crate::storage::DirStore::open(&root).unwrap());
        dir.put("k", vec![5u8; 32]).unwrap();
        let c = VarnishCache::new(dir, 1000);
        assert_eq!(c.native_get_into(), cfg!(unix));
        if cfg!(unix) {
            // end to end: a scratch read admits, the repeat is a hit
            let mut buf = vec![0u8; 64];
            assert_eq!(c.get_into("k", &mut buf).unwrap(), 32);
            assert!(buf[..32].iter().all(|&b| b == 5));
            assert_eq!(c.cached_bytes(), 32);
            assert_eq!(c.get_into("k", &mut buf).unwrap(), 32);
            assert_eq!(c.stats().hits, 1);
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn async_path_caches_too() {
        let c = VarnishCache::new(backing(2, 50), 1000);
        crate::asyncrt::block_on(async {
            c.get_async("k0").await.unwrap();
            c.get_async("k0").await.unwrap();
        });
        assert_eq!(c.stats().hits, 1);
    }
}
