//! Unified O(1) eviction core for every byte-capped cache in the tree.
//!
//! Before this module existed the repo carried two divergent byte-capped
//! caches: an intrusive O(1) LRU list inside `storage/cache.rs` and an
//! O(n) `min_by_key` victim scan inside `prefetch/tier.rs`. Both
//! [`super::VarnishCache`] and the prefetch hot tier
//! (`crate::prefetch::tier::HotTier`) are now thin facades over one
//! [`EvictCore`]:
//!
//! * a **slab** of entries addressed by index, recycled through a free
//!   list — no per-operation allocation beyond the key string;
//! * three **intrusive doubly-linked lists** (probation, main, ghost)
//!   selected by a per-entry queue tag, each with its own head/tail and
//!   byte/length accounting, so every link/unlink/victim-pick is O(1);
//! * a **ghost list** that remembers recently evicted keys *without
//!   payloads* on the same slab (entries just carry an empty `Bytes`),
//!   bounded by entry count.
//!
//! Three eviction policies ride the structure, selected by
//! [`CachePolicy`]:
//!
//! * [`CachePolicy::Lru`] — single queue (main), hits move the entry to
//!   the queue head, the victim is the queue tail.
//! * [`CachePolicy::TwoQ`] — simplified 2Q: new keys enter *probation*;
//!   probation evictions leave their key on the ghost list; re-admitting
//!   a ghost key promotes it straight to *main*. Probation drains before
//!   main is touched. Hits refresh recency within the entry's own queue.
//! * [`CachePolicy::S3Fifo`] — simplified S3-FIFO (Yang et al., 2023):
//!   two FIFO queues plus the ghost list. Hits only bump a small
//!   per-entry frequency counter (capped at 3) — no list movement. The
//!   *small* (probation) queue is evicted from while it holds ≥ 10% of
//!   capacity; a small-queue tail with nonzero frequency is promoted to
//!   main instead of evicted, a main-queue tail with nonzero frequency
//!   is rotated back to the head with its counter decremented
//!   (CLOCK-style second chance). Small-queue evictions go to the ghost
//!   list; ghost re-admissions enter main directly.
//!
//! Counters ([`CoreStats`]) are maintained inside the core so every
//! facade reports the same per-tier stats, and [`EvictCore::audit`]
//! re-walks the lists to verify link and byte accounting (used by the
//! property and stress suites in `rust/tests/test_cache.rs`).

use std::collections::HashMap;

use super::Bytes;

/// Eviction policy for a byte-capped cache (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePolicy {
    /// Least-recently-used over a single queue.
    Lru,
    /// Two-queue with a ghost list (probation → ghost → main promotion).
    TwoQ,
    /// Simplified S3-FIFO: FIFO queues + frequency second chance + ghost.
    S3Fifo,
}

impl CachePolicy {
    /// Every policy, in the order reports should list them.
    pub const ALL: [CachePolicy; 3] = [CachePolicy::Lru, CachePolicy::TwoQ, CachePolicy::S3Fifo];

    pub fn by_name(name: &str) -> Option<CachePolicy> {
        match name {
            "lru" => Some(CachePolicy::Lru),
            "2q" | "twoq" => Some(CachePolicy::TwoQ),
            "s3fifo" | "s3-fifo" => Some(CachePolicy::S3Fifo),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            CachePolicy::Lru => "lru",
            CachePolicy::TwoQ => "2q",
            CachePolicy::S3Fifo => "s3fifo",
        }
    }
}

/// Cumulative counters plus current occupancy, identical across every
/// cache built on the core.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CoreStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// re-admissions that hit the ghost list and went straight to main
    pub ghost_promotions: u64,
    pub bytes: u64,
    pub capacity: u64,
    pub entries: u64,
    /// keys currently remembered on the ghost list (no payload)
    pub ghost_entries: u64,
}

impl CoreStats {
    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

const NIL: usize = usize::MAX;
/// S3-FIFO frequency counter saturation.
const FREQ_CAP: u8 = 3;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum QueueId {
    Probation = 0,
    Main = 1,
    Ghost = 2,
}

struct Entry {
    key: String,
    /// empty for ghost entries (the key is remembered, the payload is not)
    data: Bytes,
    /// S3-FIFO access frequency (saturating at [`FREQ_CAP`])
    freq: u8,
    queue: QueueId,
    prev: usize,
    next: usize,
}

#[derive(Debug, Clone, Copy)]
struct ListEnds {
    head: usize,
    tail: usize,
    len: usize,
    bytes: u64,
}

impl ListEnds {
    const fn empty() -> ListEnds {
        ListEnds { head: NIL, tail: NIL, len: 0, bytes: 0 }
    }
}

/// The unified intrusive-list eviction structure. Not thread-safe by
/// itself; every facade guards it with its own mutex.
pub struct EvictCore {
    policy: CachePolicy,
    capacity: u64,
    ghost_cap: usize,
    map: HashMap<String, usize>,
    slab: Vec<Entry>,
    free: Vec<usize>,
    qs: [ListEnds; 3],
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
    ghost_promotions: u64,
    /// when set, keys losing residency are pushed onto `evicted_keys`
    /// (see [`EvictCore::insert_evicting`])
    track_evicted: bool,
    evicted_keys: Vec<String>,
}

impl EvictCore {
    pub fn new(policy: CachePolicy, capacity_bytes: u64) -> EvictCore {
        EvictCore {
            policy,
            capacity: capacity_bytes,
            ghost_cap: 4096,
            map: HashMap::new(),
            slab: Vec::new(),
            free: Vec::new(),
            qs: [ListEnds::empty(); 3],
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
            ghost_promotions: 0,
            track_evicted: false,
            evicted_keys: Vec::new(),
        }
    }

    /// Cap the ghost list (keys remembered after probation eviction).
    pub fn with_ghost_capacity(mut self, n: usize) -> EvictCore {
        self.ghost_cap = n;
        self.trim_ghost();
        self
    }

    pub fn policy(&self) -> CachePolicy {
        self.policy
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Resident payload bytes (ghost entries hold none).
    pub fn bytes(&self) -> u64 {
        self.qs[QueueId::Probation as usize].bytes + self.qs[QueueId::Main as usize].bytes
    }

    /// Resident entry count (excludes ghosts).
    pub fn len(&self) -> usize {
        self.qs[QueueId::Probation as usize].len + self.qs[QueueId::Main as usize].len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn ghost_len(&self) -> usize {
        self.qs[QueueId::Ghost as usize].len
    }

    /// Is `key` resident (ghost entries don't count)?
    pub fn contains(&self, key: &str) -> bool {
        self.map
            .get(key)
            .is_some_and(|&i| self.slab[i].queue != QueueId::Ghost)
    }

    pub fn stats(&self) -> CoreStats {
        CoreStats {
            hits: self.hits,
            misses: self.misses,
            insertions: self.insertions,
            evictions: self.evictions,
            ghost_promotions: self.ghost_promotions,
            bytes: self.bytes(),
            capacity: self.capacity,
            entries: self.len() as u64,
            ghost_entries: self.ghost_len() as u64,
        }
    }

    /// Counted lookup; a hit refreshes recency per the policy.
    pub fn get(&mut self, key: &str) -> Option<Bytes> {
        match self.peek(key) {
            Some(data) => {
                self.hits += 1;
                Some(data)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Uncounted lookup for pollers re-checking the *same* logical
    /// lookup: refreshes recency on hit but leaves the hit/miss
    /// counters alone.
    pub fn peek(&mut self, key: &str) -> Option<Bytes> {
        let &i = self.map.get(key)?;
        if self.slab[i].queue == QueueId::Ghost {
            return None;
        }
        self.touch(i);
        Some(self.slab[i].data.clone())
    }

    /// Admit an object; returns the number of evictions performed.
    /// Objects larger than the whole cache are rejected outright.
    pub fn insert(&mut self, key: &str, data: Bytes) -> u64 {
        if data.len() as u64 > self.capacity {
            return 0;
        }
        if let Some(&i) = self.map.get(key) {
            if self.slab[i].queue != QueueId::Ghost {
                // resident: replace the payload in place, refresh like a hit
                let old = self.slab[i].data.len() as u64;
                let qi = self.slab[i].queue as usize;
                self.qs[qi].bytes = self.qs[qi].bytes - old + data.len() as u64;
                self.slab[i].data = data;
                self.touch(i);
                return self.evict_to_fit();
            }
            // ghost hit: the key earned a second life — straight to main
            self.unlink(i);
            self.slab[i].data = data;
            self.slab[i].freq = 0;
            self.ghost_promotions += 1;
            self.insertions += 1;
            self.push_front(i, QueueId::Main);
            return self.evict_to_fit();
        }
        let queue = match self.policy {
            CachePolicy::Lru => QueueId::Main,
            CachePolicy::TwoQ | CachePolicy::S3Fifo => QueueId::Probation,
        };
        let i = self.alloc(key, data);
        self.insertions += 1;
        self.map.insert(key.to_string(), i);
        self.push_front(i, queue);
        self.evict_to_fit()
    }

    /// Like [`EvictCore::insert`], but appends the key of every entry
    /// that **lost residency** during the insert (evicted outright or
    /// demoted to the ghost list) onto `evicted`. Facades that keep a
    /// side table alongside the core — the [`super::DirStore`] fd cache
    /// maps each resident key to an open file handle — need the victim
    /// identities, not just the count, to drop their side entries in
    /// lockstep. Victim keys that leave the map entirely are *moved*
    /// into `evicted`, so the non-ghost path stays allocation-free.
    pub fn insert_evicting(
        &mut self,
        key: &str,
        data: Bytes,
        evicted: &mut Vec<String>,
    ) -> u64 {
        self.track_evicted = true;
        let n = self.insert(key, data);
        self.track_evicted = false;
        evicted.append(&mut self.evicted_keys);
        n
    }

    /// Forget `key` entirely (resident or ghost); returns whether an
    /// entry was removed. Used for invalidation on overwrite — not an
    /// eviction: counters are untouched and nothing moves to the ghost
    /// list.
    pub fn remove(&mut self, key: &str) -> bool {
        let Some(&i) = self.map.get(key) else {
            return false;
        };
        self.unlink(i);
        self.slab[i].data = Bytes::new(Vec::new());
        let k = std::mem::take(&mut self.slab[i].key);
        self.map.remove(&k);
        self.free.push(i);
        true
    }

    /// Resident keys in probation, most- to least-recently linked.
    pub fn probation_keys(&self) -> Vec<String> {
        self.keys_in(QueueId::Probation)
    }

    /// Resident keys in main, most- to least-recently linked.
    pub fn main_keys(&self) -> Vec<String> {
        self.keys_in(QueueId::Main)
    }

    /// Ghost keys, most- to least-recently evicted.
    pub fn ghost_keys(&self) -> Vec<String> {
        self.keys_in(QueueId::Ghost)
    }

    /// Re-walk every list and cross-check link structure, byte/length
    /// accounting, the key map, and the capacity/ghost bounds. O(n);
    /// meant for tests and stress suites, not the hot path.
    pub fn audit(&self) -> Result<(), String> {
        let mut listed = 0usize;
        for q in [QueueId::Probation, QueueId::Main, QueueId::Ghost] {
            let ends = self.qs[q as usize];
            let mut i = ends.head;
            let mut prev = NIL;
            let mut n = 0usize;
            let mut bytes = 0u64;
            while i != NIL {
                let e = &self.slab[i];
                if e.queue != q {
                    return Err(format!("entry {:?} tagged {:?}, linked in {q:?}", e.key, e.queue));
                }
                if e.prev != prev {
                    return Err(format!("entry {:?} has a broken prev link", e.key));
                }
                if q == QueueId::Ghost && !e.data.is_empty() {
                    return Err(format!("ghost entry {:?} still holds a payload", e.key));
                }
                if self.map.get(&e.key) != Some(&i) {
                    return Err(format!("map does not point at linked entry {:?}", e.key));
                }
                bytes += e.data.len() as u64;
                n += 1;
                if n > self.slab.len() {
                    return Err(format!("{q:?} list has a cycle"));
                }
                prev = i;
                i = e.next;
            }
            if ends.tail != prev {
                return Err(format!("{q:?} tail does not match the last linked entry"));
            }
            if n != ends.len {
                return Err(format!("{q:?} len {} != walked {n}", ends.len));
            }
            if bytes != ends.bytes {
                return Err(format!("{q:?} bytes {} != walked {bytes}", ends.bytes));
            }
            listed += n;
        }
        if listed != self.map.len() {
            return Err(format!("map holds {} keys, lists hold {listed}", self.map.len()));
        }
        if self.bytes() > self.capacity {
            return Err(format!("resident {} bytes over capacity {}", self.bytes(), self.capacity));
        }
        if self.ghost_len() > self.ghost_cap {
            return Err(format!("ghost {} over cap {}", self.ghost_len(), self.ghost_cap));
        }
        Ok(())
    }

    fn keys_in(&self, q: QueueId) -> Vec<String> {
        let mut out = Vec::with_capacity(self.qs[q as usize].len);
        let mut i = self.qs[q as usize].head;
        while i != NIL {
            out.push(self.slab[i].key.clone());
            i = self.slab[i].next;
        }
        out
    }

    fn alloc(&mut self, key: &str, data: Bytes) -> usize {
        let entry = Entry {
            key: key.to_string(),
            data,
            freq: 0,
            queue: QueueId::Main,
            prev: NIL,
            next: NIL,
        };
        if let Some(i) = self.free.pop() {
            self.slab[i] = entry;
            i
        } else {
            self.slab.push(entry);
            self.slab.len() - 1
        }
    }

    fn unlink(&mut self, i: usize) {
        let (p, n, q, sz) = {
            let e = &self.slab[i];
            (e.prev, e.next, e.queue as usize, e.data.len() as u64)
        };
        if p != NIL {
            self.slab[p].next = n;
        } else {
            self.qs[q].head = n;
        }
        if n != NIL {
            self.slab[n].prev = p;
        } else {
            self.qs[q].tail = p;
        }
        self.qs[q].len -= 1;
        self.qs[q].bytes -= sz;
    }

    fn push_front(&mut self, i: usize, q: QueueId) {
        let sz = self.slab[i].data.len() as u64;
        let qi = q as usize;
        let head = self.qs[qi].head;
        self.slab[i].queue = q;
        self.slab[i].prev = NIL;
        self.slab[i].next = head;
        if head != NIL {
            self.slab[head].prev = i;
        } else {
            self.qs[qi].tail = i;
        }
        self.qs[qi].head = i;
        self.qs[qi].len += 1;
        self.qs[qi].bytes += sz;
    }

    /// Recency refresh on a resident entry: LRU/2Q move it to the head
    /// of its queue; S3-FIFO only bumps the frequency counter.
    fn touch(&mut self, i: usize) {
        match self.policy {
            CachePolicy::Lru | CachePolicy::TwoQ => {
                let q = self.slab[i].queue;
                self.unlink(i);
                self.push_front(i, q);
            }
            CachePolicy::S3Fifo => {
                let f = self.slab[i].freq;
                self.slab[i].freq = (f + 1).min(FREQ_CAP);
            }
        }
    }

    fn evict_to_fit(&mut self) -> u64 {
        let mut evicted = 0;
        while self.bytes() > self.capacity {
            if !self.evict_one() {
                break;
            }
            evicted += 1;
        }
        self.trim_ghost();
        evicted
    }

    /// Evict one resident entry per the policy. Returns false only when
    /// nothing is resident.
    fn evict_one(&mut self) -> bool {
        match self.policy {
            CachePolicy::Lru => {
                let i = self.qs[QueueId::Main as usize].tail;
                if i == NIL {
                    return false;
                }
                self.drop_entry(i, false);
                true
            }
            CachePolicy::TwoQ => {
                // probation drains before the main queue is touched;
                // only probation victims are remembered on the ghost list
                let prob = self.qs[QueueId::Probation as usize].tail;
                if prob != NIL {
                    self.drop_entry(prob, true);
                    return true;
                }
                let main = self.qs[QueueId::Main as usize].tail;
                if main == NIL {
                    return false;
                }
                self.drop_entry(main, false);
                true
            }
            CachePolicy::S3Fifo => self.evict_one_s3fifo(),
        }
    }

    fn evict_one_s3fifo(&mut self) -> bool {
        loop {
            let small_tail = self.qs[QueueId::Probation as usize].tail;
            let small_bytes = self.qs[QueueId::Probation as usize].bytes;
            let main_tail = self.qs[QueueId::Main as usize].tail;
            // evict from the small queue while it holds ≥ 10% of capacity
            let use_small = small_tail != NIL
                && (small_bytes * 10 >= self.capacity || main_tail == NIL);
            if use_small {
                if self.slab[small_tail].freq > 0 {
                    // touched since admission: promote instead of evicting
                    self.unlink(small_tail);
                    self.slab[small_tail].freq = 0;
                    self.push_front(small_tail, QueueId::Main);
                    continue;
                }
                self.drop_entry(small_tail, true);
                return true;
            }
            if main_tail == NIL {
                return false;
            }
            if self.slab[main_tail].freq > 0 {
                // CLOCK-style second chance: rotate with decremented freq
                self.slab[main_tail].freq -= 1;
                self.unlink(main_tail);
                self.push_front(main_tail, QueueId::Main);
                continue;
            }
            self.drop_entry(main_tail, false);
            return true;
        }
    }

    /// Remove entry `i` from residency; `to_ghost` keeps the key (no
    /// payload) on the ghost list instead of freeing the slot.
    fn drop_entry(&mut self, i: usize, to_ghost: bool) {
        self.unlink(i);
        self.evictions += 1;
        self.slab[i].data = Bytes::new(Vec::new());
        if to_ghost {
            self.slab[i].freq = 0;
            if self.track_evicted {
                let key = self.slab[i].key.clone();
                self.evicted_keys.push(key);
            }
            self.push_front(i, QueueId::Ghost);
        } else {
            let key = std::mem::take(&mut self.slab[i].key);
            self.map.remove(&key);
            self.free.push(i);
            if self.track_evicted {
                // move, don't clone: the key's allocation is reused
                self.evicted_keys.push(key);
            }
        }
    }

    fn trim_ghost(&mut self) {
        while self.qs[QueueId::Ghost as usize].len > self.ghost_cap {
            let i = self.qs[QueueId::Ghost as usize].tail;
            self.unlink(i);
            let key = std::mem::take(&mut self.slab[i].key);
            self.map.remove(&key);
            self.free.push(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(n: usize, fill: u8) -> Bytes {
        Bytes::new(vec![fill; n])
    }

    #[test]
    fn policy_names() {
        assert_eq!(CachePolicy::by_name("lru"), Some(CachePolicy::Lru));
        assert_eq!(CachePolicy::by_name("2q"), Some(CachePolicy::TwoQ));
        assert_eq!(CachePolicy::by_name("twoq"), Some(CachePolicy::TwoQ));
        assert_eq!(CachePolicy::by_name("s3fifo"), Some(CachePolicy::S3Fifo));
        assert_eq!(CachePolicy::by_name("s3-fifo"), Some(CachePolicy::S3Fifo));
        assert_eq!(CachePolicy::by_name("arc"), None);
        assert_eq!(CachePolicy::S3Fifo.label(), "s3fifo");
        assert_eq!(CachePolicy::ALL.len(), 3);
    }

    #[test]
    fn lru_orders_and_evicts() {
        let mut c = EvictCore::new(CachePolicy::Lru, 300);
        c.insert("a", blob(100, 0));
        c.insert("b", blob(100, 1));
        c.insert("c", blob(100, 2));
        assert_eq!(c.main_keys(), vec!["c", "b", "a"]);
        assert!(c.get("a").is_some()); // a becomes MRU
        assert_eq!(c.main_keys(), vec!["a", "c", "b"]);
        let evicted = c.insert("d", blob(100, 3));
        assert_eq!(evicted, 1);
        assert!(!c.contains("b"), "LRU victim should be b");
        assert_eq!(c.ghost_len(), 0, "LRU never ghosts");
        c.audit().unwrap();
    }

    #[test]
    fn twoq_probation_ghost_main_flow() {
        let mut c = EvictCore::new(CachePolicy::TwoQ, 200);
        c.insert("g", blob(100, 0));
        c.insert("a", blob(100, 1));
        c.insert("b", blob(100, 2)); // evicts g (probation LRU) → ghost
        assert!(!c.contains("g"));
        assert_eq!(c.ghost_keys(), vec!["g"]);
        c.insert("g", blob(100, 3)); // ghost hit → main
        assert_eq!(c.stats().ghost_promotions, 1);
        assert_eq!(c.main_keys(), vec!["g"]);
        c.insert("x", blob(100, 4));
        c.insert("y", blob(100, 5));
        assert!(c.contains("g"), "main key evicted before probation drained");
        c.audit().unwrap();
    }

    #[test]
    fn s3fifo_second_chance_promotes_touched_keys() {
        // capacity fits two 100-byte objects; small queue is always
        // ≥ 10% of capacity here, so eviction hits the small tail
        let mut c = EvictCore::new(CachePolicy::S3Fifo, 200);
        c.insert("hot", blob(100, 0));
        c.insert("cold", blob(100, 1));
        assert!(c.get("hot").is_some()); // freq("hot") = 1
        c.insert("new", blob(100, 2));
        // victim scan hits "hot" (small tail), sees freq > 0, promotes it
        // to main, then evicts "cold"
        assert!(c.contains("hot"), "touched key not given a second chance");
        assert!(!c.contains("cold"));
        assert_eq!(c.main_keys(), vec!["hot"]);
        assert_eq!(c.ghost_keys(), vec!["cold"]);
        // ghost re-admission goes straight to main
        c.insert("cold", blob(100, 3));
        assert_eq!(c.stats().ghost_promotions, 1);
        assert!(c.main_keys().contains(&"cold".to_string()));
        c.audit().unwrap();
    }

    #[test]
    fn resident_reinsert_updates_bytes_in_place() {
        for policy in CachePolicy::ALL {
            let mut c = EvictCore::new(policy, 1000);
            c.insert("a", blob(100, 1));
            c.insert("a", blob(200, 2));
            assert_eq!(c.bytes(), 200, "{policy:?}");
            assert_eq!(c.len(), 1, "{policy:?}");
            assert_eq!(c.get("a").unwrap().len(), 200, "{policy:?}");
            assert_eq!(c.stats().insertions, 1, "{policy:?}");
            c.audit().unwrap();
        }
    }

    #[test]
    fn oversized_object_rejected_all_policies() {
        for policy in CachePolicy::ALL {
            let mut c = EvictCore::new(policy, 100);
            assert_eq!(c.insert("big", blob(500, 9)), 0);
            assert!(!c.contains("big"), "{policy:?}");
            assert_eq!(c.bytes(), 0);
            assert_eq!(c.stats().insertions, 0);
        }
    }

    #[test]
    fn ghost_list_bounded_and_auditable() {
        for policy in [CachePolicy::TwoQ, CachePolicy::S3Fifo] {
            let mut c = EvictCore::new(policy, 100).with_ghost_capacity(2);
            for i in 0..8 {
                c.insert(&format!("k{i}"), blob(100, i as u8));
            }
            assert!(c.ghost_len() <= 2, "{policy:?}");
            c.audit().unwrap();
        }
    }

    #[test]
    fn counters_track_lookups() {
        let mut c = EvictCore::new(CachePolicy::Lru, 1000);
        c.insert("a", blob(10, 0));
        assert!(c.get("a").is_some());
        assert!(c.get("a").is_some());
        assert!(c.get("b").is_none());
        assert!(c.peek("a").is_some()); // uncounted
        let s = c.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert!((s.hit_ratio() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(CoreStats::default().hit_ratio(), 0.0);
    }

    #[test]
    fn remove_drops_resident_and_ghost_entries() {
        let mut c = EvictCore::new(CachePolicy::TwoQ, 200);
        c.insert("a", blob(100, 0));
        c.insert("b", blob(100, 1));
        c.insert("c", blob(100, 2)); // evicts a → ghost
        assert!(c.remove("b"), "resident entry");
        assert!(!c.contains("b"));
        assert_eq!(c.bytes(), 100);
        assert!(c.remove("a"), "ghost entry");
        assert_eq!(c.ghost_len(), 0);
        assert!(!c.remove("nope"));
        // removal is not an eviction, and a removed ghost key re-enters
        // on probation like a brand-new key
        assert_eq!(c.stats().evictions, 1);
        c.insert("a", blob(100, 3));
        assert_eq!(c.stats().ghost_promotions, 0);
        c.audit().unwrap();
    }

    #[test]
    fn insert_evicting_reports_victim_keys() {
        // LRU: victims leave the map entirely and are moved out
        let mut c = EvictCore::new(CachePolicy::Lru, 200);
        let mut gone = Vec::new();
        c.insert_evicting("a", blob(100, 0), &mut gone);
        c.insert_evicting("b", blob(100, 1), &mut gone);
        assert!(gone.is_empty());
        let n = c.insert_evicting("c", blob(100, 2), &mut gone);
        assert_eq!(n, 1);
        assert_eq!(gone, vec!["a"]);
        // ghosting policies report demotions too (residency is lost even
        // though the key is still remembered)
        let mut c = EvictCore::new(CachePolicy::TwoQ, 200);
        let mut gone = Vec::new();
        c.insert_evicting("a", blob(100, 0), &mut gone);
        c.insert_evicting("b", blob(100, 1), &mut gone);
        c.insert_evicting("c", blob(100, 2), &mut gone);
        assert_eq!(gone, vec!["a"]);
        assert_eq!(c.ghost_keys(), vec!["a"]);
        // plain insert in between must not leak tracked keys later
        let mut c = EvictCore::new(CachePolicy::Lru, 100);
        c.insert("x", blob(100, 0));
        c.insert("y", blob(100, 1)); // evicts x, untracked
        let mut gone = Vec::new();
        c.insert_evicting("z", blob(100, 2), &mut gone);
        assert_eq!(gone, vec!["y"]);
        c.audit().unwrap();
    }

    #[test]
    fn slab_slots_recycle() {
        let mut c = EvictCore::new(CachePolicy::Lru, 200);
        for i in 0..50 {
            c.insert(&format!("k{i}"), blob(100, i as u8));
        }
        // capacity fits 2 entries; the slab must not grow past the
        // resident set + a small recycling margin
        assert!(c.slab.len() <= 3, "slab grew to {}", c.slab.len());
        c.audit().unwrap();
    }
}
