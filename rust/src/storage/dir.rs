//! Directory-backed store: real files on the local filesystem — the
//! "scratch" (locally mounted NVMe/SSD) storage of the paper when you
//! want true disk I/O instead of a simulated latency model.
//!
//! Beyond the basic `get` (one `std::fs::read` Vec per call), the store
//! implements the zero-copy [`ObjectStore::get_into`] path natively:
//! open file handles (plus their stat'd sizes) are cached per key, and a
//! read is a single positional `read_exact_at` straight into the
//! caller's buffer — no `Vec`, no `CString` for the path, no syscall
//! beyond the pread itself. Steady-state epochs over a warmed handle
//! cache perform **zero heap allocations** on the read path
//! (`tests/test_alloc.rs` pins this).

use std::collections::HashMap;
use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::{
    Bytes, CachePolicy, EvictCore, ObjectStore, ReadOp, RingCtx, StatCounters,
    StoreStats,
};

/// Max cached open handles; beyond it the **least-recently-used**
/// handle is closed (an earlier version cleared the whole cache at the
/// cap, so any working set above it re-opened every key each cycle and
/// broke the zero-alloc read steady state). Kept well below the common
/// Linux default soft `RLIMIT_NOFILE` of 1024 — the loader's fetch
/// threads, the prefetch runtime, and the process' own fds all share
/// that budget, and blowing it turns every subsequent cold-key open
/// into EMFILE mid-epoch.
const MAX_HANDLES: usize = 512;

/// The fd cache: an [`EvictCore`] LRU tracks recency and picks victims
/// (each entry charged one byte of a shared token payload, so capacity
/// in bytes == capacity in handles and no per-insert allocation), while
/// a side map holds the actual handles in lockstep — dropped via the
/// victim keys [`EvictCore::insert_evicting`] reports.
struct FdCache {
    lru: EvictCore,
    files: HashMap<String, (Arc<File>, u64)>,
    /// shared 1-byte payload; cloning it is an `Arc` bump, not an alloc
    token: Bytes,
    /// victim-key scratch, reused across inserts
    evicted: Vec<String>,
}

pub struct DirStore {
    root: PathBuf,
    stats: StatCounters,
    /// per-key open handle + object size, for the pread fast path
    handles: Mutex<FdCache>,
}

impl DirStore {
    /// Open (creating if needed) a directory store.
    pub fn open(root: impl AsRef<Path>) -> Result<DirStore> {
        DirStore::with_handle_cap(root, MAX_HANDLES)
    }

    /// [`DirStore::open`] with an explicit fd-cache capacity — lets the
    /// regression tests drive working sets past the cap without opening
    /// hundreds of real files.
    pub fn with_handle_cap(root: impl AsRef<Path>, cap: usize) -> Result<DirStore> {
        let root = root.as_ref().to_path_buf();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("create {root:?}"))?;
        Ok(DirStore {
            root,
            stats: StatCounters::default(),
            handles: Mutex::new(FdCache {
                lru: EvictCore::new(CachePolicy::Lru, cap.max(1) as u64),
                files: HashMap::new(),
                token: Bytes::new(vec![0u8]),
                evicted: Vec::new(),
            }),
        })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Currently cached open handles.
    pub fn cached_handles(&self) -> usize {
        self.handles.lock().unwrap().lru.len()
    }

    /// Cumulative single-handle evictions (LRU victims at the cap) —
    /// the wholesale-clear regression test asserts these stay
    /// one-at-a-time while the resident count holds at the cap.
    pub fn handle_evictions(&self) -> u64 {
        self.handles.lock().unwrap().lru.stats().evictions
    }

    fn path_for(&self, key: &str) -> PathBuf {
        // keys may contain '/' subdirs
        self.root.join(key)
    }

    /// Cached (handle, size) for `key`, opening and stat'ing on first
    /// use. The cold path allocates (path buffer, map entry); every
    /// later call is a lock + map lookup + LRU touch + `Arc` bump, with
    /// no heap traffic.
    fn handle(&self, key: &str) -> Result<(Arc<File>, u64)> {
        {
            let mut cache = self.handles.lock().unwrap();
            if cache.lru.peek(key).is_some() {
                let (f, len) = cache.files.get(key).expect("fd cache in lockstep");
                return Ok((f.clone(), *len));
            }
        }
        // open outside the lock: a slow open must not stall cache hits
        let path = self.path_for(key);
        let f = File::open(&path).with_context(|| format!("open {key}"))?;
        let len = f.metadata().with_context(|| format!("stat {key}"))?.len();
        let f = Arc::new(f);
        let mut cache = self.handles.lock().unwrap();
        let FdCache { lru, files, token, evicted } = &mut *cache;
        lru.insert_evicting(key, token.clone(), evicted);
        for k in evicted.drain(..) {
            files.remove(&k); // closes the victim's fd (last Arc aside)
        }
        files.insert(key.to_string(), (f.clone(), len));
        Ok((f, len))
    }
}

impl ObjectStore for DirStore {
    fn get(&self, key: &str) -> Result<Bytes> {
        let data = std::fs::read(self.path_for(key))
            .with_context(|| format!("read {key}"))?;
        self.stats.record_get(data.len() as u64);
        Ok(Bytes::new(data))
    }

    #[cfg(unix)]
    fn get_into(&self, key: &str, out: &mut [u8]) -> Result<usize> {
        use std::os::unix::fs::FileExt;
        let (f, len) = self.handle(key)?;
        let n = len as usize;
        if n > out.len() {
            return Ok(n); // too small: size only, caller grows + retries
        }
        f.read_exact_at(&mut out[..n], 0)
            .with_context(|| format!("pread {key}"))?;
        self.stats.record_get(len);
        Ok(n)
    }

    #[cfg(not(unix))]
    fn get_into(&self, key: &str, out: &mut [u8]) -> Result<usize> {
        // no positional-read API: fall back to the Vec path
        let data = self.get(key)?;
        let n = data.len();
        if n <= out.len() {
            out[..n].copy_from_slice(&data);
        }
        Ok(n)
    }

    #[cfg(unix)]
    fn get_range_into(&self, key: &str, offset: u64, out: &mut [u8]) -> Result<usize> {
        use std::os::unix::fs::FileExt;
        let (f, len) = self.handle(key)?;
        anyhow::ensure!(
            offset <= len,
            "range offset {offset} past end of {key} ({len} bytes)"
        );
        let n = out.len().min((len - offset) as usize);
        f.read_exact_at(&mut out[..n], offset)
            .with_context(|| format!("pread {key} at {offset}"))?;
        self.stats.record_get(n as u64);
        Ok(n)
    }

    fn native_get_into(&self) -> bool {
        cfg!(unix)
    }

    /// Native batched submission: a pread loop over the warm fd cache.
    /// Local NVMe reads are µs-scale, so looping inside the dispatch
    /// task is cheaper than future-per-op scaffolding; the win over the
    /// trait default is skipping the per-op `get` Vec and path alloc —
    /// ring batches over a warmed cache stay allocation-free.
    #[cfg(unix)]
    fn submit_batch(self: Arc<Self>, ops: Vec<ReadOp>, ctx: RingCtx) {
        use std::os::unix::fs::FileExt;
        for op in ops {
            let ReadOp { slot, key, offset, len, mut buf } = op;
            ctx.begin();
            let res = (|| -> Result<usize> {
                let (f, size) = self.handle(&key)?;
                let (start, n) = if len > 0 {
                    anyhow::ensure!(
                        offset <= size,
                        "range offset {offset} past end of {key} ({size} bytes)"
                    );
                    (offset, len.min((size - offset) as usize))
                } else {
                    (0, size as usize)
                };
                buf.resize(n, 0);
                f.read_exact_at(&mut buf, start)
                    .with_context(|| format!("pread {key}"))?;
                self.stats.record_get(n as u64);
                Ok(n)
            })();
            ctx.complete(slot, key, buf, res);
        }
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        let path = self.path_for(key);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, data).with_context(|| format!("write {key}"))?;
        // the cached handle (and its stat'd size) may now be stale
        let mut cache = self.handles.lock().unwrap();
        cache.lru.remove(key);
        cache.files.remove(key);
        Ok(())
    }

    fn keys(&self) -> Vec<String> {
        fn walk(dir: &Path, prefix: &str, out: &mut Vec<String>) {
            let Ok(entries) = std::fs::read_dir(dir) else { return };
            for e in entries.flatten() {
                let name = e.file_name().to_string_lossy().to_string();
                let key = if prefix.is_empty() {
                    name.clone()
                } else {
                    format!("{prefix}/{name}")
                };
                let p = e.path();
                if p.is_dir() {
                    walk(&p, &key, out);
                } else {
                    out.push(key);
                }
            }
        }
        let mut keys = Vec::new();
        walk(&self.root, "", &mut keys);
        keys.sort();
        keys
    }

    fn contains(&self, key: &str) -> bool {
        self.path_for(key).is_file()
    }

    fn label(&self) -> String {
        "scratch".to_string()
    }

    fn stats(&self) -> StoreStats {
        self.stats.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "cdl-dirstore-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn roundtrip_with_subdirs() {
        let d = tmpdir("rt");
        let s = DirStore::open(&d).unwrap();
        s.put("cls0/img_000.simg", vec![7; 32]).unwrap();
        s.put("cls1/img_001.simg", vec![8; 16]).unwrap();
        assert_eq!(s.get("cls0/img_000.simg").unwrap().len(), 32);
        assert_eq!(
            s.keys(),
            vec!["cls0/img_000.simg", "cls1/img_001.simg"]
        );
        assert!(s.contains("cls1/img_001.simg"));
        assert!(!s.contains("nope"));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_key_errors() {
        let d = tmpdir("miss");
        let s = DirStore::open(&d).unwrap();
        assert!(s.get("ghost").is_err());
        let mut buf = [0u8; 8];
        assert!(s.get_into("ghost", &mut buf).is_err());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn get_into_reads_bytes_and_reports_size() {
        let d = tmpdir("gi");
        let s = DirStore::open(&d).unwrap();
        s.put("cls/a.simg", (0u8..64).collect()).unwrap();
        assert!(s.native_get_into() == cfg!(unix));
        let mut buf = vec![0u8; 128];
        let n = s.get_into("cls/a.simg", &mut buf).unwrap();
        assert_eq!(n, 64);
        assert_eq!(&buf[..64], &(0u8..64).collect::<Vec<_>>()[..]);
        // too-small probe reports the size without writing
        let mut small = vec![0xAAu8; 8];
        assert_eq!(s.get_into("cls/a.simg", &mut small).unwrap(), 64);
        assert!(small.iter().all(|&b| b == 0xAA));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[cfg(unix)]
    #[test]
    fn fd_cache_evicts_lru_one_at_a_time_not_wholesale() {
        let d = tmpdir("lru");
        let s = DirStore::with_handle_cap(&d, 4).unwrap();
        for i in 0..6 {
            s.put(&format!("k{i}"), vec![i as u8; 16]).unwrap();
        }
        let mut buf = vec![0u8; 32];
        // fill the cache to its cap
        for i in 0..4 {
            s.get_into(&format!("k{i}"), &mut buf).unwrap();
        }
        assert_eq!(s.cached_handles(), 4);
        assert_eq!(s.handle_evictions(), 0);
        // keep k2/k3 hot, then stream the cold tail past the cap: each
        // cold open must evict exactly one LRU victim, never clear the
        // cache, and never touch the hot pair
        s.get_into("k2", &mut buf).unwrap();
        s.get_into("k3", &mut buf).unwrap();
        s.get_into("k4", &mut buf).unwrap(); // evicts k0
        s.get_into("k5", &mut buf).unwrap(); // evicts k1
        assert_eq!(s.cached_handles(), 4, "cache collapsed below the cap");
        assert_eq!(s.handle_evictions(), 2, "evictions not one-at-a-time");
        // the hot pair survived: re-reading them evicts nothing further
        s.get_into("k2", &mut buf).unwrap();
        s.get_into("k3", &mut buf).unwrap();
        assert_eq!(s.handle_evictions(), 2, "hot handles were thrashed");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[cfg(unix)]
    #[test]
    fn get_range_into_preads_at_offset() {
        let d = tmpdir("range");
        let s = DirStore::open(&d).unwrap();
        s.put("obj", (0u8..200).collect()).unwrap();
        let mut out = vec![0u8; 50];
        assert_eq!(s.get_range_into("obj", 100, &mut out).unwrap(), 50);
        assert_eq!(out, (100u8..150).collect::<Vec<_>>());
        // short tail read and out-of-bounds offset
        assert_eq!(s.get_range_into("obj", 180, &mut out).unwrap(), 20);
        assert_eq!(out[..20], (180u8..200).collect::<Vec<_>>()[..]);
        assert!(s.get_range_into("obj", 201, &mut out).is_err());
        assert!(s.get_range_into("ghost", 0, &mut out).is_err());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn put_invalidates_cached_handle() {
        let d = tmpdir("inv");
        let s = DirStore::open(&d).unwrap();
        s.put("k", vec![1u8; 32]).unwrap();
        let mut buf = vec![0u8; 64];
        assert_eq!(s.get_into("k", &mut buf).unwrap(), 32); // handle cached
        s.put("k", vec![2u8; 48]).unwrap(); // rewrite: new size + bytes
        assert_eq!(s.get_into("k", &mut buf).unwrap(), 48);
        assert!(buf[..48].iter().all(|&b| b == 2));
        let _ = std::fs::remove_dir_all(&d);
    }
}
