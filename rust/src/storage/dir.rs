//! Directory-backed store: real files on the local filesystem — the
//! "scratch" (locally mounted NVMe/SSD) storage of the paper when you
//! want true disk I/O instead of a simulated latency model.
//!
//! Beyond the basic `get` (one `std::fs::read` Vec per call), the store
//! implements the zero-copy [`ObjectStore::get_into`] path natively:
//! open file handles (plus their stat'd sizes) are cached per key, and a
//! read is a single positional `read_exact_at` straight into the
//! caller's buffer — no `Vec`, no `CString` for the path, no syscall
//! beyond the pread itself. Steady-state epochs over a warmed handle
//! cache perform **zero heap allocations** on the read path
//! (`tests/test_alloc.rs` pins this).

use std::collections::HashMap;
use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};

use anyhow::{Context, Result};

use super::{Bytes, ObjectStore, StatCounters, StoreStats};

/// Max cached open handles; beyond it the cache is cleared wholesale
/// (simple, and a dataset re-walks its keys every epoch anyway, so the
/// hot set repopulates in one pass). Kept well below the common Linux
/// default soft `RLIMIT_NOFILE` of 1024 — the loader's fetch threads,
/// the prefetch runtime, and the process' own fds all share that
/// budget, and blowing it turns every subsequent cold-key open into
/// EMFILE mid-epoch.
const MAX_HANDLES: usize = 512;

pub struct DirStore {
    root: PathBuf,
    stats: StatCounters,
    /// per-key open handle + object size, for the pread fast path
    handles: RwLock<HashMap<String, (Arc<File>, u64)>>,
}

impl DirStore {
    /// Open (creating if needed) a directory store.
    pub fn open(root: impl AsRef<Path>) -> Result<DirStore> {
        let root = root.as_ref().to_path_buf();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("create {root:?}"))?;
        Ok(DirStore {
            root,
            stats: StatCounters::default(),
            handles: RwLock::new(HashMap::new()),
        })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn path_for(&self, key: &str) -> PathBuf {
        // keys may contain '/' subdirs
        self.root.join(key)
    }

    /// Cached (handle, size) for `key`, opening and stat'ing on first
    /// use. The cold path allocates (path buffer, map entry); every
    /// later call is a read-lock + map lookup + `Arc` bump.
    fn handle(&self, key: &str) -> Result<(Arc<File>, u64)> {
        if let Some((f, len)) = self.handles.read().unwrap().get(key) {
            return Ok((f.clone(), *len));
        }
        let path = self.path_for(key);
        let f = File::open(&path).with_context(|| format!("open {key}"))?;
        let len = f.metadata().with_context(|| format!("stat {key}"))?.len();
        let f = Arc::new(f);
        let mut map = self.handles.write().unwrap();
        if map.len() >= MAX_HANDLES {
            map.clear();
        }
        map.insert(key.to_string(), (f.clone(), len));
        Ok((f, len))
    }
}

impl ObjectStore for DirStore {
    fn get(&self, key: &str) -> Result<Bytes> {
        let data = std::fs::read(self.path_for(key))
            .with_context(|| format!("read {key}"))?;
        self.stats.record_get(data.len() as u64);
        Ok(Bytes::new(data))
    }

    #[cfg(unix)]
    fn get_into(&self, key: &str, out: &mut [u8]) -> Result<usize> {
        use std::os::unix::fs::FileExt;
        let (f, len) = self.handle(key)?;
        let n = len as usize;
        if n > out.len() {
            return Ok(n); // too small: size only, caller grows + retries
        }
        f.read_exact_at(&mut out[..n], 0)
            .with_context(|| format!("pread {key}"))?;
        self.stats.record_get(len);
        Ok(n)
    }

    #[cfg(not(unix))]
    fn get_into(&self, key: &str, out: &mut [u8]) -> Result<usize> {
        // no positional-read API: fall back to the Vec path
        let data = self.get(key)?;
        let n = data.len();
        if n <= out.len() {
            out[..n].copy_from_slice(&data);
        }
        Ok(n)
    }

    fn native_get_into(&self) -> bool {
        cfg!(unix)
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        let path = self.path_for(key);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, data).with_context(|| format!("write {key}"))?;
        // the cached handle (and its stat'd size) may now be stale
        self.handles.write().unwrap().remove(key);
        Ok(())
    }

    fn keys(&self) -> Vec<String> {
        fn walk(dir: &Path, prefix: &str, out: &mut Vec<String>) {
            let Ok(entries) = std::fs::read_dir(dir) else { return };
            for e in entries.flatten() {
                let name = e.file_name().to_string_lossy().to_string();
                let key = if prefix.is_empty() {
                    name.clone()
                } else {
                    format!("{prefix}/{name}")
                };
                let p = e.path();
                if p.is_dir() {
                    walk(&p, &key, out);
                } else {
                    out.push(key);
                }
            }
        }
        let mut keys = Vec::new();
        walk(&self.root, "", &mut keys);
        keys.sort();
        keys
    }

    fn contains(&self, key: &str) -> bool {
        self.path_for(key).is_file()
    }

    fn label(&self) -> String {
        "scratch".to_string()
    }

    fn stats(&self) -> StoreStats {
        self.stats.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "cdl-dirstore-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn roundtrip_with_subdirs() {
        let d = tmpdir("rt");
        let s = DirStore::open(&d).unwrap();
        s.put("cls0/img_000.simg", vec![7; 32]).unwrap();
        s.put("cls1/img_001.simg", vec![8; 16]).unwrap();
        assert_eq!(s.get("cls0/img_000.simg").unwrap().len(), 32);
        assert_eq!(
            s.keys(),
            vec!["cls0/img_000.simg", "cls1/img_001.simg"]
        );
        assert!(s.contains("cls1/img_001.simg"));
        assert!(!s.contains("nope"));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_key_errors() {
        let d = tmpdir("miss");
        let s = DirStore::open(&d).unwrap();
        assert!(s.get("ghost").is_err());
        let mut buf = [0u8; 8];
        assert!(s.get_into("ghost", &mut buf).is_err());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn get_into_reads_bytes_and_reports_size() {
        let d = tmpdir("gi");
        let s = DirStore::open(&d).unwrap();
        s.put("cls/a.simg", (0u8..64).collect()).unwrap();
        assert!(s.native_get_into() == cfg!(unix));
        let mut buf = vec![0u8; 128];
        let n = s.get_into("cls/a.simg", &mut buf).unwrap();
        assert_eq!(n, 64);
        assert_eq!(&buf[..64], &(0u8..64).collect::<Vec<_>>()[..]);
        // too-small probe reports the size without writing
        let mut small = vec![0xAAu8; 8];
        assert_eq!(s.get_into("cls/a.simg", &mut small).unwrap(), 64);
        assert!(small.iter().all(|&b| b == 0xAA));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn put_invalidates_cached_handle() {
        let d = tmpdir("inv");
        let s = DirStore::open(&d).unwrap();
        s.put("k", vec![1u8; 32]).unwrap();
        let mut buf = vec![0u8; 64];
        assert_eq!(s.get_into("k", &mut buf).unwrap(), 32); // handle cached
        s.put("k", vec![2u8; 48]).unwrap(); // rewrite: new size + bytes
        assert_eq!(s.get_into("k", &mut buf).unwrap(), 48);
        assert!(buf[..48].iter().all(|&b| b == 2));
        let _ = std::fs::remove_dir_all(&d);
    }
}
