//! Directory-backed store: real files on the local filesystem — the
//! "scratch" (locally mounted NVMe/SSD) storage of the paper when you
//! want true disk I/O instead of a simulated latency model.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::{Bytes, ObjectStore, StatCounters, StoreStats};

pub struct DirStore {
    root: PathBuf,
    stats: StatCounters,
}

impl DirStore {
    /// Open (creating if needed) a directory store.
    pub fn open(root: impl AsRef<Path>) -> Result<DirStore> {
        let root = root.as_ref().to_path_buf();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("create {root:?}"))?;
        Ok(DirStore { root, stats: StatCounters::default() })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn path_for(&self, key: &str) -> PathBuf {
        // keys may contain '/' subdirs
        self.root.join(key)
    }
}

impl ObjectStore for DirStore {
    fn get(&self, key: &str) -> Result<Bytes> {
        let data = std::fs::read(self.path_for(key))
            .with_context(|| format!("read {key}"))?;
        self.stats.record_get(data.len() as u64);
        Ok(Bytes::new(data))
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        let path = self.path_for(key);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, data).with_context(|| format!("write {key}"))?;
        Ok(())
    }

    fn keys(&self) -> Vec<String> {
        fn walk(dir: &Path, prefix: &str, out: &mut Vec<String>) {
            let Ok(entries) = std::fs::read_dir(dir) else { return };
            for e in entries.flatten() {
                let name = e.file_name().to_string_lossy().to_string();
                let key = if prefix.is_empty() {
                    name.clone()
                } else {
                    format!("{prefix}/{name}")
                };
                let p = e.path();
                if p.is_dir() {
                    walk(&p, &key, out);
                } else {
                    out.push(key);
                }
            }
        }
        let mut keys = Vec::new();
        walk(&self.root, "", &mut keys);
        keys.sort();
        keys
    }

    fn contains(&self, key: &str) -> bool {
        self.path_for(key).is_file()
    }

    fn label(&self) -> String {
        "scratch".to_string()
    }

    fn stats(&self) -> StoreStats {
        self.stats.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "cdl-dirstore-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn roundtrip_with_subdirs() {
        let d = tmpdir("rt");
        let s = DirStore::open(&d).unwrap();
        s.put("cls0/img_000.simg", vec![7; 32]).unwrap();
        s.put("cls1/img_001.simg", vec![8; 16]).unwrap();
        assert_eq!(s.get("cls0/img_000.simg").unwrap().len(), 32);
        assert_eq!(
            s.keys(),
            vec!["cls0/img_000.simg", "cls1/img_001.simg"]
        );
        assert!(s.contains("cls1/img_001.simg"));
        assert!(!s.contains("nope"));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_key_errors() {
        let d = tmpdir("miss");
        let s = DirStore::open(&d).unwrap();
        assert!(s.get("ghost").is_err());
        let _ = std::fs::remove_dir_all(&d);
    }
}
