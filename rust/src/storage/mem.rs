//! In-memory object store — the backing blob-holder for all simulated
//! remote stores (so "S3 latency" isn't polluted by local disk I/O).

use std::collections::BTreeMap;
use std::sync::RwLock;

use anyhow::{anyhow, Result};

use super::{Bytes, ObjectStore, StatCounters, StoreStats};

pub struct MemStore {
    name: String,
    map: RwLock<BTreeMap<String, Bytes>>,
    stats: StatCounters,
}

impl MemStore {
    pub fn new(name: &str) -> MemStore {
        MemStore {
            name: name.to_string(),
            map: RwLock::new(BTreeMap::new()),
            stats: StatCounters::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn total_bytes(&self) -> u64 {
        self.map.read().unwrap().values().map(|v| v.len() as u64).sum()
    }
}

impl ObjectStore for MemStore {
    fn get(&self, key: &str) -> Result<Bytes> {
        let map = self.map.read().unwrap();
        let v = map
            .get(key)
            .cloned()
            .ok_or_else(|| anyhow!("no such key: {key}"))?;
        self.stats.record_get(v.len() as u64);
        Ok(v)
    }

    fn get_range_into(&self, key: &str, offset: u64, out: &mut [u8]) -> Result<usize> {
        let map = self.map.read().unwrap();
        let v = map.get(key).ok_or_else(|| anyhow!("no such key: {key}"))?;
        let n = super::range_from_bytes(v, key, offset, out)?;
        // account only the bytes that moved, not the whole object
        self.stats.record_get(n as u64);
        Ok(n)
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.map
            .write()
            .unwrap()
            .insert(key.to_string(), Bytes::new(data));
        Ok(())
    }

    fn keys(&self) -> Vec<String> {
        self.map.read().unwrap().keys().cloned().collect()
    }

    fn contains(&self, key: &str) -> bool {
        self.map.read().unwrap().contains_key(key)
    }

    fn label(&self) -> String {
        self.name.clone()
    }

    fn stats(&self) -> StoreStats {
        self.stats.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let s = MemStore::new("m");
        s.put("a/b", vec![9; 100]).unwrap();
        assert_eq!(s.get("a/b").unwrap().len(), 100);
        assert!(s.get("missing").is_err());
    }

    #[test]
    fn keys_sorted() {
        let s = MemStore::new("m");
        s.put("b", vec![]).unwrap();
        s.put("a", vec![]).unwrap();
        assert_eq!(s.keys(), vec!["a", "b"]);
    }

    #[test]
    fn stats_count_bytes() {
        let s = MemStore::new("m");
        s.put("k", vec![0; 64]).unwrap();
        s.get("k").unwrap();
        s.get("k").unwrap();
        let st = s.stats();
        assert_eq!(st.gets, 2);
        assert_eq!(st.bytes, 128);
    }

    #[test]
    fn total_bytes() {
        let s = MemStore::new("m");
        s.put("x", vec![0; 10]).unwrap();
        s.put("y", vec![0; 20]).unwrap();
        assert_eq!(s.total_bytes(), 30);
    }
}
