//! `ResilientStore` — the resilience layer of the chaos-ready storage
//! plane.
//!
//! Mounted between the cache/prefetch stack and the (faulty) backing
//! store, it turns raw storage failures into the paper's operational
//! reality on S3-like backends: transient errors are retried with
//! exponential backoff + decorrelated jitter, every logical request
//! carries an optional deadline that bounds its retry budget, slow
//! requests on the batched-submission path grow a *hedge* (a
//! speculative duplicate launched once the op outlives the online p95
//! estimate — first winner delivers, the loser's bytes are discarded),
//! and a per-backend circuit breaker converts a persistent outage into
//! fast per-item failures instead of a pile-up of doomed retries.
//!
//! Semantics that keep chaos runs digest-comparable:
//!
//! * Retries and hedges are *transparent*: the layer never reorders,
//!   duplicates, or truncates what the submitter observes — exactly one
//!   final verdict per logical op, byte-identical to a fault-free run.
//! * On the ring path the layer interposes via [`RingCtx::sub`] /
//!   [`RingCtx::deliver`], so every physical attempt (including hedges)
//!   rides the same `io_depth` permit budget and in-flight gauge as
//!   first-class traffic.
//! * The breaker counts *exhausted* logical ops (post-retry failures),
//!   not raw attempt noise — a flaky-but-alive backend keeps the
//!   breaker closed, a dead one opens it after
//!   [`ResilienceConfig::breaker_threshold`] consecutive exhaustions.
//!   Open-state fast-fails surface as per-item errors that the wave
//!   layer tombstones item-by-item (graceful degradation), while
//!   cache/prefetch tiers above keep serving hits untouched.
//!
//! The fault-free blocking hot path (`get_into` under `DirStore`) stays
//! allocation-free: one breaker load, the inner call, one latency
//! sample — `tests/test_alloc.rs` pins this.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::asyncrt;
use crate::telemetry::{names, Recorder, RESILIENCE_WORKER};
use crate::util::rng::Rng;

use super::ring::{Completion, CompletionSink, ReadOp, RingCtx};
use super::{BoxFut, Bytes, ObjectStore, StoreStats};

/// Knobs for the resilience layer. The config-file surface is
/// `retry_max` / `request_deadline_ms` / `hedge_after`; the rest are
/// engineering constants with sane defaults.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResilienceConfig {
    /// extra attempts after the first (0 = no retry)
    pub retry_max: u32,
    /// budget for one logical request, retries included; checked
    /// between attempts (a blocking attempt in flight cannot be
    /// cancelled mid-read). `None` = unbounded.
    pub deadline: Option<Duration>,
    /// hedge a ring op once it outlives `hedge_after × online-p95`
    /// (0.0 = hedging off; hedging applies to the batched-submission
    /// path, where a duplicate is one more future, not one more thread)
    pub hedge_after: f64,
    /// decorrelated-jitter floor
    pub backoff_base: Duration,
    /// decorrelated-jitter ceiling
    pub backoff_cap: Duration,
    /// consecutive *exhausted* ops before the breaker opens
    pub breaker_threshold: u32,
    /// open-state dwell before a half-open probe is let through
    pub breaker_cooldown: Duration,
}

impl ResilienceConfig {
    pub fn new(retry_max: u32, request_deadline_ms: u64, hedge_after: f64) -> ResilienceConfig {
        ResilienceConfig {
            retry_max,
            deadline: (request_deadline_ms > 0)
                .then(|| Duration::from_millis(request_deadline_ms)),
            hedge_after,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(100),
            breaker_threshold: 4,
            breaker_cooldown: Duration::from_millis(250),
        }
    }

    /// Whether any resilience behavior is switched on (the rig only
    /// mounts the layer when this is true).
    pub fn enabled(&self) -> bool {
        self.retry_max > 0 || self.deadline.is_some() || self.hedge_after > 0.0
    }
}

impl Default for ResilienceConfig {
    fn default() -> ResilienceConfig {
        ResilienceConfig::new(0, 0, 0.0)
    }
}

/// Circuit-breaker state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

const CLOSED: u8 = 0;
const OPEN: u8 = 1;
const HALF_OPEN: u8 = 2;

/// Per-backend circuit breaker over *exhausted* logical requests.
///
/// Closed → (threshold consecutive exhaustions) → Open → (cooldown
/// elapses, one probe admitted) → HalfOpen → probe success → Closed /
/// probe failure → Open again. Public so `tests/test_fault.rs` can
/// drive the state machine directly.
pub struct CircuitBreaker {
    state: AtomicU8,
    consecutive: AtomicU32,
    opened_at: Mutex<Option<Instant>>,
    threshold: u32,
    cooldown: Duration,
    opens: AtomicU64,
}

impl CircuitBreaker {
    pub fn new(threshold: u32, cooldown: Duration) -> CircuitBreaker {
        CircuitBreaker {
            state: AtomicU8::new(CLOSED),
            consecutive: AtomicU32::new(0),
            opened_at: Mutex::new(None),
            threshold: threshold.max(1),
            cooldown,
            opens: AtomicU64::new(0),
        }
    }

    /// May a request proceed? In the open state this admits exactly one
    /// probe per cooldown window (the caller that flips open→half-open).
    pub fn allow(&self) -> bool {
        match self.state.load(Ordering::Acquire) {
            CLOSED => true,
            HALF_OPEN => false, // a probe is already in flight
            _ => {
                let elapsed = self
                    .opened_at
                    .lock()
                    .unwrap()
                    .map(|t| t.elapsed() >= self.cooldown)
                    .unwrap_or(true);
                elapsed
                    && self
                        .state
                        .compare_exchange(OPEN, HALF_OPEN, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
            }
        }
    }

    /// A logical request succeeded: close the breaker, clear the streak.
    pub fn on_success(&self) {
        self.consecutive.store(0, Ordering::Relaxed);
        self.state.store(CLOSED, Ordering::Release);
    }

    /// A logical request exhausted its budget. A half-open probe failing
    /// re-opens immediately; otherwise the streak grows toward the
    /// threshold.
    pub fn on_failure(&self) {
        let st = self.state.load(Ordering::Acquire);
        if st == HALF_OPEN {
            self.trip();
            return;
        }
        let streak = self.consecutive.fetch_add(1, Ordering::Relaxed) + 1;
        if st == CLOSED && streak >= self.threshold {
            self.trip();
        }
    }

    fn trip(&self) {
        *self.opened_at.lock().unwrap() = Some(Instant::now());
        self.state.store(OPEN, Ordering::Release);
        self.opens.fetch_add(1, Ordering::Relaxed);
    }

    pub fn state(&self) -> BreakerState {
        match self.state.load(Ordering::Acquire) {
            CLOSED => BreakerState::Closed,
            OPEN => BreakerState::Open,
            _ => BreakerState::HalfOpen,
        }
    }

    pub fn opens(&self) -> u64 {
        self.opens.load(Ordering::Relaxed)
    }
}

/// Online p95 estimator: a 256-sample ring recomputed every 32 samples
/// on a stack copy (no steady-state allocation), armed once 64 samples
/// have landed. Feeds the hedge trigger.
struct LatencyEstimator {
    samples: Mutex<[f64; 256]>,
    count: AtomicU64,
    /// cached p95 in seconds, as f64 bits (0 = not armed yet)
    p95_bits: AtomicU64,
}

impl LatencyEstimator {
    fn new() -> LatencyEstimator {
        LatencyEstimator {
            samples: Mutex::new([0.0; 256]),
            count: AtomicU64::new(0),
            p95_bits: AtomicU64::new(0),
        }
    }

    fn record(&self, d: Duration) {
        let mut ring = self.samples.lock().unwrap();
        let n = self.count.fetch_add(1, Ordering::Relaxed);
        ring[(n % 256) as usize] = d.as_secs_f64();
        let filled = (n + 1).min(256) as usize;
        if (n + 1) % 32 == 0 && n + 1 >= 64 {
            let mut scratch = *ring;
            drop(ring);
            let window = &mut scratch[..filled];
            window.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            let idx = ((window.len() as f64 * 0.95) as usize).min(window.len() - 1);
            self.p95_bits.store(window[idx].to_bits(), Ordering::Relaxed);
        }
    }

    /// `None` until armed (≥64 samples and one recompute).
    fn p95(&self) -> Option<Duration> {
        let bits = self.p95_bits.load(Ordering::Relaxed);
        (bits != 0).then(|| Duration::from_secs_f64(f64::from_bits(bits)))
    }
}

/// Cumulative resilience counters, exported as `resilience.*` metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResilienceSnapshot {
    /// logical requests seen
    pub ops: u64,
    /// physical attempts launched (retries + hedges included)
    pub attempts: u64,
    /// backoff-retried attempts
    pub retries: u64,
    /// hedged logical ops
    pub hedges: u64,
    /// hedged ops that ultimately succeeded
    pub hedge_wins: u64,
    /// duplicate completions discarded after the winner delivered
    pub hedge_wasted: u64,
    /// logical ops that failed after the full retry budget
    pub exhausted: u64,
    /// ops whose retry budget was cut short by the deadline
    pub deadline_hits: u64,
    /// ops fast-failed by an open breaker
    pub breaker_fastfail: u64,
    /// breaker open transitions
    pub breaker_opens: u64,
    /// 0 closed / 1 open / 2 half-open
    pub breaker_state: u64,
    /// online p95 estimate in milliseconds (0 until armed)
    pub p95_ms: f64,
}

#[derive(Default)]
struct Counters {
    ops: AtomicU64,
    attempts: AtomicU64,
    retries: AtomicU64,
    hedges: AtomicU64,
    hedge_wins: AtomicU64,
    hedge_wasted: AtomicU64,
    exhausted: AtomicU64,
    deadline_hits: AtomicU64,
    breaker_fastfail: AtomicU64,
}

/// The resilience layer. See the module docs for semantics.
pub struct ResilientStore {
    inner: Arc<dyn ObjectStore>,
    cfg: ResilienceConfig,
    breaker: CircuitBreaker,
    latency: LatencyEstimator,
    rng: Mutex<Rng>,
    counters: Counters,
    recorder: Mutex<Option<Arc<Recorder>>>,
}

impl ResilientStore {
    pub fn new(
        inner: Arc<dyn ObjectStore>,
        cfg: ResilienceConfig,
        seed: u64,
    ) -> Arc<ResilientStore> {
        Arc::new(ResilientStore {
            inner,
            breaker: CircuitBreaker::new(cfg.breaker_threshold, cfg.breaker_cooldown),
            cfg,
            latency: LatencyEstimator::new(),
            rng: Mutex::new(Rng::new(seed ^ 0x7E51_11E7)),
            counters: Counters::default(),
            recorder: Mutex::new(None),
        })
    }

    pub fn set_recorder(&self, rec: Arc<Recorder>) {
        *self.recorder.lock().unwrap() = Some(rec);
    }

    pub fn config(&self) -> &ResilienceConfig {
        &self.cfg
    }

    pub fn breaker(&self) -> &CircuitBreaker {
        &self.breaker
    }

    pub fn inner(&self) -> &Arc<dyn ObjectStore> {
        &self.inner
    }

    pub fn snapshot(&self) -> ResilienceSnapshot {
        let c = &self.counters;
        ResilienceSnapshot {
            ops: c.ops.load(Ordering::Relaxed),
            attempts: c.attempts.load(Ordering::Relaxed),
            retries: c.retries.load(Ordering::Relaxed),
            hedges: c.hedges.load(Ordering::Relaxed),
            hedge_wins: c.hedge_wins.load(Ordering::Relaxed),
            hedge_wasted: c.hedge_wasted.load(Ordering::Relaxed),
            exhausted: c.exhausted.load(Ordering::Relaxed),
            deadline_hits: c.deadline_hits.load(Ordering::Relaxed),
            breaker_fastfail: c.breaker_fastfail.load(Ordering::Relaxed),
            breaker_opens: self.breaker.opens(),
            breaker_state: match self.breaker.state() {
                BreakerState::Closed => 0,
                BreakerState::Open => 1,
                BreakerState::HalfOpen => 2,
            },
            p95_ms: self
                .latency
                .p95()
                .map(|d| d.as_secs_f64() * 1e3)
                .unwrap_or(0.0),
        }
    }

    /// Decorrelated jitter (the AWS architecture-blog variant):
    /// `sleep = min(cap, uniform(base, prev × 3))`, feeding each draw
    /// back in as the next `prev`.
    fn backoff(&self, prev: &mut Duration) -> Duration {
        let base = self.cfg.backoff_base.as_secs_f64();
        let cap = self.cfg.backoff_cap.as_secs_f64();
        let hi = (prev.as_secs_f64() * 3.0).max(base);
        let draw = {
            let mut rng = self.rng.lock().unwrap();
            base + rng.f64() * (hi - base)
        };
        let next = Duration::from_secs_f64(draw.min(cap));
        *prev = next;
        next
    }

    fn recorder(&self) -> Option<Arc<Recorder>> {
        self.recorder.lock().unwrap().clone()
    }

    fn span(&self, name: &'static str, value: i64, t0: Option<f64>) {
        if let Some(r) = self.recorder() {
            let t1 = r.now();
            r.record(name, RESILIENCE_WORKER, value, t0.unwrap_or(t1), t1);
        }
    }

    /// The blocking retry driver behind `get` / `get_into` /
    /// `get_range_into` / the async path's twin. Happy path:
    /// one breaker load, the attempt, one latency sample — no
    /// allocation.
    fn with_retries<T>(&self, key: &str, mut f: impl FnMut() -> Result<T>) -> Result<T> {
        self.counters.ops.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let mut attempt = 0u32;
        let mut prev = self.cfg.backoff_base;
        loop {
            if !self.breaker.allow() {
                self.counters.breaker_fastfail.fetch_add(1, Ordering::Relaxed);
                self.span(names::BREAKER, 1, None);
                return Err(anyhow!(
                    "circuit breaker open: fast-failing {key} on {}",
                    self.inner.label()
                ));
            }
            self.counters.attempts.fetch_add(1, Ordering::Relaxed);
            let at0 = Instant::now();
            match f() {
                Ok(v) => {
                    if attempt == 0 {
                        self.latency.record(at0.elapsed());
                    }
                    self.breaker.on_success();
                    return Ok(v);
                }
                Err(e) => {
                    attempt += 1;
                    let deadline_hit =
                        self.cfg.deadline.is_some_and(|d| t0.elapsed() >= d);
                    if attempt > self.cfg.retry_max || deadline_hit {
                        if deadline_hit {
                            self.counters.deadline_hits.fetch_add(1, Ordering::Relaxed);
                        }
                        self.counters.exhausted.fetch_add(1, Ordering::Relaxed);
                        self.breaker.on_failure();
                        if self.breaker.state() == BreakerState::Open {
                            self.span(names::BREAKER, 1, None);
                        }
                        return Err(e).with_context(|| {
                            format!(
                                "{key}: retry budget exhausted after {attempt} attempt(s)"
                            )
                        });
                    }
                    self.counters.retries.fetch_add(1, Ordering::Relaxed);
                    let wait = self.backoff(&mut prev);
                    let rt0 = self.recorder().map(|r| r.now());
                    std::thread::sleep(wait);
                    self.span(names::RETRY, attempt as i64, rt0);
                }
            }
        }
    }
}

impl ObjectStore for ResilientStore {
    fn get(&self, key: &str) -> Result<Bytes> {
        self.with_retries(key, || self.inner.get(key))
    }

    fn get_async<'a>(&'a self, key: &'a str) -> BoxFut<'a, Result<Bytes>> {
        Box::pin(async move {
            self.counters.ops.fetch_add(1, Ordering::Relaxed);
            let t0 = Instant::now();
            let mut attempt = 0u32;
            let mut prev = self.cfg.backoff_base;
            loop {
                if !self.breaker.allow() {
                    self.counters.breaker_fastfail.fetch_add(1, Ordering::Relaxed);
                    self.span(names::BREAKER, 1, None);
                    return Err(anyhow!(
                        "circuit breaker open: fast-failing {key} on {}",
                        self.inner.label()
                    ));
                }
                self.counters.attempts.fetch_add(1, Ordering::Relaxed);
                let at0 = Instant::now();
                match self.inner.get_async(key).await {
                    Ok(v) => {
                        if attempt == 0 {
                            self.latency.record(at0.elapsed());
                        }
                        self.breaker.on_success();
                        return Ok(v);
                    }
                    Err(e) => {
                        attempt += 1;
                        let deadline_hit =
                            self.cfg.deadline.is_some_and(|d| t0.elapsed() >= d);
                        if attempt > self.cfg.retry_max || deadline_hit {
                            if deadline_hit {
                                self.counters
                                    .deadline_hits
                                    .fetch_add(1, Ordering::Relaxed);
                            }
                            self.counters.exhausted.fetch_add(1, Ordering::Relaxed);
                            self.breaker.on_failure();
                            return Err(e).with_context(|| {
                                format!(
                                    "{key}: retry budget exhausted after {attempt} attempt(s)"
                                )
                            });
                        }
                        self.counters.retries.fetch_add(1, Ordering::Relaxed);
                        let wait = self.backoff(&mut prev);
                        let rt0 = self.recorder().map(|r| r.now());
                        asyncrt::sleep(wait).await;
                        self.span(names::RETRY, attempt as i64, rt0);
                    }
                }
            }
        })
    }

    fn get_into(&self, key: &str, out: &mut [u8]) -> Result<usize> {
        self.with_retries(key, || self.inner.get_into(key, out))
    }

    fn get_range_into(&self, key: &str, offset: u64, out: &mut [u8]) -> Result<usize> {
        self.with_retries(key, || self.inner.get_range_into(key, offset, out))
    }

    fn native_get_into(&self) -> bool {
        self.inner.native_get_into()
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.inner.put(key, data)
    }

    fn keys(&self) -> Vec<String> {
        self.inner.keys()
    }

    fn contains(&self, key: &str) -> bool {
        self.inner.contains(key)
    }

    fn hint_order(&self, epoch: usize, keys: &[String]) {
        self.inner.hint_order(epoch, keys)
    }

    fn hint_order_append(&self, epoch: usize, keys: &[String]) {
        self.inner.hint_order_append(epoch, keys)
    }

    fn submit_batch(self: Arc<Self>, ops: Vec<ReadOp>, ctx: RingCtx) {
        if ops.is_empty() {
            return;
        }
        orchestrate_batch(self, ops, ctx);
    }

    fn label(&self) -> String {
        format!("resilient({})", self.inner.label())
    }

    fn stats(&self) -> StoreStats {
        self.inner.stats()
    }
}

/// Raw attempt results funnel through this sink back to the batch
/// reaper (never full: capacity covers the worst case of one primary +
/// one concurrent hedge per op).
struct AttemptSink {
    tx: asyncrt::Sender<Completion>,
}

impl CompletionSink for AttemptSink {
    fn push(&self, c: Completion) {
        // capacity is sized so this cannot fail; a dropped receiver
        // (reaper already exited) only loses late hedge losers
        let _ = self.tx.try_send(c);
    }
}

/// Per-logical-op bookkeeping inside one batch.
struct OpState {
    offset: u64,
    len: usize,
    started: Instant,
    /// failed attempts so far
    attempts_done: u32,
    /// physical attempts currently in flight
    inflight: u32,
    prev_backoff: Duration,
    hedged: bool,
    done: bool,
}

/// Ring-path orchestration: primary attempts go down as ONE
/// `submit_batch` on an attempt context (cheap, preserves the inner
/// store's fan-out), retries and hedges are re-driven as singleton
/// submissions, and exactly one [`RingCtx::deliver`] per logical op
/// reports the final verdict to the submitter.
fn orchestrate_batch(store: Arc<ResilientStore>, ops: Vec<ReadOp>, ctx: RingCtx) {
    let n = ops.len();
    store.counters.ops.fetch_add(n as u64, Ordering::Relaxed);

    // worst case per op: primary + one concurrent hedge
    let (tx, rx) = asyncrt::channel::<Completion>(2 * n + 2);
    let sink: Arc<dyn CompletionSink> = Arc::new(AttemptSink { tx });
    let attempt_ctx = ctx.sub(sink);

    // slot → state; slots are caller-chosen and unique within a batch
    let mut states: Vec<(usize, OpState)> = Vec::with_capacity(n);
    let mut primaries: Vec<ReadOp> = Vec::with_capacity(n);
    let hedge_delay = (store.cfg.hedge_after > 0.0)
        .then(|| store.latency.p95())
        .flatten()
        .map(|p95| p95.mul_f64(store.cfg.hedge_after).max(Duration::from_millis(1)));

    for op in ops {
        if !store.breaker.allow() {
            // open breaker: degrade fast, one tombstone per item
            store.counters.breaker_fastfail.fetch_add(1, Ordering::Relaxed);
            store.span(names::BREAKER, 1, None);
            let err = anyhow!(
                "circuit breaker open: fast-failing {} on {}",
                op.key,
                store.inner.label()
            );
            ctx.deliver(op.slot, op.key, op.buf, Err(err));
            continue;
        }
        states.push((
            op.slot,
            OpState {
                offset: op.offset,
                len: op.len,
                started: Instant::now(),
                attempts_done: 0,
                inflight: 1,
                prev_backoff: store.cfg.backoff_base,
                hedged: false,
                done: false,
            },
        ));
        primaries.push(op);
    }
    let live = primaries.len();
    if live == 0 {
        return;
    }
    store.counters.attempts.fetch_add(live as u64, Ordering::Relaxed);

    let states = Arc::new(Mutex::new(states));
    // physical attempts beyond the primaries (hedges + retries);
    // incremented under the states lock so the reaper's exit condition
    // can never miss an attempt it still has to drain
    let extra = Arc::new(AtomicU64::new(0));

    // hedge watchdogs: one sleeper per op, armed only when the p95
    // estimator is warm — fires a speculative duplicate if the primary
    // is still sole-in-flight and unfailed when the timer lands
    if let Some(delay) = hedge_delay {
        let slots: Vec<(usize, String)> = {
            let st = states.lock().unwrap();
            st.iter()
                .zip(primaries.iter())
                .map(|((slot, _), op)| (*slot, op.key.clone()))
                .collect()
        };
        for (slot, key) in slots {
            let store = store.clone();
            let states = states.clone();
            let extra = extra.clone();
            let attempt_ctx = attempt_ctx.clone();
            drop(ctx.rt().spawn(async move {
                asyncrt::sleep(delay).await;
                let launch = {
                    let mut st = states.lock().unwrap();
                    match st.iter_mut().find(|(s, _)| *s == slot) {
                        Some((_, op)) if !op.done && !op.hedged && op.inflight == 1
                            && op.attempts_done == 0 =>
                        {
                            op.hedged = true;
                            op.inflight += 1;
                            extra.fetch_add(1, Ordering::Relaxed);
                            Some((op.offset, op.len))
                        }
                        _ => None,
                    }
                };
                if let Some((offset, len)) = launch {
                    store.counters.hedges.fetch_add(1, Ordering::Relaxed);
                    store.counters.attempts.fetch_add(1, Ordering::Relaxed);
                    store.span(names::HEDGE, slot as i64, None);
                    store
                        .inner
                        .clone()
                        .submit_batch(
                            vec![ReadOp { slot, key, offset, len, buf: Vec::new() }],
                            attempt_ctx,
                        );
                }
            }));
        }
    }

    // primary wave: one batch down the stack, preserving the inner
    // store's native fan-out
    store.inner.clone().submit_batch(primaries, attempt_ctx.clone());

    // the reaper: consumes raw attempt completions, re-drives retries
    // after backoff, delivers exactly one verdict per logical op, and
    // stays alive until every physical attempt is accounted for (so
    // losing hedges are counted, not leaked)
    drop(ctx.rt().spawn(async move {
        let mut delivered = 0usize;
        let mut consumed = 0usize;
        while delivered < live
            || consumed < live + extra.load(Ordering::Relaxed) as usize
        {
            let Some(c) = rx.recv().await else { break };
            consumed += 1;
            let verdict = {
                let mut st = states.lock().unwrap();
                let Some((_, op)) = st.iter_mut().find(|(s, _)| *s == c.slot) else {
                    continue;
                };
                op.inflight -= 1;
                if op.done {
                    // the hedge race's loser: discard, count
                    store.counters.hedge_wasted.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                match c.result {
                    Ok(nbytes) => {
                        op.done = true;
                        if op.hedged {
                            store.counters.hedge_wins.fetch_add(1, Ordering::Relaxed);
                        } else if op.attempts_done == 0 {
                            store.latency.record(op.started.elapsed());
                        }
                        store.breaker.on_success();
                        Some((c.key, c.buf, Ok(nbytes)))
                    }
                    Err(e) => {
                        op.attempts_done += 1;
                        if op.inflight > 0 {
                            // a hedge twin is still running: let it race
                            None
                        } else {
                            let deadline_hit = store
                                .cfg
                                .deadline
                                .is_some_and(|d| op.started.elapsed() >= d);
                            let budget_gone = op.attempts_done > store.cfg.retry_max;
                            if budget_gone || deadline_hit || !store.breaker.allow() {
                                if deadline_hit {
                                    store
                                        .counters
                                        .deadline_hits
                                        .fetch_add(1, Ordering::Relaxed);
                                }
                                store.counters.exhausted.fetch_add(1, Ordering::Relaxed);
                                store.breaker.on_failure();
                                op.done = true;
                                let attempts = op.attempts_done;
                                Some((
                                    c.key,
                                    c.buf,
                                    Err(e).with_context(|| {
                                        format!(
                                            "retry budget exhausted after {attempts} attempt(s)"
                                        )
                                    }),
                                ))
                            } else {
                                // schedule a backoff retry
                                store.counters.retries.fetch_add(1, Ordering::Relaxed);
                                store.counters.attempts.fetch_add(1, Ordering::Relaxed);
                                op.inflight += 1;
                                extra.fetch_add(1, Ordering::Relaxed);
                                let wait = store.backoff(&mut op.prev_backoff);
                                let resub = ReadOp {
                                    slot: c.slot,
                                    key: c.key,
                                    offset: op.offset,
                                    len: op.len,
                                    buf: c.buf,
                                };
                                let store = store.clone();
                                let attempt_ctx = attempt_ctx.clone();
                                drop(attempt_ctx.rt().spawn(async move {
                                    let rt0 = store.recorder().map(|r| r.now());
                                    asyncrt::sleep(wait).await;
                                    store.span(names::RETRY, resub.slot as i64, rt0);
                                    store
                                        .inner
                                        .clone()
                                        .submit_batch(vec![resub], attempt_ctx);
                                }));
                                None
                            }
                        }
                    }
                }
            };
            if let Some((key, buf, result)) = verdict {
                ctx.deliver(c.slot, key, buf, result);
                delivered += 1;
            }
        }
    }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::fault::{FaultProfile, FaultStore};
    use crate::storage::{IoRing, MemStore};

    fn backing(n: usize) -> Arc<dyn ObjectStore> {
        let m = MemStore::new("m");
        for i in 0..n {
            m.put(&format!("k{i}"), vec![i as u8; 128]).unwrap();
        }
        Arc::new(m)
    }

    fn flaky(n: usize, seed: u64) -> Arc<FaultStore> {
        FaultStore::new(backing(n), FaultProfile::flaky(), seed)
    }

    #[test]
    fn config_enabled_gating() {
        assert!(!ResilienceConfig::new(0, 0, 0.0).enabled());
        assert!(ResilienceConfig::new(4, 0, 0.0).enabled());
        assert!(ResilienceConfig::new(0, 500, 0.0).enabled());
        assert!(ResilienceConfig::new(0, 0, 2.0).enabled());
    }

    #[test]
    fn retries_hide_flaky_faults_on_every_blocking_shape() {
        let rs = ResilientStore::new(flaky(8, 21), ResilienceConfig::new(4, 0, 0.0), 1);
        let mut out = vec![0u8; 128];
        for round in 0..40 {
            let key = format!("k{}", round % 8);
            let want = vec![(round % 8) as u8; 128];
            assert_eq!(&rs.get(&key).unwrap()[..], &want[..]);
            assert_eq!(rs.get_into(&key, &mut out).unwrap(), 128);
            assert_eq!(&out[..], &want[..]);
            assert_eq!(rs.get_range_into(&key, 64, &mut out[..32]).unwrap(), 32);
            assert_eq!(&out[..32], &want[..32]);
        }
        let s = rs.snapshot();
        assert!(s.retries > 0, "{s:?}");
        assert_eq!(s.exhausted, 0, "{s:?}");
        assert_eq!(s.breaker_opens, 0, "{s:?}");
        assert!(s.attempts > s.ops, "{s:?}");
    }

    #[test]
    fn async_path_retries_too() {
        let rs = ResilientStore::new(flaky(4, 33), ResilienceConfig::new(4, 0, 0.0), 2);
        for round in 0..40 {
            let key = format!("k{}", round % 4);
            let got = asyncrt::block_on(rs.get_async(&key)).unwrap();
            assert_eq!(&got[..], &vec![(round % 4) as u8; 128][..]);
        }
        assert!(rs.snapshot().retries > 0);
        assert_eq!(rs.snapshot().exhausted, 0);
    }

    #[test]
    fn outage_exhausts_budget_then_opens_breaker() {
        let store = FaultStore::new(backing(4), FaultProfile::outage(), 9);
        let rs = ResilientStore::new(store, ResilienceConfig::new(2, 0, 0.0), 3);
        let mut errs = 0;
        for i in 0..8 {
            if rs.get(&format!("k{}", i % 4)).is_err() {
                errs += 1;
            }
        }
        assert_eq!(errs, 8);
        let s = rs.snapshot();
        assert!(s.exhausted >= 4, "{s:?}");
        assert!(s.breaker_opens >= 1, "{s:?}");
        assert!(s.breaker_fastfail > 0, "breaker never fast-failed: {s:?}");
        // exhausted ops each burned the full budget before the trip
        assert_eq!(s.breaker_state, 1, "{s:?}");
    }

    #[test]
    fn breaker_state_machine_closes_after_heal() {
        let b = CircuitBreaker::new(2, Duration::from_millis(20));
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow());
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Closed);
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.opens(), 1);
        assert!(!b.allow(), "no probe before cooldown");
        std::thread::sleep(Duration::from_millis(25));
        assert!(b.allow(), "cooldown elapsed: one probe admitted");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.allow(), "second probe rejected while half-open");
        // probe fails: straight back to open
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.opens(), 2);
        std::thread::sleep(Duration::from_millis(25));
        assert!(b.allow());
        b.on_success();
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow());
    }

    #[test]
    fn deadline_bounds_the_retry_budget() {
        let p = FaultProfile {
            error_rate: 1.0,
            stall_rate: 0.0,
            stall_ms: 0,
            reset_rate: 0.0,
            short_read_rate: 0.0,
            max_consecutive: 0,
        };
        let store = FaultStore::new(backing(1), p, 5);
        // huge retry budget but a 30ms deadline: the deadline wins
        let mut cfg = ResilienceConfig::new(1_000, 30, 0.0);
        cfg.backoff_base = Duration::from_millis(10);
        cfg.backoff_cap = Duration::from_millis(10);
        let rs = ResilientStore::new(store, cfg, 7);
        let t0 = Instant::now();
        assert!(rs.get("k0").is_err());
        assert!(t0.elapsed() < Duration::from_secs(2), "{:?}", t0.elapsed());
        let s = rs.snapshot();
        assert_eq!(s.deadline_hits, 1, "{s:?}");
        assert_eq!(s.exhausted, 1, "{s:?}");
    }

    #[test]
    fn ring_batches_survive_flaky_faults_byte_identical() {
        let backing = backing(16);
        let faulty = FaultStore::new(backing.clone(), FaultProfile::flaky(), 17);
        let rs = ResilientStore::new(faulty, ResilienceConfig::new(4, 0, 0.0), 4);
        let ring = IoRing::new(rs.clone(), 32);
        for _wave in 0..6 {
            let ops = (0..16)
                .map(|i| ReadOp::whole(i, format!("k{i}"), Vec::new()))
                .collect();
            let mut sub = ring.submit(ops);
            let mut seen = 0;
            while let Some(c) = sub.next() {
                let n = c.result.unwrap();
                assert_eq!(&c.buf[..n], &backing.get(&c.key).unwrap()[..]);
                seen += 1;
            }
            assert_eq!(seen, 16);
        }
        let s = rs.snapshot();
        assert!(s.retries > 0, "{s:?}");
        assert_eq!(s.exhausted, 0, "{s:?}");
        let rsnap = ring.stats();
        assert_eq!(rsnap.submitted, 96);
        assert_eq!(rsnap.completed, 96);
        assert_eq!(rsnap.errors, 0);
        assert_eq!(rsnap.inflight, 0, "attempt accounting leaked the gauge");
    }

    #[test]
    fn ring_outage_degrades_per_item_not_per_wave() {
        let faulty = FaultStore::new(backing(8), FaultProfile::outage(), 19);
        let rs = ResilientStore::new(faulty, ResilienceConfig::new(1, 0, 0.0), 6);
        let ring = IoRing::new(rs.clone(), 16);
        let mut errors = 0;
        for _wave in 0..4 {
            let ops = (0..8)
                .map(|i| ReadOp::whole(i, format!("k{i}"), Vec::new()))
                .collect();
            let mut sub = ring.submit(ops);
            let mut reaped = 0;
            while let Some(c) = sub.next() {
                assert!(c.result.is_err());
                errors += 1;
                reaped += 1;
            }
            // every op gets its own verdict — the wave never wedges
            assert_eq!(reaped, 8);
        }
        assert_eq!(errors, 32);
        let s = rs.snapshot();
        assert!(s.exhausted > 0, "{s:?}");
        assert!(s.breaker_opens >= 1, "{s:?}");
        assert!(s.breaker_fastfail > 0, "later waves should fast-fail: {s:?}");
        assert_eq!(ring.stats().inflight, 0);
    }

    #[test]
    fn hedges_fire_on_stalls_and_account_cleanly() {
        use crate::storage::fault::FaultInjector;
        use crate::storage::{RemoteProfile, SimRemoteStore};
        // stall-only profile: ops never fail, some just take +150ms —
        // exactly the tail a hedge tames
        let p = FaultProfile {
            error_rate: 0.0,
            stall_rate: 0.25,
            stall_ms: 150,
            reset_rate: 0.0,
            short_read_rate: 0.0,
            max_consecutive: 2,
        };
        let backing = backing(16);
        let remote =
            SimRemoteStore::new(backing.clone(), RemoteProfile::s3().scaled(0.02), 23);
        let injector = FaultInjector::new(FaultProfile::none(), 23);
        remote.set_faults(injector.clone());
        let rs = ResilientStore::new(remote, ResilienceConfig::new(2, 0, 1.0), 8);
        // warm the p95 estimator on the clean store, then turn the
        // stalls on — the hedge threshold must reflect *healthy* tails
        let mut out = vec![0u8; 128];
        for i in 0..96 {
            let _ = rs.get_into(&format!("k{}", i % 16), &mut out);
        }
        assert!(rs.snapshot().p95_ms > 0.0, "estimator never armed");
        injector.set_profile(p);
        let ring = IoRing::new(rs.clone(), 64);
        for _wave in 0..4 {
            let ops = (0..16)
                .map(|i| ReadOp::whole(i, format!("k{i}"), Vec::new()))
                .collect();
            let mut sub = ring.submit(ops);
            while let Some(c) = sub.next() {
                let n = c.result.unwrap();
                assert_eq!(&c.buf[..n], &backing.get(&c.key).unwrap()[..]);
            }
        }
        let s = rs.snapshot();
        assert!(s.hedges > 0, "no hedges fired: {s:?}");
        // every hedged op resolved exactly once; duplicate completions
        // were discarded, never double-delivered
        assert!(s.hedge_wins <= s.hedges, "{s:?}");
        assert_eq!(s.exhausted, 0, "{s:?}");
        assert_eq!(ring.stats().inflight, 0, "hedge attempt leaked the gauge");
        assert_eq!(ring.stats().completed, 64);
    }

    #[test]
    fn fault_free_ring_path_is_transparent() {
        let rs = ResilientStore::new(backing(8), ResilienceConfig::new(4, 0, 2.0), 5);
        let ring = IoRing::new(rs.clone(), 8);
        let ops = (0..8)
            .map(|i| ReadOp::range(i, format!("k{i}"), 8, 32, Vec::new()))
            .collect();
        let mut sub = ring.submit(ops);
        let mut n = 0;
        while let Some(c) = sub.next() {
            assert_eq!(c.result.unwrap(), 32);
            n += 1;
        }
        assert_eq!(n, 8);
        let s = rs.snapshot();
        assert_eq!(s.retries, 0);
        assert_eq!(s.hedges, 0, "p95 unarmed: no hedges on a cold start");
        assert_eq!(s.exhausted, 0);
        assert_eq!(s.ops, 8);
        assert_eq!(s.attempts, 8);
    }
}
