//! Object-storage substrate.
//!
//! The paper's entire evaluation is "the same loader against storage with
//! different latency structure": local NVMe *scratch*, AWS *S3*, *Ceph*
//! object store / file system, *Gluster FS*, and a Varnish HTTP cache in
//! front of S3. We reproduce the substrate as composable stores:
//!
//! * [`MemStore`] — in-memory blobs (the backing for simulated remotes).
//! * [`DirStore`] — real files on local disk (true scratch I/O).
//! * [`remote::SimRemoteStore`] — wraps any store with first-byte latency,
//!   per-connection and NIC bandwidth, and a connection limit; presets
//!   calibrated per storage type live in [`remote::RemoteProfile`].
//! * [`cache::VarnishCache`] — byte-capped cache in front of any store
//!   (LRU by default; any [`evict::CachePolicy`]).
//! * [`crate::prefetch::PrefetchStore`] — sampler-ahead prefetch engine
//!   with a tiered cache (hot in-memory tier over any of the above as
//!   the warm tier); lives in its own subsystem, `crate::prefetch`.
//!
//! Every byte-capped cache in the tree (Varnish warm cache, prefetch hot
//! tier) is built on one O(1) eviction structure, [`evict::EvictCore`].
//!
//! Both a blocking and an async (`asyncrt`) fetch path are exposed; the
//! async path is what the Asyncio fetcher uses. Stores also receive the
//! epoch's upcoming key order through [`ObjectStore::hint_order`] —
//! prefetching layers act on it, caches forward it down the stack, and
//! plain stores ignore it.

pub mod cache;
pub mod dir;
pub mod evict;
pub mod fault;
pub mod mem;
pub mod remote;
pub mod resilient;
pub mod ring;

pub use cache::VarnishCache;
pub use dir::DirStore;
pub use evict::{CachePolicy, CoreStats, EvictCore};
pub use fault::{FaultCounters, FaultInjector, FaultProfile, FaultStore};
pub use mem::MemStore;
pub use remote::{RemoteProfile, SimRemoteStore};
pub use resilient::{
    BreakerState, CircuitBreaker, ResilienceConfig, ResilienceSnapshot, ResilientStore,
};
pub use ring::{
    Completion, CompletionSink, InflightGuard, IoRing, ReadOp, RingCtx, RingSnapshot,
    Submission,
};

use std::future::Future;
use std::pin::Pin;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

pub type Bytes = Arc<Vec<u8>>;
pub type BoxFut<'a, T> = Pin<Box<dyn Future<Output = T> + Send + 'a>>;

/// A key-value object store (S3-shaped: opaque bytes under string keys).
pub trait ObjectStore: Send + Sync {
    /// Blocking fetch.
    fn get(&self, key: &str) -> Result<Bytes>;

    /// Async fetch. Default: delegate to the blocking path (correct for
    /// fast local stores); simulated remotes override this with
    /// non-blocking latency waits.
    fn get_async<'a>(&'a self, key: &'a str) -> BoxFut<'a, Result<Bytes>> {
        Box::pin(async move { self.get(key) })
    }

    /// Zero-copy read: fetch `key` into the caller's buffer, returning
    /// the object's **total size** in bytes. When the returned size
    /// exceeds `out.len()` the buffer was too small and nothing was
    /// written — the caller grows the buffer and retries (see
    /// [`get_into_vec`], which does exactly that). This snprintf-style
    /// contract keeps the signature allocation-free in both directions.
    ///
    /// The default falls back to [`ObjectStore::get`] plus one copy, so
    /// every store works; stores with a native scratch path
    /// ([`DirStore`]) read straight into `out` with no intermediate
    /// `Vec`, and facades ([`VarnishCache`], the prefetch store) serve
    /// hits by copy-out and delegate misses downward.
    fn get_into(&self, key: &str, out: &mut [u8]) -> Result<usize> {
        let data = self.get(key)?;
        let n = data.len();
        if n <= out.len() {
            out[..n].copy_from_slice(&data);
        }
        Ok(n)
    }

    /// Ranged read: fetch `out.len()` bytes of `key` starting at byte
    /// `offset`, returning the number of bytes actually read —
    /// `min(out.len(), size - offset)`, i.e. short only when the range
    /// runs past the end of the object. An `offset` beyond the object is
    /// an error. This is the shard-window surface: one ranged read per
    /// tar shard amortizes a remote's first-byte latency over every
    /// sample inside the window, instead of paying it per image.
    ///
    /// The default falls back to [`ObjectStore::get`] plus one copy of
    /// the requested range, so every store works; [`DirStore`] preads at
    /// the offset natively and [`SimRemoteStore`] charges one latency
    /// draw plus bandwidth over the *range* (not the whole object).
    fn get_range_into(&self, key: &str, offset: u64, out: &mut [u8]) -> Result<usize> {
        let data = self.get(key)?;
        range_from_bytes(&data, key, offset, out)
    }

    /// Whether this store (or, for facades, the store at the bottom of
    /// the stack) implements [`ObjectStore::get_into`] natively — i.e.
    /// reading into a caller buffer is *cheaper* than [`ObjectStore::get`],
    /// not just a copy of it. Datasets use this to pick their raw-byte
    /// path: shared-`Bytes` stores (`MemStore` and everything simulated
    /// on top of it) already serve `get` without allocating, so forcing
    /// them through `get_into` would add a copy for nothing.
    fn native_get_into(&self) -> bool {
        false
    }

    /// Store an object (used by dataset generation and tests).
    fn put(&self, key: &str, data: Vec<u8>) -> Result<()>;

    /// All keys, sorted (the dataset manifest ordering).
    fn keys(&self) -> Vec<String>;

    /// Cheap existence check. The default scans the key manifest and
    /// never touches the data path, so stores with simulated transfer
    /// costs don't pay latency or bandwidth (and don't skew `stats()`)
    /// on a lookup; stores with a native index override it.
    fn contains(&self, key: &str) -> bool {
        self.keys().iter().any(|k| k == key)
    }

    /// Sampler-ahead hint: the epoch's upcoming key access order.
    /// Prefetching stores ([`crate::prefetch::PrefetchStore`]) schedule
    /// background fetches from it, wrapper stores forward it to their
    /// inner store, and plain stores ignore it (the default).
    fn hint_order(&self, _epoch: usize, _keys: &[String]) {}

    /// Cross-epoch hint: the *next* epoch's key order, published while
    /// the current epoch is still being consumed (the epoch-pipelined
    /// loader fires this at plan-publication time). Prefetching stores
    /// *extend* their readahead horizon — positions continue past the
    /// current epoch's, so the engine rolls across the boundary without
    /// dropping the current tail — instead of resetting it like
    /// [`ObjectStore::hint_order`]. Wrapper stores forward it; plain
    /// stores treat it as a fresh hint (the default), which ignores it.
    fn hint_order_append(&self, epoch: usize, keys: &[String]) {
        self.hint_order(epoch, keys)
    }

    /// Batched submission: execute every [`ReadOp`] in `ops` and deliver
    /// each through `ctx` ([`RingCtx::begin`] once on entering service,
    /// [`RingCtx::complete`] once with the result) — the dispatch surface
    /// behind [`IoRing`]. Runs *on the ring executor*; implementations
    /// must never block its thread on work that needs the executor
    /// itself.
    ///
    /// The default loops the blocking read paths inside the single
    /// dispatch task — correct for any store, concurrent for none.
    /// Stores whose requests genuinely overlap ([`SimRemoteStore`])
    /// spawn one future per op gated on `ctx.depth()`; facades
    /// ([`VarnishCache`], the prefetch store) serve hits inline and
    /// delegate the miss set to their inner store's native path.
    fn submit_batch(self: Arc<Self>, ops: Vec<ReadOp>, ctx: RingCtx) {
        for mut op in ops {
            ctx.begin();
            let res = if op.len > 0 {
                op.buf.resize(op.len, 0);
                self.get_range_into(&op.key, op.offset, &mut op.buf)
            } else {
                self.get(&op.key).map(|data| {
                    op.buf.clear();
                    op.buf.extend_from_slice(&data);
                    data.len()
                })
            };
            ctx.complete(op.slot, op.key, op.buf, res);
        }
    }

    /// Human label for reports ("s3", "scratch", ...).
    fn label(&self) -> String;

    /// Transfer statistics since creation.
    fn stats(&self) -> StoreStats {
        StoreStats::default()
    }
}

/// Shared helper for the [`ObjectStore::get_range_into`] contract when
/// the whole object is already in hand: copy the in-range slice into
/// `out`, erroring on an out-of-bounds `offset`.
pub fn range_from_bytes(
    data: &[u8],
    key: &str,
    offset: u64,
    out: &mut [u8],
) -> Result<usize> {
    let len = data.len() as u64;
    anyhow::ensure!(
        offset <= len,
        "range offset {offset} past end of {key} ({len} bytes)"
    );
    let n = out.len().min((len - offset) as usize);
    out[..n].copy_from_slice(&data[offset as usize..offset as usize + n]);
    Ok(n)
}

/// Drive [`ObjectStore::get_into`] against a growable scratch buffer:
/// grow-and-retry until the object fits, returning its size. `buf` keeps
/// its (largest-seen) capacity across calls, so a reused scratch reaches
/// a zero-allocation steady state after the largest object in the
/// working set has been read once.
pub fn get_into_vec(
    store: &dyn ObjectStore,
    key: &str,
    buf: &mut Vec<u8>,
) -> Result<usize> {
    const MIN_SCRATCH: usize = 64 << 10;
    if buf.is_empty() {
        buf.resize(MIN_SCRATCH, 0);
    }
    loop {
        let need = store.get_into(key, buf)?;
        if need <= buf.len() {
            return Ok(need);
        }
        buf.resize(need, 0);
    }
}

/// Cumulative transfer counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StoreStats {
    pub gets: u64,
    pub bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// Shared counter block used by store implementations. Tracks transfer
/// volume only; caching stores report hit/miss/eviction truth from
/// their eviction core ([`evict::EvictCore`]).
#[derive(Debug, Default)]
pub struct StatCounters {
    pub gets: AtomicU64,
    pub bytes: AtomicU64,
}

impl StatCounters {
    pub fn record_get(&self, bytes: u64) {
        self.gets.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StoreStats {
        StoreStats {
            gets: self.gets.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            ..StoreStats::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_async_path_works() {
        let store = MemStore::new("m");
        store.put("k", vec![1, 2, 3]).unwrap();
        let got = crate::asyncrt::block_on(store.get_async("k")).unwrap();
        assert_eq!(&*got, &[1, 2, 3]);
    }

    #[test]
    fn default_contains_stays_off_the_data_path() {
        // a store that panics if the data path is touched
        struct NoGet;
        impl ObjectStore for NoGet {
            fn get(&self, _key: &str) -> Result<Bytes> {
                panic!("contains must not call get");
            }
            fn put(&self, _key: &str, _data: Vec<u8>) -> Result<()> {
                Ok(())
            }
            fn keys(&self) -> Vec<String> {
                vec!["present".to_string()]
            }
            fn label(&self) -> String {
                "noget".to_string()
            }
        }
        let s = NoGet;
        assert!(s.contains("present"));
        assert!(!s.contains("absent"));
        s.hint_order(0, &["present".to_string()]); // default: ignored
    }

    #[test]
    fn default_get_into_copies_out_or_reports_size() {
        let store = MemStore::new("m");
        store.put("k", vec![5u8; 40]).unwrap();
        let mut big = vec![0u8; 64];
        assert_eq!(store.get_into("k", &mut big).unwrap(), 40);
        assert!(big[..40].iter().all(|&b| b == 5));
        assert_eq!(big[40], 0);
        // too-small buffer: size reported, nothing written
        let mut small = vec![9u8; 8];
        assert_eq!(store.get_into("k", &mut small).unwrap(), 40);
        assert!(small.iter().all(|&b| b == 9));
        assert!(store.get_into("ghost", &mut big).is_err());
        assert!(!store.native_get_into());
    }

    #[test]
    fn default_get_range_into_reads_the_requested_window() {
        let store = MemStore::new("m");
        store.put("k", (0u8..100).collect()).unwrap();
        let mut out = vec![0u8; 10];
        // interior range
        assert_eq!(store.get_range_into("k", 30, &mut out).unwrap(), 10);
        assert_eq!(out, (30u8..40).collect::<Vec<_>>());
        // tail range comes back short, not erroring
        assert_eq!(store.get_range_into("k", 95, &mut out).unwrap(), 5);
        assert_eq!(out[..5], (95u8..100).collect::<Vec<_>>()[..]);
        // offset at the very end reads zero bytes; past it errors
        assert_eq!(store.get_range_into("k", 100, &mut out).unwrap(), 0);
        assert!(store.get_range_into("k", 101, &mut out).is_err());
        assert!(store.get_range_into("ghost", 0, &mut out).is_err());
    }

    #[test]
    fn get_into_vec_grows_to_fit() {
        let store = MemStore::new("m");
        store.put("big", vec![3u8; 200 << 10]).unwrap();
        store.put("small", vec![4u8; 16]).unwrap();
        let mut buf = Vec::new();
        let n = get_into_vec(&store, "big", &mut buf).unwrap();
        assert_eq!(n, 200 << 10);
        assert!(buf[..n].iter().all(|&b| b == 3));
        let cap = buf.capacity();
        // smaller object reuses the grown scratch without shrinking it
        let n = get_into_vec(&store, "small", &mut buf).unwrap();
        assert_eq!(n, 16);
        assert!(buf[..16].iter().all(|&b| b == 4));
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn stat_counters_snapshot() {
        let c = StatCounters::default();
        c.record_get(10);
        c.record_get(5);
        let s = c.snapshot();
        assert_eq!(s.gets, 2);
        assert_eq!(s.bytes, 15);
    }
}
