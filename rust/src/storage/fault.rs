//! Deterministic fault injection for the storage plane.
//!
//! Real object stores don't just add latency — they throw transient
//! 5xx/timeouts, stall connections, reset them mid-transfer, and return
//! short reads. [`FaultProfile`] describes a seeded mixture of those
//! behaviors; [`FaultInjector`] turns it into per-request decisions, and
//! [`FaultStore`] wraps any [`ObjectStore`] with them for unit-level
//! chaos. [`super::SimRemoteStore`] carries an optional injector of its
//! own so the simulated remotes misbehave on *both* the blocking and
//! async paths (including the batched-submission ring).
//!
//! Two invariants make chaos runs reproducible and digest-comparable:
//!
//! * **Faults never corrupt bytes.** Every fault either delays a request
//!   (a stall, which then succeeds) or fails it outright (transient /
//!   reset / short read — a detected truncation is an error, not silent
//!   bad data). A run that completes therefore delivers exactly the
//!   bytes a fault-free run would.
//! * **Forward progress is bounded.** With `max_consecutive = n > 0`, a
//!   key that has faulted `n` times in a row is forced to succeed on the
//!   next attempt — so any retry budget above `n` is guaranteed to
//!   drain the epoch. `max_consecutive = 0` disables the cap
//!   (persistent-outage profiles, for exercising breaker trips and
//!   retry-budget exhaustion).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use super::{BoxFut, Bytes, ObjectStore, StoreStats};
use crate::util::rng::Rng;

/// One injected fault decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// transient service error (5xx-shaped): fails without transferring
    Transient,
    /// connection stalls for the given extra delay, then succeeds
    /// (p_slow→∞-shaped tail)
    Stall(Duration),
    /// connection reset mid-transfer: fails after work was started
    Reset,
    /// truncated transfer, *detected* — surfaces as an error, never as
    /// silently short bytes
    ShortRead,
}

/// Seeded fault mixture. Rates are per-request probabilities, drawn in
/// order transient → stall → reset → short-read from one roll.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultProfile {
    pub error_rate: f64,
    pub stall_rate: f64,
    /// extra delay charged by a stall fault
    pub stall_ms: u64,
    pub reset_rate: f64,
    pub short_read_rate: f64,
    /// after this many consecutive faults on one key the next attempt is
    /// forced to succeed (0 = never force — persistent outage)
    pub max_consecutive: u32,
}

impl FaultProfile {
    /// No faults at all (the inert default).
    pub fn none() -> FaultProfile {
        FaultProfile {
            error_rate: 0.0,
            stall_rate: 0.0,
            stall_ms: 0,
            reset_rate: 0.0,
            short_read_rate: 0.0,
            max_consecutive: 2,
        }
    }

    /// A misbehaving-but-alive service: ~25% of requests fault, split
    /// across all four kinds, but no key faults more than twice in a
    /// row — any retry budget ≥ 3 attempts completes the run.
    pub fn flaky() -> FaultProfile {
        FaultProfile {
            error_rate: 0.10,
            stall_rate: 0.05,
            stall_ms: 40,
            reset_rate: 0.05,
            short_read_rate: 0.05,
            max_consecutive: 2,
        }
    }

    /// Hard outage: every request fails, forever (`max_consecutive = 0`
    /// disables forced success). Exercises retry-budget exhaustion and
    /// circuit-breaker trips.
    pub fn outage() -> FaultProfile {
        FaultProfile {
            error_rate: 1.0,
            stall_rate: 0.0,
            stall_ms: 0,
            reset_rate: 0.0,
            short_read_rate: 0.0,
            max_consecutive: 0,
        }
    }

    pub fn by_name(name: &str) -> Option<FaultProfile> {
        Some(match name {
            "none" => Self::none(),
            "flaky" => Self::flaky(),
            "outage" => Self::outage(),
            _ => return None,
        })
    }

    /// Total per-request fault probability.
    pub fn fault_rate(&self) -> f64 {
        self.error_rate + self.stall_rate + self.reset_rate + self.short_read_rate
    }

    fn is_inert(&self) -> bool {
        self.fault_rate() <= 0.0
    }
}

/// Cumulative injection counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    pub decisions: u64,
    pub transient: u64,
    pub stalls: u64,
    pub resets: u64,
    pub short_reads: u64,
    /// faults suppressed by the per-key `max_consecutive` cap
    pub forced_ok: u64,
}

impl FaultCounters {
    pub fn injected(&self) -> u64 {
        self.transient + self.stalls + self.resets + self.short_reads
    }
}

/// Seeded per-request fault decider with the per-key consecutive cap.
pub struct FaultInjector {
    profile: Mutex<FaultProfile>,
    rng: Mutex<Rng>,
    /// consecutive fault count per key (bounded by the key space)
    streaks: Mutex<HashMap<String, u32>>,
    decisions: AtomicU64,
    transient: AtomicU64,
    stalls: AtomicU64,
    resets: AtomicU64,
    short_reads: AtomicU64,
    forced_ok: AtomicU64,
}

impl FaultInjector {
    pub fn new(profile: FaultProfile, seed: u64) -> Arc<FaultInjector> {
        Arc::new(FaultInjector {
            profile: Mutex::new(profile),
            rng: Mutex::new(Rng::new(seed)),
            streaks: Mutex::new(HashMap::new()),
            decisions: AtomicU64::new(0),
            transient: AtomicU64::new(0),
            stalls: AtomicU64::new(0),
            resets: AtomicU64::new(0),
            short_reads: AtomicU64::new(0),
            forced_ok: AtomicU64::new(0),
        })
    }

    pub fn profile(&self) -> FaultProfile {
        *self.profile.lock().unwrap()
    }

    /// Swap the active profile live (chaos tests script outages healing
    /// mid-run to drive breaker half-open → closed transitions).
    pub fn set_profile(&self, profile: FaultProfile) {
        *self.profile.lock().unwrap() = profile;
    }

    /// Decide the fate of one request attempt on `key`.
    pub fn decide(&self, key: &str) -> Option<Fault> {
        let p = *self.profile.lock().unwrap();
        if p.is_inert() {
            return None;
        }
        self.decisions.fetch_add(1, Ordering::Relaxed);
        let roll = self.rng.lock().unwrap().f64();
        let fault = if roll < p.error_rate {
            Some(Fault::Transient)
        } else if roll < p.error_rate + p.stall_rate {
            Some(Fault::Stall(Duration::from_millis(p.stall_ms)))
        } else if roll < p.error_rate + p.stall_rate + p.reset_rate {
            Some(Fault::Reset)
        } else if roll < p.fault_rate() {
            Some(Fault::ShortRead)
        } else {
            None
        };
        let mut streaks = self.streaks.lock().unwrap();
        match fault {
            // stalls succeed, so they end a failure streak
            Some(Fault::Stall(d)) => {
                streaks.remove(key);
                self.stalls.fetch_add(1, Ordering::Relaxed);
                Some(Fault::Stall(d))
            }
            Some(f) => {
                let streak = streaks.entry(key.to_string()).or_insert(0);
                if p.max_consecutive > 0 && *streak >= p.max_consecutive {
                    // cap reached: force success so retry budgets above
                    // the cap always drain
                    *streak = 0;
                    self.forced_ok.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
                *streak += 1;
                match f {
                    Fault::Transient => self.transient.fetch_add(1, Ordering::Relaxed),
                    Fault::Reset => self.resets.fetch_add(1, Ordering::Relaxed),
                    Fault::ShortRead => {
                        self.short_reads.fetch_add(1, Ordering::Relaxed)
                    }
                    Fault::Stall(_) => unreachable!(),
                };
                Some(f)
            }
            None => {
                streaks.remove(key);
                None
            }
        }
    }

    /// [`FaultInjector::decide`] folded into a `Result`: error-kind
    /// faults become `Err`, returning any stall delay to charge.
    pub fn roll(&self, key: &str) -> Result<Option<Duration>> {
        match self.decide(key) {
            None => Ok(None),
            Some(Fault::Stall(d)) => Ok(Some(d)),
            Some(Fault::Transient) => {
                bail!("injected transient error on {key} (simulated 5xx)")
            }
            Some(Fault::Reset) => {
                bail!("injected connection reset on {key}")
            }
            Some(Fault::ShortRead) => {
                bail!("injected short read on {key} (truncated transfer detected)")
            }
        }
    }

    pub fn counters(&self) -> FaultCounters {
        FaultCounters {
            decisions: self.decisions.load(Ordering::Relaxed),
            transient: self.transient.load(Ordering::Relaxed),
            stalls: self.stalls.load(Ordering::Relaxed),
            resets: self.resets.load(Ordering::Relaxed),
            short_reads: self.short_reads.load(Ordering::Relaxed),
            forced_ok: self.forced_ok.load(Ordering::Relaxed),
        }
    }
}

/// Chaos wrapper over any [`ObjectStore`]: every read shape rolls the
/// injector first (stalls sleep, error faults fail), writes and
/// metadata pass through untouched. The default `submit_batch` loops
/// the blocking paths, so ring submissions inject too.
pub struct FaultStore {
    inner: Arc<dyn ObjectStore>,
    injector: Arc<FaultInjector>,
}

impl FaultStore {
    pub fn new(
        inner: Arc<dyn ObjectStore>,
        profile: FaultProfile,
        seed: u64,
    ) -> Arc<FaultStore> {
        Arc::new(FaultStore { inner, injector: FaultInjector::new(profile, seed) })
    }

    pub fn injector(&self) -> &Arc<FaultInjector> {
        &self.injector
    }
}

impl ObjectStore for FaultStore {
    fn get(&self, key: &str) -> Result<Bytes> {
        if let Some(stall) = self.injector.roll(key)? {
            std::thread::sleep(stall);
        }
        self.inner.get(key)
    }

    fn get_async<'a>(&'a self, key: &'a str) -> BoxFut<'a, Result<Bytes>> {
        Box::pin(async move {
            if let Some(stall) = self.injector.roll(key)? {
                crate::asyncrt::sleep(stall).await;
            }
            self.inner.get_async(key).await
        })
    }

    fn get_into(&self, key: &str, out: &mut [u8]) -> Result<usize> {
        if let Some(stall) = self.injector.roll(key)? {
            std::thread::sleep(stall);
        }
        self.inner.get_into(key, out)
    }

    fn get_range_into(&self, key: &str, offset: u64, out: &mut [u8]) -> Result<usize> {
        if let Some(stall) = self.injector.roll(key)? {
            std::thread::sleep(stall);
        }
        self.inner.get_range_into(key, offset, out)
    }

    fn native_get_into(&self) -> bool {
        self.inner.native_get_into()
    }

    fn put(&self, key: &str, data: Vec<u8>) -> Result<()> {
        self.inner.put(key, data)
    }

    fn keys(&self) -> Vec<String> {
        self.inner.keys()
    }

    fn contains(&self, key: &str) -> bool {
        self.inner.contains(key)
    }

    fn hint_order(&self, epoch: usize, keys: &[String]) {
        self.inner.hint_order(epoch, keys)
    }

    fn hint_order_append(&self, epoch: usize, keys: &[String]) {
        self.inner.hint_order_append(epoch, keys)
    }

    fn label(&self) -> String {
        format!("fault({})", self.inner.label())
    }

    fn stats(&self) -> StoreStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;

    fn backing() -> Arc<dyn ObjectStore> {
        let m = MemStore::new("m");
        for i in 0..8 {
            m.put(&format!("k{i}"), vec![i as u8; 64]).unwrap();
        }
        Arc::new(m)
    }

    #[test]
    fn profiles_by_name() {
        assert_eq!(FaultProfile::by_name("none"), Some(FaultProfile::none()));
        assert_eq!(FaultProfile::by_name("flaky"), Some(FaultProfile::flaky()));
        assert_eq!(FaultProfile::by_name("outage"), Some(FaultProfile::outage()));
        assert!(FaultProfile::by_name("sunny").is_none());
        assert!(FaultProfile::none().is_inert());
        assert!(!FaultProfile::flaky().is_inert());
    }

    #[test]
    fn inert_profile_never_faults_or_counts() {
        let inj = FaultInjector::new(FaultProfile::none(), 1);
        for _ in 0..200 {
            assert_eq!(inj.decide("k"), None);
        }
        assert_eq!(inj.counters(), FaultCounters::default());
    }

    #[test]
    fn injection_is_seed_deterministic() {
        let a = FaultInjector::new(FaultProfile::flaky(), 9);
        let b = FaultInjector::new(FaultProfile::flaky(), 9);
        let seq_a: Vec<_> = (0..100).map(|i| a.decide(&format!("k{}", i % 4))).collect();
        let seq_b: Vec<_> = (0..100).map(|i| b.decide(&format!("k{}", i % 4))).collect();
        assert_eq!(seq_a, seq_b);
        assert!(a.counters().injected() > 0, "{:?}", a.counters());
        let c = FaultInjector::new(FaultProfile::flaky(), 10);
        let seq_c: Vec<_> = (0..100).map(|i| c.decide(&format!("k{}", i % 4))).collect();
        assert_ne!(seq_a, seq_c, "different seed, same decisions");
    }

    #[test]
    fn consecutive_cap_forces_success() {
        // guaranteed faulting, cap 2: every third attempt on a key is
        // forced to succeed
        let p = FaultProfile { max_consecutive: 2, ..FaultProfile::outage() };
        let inj = FaultInjector::new(p, 3);
        let fates: Vec<bool> =
            (0..9).map(|_| inj.decide("k").is_some()).collect();
        assert_eq!(
            fates,
            vec![true, true, false, true, true, false, true, true, false]
        );
        assert_eq!(inj.counters().forced_ok, 3);
    }

    #[test]
    fn outage_profile_never_relents() {
        let inj = FaultInjector::new(FaultProfile::outage(), 3);
        for _ in 0..50 {
            assert!(inj.roll("k").is_err());
        }
        assert_eq!(inj.counters().forced_ok, 0);
    }

    #[test]
    fn fault_store_fails_and_recovers_without_corruption() {
        let fs = FaultStore::new(backing(), FaultProfile::flaky(), 11);
        let mut oks = 0usize;
        let mut errs = 0usize;
        for i in 0..120 {
            let key = format!("k{}", i % 8);
            match fs.get(&key) {
                Ok(data) => {
                    oks += 1;
                    // bytes are never corrupted, only delayed or denied
                    assert!(data.iter().all(|&b| b == (i % 8) as u8));
                }
                Err(_) => errs += 1,
            }
        }
        assert!(oks > 0 && errs > 0, "oks {oks} errs {errs}");
        assert_eq!(fs.injector().counters().injected() - fs.injector().counters().stalls, errs as u64);
        assert!(fs.label().starts_with("fault("));
    }

    #[test]
    fn fault_store_injects_on_every_read_shape() {
        let fs = FaultStore::new(backing(), FaultProfile::outage(), 5);
        let mut out = vec![0u8; 64];
        assert!(fs.get("k0").is_err());
        assert!(fs.get_into("k0", &mut out).is_err());
        assert!(fs.get_range_into("k0", 0, &mut out).is_err());
        assert!(crate::asyncrt::block_on(fs.get_async("k0")).is_err());
        assert_eq!(fs.injector().counters().injected(), 4);
        // off the data path: no injection
        assert!(fs.contains("k0"));
        fs.set_profile_for_test();
    }

    impl FaultStore {
        fn set_profile_for_test(&self) {
            self.injector.set_profile(FaultProfile::none());
            assert!(self.get("k1").is_ok());
        }
    }

    #[test]
    fn stall_fault_delays_then_succeeds() {
        let p = FaultProfile {
            error_rate: 0.0,
            stall_rate: 1.0,
            stall_ms: 25,
            reset_rate: 0.0,
            short_read_rate: 0.0,
            max_consecutive: 2,
        };
        let fs = FaultStore::new(backing(), p, 7);
        let t0 = std::time::Instant::now();
        assert_eq!(fs.get("k1").unwrap().len(), 64);
        assert!(t0.elapsed() >= Duration::from_millis(20), "{:?}", t0.elapsed());
        assert_eq!(fs.injector().counters().stalls, 1);
    }
}
