//! [`ShardDataset`] — the map-style dataset over a
//! [`crate::shards::ShardStore`]: same index → sample mapping (and
//! therefore the same augmentation stream) as an
//! [`super::ImageFolderDataset`] over the source corpus, but every load
//! decodes straight out of a borrowed shard window instead of paying a
//! per-image storage request.
//!
//! Two shuffle levels replace the loader's generic sampler when enabled
//! ([`ShardDataset::with_shuffle`], surfaced through
//! [`super::Dataset::epoch_order`]): a seeded permutation of the *shard*
//! visit order, then a WebDataset-style reservoir over the shard-ordered
//! sample stream. Randomization happens mostly *within* a sliding window
//! of a few shards, so each window is fetched once per epoch instead of
//! being re-faulted from all over the visit order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::data::simg::SimgRef;
use crate::data::{Augment, AugmentConfig, SimgImage};
use crate::gil::Gil;
use crate::shards::ShardStore;
use crate::storage::BoxFut;
use crate::util::rng::Rng;

use super::{Dataset, ItemMeta, LaneTimes, Sample};

/// Map-style dataset over packed shards.
pub struct ShardDataset {
    store: Arc<ShardStore>,
    augment: Augment,
    epoch: AtomicUsize,
    /// `Some(seed)` enables the two-level shard shuffle; `None` defers
    /// order selection to the loader's sampler
    shuffle_seed: Option<u64>,
    /// intra-shard reservoir size (level two of the shuffle)
    reservoir: usize,
    lanes: LaneTimes,
}

impl ShardDataset {
    pub fn new(store: Arc<ShardStore>, augment_cfg: AugmentConfig) -> ShardDataset {
        // default reservoir: one shard's worth of samples — enough to
        // mix adjacent windows without tearing shard locality apart
        let reservoir = store.manifest().members(0).len().max(1);
        ShardDataset {
            store,
            augment: Augment::new(augment_cfg),
            epoch: AtomicUsize::new(0),
            shuffle_seed: None,
            reservoir,
            lanes: LaneTimes::default(),
        }
    }

    /// Enable the two-level shuffle (seeded shard order + intra-shard
    /// reservoir). With it on, [`Dataset::epoch_order`] overrides the
    /// loader's sampler.
    pub fn with_shuffle(mut self, seed: u64) -> ShardDataset {
        self.shuffle_seed = Some(seed);
        self
    }

    /// Override the reservoir size (level two of the shuffle).
    pub fn with_reservoir(mut self, n: usize) -> ShardDataset {
        self.reservoir = n.max(1);
        self
    }

    pub fn store(&self) -> &Arc<ShardStore> {
        &self.store
    }
}

impl Dataset for ShardDataset {
    fn len(&self) -> usize {
        self.store.manifest().n_samples()
    }

    fn supports_epoch_tagged(&self) -> bool {
        true
    }

    fn get_item(&self, index: usize, gil: &Gil) -> Result<Sample> {
        self.get_item_at(index, self.epoch.load(Ordering::Relaxed), gil)
    }

    fn get_item_at(&self, index: usize, epoch: usize, gil: &Gil) -> Result<Sample> {
        let t0 = Instant::now();
        let (win, off, len) = gil.io(|| self.store.sample_window_at(index))?;
        let fetch = t0.elapsed();
        self.lanes.add_storage(fetch);
        let t1 = Instant::now();
        let (crop, label) = gil.cpu(|| {
            let img = SimgImage::decode(&win[off..off + len])?;
            let crop = self.augment.apply_u8(&img, epoch, index);
            Ok((crop, img.label))
        })?;
        let decode = t1.elapsed();
        self.lanes.add_decode(decode);
        Ok(Sample {
            index,
            label,
            crop,
            raw_bytes: len,
            fetch_time: fetch.as_secs_f64(),
            decode_time: decode.as_secs_f64(),
        })
    }

    fn get_item_async<'a>(&'a self, index: usize, gil: &'a Gil) -> BoxFut<'a, Result<Sample>> {
        self.get_item_async_at(index, self.epoch.load(Ordering::Relaxed), gil)
    }

    fn get_item_async_at<'a>(
        &'a self,
        index: usize,
        epoch: usize,
        gil: &'a Gil,
    ) -> BoxFut<'a, Result<Sample>> {
        // window fetches resolve synchronously (single-flight, usually a
        // resident hit once the prefetch hint has run ahead); wrapping
        // the blocking path keeps the asyncio fetcher byte-identical
        Box::pin(async move { self.get_item_at(index, epoch, gil) })
    }

    fn set_epoch(&self, epoch: usize) {
        self.epoch.store(epoch, Ordering::Relaxed);
    }

    fn epoch_order(&self, epoch: usize) -> Option<Vec<usize>> {
        let seed = self.shuffle_seed?;
        let m = self.store.manifest();
        let mut rng =
            Rng::new(seed ^ (epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        // level one: visit shards in a fresh seeded order each epoch
        let shard_order = rng.permutation(m.n_shards());
        // level two: reservoir over the shard-ordered sample stream —
        // every sample is emitted exactly once, displaced by at most
        // ~reservoir positions from its shard run
        let cap = self.reservoir;
        let mut out = Vec::with_capacity(m.n_samples());
        let mut buf: Vec<usize> = Vec::with_capacity(cap);
        for si in shard_order {
            for i in m.members(si) {
                if buf.len() < cap {
                    buf.push(i);
                } else {
                    let j = rng.below(cap);
                    out.push(std::mem::replace(&mut buf[j], i));
                }
            }
        }
        rng.shuffle(&mut buf);
        out.extend(buf);
        Some(out)
    }

    fn hint_epoch_order(&self, epoch: usize, order: &[usize]) {
        // sample order → deduped shard-window order, forwarded down the
        // stack so the prefetch engine pulls whole windows ahead
        self.store.hint_sample_indices(epoch, order, false);
    }

    fn hint_epoch_order_next(&self, epoch: usize, order: &[usize]) {
        self.store.hint_sample_indices(epoch, order, true);
    }

    fn crop(&self) -> usize {
        self.augment.cfg.crop
    }

    fn get_item_into(&self, index: usize, gil: &Gil, out: &mut [u8]) -> Result<ItemMeta> {
        self.get_item_into_at(index, self.epoch.load(Ordering::Relaxed), gil, out)
    }

    fn get_item_into_at(
        &self,
        index: usize,
        epoch: usize,
        gil: &Gil,
        out: &mut [u8],
    ) -> Result<ItemMeta> {
        let want = self.crop() * self.crop() * 3;
        if out.len() != want {
            anyhow::bail!(
                "item {index}: slot holds {} bytes, crop needs {want}",
                out.len()
            );
        }
        let t0 = Instant::now();
        // borrow the resident window (Arc bump, no copy) ...
        let (win, off, len) = gil.io(|| self.store.sample_window_at(index))?;
        self.lanes.add_storage(t0.elapsed());
        let t1 = Instant::now();
        let res = gil.cpu(|| {
            // ... and decode straight out of it into the arena slot
            let img = SimgRef::parse(&win[off..off + len])?;
            self.augment.apply_u8_into(&img, epoch, index, out);
            Ok(ItemMeta { label: img.label, raw_bytes: len })
        });
        self.lanes.add_decode(t1.elapsed());
        res
    }

    fn lane_times(&self) -> Option<(Duration, Duration)> {
        Some((
            Duration::from_nanos(self.lanes.storage_ns.load(Ordering::Relaxed)),
            Duration::from_nanos(self.lanes.decode_ns.load(Ordering::Relaxed)),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_corpus, CorpusSpec};
    use crate::dataset::ImageFolderDataset;
    use crate::shards::pack_shards;
    use crate::storage::{MemStore, ObjectStore};

    fn pair(items: usize, shard_size: usize) -> (ImageFolderDataset, ShardDataset) {
        let src: Arc<dyn ObjectStore> = Arc::new(MemStore::new("src"));
        generate_corpus(&src, &CorpusSpec::tiny(items)).unwrap();
        let dst: Arc<dyn ObjectStore> = Arc::new(MemStore::new("dst"));
        let manifest = pack_shards(&src, &dst, shard_size).unwrap();
        let cfg = AugmentConfig { crop: 16, ..Default::default() };
        let per_file = ImageFolderDataset::new(src, cfg.clone());
        let sharded = ShardDataset::new(
            Arc::new(ShardStore::new(dst, manifest, 2)),
            cfg,
        );
        (per_file, sharded)
    }

    #[test]
    fn matches_per_file_dataset_byte_for_byte() {
        let (pf, sd) = pair(10, 4);
        assert_eq!(pf.len(), sd.len());
        let gil = Gil::native();
        for epoch in [0usize, 3] {
            for index in 0..sd.len() {
                let a = pf.get_item_at(index, epoch, &gil).unwrap();
                let b = sd.get_item_at(index, epoch, &gil).unwrap();
                assert_eq!(a.crop.data, b.crop.data, "epoch {epoch} index {index}");
                assert_eq!(a.label, b.label);
                assert_eq!(a.raw_bytes, b.raw_bytes);
                // fused path too
                let mut slot = vec![0u8; 16 * 16 * 3];
                let meta = sd.get_item_into_at(index, epoch, &gil, &mut slot).unwrap();
                assert_eq!(a.crop.data, slot);
                assert_eq!(a.label, meta.label);
            }
        }
        let (storage, decode) = sd.lane_times().unwrap();
        assert!(storage >= Duration::ZERO && decode > Duration::ZERO);
    }

    #[test]
    fn async_path_agrees_with_sync() {
        let (_, sd) = pair(6, 3);
        let gil = Gil::native();
        let a = sd.get_item_at(2, 1, &gil).unwrap();
        let b = crate::asyncrt::block_on(sd.get_item_async_at(2, 1, &gil)).unwrap();
        assert_eq!(a.crop.data, b.crop.data);
    }

    #[test]
    fn epoch_order_off_by_default_on_when_shuffled() {
        let (_, sd) = pair(12, 4);
        assert!(sd.epoch_order(0).is_none());
        let sd = sd.with_shuffle(7);
        let o0 = sd.epoch_order(0).unwrap();
        // a permutation of 0..len, deterministic, epoch-dependent
        let mut seen = vec![false; 12];
        for &i in &o0 {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
        assert_eq!(o0, sd.epoch_order(0).unwrap());
        assert_ne!(o0, sd.epoch_order(1).unwrap());
    }

    #[test]
    fn two_level_shuffle_keeps_shard_locality() {
        // with a reservoir of one shard, the distinct-shard sequence of
        // the visit order (deduped consecutively) must stay close to the
        // shard count — each window is faulted once, maybe twice, per
        // epoch rather than being re-entered from all over the order
        let (_, sd) = pair(64, 8);
        let sd = sd.with_shuffle(11);
        let m_shards = sd.store().manifest().n_shards();
        for epoch in 0..3 {
            let order = sd.epoch_order(epoch).unwrap();
            let mut runs = 0usize;
            let mut prev = usize::MAX;
            for &i in &order {
                let s = sd.store().manifest().shard_of(i);
                if s != prev {
                    runs += 1;
                    prev = s;
                }
            }
            assert!(
                runs <= 4 * m_shards,
                "epoch {epoch}: {runs} shard runs for {m_shards} shards — locality lost"
            );
        }
    }
}
