//! Map-style `Dataset` — the bottom layer of the paper's pipeline
//! (Fig 1): `__getitem__(index)` loads one object from storage, decodes
//! it, and applies the augmentation transform.
//!
//! The GIL of the *calling worker process* is passed into `get_item`
//! because in CPython the decode/augment CPU work executes under the
//! worker's interpreter lock while storage I/O releases it — that split
//! is exactly what the fetcher-parallelism results hinge on.

pub mod pool;
pub mod shard;

pub use shard::ShardDataset;

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::data::simg::SimgRef;
use crate::data::{Augment, AugmentConfig, SimgImage, U8Tensor};
use crate::gil::Gil;
use crate::storage::{BoxFut, Bytes, ObjectStore};
use crate::util::rng::Rng;

/// One loaded training item.
#[derive(Debug, Clone)]
pub struct Sample {
    pub index: usize,
    pub label: u16,
    /// augmented u8 HWC crop (normalize happens on-device, L1 kernel)
    pub crop: U8Tensor,
    /// size of the stored object (throughput accounting uses this)
    pub raw_bytes: usize,
    /// storage fetch time (s)
    pub fetch_time: f64,
    /// decode+augment CPU time (s), including GIL wait
    pub decode_time: f64,
}

/// Metadata of one item loaded through the fused write-into path — a
/// [`Sample`] minus the crop, which went straight into a batch-arena
/// slot instead of its own allocation. Timing lives in the `get_item`
/// telemetry spans, not here: the fused path avoids per-item clock
/// reads it has no consumer for.
#[derive(Debug, Clone, Copy)]
pub struct ItemMeta {
    pub label: u16,
    /// size of the stored object (throughput accounting uses this)
    pub raw_bytes: usize,
}

/// Copy a fully-loaded sample's crop into an arena slot — the fallback
/// assembly for datasets without a fused write-into path. A size
/// mismatch is a per-batch error, not a panic.
pub fn copy_sample_into(s: &Sample, out: &mut [u8]) -> Result<ItemMeta> {
    if s.crop.data.len() != out.len() {
        anyhow::bail!(
            "item {}: crop is {} bytes but the slot holds {}",
            s.index,
            s.crop.data.len(),
            out.len()
        );
    }
    out.copy_from_slice(&s.crop.data);
    Ok(ItemMeta { label: s.label, raw_bytes: s.raw_bytes })
}

/// Map-style dataset interface.
pub trait Dataset: Send + Sync {
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `__getitem__`: blocking fetch + decode + augment.
    fn get_item(&self, index: usize, gil: &Gil) -> Result<Sample>;

    /// Async variant used by the asyncio fetcher (storage wait is
    /// non-blocking; CPU work still blocks the loop, as in CPython).
    fn get_item_async<'a>(&'a self, index: usize, gil: &'a Gil) -> BoxFut<'a, Result<Sample>>;

    /// Set the augmentation epoch (torch reseeds per epoch).
    fn set_epoch(&self, epoch: usize);

    /// Sampler-ahead hint: the epoch's upcoming item access order.
    /// Storage-backed datasets translate it to keys and forward it to
    /// their store (`ObjectStore::hint_order`), which lets a prefetch
    /// layer (`crate::prefetch`) fetch ahead of demand. Default: ignore.
    fn hint_epoch_order(&self, _epoch: usize, _order: &[usize]) {}

    /// Storage-aware epoch visit order: a dataset that knows how its
    /// samples are laid out can override the loader's generic sampler
    /// with its own (seeded, deterministic) permutation — the shard
    /// dataset uses this for its two-level shuffle, which randomizes the
    /// shard visit order but keeps samples of one shard window close
    /// together so each window is fetched once per epoch. Returning
    /// `None` (the default) defers to the loader's sampler
    /// (`shuffle`/`seed` config). The returned order must be a
    /// permutation of `0..len()`.
    fn epoch_order(&self, _epoch: usize) -> Option<Vec<usize>> {
        None
    }

    /// Cross-epoch variant of [`Dataset::hint_epoch_order`]: the *next*
    /// epoch's access order, published while the current epoch's tail is
    /// still draining (the epoch-pipelined loader fires this at plan
    /// publication time). Storage-backed datasets forward it to
    /// `ObjectStore::hint_order_append`, which *extends* the prefetch
    /// horizon instead of resetting it — the engine keeps finishing the
    /// current epoch's readahead and rolls straight into the next.
    /// Default: treat it like a fresh epoch hint.
    fn hint_epoch_order_next(&self, epoch: usize, order: &[usize]) {
        self.hint_epoch_order(epoch, order);
    }

    // ---- epoch-tagged loads (cross-epoch pipelining, PR 5) -----------

    /// Whether this dataset honors the epoch tag on the `*_at` loads
    /// below. The epoch-pipelined loader keeps items of two adjacent
    /// epochs in flight at once, which is only deterministic when the
    /// augmentation epoch travels with each call — a dataset that
    /// relies on global [`Dataset::set_epoch`] state must report
    /// `false` (the default), and the loader then falls back to drained
    /// boundaries instead of silently mis-seeding the pipelined head.
    fn supports_epoch_tagged(&self) -> bool {
        false
    }

    /// Epoch-tagged `__getitem__`: like [`Dataset::get_item`], but the
    /// augmentation epoch travels *with the call* instead of through the
    /// global [`Dataset::set_epoch`] state. The epoch-pipelined loader
    /// keeps items of two adjacent epochs in flight at once, so the
    /// global epoch cannot disambiguate them. The default ignores the
    /// tag (correct only for drained, one-epoch-at-a-time loaders);
    /// epoch-aware datasets override it.
    fn get_item_at(&self, index: usize, _epoch: usize, gil: &Gil) -> Result<Sample> {
        self.get_item(index, gil)
    }

    /// Epoch-tagged async variant of [`Dataset::get_item_at`].
    fn get_item_async_at<'a>(
        &'a self,
        index: usize,
        _epoch: usize,
        gil: &'a Gil,
    ) -> BoxFut<'a, Result<Sample>> {
        self.get_item_async(index, gil)
    }

    /// Epoch-tagged variant of [`Dataset::get_item_into`].
    fn get_item_into_at(
        &self,
        index: usize,
        _epoch: usize,
        gil: &Gil,
        out: &mut [u8],
    ) -> Result<ItemMeta> {
        self.get_item_into(index, gil, out)
    }

    /// Epoch-tagged variant of [`Dataset::process_raw_into`].
    fn process_raw_into_at(
        &self,
        index: usize,
        _epoch: usize,
        raw: &[u8],
        gil: &Gil,
        out: &mut [u8],
    ) -> Result<ItemMeta> {
        self.process_raw_into(index, raw, gil, out)
    }

    /// Output crop side (informs collate shapes).
    fn crop(&self) -> usize;

    // ---- fused write-into path (batch arena, PR 3) --------------------

    /// `__getitem__` fused with collate: load item `index` and write its
    /// augmented crop directly into `out` (length `crop()²·3` — one
    /// arena slot), returning the metadata. The default routes through
    /// [`Dataset::get_item`] plus one copy, so any dataset works behind
    /// the arena; decode-aware datasets override it to skip every
    /// intermediate buffer.
    fn get_item_into(&self, index: usize, gil: &Gil, out: &mut [u8]) -> Result<ItemMeta> {
        let s = self.get_item(index, gil)?;
        copy_sample_into(&s, out)
    }

    /// Whether this dataset supports the raw-bytes fused path
    /// ([`Dataset::get_raw_async`] + [`Dataset::process_raw_into`]).
    /// The asyncio fetcher uses it to split storage wait (awaited on the
    /// event loop) from decode (written straight into the slab).
    fn supports_raw(&self) -> bool {
        false
    }

    /// Fetch the raw stored bytes of item `index` (no decode). Only
    /// meaningful when [`Dataset::supports_raw`] returns true.
    fn get_raw_async<'a>(&'a self, _index: usize) -> BoxFut<'a, Result<Bytes>> {
        Box::pin(async move {
            Err(anyhow::anyhow!("fused raw fetch unsupported by this dataset"))
        })
    }

    /// Decode + augment previously fetched raw bytes into `out` under
    /// the caller's GIL. Only meaningful when [`Dataset::supports_raw`]
    /// returns true.
    fn process_raw_into(
        &self,
        _index: usize,
        _raw: &[u8],
        _gil: &Gil,
        _out: &mut [u8],
    ) -> Result<ItemMeta> {
        Err(anyhow::anyhow!("fused decode unsupported by this dataset"))
    }

    /// Cumulative `(storage wait, decode/augment)` time across every
    /// item this dataset has served — the storage-wait and decode stall
    /// lanes of the observability plane. `None` when the dataset does
    /// not attribute its load time (the default).
    fn lane_times(&self) -> Option<(Duration, Duration)> {
        None
    }

    // ---- batched-submission ring path --------------------------------

    /// Describe item `index` as a ranged read descriptor for the
    /// batched-submission ring: write the storage key into `key`
    /// (cleared and reused across calls, so the wave path stays
    /// allocation-free) and return the `(offset, len)` of the raw
    /// bytes, with `(0, 0)` meaning the whole object. The raw bytes a
    /// descriptor reads must be exactly what
    /// [`Dataset::process_raw_into_at`] decodes. `None` (the default)
    /// means this dataset cannot express its reads as plain
    /// descriptors, and fetchers fall back to the per-item engines —
    /// the shard dataset stays on its window cache this way.
    fn raw_desc(&self, _index: usize, _key: &mut String) -> Option<(u64, usize)> {
        None
    }

    /// The store ring descriptors resolve against — the stack an
    /// [`crate::storage::IoRing`] should wrap for this dataset's raw
    /// reads. `None` (the default) disables the ring path.
    fn ring_store(&self) -> Option<Arc<dyn ObjectStore>> {
        None
    }
}

thread_local! {
    /// Reusable raw-byte scratch for the fused `get_item_into` path over
    /// a store with a native `get_into` (true scratch I/O): grown to the
    /// largest object seen on this thread, then reused forever — the
    /// read path stays allocation-free in steady state.
    static RAW_SCRATCH: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
}

/// Cumulative per-lane item-load time, feeding
/// [`Dataset::lane_times`]. Two relaxed atomic adds per item — cheap
/// enough for the zero-alloc hot path.
#[derive(Debug, Default)]
struct LaneTimes {
    storage_ns: AtomicU64,
    decode_ns: AtomicU64,
}

impl LaneTimes {
    fn add_storage(&self, d: Duration) {
        self.storage_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    fn add_decode(&self, d: Duration) {
        self.decode_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }
}

/// Dataset over SIMG objects in any [`ObjectStore`] (the ImageNet-folder
/// analogue).
pub struct ImageFolderDataset {
    store: Arc<dyn ObjectStore>,
    keys: Vec<String>,
    augment: Augment,
    epoch: AtomicUsize,
    /// whether the fused path should read via `ObjectStore::get_into`
    /// (stores whose `get` already serves shared `Bytes` without
    /// allocating — MemStore and the simulated remotes over it — skip
    /// the copy-out; true file-backed stores skip the per-read `Vec`)
    use_get_into: bool,
    lanes: LaneTimes,
}

impl ImageFolderDataset {
    pub fn new(store: Arc<dyn ObjectStore>, augment_cfg: AugmentConfig) -> Self {
        let keys = store.keys();
        let use_get_into = store.native_get_into();
        ImageFolderDataset {
            store,
            keys,
            augment: Augment::new(augment_cfg),
            epoch: AtomicUsize::new(0),
            use_get_into,
            lanes: LaneTimes::default(),
        }
    }

    /// Restrict to the first `n` keys (the paper's `dataset_limit`).
    pub fn with_limit(mut self, n: usize) -> Self {
        self.keys.truncate(n);
        self
    }

    pub fn store(&self) -> &Arc<dyn ObjectStore> {
        &self.store
    }

    pub fn keys(&self) -> &[String] {
        &self.keys
    }

    /// decode + augment under the caller's GIL (CPU-bound section).
    fn process(
        &self,
        index: usize,
        epoch: usize,
        raw: &[u8],
        gil: &Gil,
    ) -> Result<(U8Tensor, u16)> {
        gil.cpu(|| {
            let img = SimgImage::decode(raw)?;
            let crop = self.augment.apply_u8(&img, epoch, index);
            Ok((crop, img.label))
        })
    }
}

impl Dataset for ImageFolderDataset {
    fn len(&self) -> usize {
        self.keys.len()
    }

    fn supports_epoch_tagged(&self) -> bool {
        true
    }

    fn get_item(&self, index: usize, gil: &Gil) -> Result<Sample> {
        self.get_item_at(index, self.epoch.load(Ordering::Relaxed), gil)
    }

    fn get_item_at(&self, index: usize, epoch: usize, gil: &Gil) -> Result<Sample> {
        let key = &self.keys[index];
        let t0 = Instant::now();
        let raw = gil.io(|| self.store.get(key))?;
        let fetch = t0.elapsed();
        self.lanes.add_storage(fetch);
        let t1 = Instant::now();
        let (crop, label) = self.process(index, epoch, &raw, gil)?;
        let decode = t1.elapsed();
        self.lanes.add_decode(decode);
        Ok(Sample {
            index,
            label,
            crop,
            raw_bytes: raw.len(),
            fetch_time: fetch.as_secs_f64(),
            decode_time: decode.as_secs_f64(),
        })
    }

    fn get_item_async<'a>(&'a self, index: usize, gil: &'a Gil) -> BoxFut<'a, Result<Sample>> {
        self.get_item_async_at(index, self.epoch.load(Ordering::Relaxed), gil)
    }

    fn get_item_async_at<'a>(
        &'a self,
        index: usize,
        epoch: usize,
        gil: &'a Gil,
    ) -> BoxFut<'a, Result<Sample>> {
        Box::pin(async move {
            let key = &self.keys[index];
            let t0 = Instant::now();
            let raw = self.store.get_async(key).await?;
            let fetch = t0.elapsed();
            self.lanes.add_storage(fetch);
            let t1 = Instant::now();
            let (crop, label) = self.process(index, epoch, &raw, gil)?;
            let decode = t1.elapsed();
            self.lanes.add_decode(decode);
            Ok(Sample {
                index,
                label,
                crop,
                raw_bytes: raw.len(),
                fetch_time: fetch.as_secs_f64(),
                decode_time: decode.as_secs_f64(),
            })
        })
    }

    fn set_epoch(&self, epoch: usize) {
        self.epoch.store(epoch, Ordering::Relaxed);
    }

    fn hint_epoch_order(&self, epoch: usize, order: &[usize]) {
        let keys: Vec<String> = order
            .iter()
            .filter_map(|&i| self.keys.get(i).cloned())
            .collect();
        self.store.hint_order(epoch, &keys);
    }

    fn hint_epoch_order_next(&self, epoch: usize, order: &[usize]) {
        let keys: Vec<String> = order
            .iter()
            .filter_map(|&i| self.keys.get(i).cloned())
            .collect();
        self.store.hint_order_append(epoch, &keys);
    }

    fn crop(&self) -> usize {
        self.augment.cfg.crop
    }

    fn get_item_into(&self, index: usize, gil: &Gil, out: &mut [u8]) -> Result<ItemMeta> {
        self.get_item_into_at(index, self.epoch.load(Ordering::Relaxed), gil, out)
    }

    fn get_item_into_at(
        &self,
        index: usize,
        epoch: usize,
        gil: &Gil,
        out: &mut [u8],
    ) -> Result<ItemMeta> {
        let key = &self.keys[index];
        if self.use_get_into {
            // zero-copy read: storage writes straight into this thread's
            // reusable scratch (no per-read Vec), decode straight into
            // the arena slot — end to end, no allocation in steady state
            return RAW_SCRATCH.with(|s| {
                let mut buf = s.borrow_mut();
                let t0 = Instant::now();
                let n = gil.io(|| {
                    crate::storage::get_into_vec(&*self.store, key, &mut buf)
                })?;
                self.lanes.add_storage(t0.elapsed());
                self.process_raw_into_at(index, epoch, &buf[..n], gil, out)
            });
        }
        let t0 = Instant::now();
        let raw = gil.io(|| self.store.get(key))?;
        self.lanes.add_storage(t0.elapsed());
        self.process_raw_into_at(index, epoch, &raw, gil, out)
    }

    fn supports_raw(&self) -> bool {
        true
    }

    fn get_raw_async<'a>(&'a self, index: usize) -> BoxFut<'a, Result<Bytes>> {
        Box::pin(async move {
            let t0 = Instant::now();
            let res = self.store.get_async(&self.keys[index]).await;
            self.lanes.add_storage(t0.elapsed());
            res
        })
    }

    fn process_raw_into(
        &self,
        index: usize,
        raw: &[u8],
        gil: &Gil,
        out: &mut [u8],
    ) -> Result<ItemMeta> {
        self.process_raw_into_at(index, self.epoch.load(Ordering::Relaxed), raw, gil, out)
    }

    fn process_raw_into_at(
        &self,
        index: usize,
        epoch: usize,
        raw: &[u8],
        gil: &Gil,
        out: &mut [u8],
    ) -> Result<ItemMeta> {
        // a mis-sized slot is a per-batch error, not a worker panic
        // (apply_u8_into asserts the same invariant)
        let want = self.crop() * self.crop() * 3;
        if out.len() != want {
            anyhow::bail!(
                "item {index}: slot holds {} bytes, crop needs {want}",
                out.len()
            );
        }
        let t0 = Instant::now();
        let res = gil.cpu(|| {
            // zero-copy parse off the storage bytes, augment straight
            // into the arena slot: no decode buffer, no crop tensor
            let img = SimgRef::parse(raw)?;
            self.augment.apply_u8_into(&img, epoch, index, out);
            Ok(ItemMeta { label: img.label, raw_bytes: raw.len() })
        });
        self.lanes.add_decode(t0.elapsed());
        res
    }

    fn lane_times(&self) -> Option<(Duration, Duration)> {
        Some((
            Duration::from_nanos(self.lanes.storage_ns.load(Ordering::Relaxed)),
            Duration::from_nanos(self.lanes.decode_ns.load(Ordering::Relaxed)),
        ))
    }

    fn raw_desc(&self, index: usize, key: &mut String) -> Option<(u64, usize)> {
        let k = self.keys.get(index)?;
        key.clear();
        key.push_str(k);
        Some((0, 0)) // whole object; process_raw_into_at decodes it
    }

    fn ring_store(&self) -> Option<Arc<dyn ObjectStore>> {
        Some(self.store.clone())
    }
}

/// `get_random_item` from the paper's §3.2: draw a random index and load
/// it (used by the Dataset-pool experiment).
pub fn get_random_item(
    ds: &dyn Dataset,
    rng: &mut Rng,
    gil: &Gil,
) -> Result<Sample> {
    let idx = rng.below(ds.len());
    ds.get_item(idx, gil)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_corpus, CorpusSpec};
    use crate::storage::MemStore;

    pub(crate) fn tiny_dataset(items: usize, crop: usize) -> ImageFolderDataset {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        generate_corpus(&store, &CorpusSpec::tiny(items)).unwrap();
        ImageFolderDataset::new(
            store,
            AugmentConfig { crop, ..Default::default() },
        )
    }

    #[test]
    fn get_item_shapes_and_metadata() {
        let ds = tiny_dataset(8, 32);
        let gil = Gil::native();
        let s = ds.get_item(3, &gil).unwrap();
        assert_eq!(s.index, 3);
        assert_eq!(s.crop.shape, vec![32, 32, 3]);
        assert!(s.raw_bytes > 0);
        assert!(s.fetch_time >= 0.0 && s.decode_time > 0.0);
    }

    #[test]
    fn async_and_sync_agree() {
        let ds = tiny_dataset(4, 16);
        let gil = Gil::native();
        let a = ds.get_item(1, &gil).unwrap();
        let b = crate::asyncrt::block_on(ds.get_item_async(1, &gil)).unwrap();
        assert_eq!(a.crop, b.crop);
        assert_eq!(a.label, b.label);
    }

    #[test]
    fn epoch_changes_augmentation() {
        let ds = tiny_dataset(4, 16);
        let gil = Gil::native();
        let a = ds.get_item(0, &gil).unwrap();
        ds.set_epoch(1);
        let b = ds.get_item(0, &gil).unwrap();
        assert_ne!(a.crop.data, b.crop.data);
    }

    #[test]
    fn fused_into_path_matches_get_item_bytes() {
        let ds = tiny_dataset(6, 24);
        let gil = Gil::native();
        for index in 0..6 {
            let s = ds.get_item(index, &gil).unwrap();
            let mut slot = vec![0u8; 24 * 24 * 3];
            let meta = ds.get_item_into(index, &gil, &mut slot).unwrap();
            assert_eq!(s.crop.data, slot, "index {index}");
            assert_eq!(s.label, meta.label);
            assert_eq!(s.raw_bytes, meta.raw_bytes);
        }
    }

    #[test]
    fn raw_async_plus_process_matches_sync() {
        let ds = tiny_dataset(4, 16);
        let gil = Gil::native();
        assert!(ds.supports_raw());
        let raw = crate::asyncrt::block_on(ds.get_raw_async(2)).unwrap();
        let mut slot = vec![0u8; 16 * 16 * 3];
        let meta = ds.process_raw_into(2, &raw, &gil, &mut slot).unwrap();
        let s = ds.get_item(2, &gil).unwrap();
        assert_eq!(s.crop.data, slot);
        assert_eq!(s.label, meta.label);
    }

    #[test]
    fn default_fused_fallback_copies_through_get_item() {
        // a wrapper dataset without its own fused impl still works
        struct Wrap(ImageFolderDataset);
        impl Dataset for Wrap {
            fn len(&self) -> usize {
                self.0.len()
            }
            fn get_item(&self, index: usize, gil: &Gil) -> Result<Sample> {
                self.0.get_item(index, gil)
            }
            fn get_item_async<'a>(
                &'a self,
                index: usize,
                gil: &'a Gil,
            ) -> BoxFut<'a, Result<Sample>> {
                self.0.get_item_async(index, gil)
            }
            fn set_epoch(&self, epoch: usize) {
                self.0.set_epoch(epoch)
            }
            fn crop(&self) -> usize {
                self.0.crop()
            }
        }
        let w = Wrap(tiny_dataset(3, 16));
        let gil = Gil::native();
        assert!(!w.supports_raw());
        // a set_epoch-style wrapper must not advertise epoch-tagged
        // loads (the pipelined loader gates on this); the built-in
        // dataset does
        assert!(!w.supports_epoch_tagged());
        assert!(w.0.supports_epoch_tagged());
        let mut slot = vec![0u8; 16 * 16 * 3];
        let meta = w.get_item_into(1, &gil, &mut slot).unwrap();
        let s = w.get_item(1, &gil).unwrap();
        assert_eq!(s.crop.data, slot);
        assert_eq!(s.label, meta.label);
        assert!(crate::asyncrt::block_on(w.get_raw_async(0)).is_err());
    }

    #[test]
    fn dirstore_fused_path_routes_through_get_into_and_matches() {
        // a DirStore-backed dataset takes the zero-copy scratch read in
        // get_item_into; bytes must match the legacy get_item path
        let root = std::env::temp_dir()
            .join(format!("cdl-ds-getinto-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let store: Arc<dyn ObjectStore> =
            Arc::new(crate::storage::DirStore::open(&root).unwrap());
        generate_corpus(&store, &CorpusSpec::tiny(5)).unwrap();
        let ds = ImageFolderDataset::new(
            store,
            AugmentConfig { crop: 16, ..Default::default() },
        );
        assert_eq!(ds.use_get_into, cfg!(unix));
        let gil = Gil::native();
        for index in 0..5 {
            let s = ds.get_item(index, &gil).unwrap();
            let mut slot = vec![0u8; 16 * 16 * 3];
            let meta = ds.get_item_into(index, &gil, &mut slot).unwrap();
            assert_eq!(s.crop.data, slot, "index {index}");
            assert_eq!(s.label, meta.label);
            assert_eq!(s.raw_bytes, meta.raw_bytes);
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn lane_times_accumulate_per_lane() {
        let ds = tiny_dataset(4, 16);
        let gil = Gil::native();
        let (s0, d0) = ds.lane_times().unwrap();
        assert_eq!(s0, Duration::ZERO);
        assert_eq!(d0, Duration::ZERO);
        ds.get_item(0, &gil).unwrap();
        let mut slot = vec![0u8; 16 * 16 * 3];
        ds.get_item_into(1, &gil, &mut slot).unwrap();
        let (_, d1) = ds.lane_times().unwrap();
        // both the legacy and the fused path feed the decode lane
        // (MemStore reads can legitimately round to ~0 storage time)
        assert!(d1 > Duration::ZERO);
    }

    #[test]
    fn limit_truncates() {
        let ds = tiny_dataset(10, 16).with_limit(4);
        assert_eq!(ds.len(), 4);
    }

    #[test]
    fn random_item_in_range() {
        let ds = tiny_dataset(5, 16);
        let gil = Gil::native();
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let s = get_random_item(&ds, &mut rng, &gil).unwrap();
            assert!(s.index < 5);
        }
    }
}
