//! Map-style `Dataset` — the bottom layer of the paper's pipeline
//! (Fig 1): `__getitem__(index)` loads one object from storage, decodes
//! it, and applies the augmentation transform.
//!
//! The GIL of the *calling worker process* is passed into `get_item`
//! because in CPython the decode/augment CPU work executes under the
//! worker's interpreter lock while storage I/O releases it — that split
//! is exactly what the fetcher-parallelism results hinge on.

pub mod pool;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::data::{Augment, AugmentConfig, SimgImage, U8Tensor};
use crate::gil::Gil;
use crate::storage::{BoxFut, ObjectStore};
use crate::util::rng::Rng;

/// One loaded training item.
#[derive(Debug, Clone)]
pub struct Sample {
    pub index: usize,
    pub label: u16,
    /// augmented u8 HWC crop (normalize happens on-device, L1 kernel)
    pub crop: U8Tensor,
    /// size of the stored object (throughput accounting uses this)
    pub raw_bytes: usize,
    /// storage fetch time (s)
    pub fetch_time: f64,
    /// decode+augment CPU time (s), including GIL wait
    pub decode_time: f64,
}

/// Map-style dataset interface.
pub trait Dataset: Send + Sync {
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `__getitem__`: blocking fetch + decode + augment.
    fn get_item(&self, index: usize, gil: &Gil) -> Result<Sample>;

    /// Async variant used by the asyncio fetcher (storage wait is
    /// non-blocking; CPU work still blocks the loop, as in CPython).
    fn get_item_async<'a>(&'a self, index: usize, gil: &'a Gil) -> BoxFut<'a, Result<Sample>>;

    /// Set the augmentation epoch (torch reseeds per epoch).
    fn set_epoch(&self, epoch: usize);

    /// Sampler-ahead hint: the epoch's upcoming item access order.
    /// Storage-backed datasets translate it to keys and forward it to
    /// their store (`ObjectStore::hint_order`), which lets a prefetch
    /// layer (`crate::prefetch`) fetch ahead of demand. Default: ignore.
    fn hint_epoch_order(&self, _epoch: usize, _order: &[usize]) {}

    /// Output crop side (informs collate shapes).
    fn crop(&self) -> usize;
}

/// Dataset over SIMG objects in any [`ObjectStore`] (the ImageNet-folder
/// analogue).
pub struct ImageFolderDataset {
    store: Arc<dyn ObjectStore>,
    keys: Vec<String>,
    augment: Augment,
    epoch: AtomicUsize,
}

impl ImageFolderDataset {
    pub fn new(store: Arc<dyn ObjectStore>, augment_cfg: AugmentConfig) -> Self {
        let keys = store.keys();
        ImageFolderDataset {
            store,
            keys,
            augment: Augment::new(augment_cfg),
            epoch: AtomicUsize::new(0),
        }
    }

    /// Restrict to the first `n` keys (the paper's `dataset_limit`).
    pub fn with_limit(mut self, n: usize) -> Self {
        self.keys.truncate(n);
        self
    }

    pub fn store(&self) -> &Arc<dyn ObjectStore> {
        &self.store
    }

    pub fn keys(&self) -> &[String] {
        &self.keys
    }

    /// decode + augment under the caller's GIL (CPU-bound section).
    fn process(&self, index: usize, raw: &[u8], gil: &Gil) -> Result<(U8Tensor, u16)> {
        let epoch = self.epoch.load(Ordering::Relaxed);
        gil.cpu(|| {
            let img = SimgImage::decode(raw)?;
            let crop = self.augment.apply_u8(&img, epoch, index);
            Ok((crop, img.label))
        })
    }
}

impl Dataset for ImageFolderDataset {
    fn len(&self) -> usize {
        self.keys.len()
    }

    fn get_item(&self, index: usize, gil: &Gil) -> Result<Sample> {
        let key = &self.keys[index];
        let t0 = Instant::now();
        let raw = gil.io(|| self.store.get(key))?;
        let fetch_time = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let (crop, label) = self.process(index, &raw, gil)?;
        Ok(Sample {
            index,
            label,
            crop,
            raw_bytes: raw.len(),
            fetch_time,
            decode_time: t1.elapsed().as_secs_f64(),
        })
    }

    fn get_item_async<'a>(&'a self, index: usize, gil: &'a Gil) -> BoxFut<'a, Result<Sample>> {
        Box::pin(async move {
            let key = &self.keys[index];
            let t0 = Instant::now();
            let raw = self.store.get_async(key).await?;
            let fetch_time = t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            let (crop, label) = self.process(index, &raw, gil)?;
            Ok(Sample {
                index,
                label,
                crop,
                raw_bytes: raw.len(),
                fetch_time,
                decode_time: t1.elapsed().as_secs_f64(),
            })
        })
    }

    fn set_epoch(&self, epoch: usize) {
        self.epoch.store(epoch, Ordering::Relaxed);
    }

    fn hint_epoch_order(&self, epoch: usize, order: &[usize]) {
        let keys: Vec<String> = order
            .iter()
            .filter_map(|&i| self.keys.get(i).cloned())
            .collect();
        self.store.hint_order(epoch, &keys);
    }

    fn crop(&self) -> usize {
        self.augment.cfg.crop
    }
}

/// `get_random_item` from the paper's §3.2: draw a random index and load
/// it (used by the Dataset-pool experiment).
pub fn get_random_item(
    ds: &dyn Dataset,
    rng: &mut Rng,
    gil: &Gil,
) -> Result<Sample> {
    let idx = rng.below(ds.len());
    ds.get_item(idx, gil)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_corpus, CorpusSpec};
    use crate::storage::MemStore;

    pub(crate) fn tiny_dataset(items: usize, crop: usize) -> ImageFolderDataset {
        let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        generate_corpus(&store, &CorpusSpec::tiny(items)).unwrap();
        ImageFolderDataset::new(
            store,
            AugmentConfig { crop, ..Default::default() },
        )
    }

    #[test]
    fn get_item_shapes_and_metadata() {
        let ds = tiny_dataset(8, 32);
        let gil = Gil::native();
        let s = ds.get_item(3, &gil).unwrap();
        assert_eq!(s.index, 3);
        assert_eq!(s.crop.shape, vec![32, 32, 3]);
        assert!(s.raw_bytes > 0);
        assert!(s.fetch_time >= 0.0 && s.decode_time > 0.0);
    }

    #[test]
    fn async_and_sync_agree() {
        let ds = tiny_dataset(4, 16);
        let gil = Gil::native();
        let a = ds.get_item(1, &gil).unwrap();
        let b = crate::asyncrt::block_on(ds.get_item_async(1, &gil)).unwrap();
        assert_eq!(a.crop, b.crop);
        assert_eq!(a.label, b.label);
    }

    #[test]
    fn epoch_changes_augmentation() {
        let ds = tiny_dataset(4, 16);
        let gil = Gil::native();
        let a = ds.get_item(0, &gil).unwrap();
        ds.set_epoch(1);
        let b = ds.get_item(0, &gil).unwrap();
        assert_ne!(a.crop.data, b.crop.data);
    }

    #[test]
    fn limit_truncates() {
        let ds = tiny_dataset(10, 16).with_limit(4);
        assert_eq!(ds.len(), 4);
    }

    #[test]
    fn random_item_in_range() {
        let ds = tiny_dataset(5, 16);
        let gil = Gil::native();
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let s = get_random_item(&ds, &mut rng, &gil).unwrap();
            assert!(s.index < 5);
        }
    }
}
