//! The paper's §3.2 Dataset-level concurrency experiment (Fig 12):
//! bypass the Dataloader entirely, instantiate the bare Dataset, and
//! load random items through a `multiprocessing.Pool` of increasing
//! size. Each pool member is a separate *process* (own GIL).
//!
//! Reports end-to-end throughput (Mbit/s over the whole experiment) and
//! the median per-item request time — the two curves of Fig 12.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::dataset::Dataset;
use crate::gil::{Gil, Runtime};
use crate::util::rng::Rng;

/// Result of one pool-size point.
#[derive(Debug, Clone)]
pub struct PoolResult {
    pub pool_size: usize,
    pub items: usize,
    pub bytes: u64,
    pub wall_secs: f64,
    pub throughput_mbit_s: f64,
    pub median_request_s: f64,
    pub request_times: Vec<f64>,
}

/// Load `total_items` random items through a pool of `pool_size`
/// simulated processes (threads with independent GILs).
pub fn run_pool(
    ds: Arc<dyn Dataset>,
    pool_size: usize,
    total_items: usize,
    runtime: Runtime,
    python_tax: f64,
    seed: u64,
) -> PoolResult {
    let remaining = AtomicUsize::new(total_items);
    let bytes = AtomicUsize::new(0);
    let times: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(total_items));
    let t0 = Instant::now();

    std::thread::scope(|s| {
        for p in 0..pool_size {
            let ds = ds.clone();
            let remaining = &remaining;
            let bytes = &bytes;
            let times = &times;
            // one GIL per pool member: multiprocessing semantics
            let gil = Gil::new(runtime, python_tax);
            let mut rng = Rng::new(seed ^ (p as u64) << 17);
            s.spawn(move || loop {
                if remaining
                    .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                        v.checked_sub(1)
                    })
                    .is_err()
                {
                    break;
                }
                let t = Instant::now();
                let idx = rng.below(ds.len());
                match ds.get_item(idx, &gil) {
                    Ok(sample) => {
                        bytes.fetch_add(sample.raw_bytes, Ordering::Relaxed);
                        times.lock().unwrap().push(t.elapsed().as_secs_f64());
                    }
                    Err(e) => {
                        eprintln!("pool get_item failed: {e:#}");
                    }
                }
            });
        }
    });

    let wall = t0.elapsed().as_secs_f64();
    let bytes = bytes.load(Ordering::Relaxed) as u64;
    let request_times = times.into_inner().unwrap();
    PoolResult {
        pool_size,
        items: request_times.len(),
        bytes,
        wall_secs: wall,
        throughput_mbit_s: crate::util::fmt::mbit_s(bytes, wall),
        median_request_s: crate::util::stats::median(&request_times),
        request_times,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_corpus, CorpusSpec};
    use crate::dataset::ImageFolderDataset;
    use crate::data::AugmentConfig;
    use crate::storage::{MemStore, ObjectStore, RemoteProfile, SimRemoteStore};

    fn dataset_on(profile: Option<RemoteProfile>) -> Arc<dyn Dataset> {
        let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        generate_corpus(&mem, &CorpusSpec::tiny(16)).unwrap();
        let store: Arc<dyn ObjectStore> = match profile {
            Some(p) => SimRemoteStore::new(mem, p, 3),
            None => mem,
        };
        Arc::new(ImageFolderDataset::new(
            store,
            AugmentConfig { crop: 16, ..Default::default() },
        ))
    }

    #[test]
    fn pool_loads_exact_count() {
        let ds = dataset_on(None);
        let r = run_pool(ds, 4, 40, Runtime::Native, 1.0, 1);
        assert_eq!(r.items, 40);
        assert!(r.bytes > 0);
        assert!(r.throughput_mbit_s > 0.0);
    }

    #[test]
    fn concurrency_beats_serial_on_latency() {
        // with 30ms-median latency, pool of 8 must beat pool of 1 clearly
        let profile = RemoteProfile::s3().scaled(0.25);
        let ds = dataset_on(Some(profile.clone()));
        let r1 = run_pool(ds.clone(), 1, 12, Runtime::Native, 1.0, 2);
        let ds2 = dataset_on(Some(profile));
        let r8 = run_pool(ds2, 8, 12, Runtime::Native, 1.0, 2);
        assert!(
            r8.wall_secs < r1.wall_secs * 0.6,
            "pool8 {} vs pool1 {}",
            r8.wall_secs,
            r1.wall_secs
        );
    }
}
