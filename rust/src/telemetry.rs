//! Span telemetry — the "Measured activities" lane of the paper's Fig 1.
//!
//! Every instrumented activity (`get_batch`, `get_item`,
//! `training_batch_to_device`, `run_training_batch`, the Lightning lanes,
//! worker spawns…) is recorded as a [`Span`] with worker id, batch id and
//! a start/end pair on a shared monotonic clock. Reports derive medians
//! (Fig 14), timelines (Fig 2/17/19), fade-in/out histograms (Fig 23) and
//! the Table 3 GPU-utilization aggregates from the same recorder.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::stats;
use crate::util::table::Table;

/// One recorded activity interval.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub name: &'static str,
    pub worker: u32,
    pub batch: i64,
    /// start/end seconds on the recorder clock
    pub t0: f64,
    pub t1: f64,
}

impl Span {
    pub fn duration(&self) -> f64 {
        self.t1 - self.t0
    }
}

/// Standard span names (the paper's measurement points).
pub mod names {
    pub const GET_BATCH: &str = "get_batch"; // next_data wait
    pub const BATCH_INFLIGHT: &str = "batch_inflight"; // fetch start → queued
    pub const GET_ITEM: &str = "get_item"; // Dataset __getitem__
    pub const TO_DEVICE: &str = "training_batch_to_device";
    pub const TRAIN_BATCH: &str = "run_training_batch";
    pub const OPTIMIZER_STEP: &str = "optimizer_step";
    pub const WORKER_SPAWN: &str = "worker_spawn";
    pub const PIN_MEMORY: &str = "pin_memory";
    /// background GET issued by the prefetch engine
    pub const PREFETCH_FETCH: &str = "prefetch_fetch";
    /// demand lookup that waited on an in-flight prefetch
    pub const PREFETCH_WAIT: &str = "prefetch_wait";
    // Lightning lanes (Fig 17)
    pub const ADVANCE: &str = "advance";
    pub const PRERUN: &str = "prerun";
    pub const NEXT_DATA: &str = "next_data";
    pub const PREP_TRAINING: &str = "prep_training";
    pub const POSTRUN: &str = "postrun";
}

/// Thread-safe span recorder with a shared origin clock.
pub struct Recorder {
    origin: Instant,
    spans: Mutex<Vec<Span>>,
    enabled: AtomicBool,
}

impl Recorder {
    pub fn new() -> Arc<Recorder> {
        Arc::new(Recorder {
            origin: Instant::now(),
            spans: Mutex::new(Vec::new()),
            enabled: AtomicBool::new(true),
        })
    }

    /// Seconds since recorder creation.
    pub fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn record(&self, name: &'static str, worker: u32, batch: i64, t0: f64, t1: f64) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        self.spans.lock().unwrap().push(Span { name, worker, batch, t0, t1 });
    }

    /// Time a closure as a span.
    pub fn time<T>(
        &self,
        name: &'static str,
        worker: u32,
        batch: i64,
        f: impl FnOnce() -> T,
    ) -> T {
        let t0 = self.now();
        let out = f();
        self.record(name, worker, batch, t0, self.now());
        out
    }

    pub fn len(&self) -> usize {
        self.spans.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot all spans (sorted by start time).
    pub fn snapshot(&self) -> Vec<Span> {
        let mut v = self.spans.lock().unwrap().clone();
        v.sort_by(|a, b| a.t0.partial_cmp(&b.t0).unwrap());
        v
    }

    pub fn clear(&self) {
        self.spans.lock().unwrap().clear();
    }

    /// Durations of all spans with the given name.
    pub fn durations(&self, name: &str) -> Vec<f64> {
        self.spans
            .lock()
            .unwrap()
            .iter()
            .filter(|s| s.name == name)
            .map(|s| s.duration())
            .collect()
    }

    pub fn median(&self, name: &str) -> f64 {
        stats::median(&self.durations(name))
    }

    /// Per-name summary table (Fig 14-style medians).
    pub fn summary_table(&self, title: &str) -> Table {
        use std::collections::BTreeMap;
        let spans = self.spans.lock().unwrap();
        let mut by_name: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
        for s in spans.iter() {
            by_name.entry(s.name).or_default().push(s.duration());
        }
        let mut t = Table::new(
            title,
            &["span", "count", "median_s", "mean_s", "p90_s", "max_s"],
        );
        for (name, durs) in by_name {
            let s = stats::Summary::of(&durs);
            t.row(&[
                name.to_string(),
                s.count.to_string(),
                format!("{:.4}", s.p50),
                format!("{:.4}", s.mean),
                format!("{:.4}", s.p90),
                format!("{:.4}", s.max),
            ]);
        }
        t
    }

    /// CSV export of the raw timeline (Fig 2 / Fig 17 data).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("name,worker,batch,t0,t1,duration\n");
        for s in self.snapshot() {
            out.push_str(&format!(
                "{},{},{},{:.6},{:.6},{:.6}\n",
                s.name,
                s.worker,
                s.batch,
                s.t0,
                s.t1,
                s.duration()
            ));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// GPU utilization sampling (Table 3 metrics)
// ---------------------------------------------------------------------------

/// Shared gauges exported by the simulated device.
#[derive(Debug, Default)]
pub struct DeviceGauges {
    /// busy-compute flag ⇒ util sample in percent ×100 (0 if idle)
    pub util_x100: AtomicU64,
    /// memory utilization in percent ×100
    pub mem_x100: AtomicU64,
}

/// One 10 Hz utilization sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UtilSample {
    pub t: f64,
    pub util: f64,
    pub mem: f64,
}

/// Sidecar sampler thread at `hz` (paper: 10 Hz).
pub struct UtilSampler {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<Vec<UtilSample>>>,
}

impl UtilSampler {
    pub fn start(rec: Arc<Recorder>, gauges: Arc<DeviceGauges>, hz: f64) -> UtilSampler {
        let stop = Arc::new(AtomicBool::new(false));
        let st = stop.clone();
        let period = std::time::Duration::from_secs_f64(1.0 / hz);
        let handle = std::thread::Builder::new()
            .name("util-sampler".into())
            .spawn(move || {
                let mut samples = Vec::new();
                while !st.load(Ordering::Relaxed) {
                    samples.push(UtilSample {
                        t: rec.now(),
                        util: gauges.util_x100.load(Ordering::Relaxed) as f64 / 100.0,
                        mem: gauges.mem_x100.load(Ordering::Relaxed) as f64 / 100.0,
                    });
                    std::thread::sleep(period);
                }
                samples
            })
            .expect("spawn util sampler");
        UtilSampler { stop, handle: Some(handle) }
    }

    pub fn stop(mut self) -> Vec<UtilSample> {
        self.stop.store(true, Ordering::Relaxed);
        self.handle.take().map(|h| h.join().unwrap()).unwrap_or_default()
    }
}

/// Table 3 aggregate: (util=0 %, mean util>0 %, mem=0 %, mean mem>0 %).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UtilAggregate {
    pub util_zero_pct: f64,
    pub util_nonzero_mean: f64,
    pub mem_zero_pct: f64,
    pub mem_nonzero_mean: f64,
}

pub fn aggregate_util(samples: &[UtilSample]) -> UtilAggregate {
    let agg = |vals: Vec<f64>| -> (f64, f64) {
        if vals.is_empty() {
            return (f64::NAN, f64::NAN);
        }
        let zero = vals.iter().filter(|v| **v <= 0.0).count();
        let nonzero: Vec<f64> = vals.iter().copied().filter(|v| *v > 0.0).collect();
        (
            100.0 * zero as f64 / vals.len() as f64,
            stats::mean(&nonzero),
        )
    };
    let (uz, um) = agg(samples.iter().map(|s| s.util).collect());
    let (mz, mm) = agg(samples.iter().map(|s| s.mem).collect());
    UtilAggregate {
        util_zero_pct: uz,
        util_nonzero_mean: um,
        mem_zero_pct: mz,
        mem_nonzero_mean: mm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_median() {
        let r = Recorder::new();
        r.record(names::GET_ITEM, 0, 1, 0.0, 0.1);
        r.record(names::GET_ITEM, 1, 1, 0.0, 0.3);
        r.record(names::GET_ITEM, 2, 2, 0.0, 0.2);
        assert_eq!(r.len(), 3);
        assert!((r.median(names::GET_ITEM) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn time_closure() {
        let r = Recorder::new();
        let out = r.time(names::TRAIN_BATCH, 0, 0, || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            5
        });
        assert_eq!(out, 5);
        let d = r.durations(names::TRAIN_BATCH);
        assert_eq!(d.len(), 1);
        assert!(d[0] >= 0.009);
    }

    #[test]
    fn disabled_recorder_drops_spans() {
        let r = Recorder::new();
        r.set_enabled(false);
        r.record("x", 0, 0, 0.0, 1.0);
        assert!(r.is_empty());
    }

    #[test]
    fn csv_has_rows() {
        let r = Recorder::new();
        r.record(names::GET_BATCH, 0, 0, 0.1, 0.4);
        let csv = r.to_csv();
        assert!(csv.starts_with("name,worker"));
        assert!(csv.contains("get_batch,0,0"));
    }

    #[test]
    fn summary_table_renders() {
        let r = Recorder::new();
        r.record(names::GET_BATCH, 0, 0, 0.0, 0.5);
        r.record(names::TO_DEVICE, 0, 0, 0.5, 0.6);
        let t = r.summary_table("spans");
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn util_sampler_and_aggregate() {
        let rec = Recorder::new();
        let gauges = Arc::new(DeviceGauges::default());
        let sampler = UtilSampler::start(rec, gauges.clone(), 100.0);
        std::thread::sleep(std::time::Duration::from_millis(50));
        gauges.util_x100.store(7200, Ordering::Relaxed);
        gauges.mem_x100.store(4000, Ordering::Relaxed);
        std::thread::sleep(std::time::Duration::from_millis(50));
        let samples = sampler.stop();
        assert!(samples.len() >= 5);
        let agg = aggregate_util(&samples);
        assert!(agg.util_zero_pct > 10.0 && agg.util_zero_pct < 90.0);
        assert!((agg.util_nonzero_mean - 72.0).abs() < 1.0);
    }

    #[test]
    fn aggregate_empty_is_nan() {
        let a = aggregate_util(&[]);
        assert!(a.util_zero_pct.is_nan());
    }
}
