//! Experiment configuration: a key=value config file format plus CLI
//! overrides, mapping onto the loader/trainer/storage/device knobs.
//!
//! Example (`configs/s3_threaded.cfg`):
//! ```text
//! storage = s3
//! shard_size = 64           # samples per tar shard (0 = per-file objects)
//! shard_shuffle = true      # two-level shuffle: shard order + reservoir
//! items = 512
//! batch_size = 64
//! num_workers = 4
//! fetch_impl = threaded
//! num_fetch_workers = 16
//! prefetch_depth = 128      # sampler-ahead readahead window (items)
//! prefetch_policy = 2q      # hot-tier policy: lru | 2q | s3fifo
//! arena_slabs = 16          # recycled batch-slab pool (0 = legacy copy path)
//! work_stealing = true      # shared batch injector instead of round-robin
//! steal_items = true        # idle workers fill stragglers' tail items
//! consumer_credit = 8       # reorder-buffer bound in batches (0 = unbounded)
//! epoch_pipeline = 1        # epochs published ahead of the consumer (0 = drain)
//! io_depth = 256            # in-flight reads of the submission ring (0 = per-item)
//! autotune = true           # Governor hill-climbs the knobs above at epoch seams
//! fault_profile = flaky     # seeded chaos on the remote: none | flaky | outage
//! retry_max = 4             # resilience: extra attempts per read (0 = off)
//! request_deadline_ms = 2000 # resilience: per-request budget (0 = unbounded)
//! hedge_after = 1.5         # resilience: hedge past this multiple of online p95
//! cache_bytes = 2147483648  # varnish cache capacity (0 = no cache)
//! cache_policy = lru        # varnish eviction policy: lru | 2q | s3fifo
//! trainer = torch
//! epochs = 2
//! latency_scale = 0.25
//! ```

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::dataloader::{DataloaderConfig, FetchImpl, StartMethod};
use crate::gil;
use crate::storage::CachePolicy;
use crate::trainer::{TrainerConfig, TrainerKind};

/// Parsed experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// storage profile name (s3, scratch, ceph_os, ceph_fs, gluster_fs,
    /// colab_s3, mem)
    pub storage: String,
    /// samples per tar shard (0 = per-file objects, no shard layer):
    /// with shards, the remote serves packed tars and the loader reads
    /// them through window-granular ranged fetches
    pub shard_size: usize,
    /// two-level shard shuffle (seeded shard order + intra-shard
    /// reservoir) instead of the loader's global sampler; only
    /// meaningful with `shard_size > 0`
    pub shard_shuffle: bool,
    /// Varnish cache capacity in bytes (0 = no cache)
    pub cache_bytes: u64,
    /// Varnish cache eviction policy (lru | 2q | s3fifo)
    pub cache_policy: CachePolicy,
    pub items: usize,
    pub classes: usize,
    pub mean_kb: usize,
    pub crop: usize,
    pub latency_scale: f64,
    pub seed: u64,
    pub loader: DataloaderConfig,
    pub trainer: TrainerConfig,
    /// "sim" or "xla"
    pub device: String,
    pub artifacts_dir: String,
    /// telemetry span-ring capacity (0 = default; raise for long
    /// `--trace` runs so the lock-free ring doesn't wrap)
    pub span_capacity: usize,
    /// enable the Governor autotuner: hill-climb loader knobs
    /// (prefetch/io depth, credit, steal, pipeline, active workers)
    /// at epoch seams from live telemetry
    pub autotune: bool,
    /// chaos profile injected into the simulated remote
    /// (none | flaky | outage); deterministic under `seed`
    pub fault_profile: String,
    /// resilience: extra read attempts after the first (0 = no retry)
    pub retry_max: u32,
    /// resilience: per-request deadline in ms bounding the retry
    /// budget (0 = unbounded)
    pub request_deadline_ms: u64,
    /// resilience: hedge a ring read once it outlives this multiple of
    /// the online p95 (0 = hedging off)
    pub hedge_after: f64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            storage: "s3".into(),
            shard_size: 0,
            shard_shuffle: false,
            cache_bytes: 0,
            cache_policy: CachePolicy::Lru,
            items: 256,
            classes: 512,
            mean_kb: 115,
            crop: 64,
            latency_scale: 0.25,
            seed: 7,
            loader: DataloaderConfig::default(),
            trainer: TrainerConfig::torch(1),
            device: "sim".into(),
            artifacts_dir: "artifacts".into(),
            span_capacity: 0,
            autotune: false,
            fault_profile: "none".into(),
            retry_max: 0,
            request_deadline_ms: 0,
            hedge_after: 0.0,
        }
    }
}

impl ExperimentConfig {
    /// Parse a `key = value` config file (# comments allowed).
    pub fn from_file(path: &str) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {path}"))?;
        let mut cfg = ExperimentConfig::default();
        cfg.apply_text(&text)?;
        Ok(cfg)
    }

    pub fn apply_text(&mut self, text: &str) -> Result<()> {
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("config line {} has no '=': {line:?}", lineno + 1);
            };
            self.set(k.trim(), v.trim())
                .with_context(|| format!("line {}", lineno + 1))?;
        }
        Ok(())
    }

    /// Apply a map of overrides (CLI `--set k=v`).
    pub fn apply_overrides(&mut self, kv: &BTreeMap<String, String>) -> Result<()> {
        for (k, v) in kv {
            self.set(k, v)?;
        }
        Ok(())
    }

    /// Set one knob by name.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "storage" => self.storage = value.to_string(),
            "shard_size" => self.shard_size = value.parse()?,
            "shard_shuffle" => self.shard_shuffle = value.parse()?,
            "cache_bytes" => self.cache_bytes = value.parse()?,
            "cache_policy" => {
                self.cache_policy = match CachePolicy::by_name(value) {
                    Some(p) => p,
                    None => bail!("unknown cache_policy {value} (lru|2q|s3fifo)"),
                }
            }
            "items" => self.items = value.parse()?,
            "classes" => self.classes = value.parse()?,
            "mean_kb" => self.mean_kb = value.parse()?,
            "crop" => self.crop = value.parse()?,
            "latency_scale" => self.latency_scale = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "batch_size" => self.loader.batch_size = value.parse()?,
            "num_workers" => self.loader.num_workers = value.parse()?,
            "prefetch_factor" => self.loader.prefetch_factor = value.parse()?,
            "fetch_impl" => {
                self.loader.fetch_impl = match value {
                    "vanilla" => FetchImpl::Vanilla,
                    "threaded" => FetchImpl::Threaded,
                    "asyncio" => FetchImpl::Asyncio,
                    _ => bail!("unknown fetch_impl {value}"),
                }
            }
            "num_fetch_workers" => self.loader.num_fetch_workers = value.parse()?,
            "batch_pool" => self.loader.batch_pool = value.parse()?,
            "prefetch_depth" => self.loader.prefetch_depth = value.parse()?,
            "prefetch_policy" => {
                self.loader.prefetch_policy = match CachePolicy::by_name(value) {
                    Some(p) => p,
                    None => bail!("unknown prefetch_policy {value} (lru|2q|s3fifo)"),
                }
            }
            "arena_slabs" => self.loader.arena_slabs = value.parse()?,
            "work_stealing" => self.loader.work_stealing = value.parse()?,
            "steal_items" => self.loader.steal_items = value.parse()?,
            "consumer_credit" => self.loader.consumer_credit = value.parse()?,
            "epoch_pipeline" => self.loader.epoch_pipeline = value.parse()?,
            "io_depth" => self.loader.io_depth = value.parse()?,
            "pin_memory" => self.loader.pin_memory = value.parse()?,
            "start_method" => {
                self.loader.start_method = match value {
                    "fork" => StartMethod::Fork,
                    "spawn" => StartMethod::Spawn,
                    _ => bail!("unknown start_method {value}"),
                }
            }
            "lazy_init" => self.loader.lazy_init = value.parse()?,
            "worker_runtime" => {
                self.loader.runtime = match value {
                    "python" => gil::Runtime::Python,
                    "native" => gil::Runtime::Native,
                    _ => bail!("unknown worker_runtime {value}"),
                }
            }
            "python_tax" => self.loader.python_tax = value.parse()?,
            "shuffle" => self.loader.shuffle = value.parse()?,
            "drop_last" => self.loader.drop_last = value.parse()?,
            "spawn_cost_ms" => {
                self.loader.spawn_cost_override =
                    Some(Duration::from_millis(value.parse()?))
            }
            "trainer" => {
                self.trainer.kind = match value {
                    "torch" => TrainerKind::Torch,
                    "lightning" => TrainerKind::Lightning,
                    _ => bail!("unknown trainer {value}"),
                }
            }
            "epochs" => self.trainer.epochs = value.parse()?,
            "log_every_n_steps" => self.trainer.log_every_n_steps = value.parse()?,
            "gpu_stats_monitor" => self.trainer.gpu_stats_monitor = value.parse()?,
            "device" => self.device = value.to_string(),
            "artifacts_dir" => self.artifacts_dir = value.to_string(),
            "span_capacity" => self.span_capacity = value.parse()?,
            "autotune" => self.autotune = value.parse()?,
            "fault_profile" => {
                if crate::storage::FaultProfile::by_name(value).is_none() {
                    bail!("unknown fault_profile {value} (none|flaky|outage)");
                }
                self.fault_profile = value.to_string();
            }
            "retry_max" => self.retry_max = value.parse()?,
            "request_deadline_ms" => self.request_deadline_ms = value.parse()?,
            "hedge_after" => self.hedge_after = value.parse()?,
            _ => bail!("unknown config key {key}"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_file() {
        let mut cfg = ExperimentConfig::default();
        cfg.apply_text(
            "storage = scratch\n\
             # comment\n\
             items = 99\n\
             fetch_impl = asyncio\n\
             trainer = lightning\n\
             epochs = 3\n",
        )
        .unwrap();
        assert_eq!(cfg.storage, "scratch");
        assert_eq!(cfg.items, 99);
        assert_eq!(cfg.loader.fetch_impl, FetchImpl::Asyncio);
        assert_eq!(cfg.trainer.kind, TrainerKind::Lightning);
        assert_eq!(cfg.trainer.epochs, 3);
    }

    #[test]
    fn rejects_unknown_key_and_bad_value() {
        let mut cfg = ExperimentConfig::default();
        assert!(cfg.set("nope", "1").is_err());
        assert!(cfg.set("items", "abc").is_err());
        assert!(cfg.set("fetch_impl", "warp").is_err());
        assert!(cfg.set("prefetch_policy", "arc").is_err());
        assert!(cfg.set("cache_policy", "arc").is_err());
    }

    #[test]
    fn prefetch_knobs_parse() {
        let mut cfg = ExperimentConfig::default();
        cfg.apply_text("prefetch_depth = 128\nprefetch_policy = 2q\n")
            .unwrap();
        assert_eq!(cfg.loader.prefetch_depth, 128);
        assert_eq!(cfg.loader.prefetch_policy, CachePolicy::TwoQ);
    }

    #[test]
    fn cache_policy_parses_like_prefetch_policy() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.cache_policy, CachePolicy::Lru);
        cfg.apply_text("cache_bytes = 4096\ncache_policy = s3fifo\n")
            .unwrap();
        assert_eq!(cfg.cache_bytes, 4096);
        assert_eq!(cfg.cache_policy, CachePolicy::S3Fifo);
        cfg.set("cache_policy", "2q").unwrap();
        assert_eq!(cfg.cache_policy, CachePolicy::TwoQ);
        cfg.set("prefetch_policy", "s3fifo").unwrap();
        assert_eq!(cfg.loader.prefetch_policy, CachePolicy::S3Fifo);
    }

    #[test]
    fn hotpath_knobs_parse() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.loader.arena_slabs, 0);
        assert!(!cfg.loader.work_stealing);
        cfg.apply_text("arena_slabs = 24\nwork_stealing = true\n").unwrap();
        assert_eq!(cfg.loader.arena_slabs, 24);
        assert!(cfg.loader.work_stealing);
        assert!(cfg.set("work_stealing", "maybe").is_err());
    }

    #[test]
    fn tail_knobs_parse() {
        let mut cfg = ExperimentConfig::default();
        assert!(!cfg.loader.steal_items);
        assert_eq!(cfg.loader.consumer_credit, 0);
        cfg.apply_text("steal_items = true\nconsumer_credit = 6\n").unwrap();
        assert!(cfg.loader.steal_items);
        assert_eq!(cfg.loader.consumer_credit, 6);
        assert!(cfg.set("steal_items", "2").is_err());
        assert!(cfg.set("consumer_credit", "x").is_err());
    }

    #[test]
    fn epoch_pipeline_knob_parses() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.loader.epoch_pipeline, 0);
        cfg.apply_text("epoch_pipeline = 2\n").unwrap();
        assert_eq!(cfg.loader.epoch_pipeline, 2);
        assert!(cfg.set("epoch_pipeline", "deep").is_err());
    }

    #[test]
    fn io_depth_knob_parses() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.loader.io_depth, 0);
        cfg.apply_text("io_depth = 256\n").unwrap();
        assert_eq!(cfg.loader.io_depth, 256);
        assert!(cfg.set("io_depth", "deep").is_err());
    }

    #[test]
    fn shard_knobs_parse() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.shard_size, 0);
        assert!(!cfg.shard_shuffle);
        cfg.apply_text("shard_size = 64\nshard_shuffle = true\n").unwrap();
        assert_eq!(cfg.shard_size, 64);
        assert!(cfg.shard_shuffle);
        assert!(cfg.set("shard_size", "many").is_err());
        assert!(cfg.set("shard_shuffle", "2").is_err());
    }

    #[test]
    fn span_capacity_knob_parses() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.span_capacity, 0);
        cfg.apply_text("span_capacity = 262144\n").unwrap();
        assert_eq!(cfg.span_capacity, 262_144);
        assert!(cfg.set("span_capacity", "big").is_err());
    }

    #[test]
    fn autotune_knob_parses() {
        let mut cfg = ExperimentConfig::default();
        assert!(!cfg.autotune);
        cfg.apply_text("autotune = true\n").unwrap();
        assert!(cfg.autotune);
        assert!(cfg.set("autotune", "yes").is_err());
    }

    #[test]
    fn resilience_knobs_parse() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.fault_profile, "none");
        assert_eq!(cfg.retry_max, 0);
        assert_eq!(cfg.request_deadline_ms, 0);
        assert_eq!(cfg.hedge_after, 0.0);
        cfg.apply_text(
            "fault_profile = flaky\nretry_max = 4\n\
             request_deadline_ms = 2000\nhedge_after = 1.5\n",
        )
        .unwrap();
        assert_eq!(cfg.fault_profile, "flaky");
        assert_eq!(cfg.retry_max, 4);
        assert_eq!(cfg.request_deadline_ms, 2000);
        assert_eq!(cfg.hedge_after, 1.5);
        assert!(cfg.set("fault_profile", "sunny").is_err());
        assert!(cfg.set("retry_max", "lots").is_err());
        assert!(cfg.set("hedge_after", "soon").is_err());
    }

    #[test]
    fn overrides_apply_in_order() {
        let mut cfg = ExperimentConfig::default();
        let mut kv = BTreeMap::new();
        kv.insert("batch_size".to_string(), "16".to_string());
        kv.insert("num_workers".to_string(), "8".to_string());
        cfg.apply_overrides(&kv).unwrap();
        assert_eq!(cfg.loader.batch_size, 16);
        assert_eq!(cfg.loader.num_workers, 8);
    }

    #[test]
    fn spawn_cost_override() {
        let mut cfg = ExperimentConfig::default();
        cfg.set("spawn_cost_ms", "250").unwrap();
        assert_eq!(cfg.loader.spawn_cost(), Duration::from_millis(250));
    }
}
