//! Training-loop harnesses: **Torch** (bare loop, the
//! pytorch/examples/imagenet shape) and **Lightning** (the wrapper with
//! hooks, callbacks and logger — §A.3 attributes the Torch/Lightning gap
//! to exactly these).
//!
//! The Lightning harness reproduces the lane structure of Fig 17:
//! `advance ⊃ prerun ⊃ {next_data, to_device}` then `prep_training`,
//! `run_training_batch`, `postrun`; `prep_training`/`postrun` run the
//! hook chain whose cost depends on the GpuStatsMonitor callback and
//! `log_every_n_steps` (the paper's "slightly too aggressive logging").

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::dataloader::Dataloader;
use crate::device::Device;
use crate::telemetry::{
    aggregate_util, names, Recorder, UtilAggregate, UtilSampler,
};
use crate::util::fmt::mbit_s;

/// Which harness drives the loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainerKind {
    Torch,
    Lightning,
}

impl TrainerKind {
    pub fn label(&self) -> &'static str {
        match self {
            TrainerKind::Torch => "torch",
            TrainerKind::Lightning => "lightning",
        }
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub kind: TrainerKind,
    pub epochs: usize,
    /// Lightning: steps between logger flushes (paper default 50; the
    /// paper's own config effectively logged every step)
    pub log_every_n_steps: usize,
    /// Lightning: GpuStatsMonitor callback installed (the culprit hook)
    pub gpu_stats_monitor: bool,
    /// Lightning: profiler attached (extra per-hook cost)
    pub profiler: bool,
    /// base cost of running the hook/callback chain once
    pub hook_cost: Duration,
    /// cost of a logger flush (GpuStatsMonitor query + write)
    pub logging_cost: Duration,
    /// stop after this many batches per epoch (0 = whole epoch)
    pub max_batches: usize,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            kind: TrainerKind::Torch,
            epochs: 1,
            log_every_n_steps: 1, // the paper's "too aggressive" default
            gpu_stats_monitor: true,
            profiler: false,
            hook_cost: Duration::from_micros(300),
            logging_cost: Duration::from_millis(25),
            max_batches: 0,
        }
    }
}

impl TrainerConfig {
    pub fn torch(epochs: usize) -> TrainerConfig {
        TrainerConfig {
            kind: TrainerKind::Torch,
            epochs,
            gpu_stats_monitor: false,
            ..Default::default()
        }
    }

    /// Lightning with the paper's (costly) default instrumentation.
    pub fn lightning(epochs: usize) -> TrainerConfig {
        TrainerConfig { kind: TrainerKind::Lightning, epochs, ..Default::default() }
    }

    /// Lightning after the paper's fix (§A.3.1): reduced logging
    /// frequency, profiler removed.
    pub fn lightning_tuned(epochs: usize) -> TrainerConfig {
        TrainerConfig {
            kind: TrainerKind::Lightning,
            epochs,
            log_every_n_steps: 50,
            profiler: false,
            ..Default::default()
        }
    }

    fn hook_chain_cost(&self, step: usize) -> Duration {
        let mut cost = self.hook_cost;
        if self.gpu_stats_monitor && step % self.log_every_n_steps.max(1) == 0 {
            cost += self.logging_cost;
        }
        if self.profiler {
            cost += self.hook_cost * 4;
        }
        cost
    }
}

/// End-to-end result of a training run (one row of Table 3 / Fig 13).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub kind: TrainerKind,
    pub runtime_s: f64,
    pub images: u64,
    pub bytes: u64,
    pub img_per_s: f64,
    pub mbit_per_s: f64,
    pub losses: Vec<f32>,
    pub util: UtilAggregate,
    /// median get_batch / to_device / train durations (Fig 14)
    pub median_get_batch: f64,
    pub median_to_device: f64,
    pub median_train: f64,
}

impl TrainReport {
    pub fn summary(&self) -> String {
        format!(
            "{}: {:.2}s, {:.1} img/s, {:.1} Mbit/s, util=0 {:.1}%, util>0 {:.1}%",
            self.kind.label(),
            self.runtime_s,
            self.img_per_s,
            self.mbit_per_s,
            self.util.util_zero_pct,
            self.util.util_nonzero_mean
        )
    }
}

/// Busy-wait helper for hook costs (hooks burn CPU, they don't sleep).
fn busy_wait(d: Duration) {
    let end = Instant::now() + d;
    while Instant::now() < end {
        std::hint::spin_loop();
    }
}

/// Run a full training experiment: epochs × batches through the loader
/// into the device, with the 10 Hz utilization sidecar.
pub fn train(
    dl: &Dataloader,
    device: &Device,
    cfg: &TrainerConfig,
    recorder: Arc<Recorder>,
) -> Result<TrainReport> {
    train_observed(dl, device, cfg, recorder, None)
}

/// [`train`] with an epoch-end hook: `on_epoch_end(epoch)` fires after
/// each epoch's batches drain (`cdl run --metrics` snapshots the
/// metrics hub here — one JSON line per epoch).
pub fn train_observed(
    dl: &Dataloader,
    device: &Device,
    cfg: &TrainerConfig,
    recorder: Arc<Recorder>,
    mut on_epoch_end: Option<&mut dyn FnMut(usize)>,
) -> Result<TrainReport> {
    let sampler = UtilSampler::start(recorder.clone(), device.gauges(), 10.0);
    let t_start = recorder.now();
    let mut images = 0u64;
    let mut bytes = 0u64;
    let mut losses = Vec::new();
    let mut step = 0usize;

    for epoch in 0..cfg.epochs {
        let mut iter = dl.epoch(epoch);
        loop {
            if cfg.max_batches > 0 && step % dl.batches_per_epoch().max(1) >= cfg.max_batches {
                // drain remaining batches of this epoch cheaply (still
                // recycling their slabs)
                match iter.next() {
                    Some(b) => {
                        b.recycle();
                        continue;
                    }
                    None => break,
                }
            }
            match cfg.kind {
                TrainerKind::Torch => {
                    let Some(batch) = iter.next() else { break };
                    images += batch.len() as u64;
                    bytes += batch.raw_bytes;
                    let db = device.to_device(batch);
                    losses.push(device.train_batch(&db)?);
                    // slab lifecycle: host buffers return to the arena
                    db.recycle();
                }
                TrainerKind::Lightning => {
                    let t_adv = recorder.now();
                    // prerun: next_data + batch_to_device
                    let t_pre = recorder.now();
                    let t_nd = recorder.now();
                    let Some(batch) = iter.next() else { break };
                    recorder.record(
                        names::NEXT_DATA,
                        0,
                        batch.id as i64,
                        t_nd,
                        recorder.now(),
                    );
                    images += batch.len() as u64;
                    bytes += batch.raw_bytes;
                    let db = device.to_device(batch);
                    recorder.record(
                        names::PRERUN,
                        0,
                        db.batch.id as i64,
                        t_pre,
                        recorder.now(),
                    );
                    // prep_training: on_train_batch_start hook chain
                    let t_prep = recorder.now();
                    busy_wait(cfg.hook_chain_cost(step));
                    recorder.record(
                        names::PREP_TRAINING,
                        0,
                        db.batch.id as i64,
                        t_prep,
                        recorder.now(),
                    );
                    losses.push(device.train_batch(&db)?);
                    // postrun: on_train_batch_end hook chain
                    let t_post = recorder.now();
                    busy_wait(cfg.hook_chain_cost(step));
                    recorder.record(
                        names::POSTRUN,
                        0,
                        db.batch.id as i64,
                        t_post,
                        recorder.now(),
                    );
                    recorder.record(
                        names::ADVANCE,
                        0,
                        db.batch.id as i64,
                        t_adv,
                        recorder.now(),
                    );
                    db.recycle();
                }
            }
            step += 1;
        }
        if let Some(hook) = on_epoch_end.as_mut() {
            hook(epoch);
        }
    }

    let runtime_s = recorder.now() - t_start;
    let samples = sampler.stop();
    Ok(TrainReport {
        kind: cfg.kind,
        runtime_s,
        images,
        bytes,
        img_per_s: images as f64 / runtime_s,
        mbit_per_s: mbit_s(bytes, runtime_s),
        losses,
        util: aggregate_util(&samples),
        median_get_batch: recorder.median(names::BATCH_INFLIGHT),
        median_to_device: recorder.median(names::TO_DEVICE),
        median_train: recorder.median(names::TRAIN_BATCH),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate_corpus, CorpusSpec};
    use crate::data::AugmentConfig;
    use crate::dataloader::{DataloaderConfig, FetchImpl};
    use crate::dataset::{Dataset, ImageFolderDataset};
    use crate::device::{Backend, Device, DeviceConfig};
    use crate::storage::{MemStore, ObjectStore};

    fn mk_loader(rec: Arc<Recorder>) -> Dataloader {
        let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        generate_corpus(&mem, &CorpusSpec::tiny(24)).unwrap();
        let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
            mem,
            AugmentConfig { crop: 16, ..Default::default() },
        ));
        Dataloader::new(
            ds,
            DataloaderConfig {
                batch_size: 8,
                num_workers: 2,
                fetch_impl: FetchImpl::Threaded,
                num_fetch_workers: 4,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            rec,
        )
    }

    fn mk_device(rec: Arc<Recorder>) -> Device {
        Device::new(
            Backend::Sim {
                step_time: Duration::from_millis(3),
                loss0: 6.0,
                decay: 0.01,
            },
            DeviceConfig::default(),
            rec,
        )
    }

    #[test]
    fn torch_loop_counts_everything() {
        let rec = Recorder::new();
        let dl = mk_loader(rec.clone());
        let dev = mk_device(rec.clone());
        let r = train(&dl, &dev, &TrainerConfig::torch(2), rec).unwrap();
        assert_eq!(r.images, 48);
        assert_eq!(r.losses.len(), 6);
        assert!(r.img_per_s > 0.0);
        assert!(r.mbit_per_s > 0.0);
        assert!(r.median_train > 0.0);
    }

    #[test]
    fn torch_loop_recycles_arena_slabs() {
        let rec = Recorder::new();
        let mem: Arc<dyn ObjectStore> = Arc::new(MemStore::new("m"));
        generate_corpus(&mem, &CorpusSpec::tiny(24)).unwrap();
        let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
            mem,
            AugmentConfig { crop: 16, ..Default::default() },
        ));
        let dl = Dataloader::new(
            ds,
            DataloaderConfig {
                batch_size: 8,
                num_workers: 2,
                arena_slabs: 8,
                spawn_cost_override: Some(Duration::ZERO),
                ..Default::default()
            },
            rec.clone(),
        );
        let dev = mk_device(rec.clone());
        let r = train(&dl, &dev, &TrainerConfig::torch(2), rec).unwrap();
        assert_eq!(r.images, 48);
        let s = dl.arena().unwrap().stats();
        assert_eq!(s.checkouts, 6, "{s:?}");
        assert_eq!(s.recycled, 6, "{s:?}");
        // the second epoch must run on recycled slabs
        assert!(s.reused >= 3, "{s:?}");
    }

    #[test]
    fn lightning_records_lanes_and_is_slower() {
        let rec = Recorder::new();
        let dl = mk_loader(rec.clone());
        let dev = mk_device(rec.clone());
        let torch = train(&dl, &dev, &TrainerConfig::torch(1), rec.clone()).unwrap();

        let rec2 = Recorder::new();
        let dl2 = mk_loader(rec2.clone());
        let dev2 = mk_device(rec2.clone());
        let mut lcfg = TrainerConfig::lightning(1);
        lcfg.logging_cost = Duration::from_millis(30);
        let lightning = train(&dl2, &dev2, &lcfg, rec2.clone()).unwrap();

        assert!(lightning.runtime_s > torch.runtime_s);
        for lane in [
            names::ADVANCE,
            names::PRERUN,
            names::NEXT_DATA,
            names::PREP_TRAINING,
            names::POSTRUN,
        ] {
            assert_eq!(rec2.durations(lane).len(), 3, "{lane}");
        }
        // advance encloses its sub-lanes
        assert!(rec2.median(names::ADVANCE) >= rec2.median(names::PREP_TRAINING));
    }

    #[test]
    fn tuned_lightning_cheaper_than_default() {
        let mk = |cfg: &TrainerConfig| {
            let rec = Recorder::new();
            let dl = mk_loader(rec.clone());
            let dev = mk_device(rec.clone());
            train(&dl, &dev, cfg, rec).unwrap().runtime_s
        };
        let mut default = TrainerConfig::lightning(1);
        default.logging_cost = Duration::from_millis(40);
        let mut tuned = TrainerConfig::lightning_tuned(1);
        tuned.logging_cost = Duration::from_millis(40);
        assert!(mk(&tuned) < mk(&default));
    }
}
