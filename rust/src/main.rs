//! `cdl` — ConcurrentDataloader CLI.
//!
//! ```text
//! cdl gen-data   --root data/imagenet-syn --items 4096 [--mean-kb 115]
//! cdl run        [--config file.cfg] [--set k=v,k=v]
//! cdl reproduce  <t3|f2|f5|...|all> [--scale quick|paper|<f>]
//! cdl train      --artifacts artifacts [--steps 300] [--batch 16]
//! cdl list
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use cdl::bench::{self, Scale};
use cdl::config::ExperimentConfig;
use cdl::data::synth::{generate_corpus, CorpusSpec};
use cdl::data::AugmentConfig;
use cdl::dataloader::{Dataloader, DataloaderConfig, FetchImpl};
use cdl::dataset::{Dataset, ImageFolderDataset};
use cdl::device::Device;
use cdl::runtime::XlaEngine;
use cdl::storage::{DirStore, ObjectStore};
use cdl::telemetry::Recorder;
use cdl::trainer;
use cdl::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "gen-data" => cmd_gen_data(rest),
        "run" => cmd_run(rest),
        "reproduce" => cmd_reproduce(rest),
        "train" => cmd_train(rest),
        "list" => {
            println!("experiments: {:?}", bench::ALL_EXPERIMENTS);
            Ok(())
        }
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other}\n\n{}", usage()),
    }
}

fn usage() -> &'static str {
    "usage: cdl <gen-data|run|reproduce|train|list> [options]\n\
     run `cdl <cmd> --help` for per-command options"
}

fn print_usage() {
    println!("{}", usage());
}

fn cmd_gen_data(argv: &[String]) -> Result<()> {
    let p = Args::new("cdl gen-data", "generate a synthetic ImageNet-like corpus")
        .opt("root", "data/imagenet-syn", "output directory")
        .opt("items", "4096", "number of images")
        .opt("classes", "512", "number of classes")
        .opt("mean-kb", "115", "mean object size (kB)")
        .opt("seed", "7", "corpus seed")
        .parse(argv)?;
    let store: Arc<dyn ObjectStore> = Arc::new(DirStore::open(p.get("root"))?);
    let spec = CorpusSpec {
        items: p.usize("items")?,
        classes: p.usize("classes")?,
        mean_bytes: p.usize("mean-kb")? * 1024,
        sigma: 0.35,
        seed: p.u64("seed")?,
    };
    let t0 = std::time::Instant::now();
    let (keys, bytes) = generate_corpus(&store, &spec)?;
    println!(
        "wrote {} objects, {} to {} in {:.1}s",
        keys.len(),
        cdl::util::fmt_bytes(bytes),
        p.get("root"),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_run(argv: &[String]) -> Result<()> {
    let p = Args::new("cdl run", "run one training experiment from a config")
        .opt("config", "", "config file (key = value)")
        .opt("set", "", "comma-separated overrides k=v,k=v")
        .opt("trace", "", "write a Chrome trace_event JSON (Perfetto-loadable)")
        .opt("metrics", "", "write per-epoch metrics snapshots (JSONL)")
        .flag("autotune", "enable the Governor: hill-climb loader knobs at epoch seams")
        .parse(argv)?;
    let mut cfg = if p.get("config").is_empty() {
        ExperimentConfig::default()
    } else {
        ExperimentConfig::from_file(p.get("config"))?
    };
    if !p.get("set").is_empty() {
        let mut kv = BTreeMap::new();
        for pair in p.get("set").split(',') {
            let Some((k, v)) = pair.split_once('=') else {
                bail!("bad --set entry {pair}");
            };
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        cfg.apply_overrides(&kv)?;
    }

    let spec = cdl::bench::rig::RigSpec {
        storage: Box::leak(cfg.storage.clone().into_boxed_str()),
        latency_scale: cfg.latency_scale,
        shard_size: cfg.shard_size,
        shard_shuffle: cfg.shard_shuffle,
        cache_bytes: cfg.cache_bytes,
        cache_policy: cfg.cache_policy,
        items: cfg.items,
        mean_kb: cfg.mean_kb,
        crop: cfg.crop,
        batch_size: cfg.loader.batch_size,
        num_workers: cfg.loader.num_workers,
        prefetch_factor: cfg.loader.prefetch_factor,
        fetch_impl: cfg.loader.fetch_impl,
        num_fetch_workers: cfg.loader.num_fetch_workers,
        batch_pool: cfg.loader.batch_pool,
        prefetch_depth: cfg.loader.prefetch_depth,
        prefetch_policy: cfg.loader.prefetch_policy,
        arena_slabs: cfg.loader.arena_slabs,
        work_stealing: cfg.loader.work_stealing,
        steal_items: cfg.loader.steal_items,
        consumer_credit: cfg.loader.consumer_credit,
        epoch_pipeline: cfg.loader.epoch_pipeline,
        io_depth: cfg.loader.io_depth,
        // the rig pairs pinning with the spawn start method itself
        // (torch's rule), so pass the raw knob — `pin_memory=true`
        // must pin, not silently no-op under the default fork
        pin_memory: cfg.loader.pin_memory,
        lazy_init: cfg.loader.lazy_init,
        runtime: cfg.loader.runtime,
        trainer: cfg.trainer.kind,
        epochs: cfg.trainer.epochs,
        seed: cfg.seed,
        span_capacity: cfg.span_capacity,
        autotune: cfg.autotune || p.flag("autotune"),
        fault_profile: Box::leak(cfg.fault_profile.clone().into_boxed_str()),
        retry_max: cfg.retry_max,
        request_deadline_ms: cfg.request_deadline_ms,
        hedge_after: cfg.hedge_after,
    };
    let rig = cdl::bench::rig::build(&spec)?;
    let metrics_path = p.get("metrics").to_string();
    let want_hook = !metrics_path.is_empty() || rig.autotune.is_some();
    let mut metric_lines: Vec<String> = Vec::new();
    let mut on_epoch_end = |epoch: usize| {
        // tick first so the snapshot sees this epoch's decision
        cdl::bench::rig::autotune_tick(&rig, epoch);
        if !metrics_path.is_empty() {
            metric_lines
                .push(cdl::bench::rig::metrics_snapshot(&rig, epoch).to_string());
        }
    };
    let report = trainer::train_observed(
        &rig.dataloader,
        &rig.device,
        &rig.trainer_cfg,
        rig.recorder.clone(),
        if want_hook { Some(&mut on_epoch_end) } else { None },
    )?;
    println!("{}", report.summary());
    if let Some(h) = &rig.autotune {
        let h = h.lock().unwrap();
        let (probes, keeps, reverts) = h.governor.counts();
        let (bps, _) = h.governor.baseline();
        println!(
            "governor: {probes} probes ({keeps} kept, {reverts} reverted), \
             baseline {bps:.1} batches/s, phase {}",
            h.governor.phase_label()
        );
    }
    if let Some(a) = rig.dataloader.arena() {
        let s = a.stats();
        println!(
            "batch arena: {} checkouts ({} reused, {} fresh), {} recycled, \
             {} pooled",
            s.checkouts, s.reused, s.fresh, s.recycled, s.pooled,
        );
    }
    if let Some(p) = &rig.prefetch {
        println!("{}", p.summary_table("prefetch tiers").render());
    }
    if let Some(c) = &rig.cache {
        let t = c.tier_stats();
        println!(
            "varnish cache [{}]: {}/{} bytes, {} entries (+{} ghosts), \
             {} evictions, hit ratio {:.1}%",
            c.policy().label(),
            t.bytes,
            t.capacity,
            t.entries,
            t.ghost_entries,
            t.evictions,
            100.0 * c.hit_ratio(),
        );
    }
    if !metrics_path.is_empty() {
        std::fs::write(&metrics_path, metric_lines.join("\n") + "\n")?;
        println!("metrics: {} epoch snapshots -> {metrics_path}", metric_lines.len());
    }
    let trace_path = p.get("trace");
    if !trace_path.is_empty() {
        let spans = rig.recorder.snapshot();
        let doc = cdl::telemetry::chrome::chrome_trace(&spans);
        std::fs::write(trace_path, doc.to_string() + "\n")?;
        println!(
            "trace: {} spans ({} dropped) -> {trace_path}",
            spans.len(),
            rig.recorder.dropped()
        );
    }
    Ok(())
}

fn cmd_reproduce(argv: &[String]) -> Result<()> {
    let p = Args::new("cdl reproduce", "regenerate a paper table/figure")
        .opt("scale", "quick", "quick | paper | <items multiplier>")
        .opt(
            "baseline",
            "",
            "hotpath only: baseline JSON to write (or check against)",
        )
        .flag("check", "with --baseline: compare instead of write, fail on regression")
        .parse(argv)?;
    let Some(exp) = p.positional.first() else {
        bail!("which experiment? one of {:?} or 'all'", bench::ALL_EXPERIMENTS);
    };
    let scale = match p.get("scale") {
        "quick" => Scale::quick(),
        "paper" => Scale::paper(),
        s => Scale { items: s.parse()?, ..Scale::quick() },
    };
    if !p.get("baseline").is_empty() {
        if exp.as_str() != "hotpath" {
            bail!("--baseline is only wired for the hotpath experiment");
        }
        return bench::exp_hotpath::run_with_baseline(
            scale,
            p.get("baseline"),
            p.flag("check"),
        );
    }
    bench::run_experiment(exp, scale)
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let p = Args::new(
        "cdl train",
        "end-to-end training of the AOT-compiled model via PJRT",
    )
    .opt("artifacts", "artifacts", "artifacts directory")
    .opt("steps", "100", "training steps")
    .opt("batch", "16", "batch size (must match an artifact variant)")
    .opt("image", "64", "image side (must match an artifact variant)")
    .opt("items", "256", "synthetic corpus size")
    .opt("storage", "scratch", "storage profile")
    .opt("workers", "4", "loader workers")
    .opt("fetch", "threaded", "vanilla|threaded|asyncio")
    .parse(argv)?;

    let batch = p.usize("batch")?;
    let image = p.usize("image")?;
    let engine = Arc::new(XlaEngine::start(p.get("artifacts"))?);
    let variant = engine.manifest().train_variant(batch, image)?;
    println!(
        "model: {} params, artifact {variant}",
        engine.manifest().num_params()
    );
    engine.init_params()?;

    let recorder = Recorder::new();
    let spec = cdl::bench::rig::RigSpec {
        storage: Box::leak(p.get("storage").to_string().into_boxed_str()),
        latency_scale: 0.25,
        shard_size: 0,
        shard_shuffle: false,
        cache_bytes: 0,
        cache_policy: cdl::prefetch::CachePolicy::Lru,
        items: p.usize("items")?,
        mean_kb: 48,
        crop: image,
        batch_size: batch,
        num_workers: p.usize("workers")?,
        prefetch_factor: 2,
        fetch_impl: match p.get("fetch") {
            "vanilla" => FetchImpl::Vanilla,
            "asyncio" => FetchImpl::Asyncio,
            _ => FetchImpl::Threaded,
        },
        num_fetch_workers: 16,
        batch_pool: 0,
        prefetch_depth: 0,
        prefetch_policy: cdl::prefetch::CachePolicy::Lru,
        arena_slabs: 0,
        work_stealing: false,
        steal_items: false,
        consumer_credit: 0,
        epoch_pipeline: 0,
        io_depth: 0,
        pin_memory: false,
        lazy_init: true,
        runtime: cdl::gil::Runtime::Native,
        trainer: trainer::TrainerKind::Torch,
        epochs: 1,
        seed: 7,
        span_capacity: 0,
        autotune: false,
        fault_profile: "none",
        retry_max: 0,
        request_deadline_ms: 0,
        hedge_after: 0.0,
    };
    let store = cdl::bench::rig::build_store(&spec)?.store;
    let ds: Arc<dyn Dataset> = Arc::new(ImageFolderDataset::new(
        store,
        AugmentConfig { crop: image, ..Default::default() },
    ));
    let dl = Dataloader::new(
        ds,
        DataloaderConfig {
            batch_size: batch,
            num_workers: spec.num_workers,
            fetch_impl: spec.fetch_impl,
            drop_last: true,
            runtime: cdl::gil::Runtime::Native,
            spawn_cost_override: Some(std::time::Duration::from_millis(2)),
            ..Default::default()
        },
        recorder.clone(),
    );
    let device = Device::xla(engine, &variant, recorder.clone());

    let steps = p.usize("steps")?;
    let t0 = std::time::Instant::now();
    let mut done = 0usize;
    let mut epoch = 0usize;
    let mut losses: Vec<f32> = Vec::new();
    'outer: loop {
        for b in dl.epoch(epoch) {
            let db = device.to_device(b);
            let loss = device.train_batch(&db)?;
            losses.push(loss);
            done += 1;
            if done % 10 == 0 {
                println!("step {done:>5}  loss {loss:.4}");
            }
            if done >= steps {
                break 'outer;
            }
        }
        epoch += 1;
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "trained {done} steps ({} images) in {secs:.1}s — {:.1} img/s; \
         loss {:.3} → {:.3}",
        done * batch,
        (done * batch) as f64 / secs,
        losses.first().copied().unwrap_or(f32::NAN),
        losses.last().copied().unwrap_or(f32::NAN),
    );
    Ok(())
}
